"""Sharded batch serving in ~40 lines: one packed Φ̂, a stream of observation
chunks, a device mesh, per-shard early exit.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/batch_serving.py [--devices 4]

Shows the three amortizations of the serving mode (pack once, compile once,
stop per shard) through the :class:`repro.parallel.batch.BatchServer` API —
the CLI twin is ``python -m repro.launch.serve``; background in
docs/architecture.md.
"""
import argparse
import time

import jax

from repro.core import relative_error
from repro.launch.serve import build_stream
from repro.parallel import BatchServer, make_batch_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--bits", type=int, default=4, help="packed Φ̂ precision")
    args = ap.parse_args()

    from repro.configs.serve_batch import SMOKE as cfg

    key = jax.random.PRNGKey(cfg.seed)
    phi, chunks, truths = build_stream(cfg, key)
    mesh = make_batch_mesh(args.devices)

    # pack ONCE at construction; every chunk streams the same int codes
    srv = BatchServer(phi, cfg.s, cfg.n_iters, mesh=mesh, key=key,
                      bits_phi=args.bits, bits_y=8, backend="packed",
                      exit_tol=cfg.exit_tol)
    print(f"serving on a {srv.n_shards}-device batch mesh, "
          f"Φ̂ packed at {args.bits} bits ({srv.phi.nbytes:,} B/application)")

    for i, res in enumerate(srv.serve(chunks)):
        t0 = time.time()
        jax.block_until_ready(res.x)
        rel = [float(relative_error(res.x[b], truths[i][b]))
               for b in range(cfg.chunk)]
        print(f"chunk {i}: {cfg.chunk} items in {time.time() - t0:.3f}s "
              f"(drain) | rel_error mean={sum(rel) / len(rel):.4f} "
              f"worst={max(rel):.4f}")
    print(f"served {srv.n_items} items in {srv.n_chunks} chunks; "
          f"compiled shapes: {srv.compile_cache_keys}")


if __name__ == "__main__":
    main()
