"""Train a small LM with the paper's two operators in the trainer:

 * H_s — IHT weight projection (iterative magnitude pruning as projected GD),
 * Q_b — unbiased 8-bit gradient compression (the cross-pod payload).

    PYTHONPATH=src python examples/train_lm_sparse.py [--steps 200]
"""
import argparse

import jax

from repro.configs import get_smoke_config
from repro.data import SyntheticStream
from repro.optim import IHTConfig, adamw, cosine_schedule, sparsity_report
from repro.quant.policy import QuantPolicy
from repro.train import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--grad-bits", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    policy = QuantPolicy(grad_bits=args.grad_bits or None)
    iht = IHTConfig(sparsity=args.sparsity, min_size=2048, every=1)
    opt = adamw(cosine_schedule(3e-3, warmup=20, total=args.steps))
    step = jax.jit(make_train_step(cfg, opt, policy=policy, iht=iht))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    stream = SyntheticStream(0, args.batch, args.seq, cfg.vocab_size)

    print(f"training {cfg.name} ({cfg.param_count()/1e3:.0f}k params) "
          f"with H_s sparsity={args.sparsity} and Q{args.grad_bits} gradients")
    for i in range(args.steps):
        batch = stream.at_step(i)
        batch["memory"] = None
        state, m = step(state, batch)
        if i % 25 == 0 or i == args.steps - 1:
            sp = sparsity_report(state.params, iht)
            print(f"step {i:4d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.2f}  weight_zeros={sp:.1%}")
    print("done — loss decreased under 50% weight sparsity + 8-bit gradients.")


if __name__ == "__main__":
    main()
