"""End-to-end driver: MRI brain recovery from quantized k-space (paper §5).

Builds a Shepp–Logan (or randomized brain) phantom, undersamples its 2D
Fourier transform with a variable-density Cartesian mask, quantizes the
acquired samples, and recovers the image with matrix-free QNIHT.

``--sparsity-basis pixel`` (default) recovers the s-sparsified phantom
through Φ = P_Ω F. ``--sparsity-basis haar`` (or ``db4``) recovers the
**full, unsparsified** phantom through the composed Φ = P_Ω F W† — the
solver iterates on the wavelet coefficients and the report shows W† x̂ in
image space. Either way no dense Φ is ever materialized (at 256×256 it
would be ~2 GB).

Each bit-width runs twice: with the paper's single per-tensor scale c_y, and
with per-band radial k-space scaling (``--n-bands`` scales, 4 bytes each) —
the group-scaling mechanism that keeps 4- and 2-bit observations recoverable
against k-space's dynamic range.

    PYTHONPATH=src python examples/mri_recovery.py [--resolution 96] [--sparsity-basis haar]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psnr, qniht, relative_error
from repro.sensing import ascii_render, make_mri_problem, quantize_observations


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--resolution", type=int, default=96)
    ap.add_argument("--sparsity", type=int, default=None,
                    help="s (default: 300 pixels, or ~12%% of N wavelet coeffs)")
    ap.add_argument("--fraction", type=float, default=0.35)
    ap.add_argument("--density", default="variable", choices=["uniform", "variable"])
    ap.add_argument("--phantom", default="shepp-logan", choices=["shepp-logan", "brain"])
    ap.add_argument("--sparsity-basis", default="pixel",
                    choices=["pixel", "haar", "db4"],
                    help="pixel: s-sparsified phantom via P_Ω F; haar/db4: the "
                         "full phantom via the composed P_Ω F W†")
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--n-bands", type=int, default=16,
                    help="radial k-space bands for the per-band quantizer rows")
    ap.add_argument("--seed", type=int, default=5)
    args = ap.parse_args()

    key = jax.random.PRNGKey(args.seed)
    r = args.resolution
    basis = args.sparsity_basis
    s = args.sparsity if args.sparsity is not None else (
        300 if basis == "pixel" else max(1, round(0.12 * r * r)))
    prob = make_mri_problem(r, s, args.fraction, key, density=args.density,
                            phantom=args.phantom, sparsity_basis=basis)
    m, n = prob.op.shape
    print(f"k-space: {m}/{n} samples ({100 * m / n:.0f}%, {args.density} density)")
    model = "P_Ω F" if basis == "pixel" else f"P_Ω F W† ({basis})"
    print(f"Φ = {model} (matrix-free): {prob.op.nbytes / 1e3:.1f} KB operator data "
          f"vs {m * n * 8 / 1e6:.0f} MB dense complex64")

    img_true = prob.image_true.reshape(r, r)
    what = f"s-sparse phantom (s = {s})" if basis == "pixel" else \
        f"FULL phantom ({basis}-domain recovery, s = {s} of {n} coefficients)"
    print(f"\n{what}:")
    print(ascii_render(img_true, width=min(r, 64)))

    # zero-filled inverse FFT: the non-CS baseline every scanner can do
    kspace = getattr(prob.op, "kspace_op", prob.op)
    zf = jnp.real(kspace.rmv(prob.y)).reshape(r, r)
    print("\nzero-filled adjoint (no CS):")
    print(ascii_render(zf, width=min(r, 64)))
    print(f"  psnr={float(psnr(zf, img_true)):.1f} dB")

    runs = [("32-bit y", None, "per_tensor")]
    for by in (8, 4, 2):
        runs.append((f"{by}-bit y (per-tensor c_y)", by, "per_tensor"))
        runs.append((f"{by}-bit y ({args.n_bands}-band)", by, "per_band"))
    for name, by, gran in runs:
        kw = dict(real_signal=True, nonneg=basis == "pixel")
        y = prob.y
        if by:
            yq = quantize_observations(prob.y, by, key, granularity=gran,
                                       op=prob.op, n_bands=args.n_bands)
            q_noise = float(jnp.linalg.norm(yq - prob.y) / jnp.linalg.norm(prob.y))
            print(f"\nquantizing k-space to {by} bits, {gran} scale "
                  f"(relative quantization noise {q_noise:.1%})")
            y = yq
        t0 = time.time()
        res = qniht(prob.op, y, s, args.iters, **kw)
        jax.block_until_ready(res.x)
        img = prob.to_image(res.x).reshape(r, r)
        print(f"\n{name} matrix-free QNIHT "
              f"({time.time() - t0:.1f}s, {args.iters} iterations):")
        print(ascii_render(img, width=min(r, 64)))
        print(f"  psnr={float(psnr(img, img_true)):.1f} dB  "
              f"rel_error={float(relative_error(img.ravel(), prob.image_true)):.4f}  "
              f"support_size={int(np.sum(np.abs(np.asarray(res.x)) > 0))}")


if __name__ == "__main__":
    main()
