"""Serve a model with weight-only quantization — the paper's low-precision
data representation applied to the decode loop (IHT's LM twin: a bandwidth-
bound iteration re-streaming a fixed large operand).

    PYTHONPATH=src python examples/serve_quantized.py [--bits 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    param_bytes,
    prefill,
    quantize_params,
)
from repro.quant.policy import QuantPolicy


def generate(cfg, params, prompt, n_new, policy, key):
    cache = init_cache(cfg, prompt.shape[0], prompt.shape[1] + n_new + 8, policy)
    logits, cache = prefill(cfg, params, prompt, cache, policy=policy)
    toks = [jnp.argmax(logits, -1)]
    pos = prompt.shape[1]
    for i in range(n_new - 1):
        logits, cache = decode_step(cfg, params, toks[-1], cache, policy=policy,
                                    position=jnp.asarray(pos + i))
        toks.append(jnp.argmax(logits, -1))
    return jnp.stack(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_32b")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prompt = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)

    fp = QuantPolicy()
    out_full = generate(cfg, params, prompt, args.new_tokens, fp, key)

    qparams = quantize_params(params, args.bits)
    qpol = QuantPolicy(weight_bits=args.bits, kv_bits=8)
    t0 = time.time()
    out_q = generate(cfg, qparams, prompt, args.new_tokens, qpol, key)
    dt = time.time() - t0

    agree = float(jnp.mean((out_full == out_q).astype(jnp.float32)))
    # NB: this demo model is RANDOM-INIT (near-uniform logits) — greedy-token
    # agreement is a harsh metric here; trained checkpoints tolerate W4 far
    # better (see tests' error-scaling law).
    b_full, b_q = param_bytes(params), param_bytes(qparams)
    print(f"model: {cfg.name} | W{args.bits} + KV8 serving")
    print(f"weight bytes: {b_full:,} -> {b_q:,} ({b_full / b_q:.1f}x fewer streamed)")
    print(f"greedy tokens agree with full precision: {agree:.0%} "
          f"({args.new_tokens} tokens, {dt:.1f}s on CPU)")
    print("full :", out_full[0][:12].tolist())
    print(f"w{args.bits}   :", out_q[0][:12].tolist())


if __name__ == "__main__":
    main()
