"""Quickstart: recover a sparse signal with low-precision NIHT in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import niht, qniht, relative_error, support_recovery
from repro.sensing import make_gaussian_problem

key = jax.random.PRNGKey(0)

# A compressive-sensing instance: 16-sparse x in R^512 from 256 noisy measurements.
prob = make_gaussian_problem(m=256, n=512, s=16, snr_db=20.0, key=key)

# Full-precision NIHT (the baseline the paper starts from)...
full = niht(prob.phi, prob.y, prob.s, n_iters=50)

# ...and the paper's contribution: the SAME problem with the measurement matrix
# quantized to 2 bits and the observations to 8 bits (Algorithm 1).
low = qniht(prob.phi, prob.y, prob.s, n_iters=50, bits_phi=2, bits_y=8, key=key)

for name, res in (("32-bit NIHT", full), ("2&8-bit QNIHT", low)):
    print(f"{name:>14}: rel_error={float(relative_error(res.x, prob.x_true)):.4f}  "
          f"support_recovered={float(support_recovery(res.x, prob.x_true, prob.s)):.0%}  "
          f"(data bytes: {'1/16th' if 'Q' in name else 'full'})")

print("\nStored measurement-matrix bytes: 32-bit =", prob.phi.size * 4,
      " 2-bit packed =", prob.phi.size // 4)
