"""End-to-end driver: radio-interferometer sky recovery (the paper's Fig. 1).

Simulates a LOFAR-like station, forms the measurement matrix, observes a
sparse sky at 0 dB antenna SNR, and recovers it with NIHT at several data
precisions — including the paper's headline 2-bit Φ / 8-bit y.

    PYTHONPATH=src python examples/sky_recovery.py [--resolution 64] [--sources 15]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import niht, qniht, relative_error, source_recovery, support_recovery
from repro.sensing import (
    Station,
    ascii_render,
    dirty_image,
    make_sky,
    measurement_matrix,
    visibilities,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--resolution", type=int, default=48)
    ap.add_argument("--sources", type=int, default=12)
    ap.add_argument("--antennas", type=int, default=30)
    ap.add_argument("--snr-db", type=float, default=0.0)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--seed", type=int, default=302)
    args = ap.parse_args()

    key = jax.random.PRNGKey(args.seed)
    r = args.resolution

    print(f"station: {args.antennas} antennas (LBA-like), "
          f"M = {args.antennas * (args.antennas - 1)} baselines")
    st = Station(n_antennas=args.antennas, seed=args.seed)
    phi = measurement_matrix(st, r, extent=1.5)
    print(f"Φ: {phi.shape} complex64 "
          f"({phi.size * 8 / 1e6:.0f} MB at full precision, "
          f"{phi.size * 2 * 2 / 8 / 1e6:.1f} MB at 2 bits)")

    x = make_sky(r, args.sources, key, min_sep=max(3, r // 16))
    y, _ = visibilities(phi, x, args.snr_db, key)
    img_true = x.reshape(r, r)

    print(f"\ntrue sky ({args.sources} sources, SNR {args.snr_db} dB):")
    print(ascii_render(img_true, width=min(r, 64)))

    di = dirty_image(phi, y, r)
    print("\nleast-squares estimate (dirty image):")
    print(ascii_render(di, width=min(r, 64)))

    for name, bp, by in (("32-bit", None, None), ("4&8-bit", 4, 8), ("2&8-bit", 2, 8)):
        t0 = time.time()
        if bp is None:
            res = niht(phi, y, args.sources, args.iters, real_signal=True, nonneg=True)
        else:
            res = qniht(phi, y, args.sources, args.iters, bits_phi=bp, bits_y=by,
                        key=key, real_signal=True, nonneg=True)
        jax.block_until_ready(res.x)
        img = jnp.real(res.x).reshape(r, r)
        print(f"\n{name} NIHT recovery "
              f"({time.time() - t0:.1f}s, {args.iters} iterations):")
        print(ascii_render(img, width=min(r, 64)))
        print(f"  rel_error={float(relative_error(res.x, x)):.4f}  "
              f"support={float(support_recovery(res.x, x, args.sources)):.0%}  "
              f"sources_resolved={float(source_recovery(img, img_true, args.sources, 1)):.0%}")


if __name__ == "__main__":
    main()
