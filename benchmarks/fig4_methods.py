"""Fig. 4: recovery error and exact (support) recovery across methods —
NIHT (32-bit), QNIHT (2&8), IHT, CoSaMP, FISTA-ℓ1 — on the telescope problem."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.configs.lofar_cs302 import BENCH, SMOKE
from repro.core import cosamp, fista_l1, iht, niht, qniht, relative_error, support_recovery
from repro.sensing import Station, make_sky, measurement_matrix, visibilities


def run(fast: bool = True):
    cs = SMOKE if fast else BENCH
    key = jax.random.PRNGKey(cs.seed)
    st = Station(n_antennas=cs.n_antennas, seed=cs.seed)
    phi = measurement_matrix(st, cs.resolution, cs.extent)
    x = make_sky(cs.resolution, cs.n_sources, key, min_sep=cs.min_sep)
    y, _ = visibilities(phi, x, cs.snr_db, key)
    s = cs.n_sources
    rows = []

    def bench(name, fn, n_iters):
        t0 = time.perf_counter()
        out = fn()
        xh = out.x if hasattr(out, "x") else out[0]
        jax.block_until_ready(xh)
        us = (time.perf_counter() - t0) * 1e6 / n_iters
        rows.append(row(
            f"fig4/{name}", us,
            f"rel_err={float(relative_error(xh, x)):.4f} "
            f"exact_recovery={float(support_recovery(xh, x, s)):.3f}"
        ))

    bench("niht_32bit", lambda: niht(phi, y, s, cs.n_iters, real_signal=True, nonneg=True), cs.n_iters)
    bench("qniht_2_8bit", lambda: qniht(phi, y, s, cs.n_iters, bits_phi=2, bits_y=8,
                                        key=key, real_signal=True, nonneg=True), cs.n_iters)
    bench("qniht_4_8bit", lambda: qniht(phi, y, s, cs.n_iters, bits_phi=4, bits_y=8,
                                        key=key, real_signal=True, nonneg=True), cs.n_iters)
    bench("iht_unit_step", lambda: iht(phi, y, s, cs.n_iters * 2, real_signal=True), cs.n_iters * 2)
    bench("cosamp", lambda: cosamp(phi, y, s, max(8, cs.n_iters // 3), real_signal=True),
          max(8, cs.n_iters // 3))
    bench("fista_l1", lambda: fista_l1(phi, y, n_iters=cs.n_iters * 3, real_signal=True),
          cs.n_iters * 3)
    return rows
