"""Fig. 1: sky recovery quality, 32-bit NIHT vs low-precision QNIHT on the
LOFAR-like station (0 dB antenna SNR)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.configs.lofar_cs302 import BENCH, SMOKE
from repro.core import niht, qniht, relative_error, source_recovery, support_recovery
from repro.sensing import Station, dirty_image, make_sky, measurement_matrix, visibilities


def run(fast: bool = True):
    cs = SMOKE if fast else BENCH
    key = jax.random.PRNGKey(cs.seed)
    st = Station(n_antennas=cs.n_antennas, seed=cs.seed)
    phi = measurement_matrix(st, cs.resolution, cs.extent)
    x = make_sky(cs.resolution, cs.n_sources, key, min_sep=cs.min_sep)
    y, _ = visibilities(phi, x, cs.snr_db, key)
    r = cs.resolution
    img_t = x.reshape(r, r)
    rows = []

    # least-squares (dirty image) baseline — what Fig 1(b) shows
    t0 = time.perf_counter()
    di = jax.block_until_ready(dirty_image(phi, y, r))
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(row(
        "fig1/dirty_image", dt,
        f"src_recovery={float(source_recovery(di, img_t, cs.n_sources, 1)):.3f}"
    ))

    variants = [("32bit", None, None), ("8&8bit", 8, 8), ("4&8bit", 4, 8), ("2&8bit", 2, 8)]
    for name, bp, by in variants:
        t0 = time.perf_counter()
        if bp is None:
            res = niht(phi, y, cs.n_sources, cs.n_iters, real_signal=True, nonneg=True)
        else:
            res = qniht(phi, y, cs.n_sources, cs.n_iters, bits_phi=bp, bits_y=by,
                        key=key, real_signal=True, nonneg=True)
        jax.block_until_ready(res.x)
        dt = (time.perf_counter() - t0) * 1e6 / cs.n_iters
        img_h = jnp.real(res.x).reshape(r, r)
        rows.append(row(
            f"fig1/qniht_{name}", dt,
            f"rel_err={float(relative_error(res.x, x)):.4f} "
            f"supp={float(support_recovery(res.x, x, cs.n_sources)):.3f} "
            f"src={float(source_recovery(img_h, img_t, cs.n_sources, 1)):.3f}"
        ))
    return rows
