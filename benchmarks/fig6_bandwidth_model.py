"""Fig. 6: FPGA (and TPU) bandwidth model + end-to-end time to 90% support
recovery.

Paper law (supplementary §8.1): per-iteration time T = size(Φ̂)/P with a fixed
consumption rate (FPGA: P = 12.8 GB/s; our target TPU v5e: 819 GB/s HBM). The
end-to-end number multiplies the modeled per-iteration time by the *measured*
iteration count to reach 90% support recovery at each precision — same
methodology as the paper's 9.19× headline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs.lofar_cs302 import BENCH, SMOKE
from repro.core import niht, qniht, support_recovery
from repro.sensing import Station, make_sky, measurement_matrix, visibilities

FPGA_BW = 12.8e9
TPU_HBM_BW = 819e9


def _iters_to_support(res_x_trace, x, s, target=0.9):
    for i, xs in enumerate(res_x_trace):
        if float(support_recovery(xs, x, s)) >= target:
            return i + 1
    return len(res_x_trace)


def run(fast: bool = True):
    cs = SMOKE if fast else BENCH
    key = jax.random.PRNGKey(cs.seed)
    st = Station(n_antennas=cs.n_antennas, seed=cs.seed)
    phi = measurement_matrix(st, cs.resolution, cs.extent)
    x = make_sky(cs.resolution, cs.n_sources, key, min_sep=cs.min_sep)
    y, _ = visibilities(phi, x, cs.snr_db, key)
    s = cs.n_sources
    # complex -> 2 real planes; one iteration streams Φ̂ twice (fwd + adjoint)
    full_bytes = phi.size * 8 * 2
    rows = []

    results = {}
    for name, bp, by in (("32", None, None), ("8&8", 8, 8), ("4&8", 4, 8), ("2&8", 2, 8)):
        if bp is None:
            res = niht(phi, y, s, cs.n_iters, real_signal=True, nonneg=True)
            stream_bytes = full_bytes
        else:
            res = qniht(phi, y, s, cs.n_iters, bits_phi=bp, bits_y=by, key=key,
                        real_signal=True, nonneg=True)
            stream_bytes = full_bytes * bp / 32
        # iterations to 90% support: re-run trace via resid (cheap proxy: use
        # final support + resid trace length heuristic) — run step-by-step only
        # in fast mode sizes
        n_iters_needed = _iters_needed(phi, y, x, s, bp, by, key, cs.n_iters)
        results[name] = (stream_bytes, n_iters_needed)
        for hw, bw in (("fpga", FPGA_BW), ("tpu_v5e", TPU_HBM_BW)):
            t_iter = stream_bytes / bw * 1e6
            rows.append(row(
                f"fig6/{hw}_{name}bit", t_iter,
                f"iters_to_90pct={n_iters_needed} "
                f"end_to_end_us={t_iter * n_iters_needed:.1f}"
            ))

    b32, i32 = results["32"]
    b28, i28 = results["2&8"]
    speedup = (b32 * i32) / (b28 * i28)
    rows.append(row("fig6/end_to_end_speedup_2_8_vs_32", 0.0,
                    f"speedup={speedup:.2f}x paper_fpga=9.19x"))
    return rows


def _iters_needed(phi, y, x, s, bp, by, key, max_iters):
    """Measured iterations to 90% support recovery (stepwise re-run)."""
    from repro.core.niht import qniht as _q

    for n in range(2, max_iters + 1, 2):
        res = (_q(phi, y, s, n, real_signal=True, nonneg=True) if bp is None else
               _q(phi, y, s, n, bits_phi=bp, bits_y=by, key=key,
                  real_signal=True, nonneg=True))
        if float(support_recovery(res.x, x, s)) >= 0.9:
            return n
    return max_iters
