"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Default is CI-sized (``fast``);
``--full`` uses the paper-scale settings (256×256 sky, 100 realizations, ...).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset, e.g. --only fig1 fig11 roofline")
    args = ap.parse_args(argv)

    from benchmarks import (
        fig1_sky,
        fig3_error_coeffs,
        fig4_methods,
        fig5_cpu_speedup,
        fig6_bandwidth_model,
        fig7_rip_bits,
        fig9_clean,
        fig11_gaussian,
        kernels_micro,
        roofline,
    )

    suites = {
        "fig1": fig1_sky,
        "fig3": fig3_error_coeffs,
        "fig4": fig4_methods,
        "fig5": fig5_cpu_speedup,
        "fig6": fig6_bandwidth_model,
        "fig7": fig7_rip_bits,
        "fig9": fig9_clean,
        "fig11": fig11_gaussian,
        "kernels": kernels_micro,
        "roofline": roofline,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k in args.only}

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites.items():
        t0 = time.time()
        try:
            for r in mod.run(fast=not args.full):
                print(r, flush=True)
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
