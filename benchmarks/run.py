"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Default is CI-sized (``fast``);
``--full`` uses the paper-scale settings (256×256 sky, 100 realizations, ...).
``--json <path>`` additionally writes the rows as a JSON list of
``{name, us_per_call, derived}`` objects — the machine-readable perf
trajectory future PRs diff against.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _parse_row(r: str) -> dict:
    name, us, derived = r.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset, e.g. --only fig1 fig11 roofline")
    ap.add_argument("--suite", action="append", default=None, metavar="NAME",
                    help="run one named suite (repeatable), e.g. "
                         "--suite mri-groupscale; combines with --only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as JSON to PATH")
    args = ap.parse_args(argv)

    from benchmarks import (
        fig1_sky,
        fig3_error_coeffs,
        fig4_methods,
        fig5_cpu_speedup,
        fig5_recovery_backend,
        fig6_bandwidth_model,
        fig7_rip_bits,
        fig9_clean,
        fig11_gaussian,
        fig_batch_scaling,
        fig_fault,
        fig_mri,
        kernels_micro,
        roofline,
    )

    class _FnSuite:
        """Adapter: expose a bare sweep function under the module protocol."""

        def __init__(self, fn):
            self.run = fn

    suites = {
        "fig1": fig1_sky,
        "fig3": fig3_error_coeffs,
        "fig4": fig4_methods,
        "fig5": fig5_cpu_speedup,
        "fig5b": fig5_recovery_backend,
        "fig6": fig6_bandwidth_model,
        "fig7": fig7_rip_bits,
        "fig9": fig9_clean,
        "fig11": fig11_gaussian,
        "mri": fig_mri,
        "mri-groupscale": _FnSuite(fig_mri.run_groupscale),
        "mri-fullimage": _FnSuite(fig_mri.run_fullimage),
        "batch-scaling": fig_batch_scaling,
        "fault": fig_fault,
        "kernels": kernels_micro,
        "roofline": roofline,
    }
    selected = list(args.only or []) + list(args.suite or [])
    if selected:
        unknown = [s for s in selected if s not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; choose from {sorted(suites)}")
        suites = {k: v for k, v in suites.items() if k in selected}
    else:
        # opt-in only: the full default run already covers these rows via "mri",
        # batch-scaling spawns forced-device-count subprocesses (minutes), and
        # fault measures checkpoint disk I/O that CI runners report noisily
        suites.pop("mri-groupscale")
        suites.pop("mri-fullimage")
        suites.pop("batch-scaling")
        suites.pop("fault")

    print("name,us_per_call,derived")
    failures = 0
    all_rows: list[str] = []
    for name, mod in suites.items():
        t0 = time.time()
        try:
            for r in mod.run(fast=not args.full):
                all_rows.append(r)
                print(r, flush=True)
        except Exception as e:
            failures += 1
            err_row = f"{name}/ERROR,0,{type(e).__name__}:{e}"
            all_rows.append(err_row)
            print(err_row, flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([_parse_row(r) for r in all_rows], f, indent=1)
        print(f"# wrote {len(all_rows)} rows to {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
