"""Fig. 9 (supplementary): CLEAN vs IHT at 0 dB — CLEAN picks up noise
artifacts as sources; IHT's joint sparse estimate does not."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import clean, niht, source_recovery
from repro.sensing import (
    Station, dirty_beam, dirty_image, make_sky, measurement_matrix, visibilities,
)


def run(fast: bool = True):
    r = 32 if fast else 64
    s = 8 if fast else 15
    key = jax.random.PRNGKey(9)
    st = Station(n_antennas=30)
    phi = measurement_matrix(st, r, extent=1.5)
    x = make_sky(r, s, key, min_sep=4)
    y, _ = visibilities(phi, x, 0.0, key)   # 0 dB like the paper
    img_t = x.reshape(r, r)
    rows = []

    t0 = time.perf_counter()
    di = dirty_image(phi, y, r)
    db = dirty_beam(phi, r)
    comps, resid, _ = clean(di, db, gain=0.1, n_iters=100 if fast else 300)
    jax.block_until_ready(comps)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(row(
        "fig9/clean", us,
        f"src_recovery={float(source_recovery(comps, img_t, s, 1)):.3f}"
    ))

    t0 = time.perf_counter()
    res = niht(phi, y, s, 30, real_signal=True, nonneg=True)
    jax.block_until_ready(res.x)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(row(
        "fig9/niht_32bit", us,
        f"src_recovery={float(source_recovery(jnp.real(res.x).reshape(r, r), img_t, s, 1)):.3f}"
    ))
    return rows
