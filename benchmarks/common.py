"""Shared benchmark utilities: timing + CSV rows (`name,us_per_call,derived`)
+ the per-suite JSON trajectory files (`BENCH_*.json`, one run per PR)."""
from __future__ import annotations

import json
import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def measure(fn):
    """(µs, result): the result call doubles as the compile warmup."""
    res = jax.block_until_ready(fn())
    return time_fn(fn, warmup=0, iters=3), res


def roofline_fields(measured_us: float, predicted_us) -> dict:
    """The measured-vs-model triple every BENCH row carries.

    ``measured_us`` duplicates ``us_per_call`` under its roofline name;
    ``predicted_us`` is the machine-roofline floor for the same work
    (:mod:`benchmarks.roofline`'s measured-peak model — None when no model
    applies); ``roofline_frac`` = predicted/measured — the fraction of the
    attainable ceiling actually achieved (1.0 = at the roofline; >1 flags a
    model undercount, deliberately not clamped)."""
    out = {"measured_us": round(measured_us, 1), "predicted_us": None,
           "roofline_frac": None}
    if predicted_us and predicted_us > 0:
        out["predicted_us"] = round(predicted_us, 1)
        out["roofline_frac"] = round(predicted_us / measured_us, 4)
    return out


def write_json(records: list, path: str) -> None:
    """Timestamp + write one suite's record dicts to its BENCH_*.json file."""
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    for r in records:
        r["timestamp"] = stamp
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
