"""Fig. 11 (supplementary): Gaussian toy — recovery error and exact recovery
vs SNR, 32-bit vs 2&8-bit, averaged over realizations."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs.gaussian_toy import CONFIG, SMOKE
from repro.core import niht, qniht, relative_error, support_recovery
from repro.sensing import make_gaussian_problem


def run(fast: bool = True):
    g = SMOKE if fast else CONFIG
    rows = []
    for snr in g.snr_grid:
        errs = {"32": [], "2&8": []}
        supp = {"32": [], "2&8": []}
        t0 = time.perf_counter()
        for trial in range(g.n_realizations):
            key = jax.random.PRNGKey(1000 * trial + int(snr * 10) % 997)
            prob = make_gaussian_problem(g.m, g.n, g.s, float(snr), key)
            r32 = niht(prob.phi, prob.y, g.s, g.n_iters)
            r28 = qniht(prob.phi, prob.y, g.s, g.n_iters,
                        bits_phi=g.bits_phi, bits_y=g.bits_y, key=key)
            errs["32"].append(float(relative_error(r32.x, prob.x_true)))
            errs["2&8"].append(float(relative_error(r28.x, prob.x_true)))
            supp["32"].append(float(support_recovery(r32.x, prob.x_true, g.s)))
            supp["2&8"].append(float(support_recovery(r28.x, prob.x_true, g.s)))
        us = (time.perf_counter() - t0) * 1e6 / g.n_realizations
        rows.append(row(
            f"fig11/snr_{snr:+.0f}dB", us,
            f"err32={np.mean(errs['32']):.4f} err2_8={np.mean(errs['2&8']):.4f} "
            f"supp32={np.mean(supp['32']):.3f} supp2_8={np.mean(supp['2&8']):.3f} "
            f"n={g.n_realizations}"
        ))
    return rows
