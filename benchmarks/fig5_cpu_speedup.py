"""Fig. 5: CPU speed-up of low-precision IHT.

The paper's AVX2 kernels get 2.84×(8-bit)/4.19×(4-bit) end-to-end because the
iteration is memory-bound. Here we *measure* the XLA-CPU per-iteration matvec
wall-time at f32 and at int8 (XLA lowers int8 dots to VNNI-style paths where
available) and report the paper-style bandwidth model (bytes ratio) alongside:
the measured number is hardware truth for THIS container, the model is the
roofline expectation for a memory-bound implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.quant import quantize_codes
from repro.quant.pack import pack_codes


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    m, n = (870, 4096) if fast else (870, 65536)
    phi = jax.random.normal(key, (m, n), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(key, 2), (m,), jnp.float32)
    rows = []

    # one IHT iteration's two matvecs at f32 (the 32-bit baseline)
    @jax.jit
    def iter_f32(phi, x, r):
        g = phi.T @ r
        return phi @ (x + 0.1 * g)

    us32 = time_fn(iter_f32, phi, x, r, warmup=2, iters=5)
    rows.append(row("fig5/iter_f32", us32, "speedup=1.00x bytes_ratio=1.00"))

    # int8 codes path: integer dot (XLA int8 kernels) + scale correction
    codes, scale = quantize_codes(phi, 8, key)
    codes_t = codes.T.copy()

    @jax.jit
    def iter_int8(codes, codes_t, x, r):
        xq = jnp.clip(jnp.round(x * 127 / (jnp.max(jnp.abs(x)) + 1e-9)), -127, 127
                      ).astype(jnp.int8)
        rq = jnp.clip(jnp.round(r * 127 / (jnp.max(jnp.abs(r)) + 1e-9)), -127, 127
                      ).astype(jnp.int8)
        g = jax.lax.dot(codes_t.astype(jnp.int32), rq.astype(jnp.int32)[:, None])
        y = jax.lax.dot(codes.astype(jnp.int32), xq.astype(jnp.int32)[:, None])
        return g.astype(jnp.float32), y.astype(jnp.float32)

    us8 = time_fn(iter_int8, codes, codes_t, x, r, warmup=2, iters=5)
    rows.append(row("fig5/iter_int8_measured", us8,
                    f"speedup={us32 / us8:.2f}x bytes_ratio=4.00 paper=2.84x"))

    # bandwidth model (paper's law: time ∝ streamed bytes of Φ̂)
    for bits, paper in ((8, "2.84x"), (4, "4.19x"), (2, "n/a")):
        packed_bytes = pack_codes(codes, bits).size
        ratio = (phi.size * 4) / packed_bytes
        rows.append(row(
            f"fig5/iter_int{bits}_bw_model", us32 / ratio,
            f"speedup={ratio:.2f}x bytes_ratio={ratio:.2f} paper_cpu={paper}"
        ))
    return rows
