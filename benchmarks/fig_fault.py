"""Fault tolerance: checkpoint overhead and recovery time (BENCH_fault.json).

Measures what the preemption-safe recovery path costs when nothing goes wrong,
and what it buys when something does:

* ``overhead`` rows — the segmented checkpointed solve
  (:func:`repro.launch.resilience.recover_resilient`) vs the one-shot
  ``qniht_batch`` on the same problem, swept over ``ckpt_every``. The derived
  column reports the amortized checkpoint cost in µs per solver iteration and
  the per-checkpoint write cost; ``us_per_call`` is the whole solve. Includes
  an ``async`` variant (checkpoint I/O overlapped with the next segment).
* ``recovery`` rows — a run is preempted at roughly the halfway checkpoint,
  then resumed: ``us_per_call`` is the *resume* wall time (process-local:
  restore + the remaining iterations; it excludes process/jax startup, which
  dominates a cold restart and is not a property of this layer). ``restore``
  times the checkpoint read+rebuild alone.

Everything runs in-process with a simulated preemption guard — the real
kill -TERM path is pinned (bitwise) in ``tests/test_fault_injection.py``; this
file is about the numbers, not the contract.

Every run rewrites ``BENCH_fault.json`` (override via ``BENCH_FAULT_JSON``).
"""
from __future__ import annotations

import os
import time

JSON_PATH = os.environ.get("BENCH_FAULT_JSON", "BENCH_fault.json")


def _ckpt_dir_bytes(d):
    total = 0
    for root, _, files in os.walk(d):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


class _GuardAt:
    """Simulated preemption: `requested` flips once `polls` reaches `after`."""

    def __init__(self, after):
        self.polls = 0
        self.after = after

    @property
    def requested(self):
        self.polls += 1
        return self.polls >= self.after


def run(fast: bool = True):
    import shutil
    import tempfile

    import jax
    import jax.numpy as jnp

    from benchmarks.common import row, write_json
    from repro.core import qniht_batch, solver_init
    from repro.launch.resilience import Preempted, recover_resilient
    from repro.sensing import make_gaussian_problem
    from repro.train.checkpoint import latest_step, restore_latest

    B, m, n, s = (8, 64, 128, 6) if fast else (32, 256, 512, 16)
    n_iters = 32 if fast else 96
    sweep = (4, 8, 16) if fast else (4, 8, 16, 32, 96)
    key = jax.random.PRNGKey(0)
    base = make_gaussian_problem(m, n, s, 20.0, key)
    Y = jnp.stack([make_gaussian_problem(m, n, s, 20.0,
                                         jax.random.fold_in(key, b + 1),
                                         phi=base.phi).y for b in range(B)])
    kw = dict(bits_y=8, key=key, with_trace=False)

    records, rows = [], []

    def timed(fn):
        out = fn()          # warm: compiles cached for the repeat
        t0 = time.perf_counter()
        out = fn()
        return (time.perf_counter() - t0) * 1e6, out

    base_us, ref = timed(lambda: jax.block_until_ready(
        qniht_batch(base.phi, Y, s, n_iters, **kw).x))
    rows.append(row("fault/baseline_one_shot", base_us,
                    f"B={B} m={m} n={n} n_iters={n_iters}"))
    records.append({"name": "baseline_one_shot", "us_per_call": base_us,
                    "B": B, "m": m, "n": n, "n_iters": n_iters})

    for every in sweep:
        for mode in ("sync", "async"):
            d = tempfile.mkdtemp(prefix="bench_fault_")
            try:
                us, got = timed(lambda: jax.block_until_ready(recover_resilient(
                    base.phi, Y, s, n_iters, checkpoint_dir=d,
                    ckpt_every=every, async_save=mode == "async", **kw).x))
                assert bool(jnp.all(got == ref)), "bitwise parity violated"
                n_ckpts = -(-n_iters // every)
                ovh_iter = (us - base_us) / n_iters
                ovh_ckpt = (us - base_us) / n_ckpts
                size = _ckpt_dir_bytes(d)
                rows.append(row(
                    f"fault/overhead_every{every}_{mode}", us,
                    f"+{ovh_iter:.1f}us/iter +{ovh_ckpt:.1f}us/ckpt "
                    f"n_ckpts={n_ckpts} dir={size}B parity=bitwise"))
                records.append({
                    "name": f"overhead_every{every}_{mode}", "us_per_call": us,
                    "ckpt_every": every, "mode": mode,
                    "overhead_us_per_iter": ovh_iter,
                    "overhead_us_per_ckpt": ovh_ckpt,
                    "n_checkpoints": n_ckpts, "ckpt_dir_bytes": size,
                    "baseline_us": base_us, "n_iters": n_iters})
            finally:
                shutil.rmtree(d, ignore_errors=True)

    # recovery: preempt at ~half the checkpoints, then resume to completion
    every = sweep[1]
    d = tempfile.mkdtemp(prefix="bench_fault_rec_")
    try:
        half = max(1, (n_iters // every) // 2)
        try:
            recover_resilient(base.phi, Y, s, n_iters, checkpoint_dir=d,
                              ckpt_every=every, guard=_GuardAt(half), **kw)
        except Preempted:
            pass
        k0 = latest_step(d)

        t0 = time.perf_counter()
        target = jax.eval_shape(
            lambda: solver_init(base.phi, Y, s, n_iters, **kw))
        state, _ = restore_latest(d, target)
        jax.block_until_ready(state.X)
        restore_us = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        got = recover_resilient(base.phi, Y, s, n_iters, checkpoint_dir=d,
                                ckpt_every=every, resume=True, **kw)
        jax.block_until_ready(got.x)
        resume_us = (time.perf_counter() - t0) * 1e6
        assert bool(jnp.all(got.x == ref)), "resume parity violated"

        rows.append(row("fault/restore_state", restore_us,
                        f"k={k0}/{n_iters} leaves={len(jax.tree_util.tree_leaves(state))}"))
        rows.append(row("fault/recovery_resume", resume_us,
                        f"from_k={k0} remaining={n_iters - k0} "
                        f"vs_full_run={resume_us / max(base_us, 1):.2f}x parity=bitwise"))
        records.append({"name": "restore_state", "us_per_call": restore_us,
                        "resumed_from_k": k0, "n_iters": n_iters})
        records.append({"name": "recovery_resume", "us_per_call": resume_us,
                        "resumed_from_k": k0, "remaining_iters": n_iters - k0,
                        "ckpt_every": every, "baseline_us": base_us})
    finally:
        shutil.rmtree(d, ignore_errors=True)

    write_json(records, JSON_PATH)
    return rows


if __name__ == "__main__":
    for r in run(fast=True):
        print(r)
