"""Roofline analysis: aggregate the dry-run JSONs into per-cell terms.

Per (arch × shape × mesh), from the compiled artifact:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis is per-device on a partitioned module — verified against an
analytic sharded matmul; scan-body undercounting is fixed by the dry-run's
depth-extrapolated probes.) Dominant term = the bottleneck; roofline fraction
= MODEL_FLOPS / (devices · peak · max_term) — how close the cell is to the
hardware ceiling given its bottleneck.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

# TPU v5e target constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link


def load_cells(dry_dir: str = "experiments/dryrun", policy: str = "fp"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*.{policy}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze(rec: dict) -> dict:
    """Roofline terms (seconds) for one dry-run record."""
    n_dev = rec["n_devices"]
    cost = rec.get("cost_analysis_depth_corrected") or rec.get("cost_analysis", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll_total = rec.get("collective_bytes", {}).get("total", 0)
    # collective bytes were parsed from the per-device module
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values()) or 1e-30
    model_flops = rec.get("model_flops", 0)
    useful_ratio = model_flops / max(flops_dev * n_dev, 1e-30)
    roofline_frac = model_flops / (n_dev * PEAK_FLOPS * t_bound)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "policy": rec.get("policy", "fp"),
        "t_compute_s": t_compute, "t_memory_s": t_memory, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flop_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "mem_per_device": rec.get("memory_analysis", {}),
        "state_bytes_per_device": rec.get("state_bytes_per_device", 0),
    }


def run(fast: bool = True, dry_dir: str = "experiments/dryrun"):
    rows = []
    cells = load_cells(dry_dir)
    if not cells:
        return [row("roofline/no_dryrun_data", 0.0,
                    "run scripts/run_dryruns.py first")]
    for rec in cells:
        tag = f"{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append(row(f"roofline/{tag}", 0.0, "skipped=" + rec["reason"][:60]))
            continue
        if rec.get("status") != "ok":
            rows.append(row(f"roofline/{tag}", 0.0, "status=" + str(rec.get("status"))))
            continue
        a = analyze(rec)
        t_us = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"]) * 1e6
        rows.append(row(
            f"roofline/{tag}", t_us,
            f"dominant={a['dominant']} "
            f"tc={a['t_compute_s']*1e3:.2f}ms tm={a['t_memory_s']*1e3:.2f}ms "
            f"tx={a['t_collective_s']*1e3:.2f}ms "
            f"roofline_frac={a['roofline_fraction']:.3f} "
            f"useful={a['useful_flop_ratio']:.2f}"
        ))
    return rows


def markdown_table(dry_dir: str = "experiments/dryrun", policy: str = "fp") -> str:
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | MODEL_FLOPS/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(dry_dir, policy):
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | skipped | — | — |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | {rec.get('status')} | — | — |")
            continue
        a = analyze(rec)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} "
            f"| {a['t_collective_s']*1e3:.2f} | **{a['dominant']}** "
            f"| {a['useful_flop_ratio']:.2f} | {a['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
