"""Roofline analysis: dry-run TPU projections + the measured machine model.

**Dry-run path** (the original): aggregate the dry-run JSONs into per-cell
terms against TPU v5e constants. Per (arch × shape × mesh), from the compiled
artifact:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis is per-device on a partitioned module — verified against an
analytic sharded matmul; scan-body undercounting is fixed by the dry-run's
depth-extrapolated probes.) Dominant term = the bottleneck; roofline fraction
= MODEL_FLOPS / (devices · peak · max_term) — how close the cell is to the
hardware ceiling given its bottleneck.

**Machine path** (this machine, whatever it is): :func:`machine_peaks` times
a large streaming reduction and an f32 gemm once per process to measure the
*attainable* bandwidth and FLOP ceilings of the backend actually running,
and :func:`predict_recovery_us` / :func:`predict_fft_recovery_us` turn a
recovery configuration into a per-solve roofline floor

    predicted_us = n_iters · max(bytes_per_iter / BW, flops_per_iter / F)

(no-backtrack iteration: 3 forward + 1 adjoint operator applications — the
bytes term is the paper's ``size(Φ̂)/BW`` law, which batching amortizes:
B problems share one codes stream, while the FLOPs term grows with B).
``benchmarks/common.roofline_fields`` threads the prediction into every
BENCH_recovery / BENCH_mri row as ``predicted_us`` / ``roofline_frac``.
"""
from __future__ import annotations

import functools
import glob
import json
import math
import os
import time

from benchmarks.common import row

# TPU v5e target constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link


# ---------------------------------------------------------------------------
# Measured machine peaks + recovery-iteration model
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def machine_peaks() -> dict:
    """Attainable (not datasheet) ceilings of the running backend, measured
    once per process: ``bw`` from a 64 MB f32 streaming reduction (read-bound,
    the shape of a packed-codes pass) and ``flops`` from a 512³ f32 matmul."""
    import jax
    import jax.numpy as jnp

    def best_seconds(fn, *args, reps: int = 5) -> float:
        jax.block_until_ready(fn(*args))        # compile + warm
        best = math.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    a = jnp.ones((16 * 1024 * 1024,), jnp.float32)          # 64 MB
    t_sum = best_seconds(jax.jit(jnp.sum), a)
    bw = a.size * 4 / t_sum

    d = 512
    w = jnp.ones((d, d), jnp.float32)
    t_mm = best_seconds(jax.jit(lambda u, v: u @ v), w, w)
    flops = 2.0 * d**3 / t_mm
    return {"bw_bytes_per_s": bw, "flops_per_s": flops,
            "backend": jax.default_backend()}


def recovery_iteration_model(m: int, n: int, stream_bits, batch: int = 1) -> dict:
    """Bytes + FLOPs one no-backtrack QNIHT iteration moves for a dense/packed
    (M, N) operator: 3 forward + 1 adjoint applications. The operator stream
    (``stream_bits=None`` → f32) is paid once per application regardless of B;
    the mat-vec FLOPs and the (B,·) vector traffic scale with B."""
    phi_bytes = m * n * 4 if stream_bits is None else m * ((n * stream_bits + 7) // 8)
    vec_bytes = 4 * batch * 2 * (m + n)      # per application: operand + result rows
    return {
        "bytes_per_iter": 4 * (phi_bytes + vec_bytes),
        "flops_per_iter": 4 * 2 * m * n * batch,
    }


def predict_recovery_us(m: int, n: int, n_iters: int, stream_bits,
                        batch: int = 1, peaks: dict | None = None) -> float:
    """Roofline floor (µs) for a full dense/packed recovery solve."""
    p = peaks or machine_peaks()
    it = recovery_iteration_model(m, n, stream_bits, batch)
    t_iter = max(it["bytes_per_iter"] / p["bw_bytes_per_s"],
                 it["flops_per_iter"] / p["flops_per_s"])
    return n_iters * t_iter * 1e6


def predict_fft_recovery_us(resolution: int, n_iters: int, batch: int = 1,
                            peaks: dict | None = None) -> float:
    """Roofline floor (µs) for a matrix-free MRI solve: 4 FFT-based operator
    applications per iteration over an r×r complex grid (≈ 5·N·log2 N flops and
    ~3 complex-array passes each — a deliberately coarse model; its point is a
    stable floor for ``roofline_frac`` trendlines, not an exact simulator)."""
    p = peaks or machine_peaks()
    n_pix = resolution * resolution
    flops = 4 * 5.0 * n_pix * math.log2(max(n_pix, 2)) * batch
    byts = 4 * 3 * n_pix * 8 * batch
    t_iter = max(byts / p["bw_bytes_per_s"], flops / p["flops_per_s"])
    return n_iters * t_iter * 1e6


def load_cells(dry_dir: str = "experiments/dryrun", policy: str = "fp"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*.{policy}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze(rec: dict) -> dict:
    """Roofline terms (seconds) for one dry-run record."""
    n_dev = rec["n_devices"]
    cost = rec.get("cost_analysis_depth_corrected") or rec.get("cost_analysis", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll_total = rec.get("collective_bytes", {}).get("total", 0)
    # collective bytes were parsed from the per-device module
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values()) or 1e-30
    model_flops = rec.get("model_flops", 0)
    useful_ratio = model_flops / max(flops_dev * n_dev, 1e-30)
    roofline_frac = model_flops / (n_dev * PEAK_FLOPS * t_bound)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "policy": rec.get("policy", "fp"),
        "t_compute_s": t_compute, "t_memory_s": t_memory, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flop_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "mem_per_device": rec.get("memory_analysis", {}),
        "state_bytes_per_device": rec.get("state_bytes_per_device", 0),
    }


def run(fast: bool = True, dry_dir: str = "experiments/dryrun"):
    rows = []
    p = machine_peaks()
    rows.append(row(
        "roofline/machine_peaks", 0.0,
        f"backend={p['backend']} bw={p['bw_bytes_per_s'] / 1e9:.1f}GB/s "
        f"flops={p['flops_per_s'] / 1e9:.1f}GFLOP/s (measured, attainable)"))
    for bits, batch in ((None, 1), (8, 1), (8, 8), (2, 8)):
        pred = predict_recovery_us(256, 512, 50, bits, batch, p)
        tag = "f32" if bits is None else f"int{bits}"
        it = recovery_iteration_model(256, 512, bits, batch)
        rows.append(row(
            f"roofline/predict_recover_{tag}_b{batch}", pred,
            f"bytes/iter={it['bytes_per_iter']} flops/iter={it['flops_per_iter']} "
            f"(floor for fig5b CONFIG m=256 n=512 iters=50)"))
    cells = load_cells(dry_dir)
    if not cells:
        rows.append(row("roofline/no_dryrun_data", 0.0,
                        "run scripts/run_dryruns.py first"))
        return rows
    for rec in cells:
        tag = f"{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append(row(f"roofline/{tag}", 0.0, "skipped=" + rec["reason"][:60]))
            continue
        if rec.get("status") != "ok":
            rows.append(row(f"roofline/{tag}", 0.0, "status=" + str(rec.get("status"))))
            continue
        a = analyze(rec)
        t_us = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"]) * 1e6
        rows.append(row(
            f"roofline/{tag}", t_us,
            f"dominant={a['dominant']} "
            f"tc={a['t_compute_s']*1e3:.2f}ms tm={a['t_memory_s']*1e3:.2f}ms "
            f"tx={a['t_collective_s']*1e3:.2f}ms "
            f"roofline_frac={a['roofline_fraction']:.3f} "
            f"useful={a['useful_flop_ratio']:.2f}"
        ))
    return rows


def markdown_table(dry_dir: str = "experiments/dryrun", policy: str = "fp") -> str:
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | MODEL_FLOPS/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(dry_dir, policy):
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | skipped | — | — |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | {rec.get('status')} | — | — |")
            continue
        a = analyze(rec)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} "
            f"| {a['t_collective_s']*1e3:.2f} | **{a['dominant']}** "
            f"| {a['useful_flop_ratio']:.2f} | {a['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
