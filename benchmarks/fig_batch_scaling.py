"""Sharded batch serving: items/sec vs device count (BENCH_batch.json).

Measures the multi-device serving path (``qniht_batch_sharded`` /
``repro.parallel.batch``) on a forced multi-host-device CPU view: each device
count runs in a fresh subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag is only read
at backend initialization). Two workloads:

* **gaussian serve mix** — the heterogeneous stream of
  :mod:`repro.configs.serve_batch`: B = 64 rows against one (512, 1024) Φ,
  rows 0..7 a *burst* of hard items (geometrically decaying coefficients at
  15 dB — near-compressible, slow support resolution) and the rest clean flat
  s-sparse rows at 30 dB. ``n_iters = 96`` is the fixed serving horizon,
  provisioned for the hard rows; the per-row freeze rule (``exit_tol=1e-5``)
  is what makes the horizon cheap per item.
* **mri batch** — B = 8 randomized 64×64 brain phantoms through the
  matrix-free ``SubsampledFourierOperator`` (int8 observations), showing the
  sharded dispatch is operator-generic.

Comparisons recorded per device count (and asserted in the rows):

* ``baseline`` — the single-device ``qniht_batch`` path with its defaults
  (no early exit): pays the full horizon for every row. This is the
  pre-existing path a single-device deployment runs, and the denominator of
  ``speedup_vs_single_device``.
* ``sharded N`` — ``qniht_batch_sharded`` on an N-device ``batch`` mesh with
  the freeze rule. **Parity**: every sharded run is compared against the
  single-device path *with the same early-exit configuration* (the freeze
  rule is row-local, so results are invariant to the mesh width). Parity is
  bitwise whenever XLA's batched ops are batching-invariant at the problem
  shape — pinned on an 8-device mesh in tests/test_sharded_batch.py — and
  otherwise differs by ULP-level f32 accumulation (``max_dev_vs_singledev``
  records the worst element; the same hedge the ``qniht_batch`` ↔ ``qniht``
  row contract has always carried).

Scaling interpretation (honesty notes, also in docs/benchmarks.md): forced
host devices timeshare the container's physical cores (``host_cores`` in
every row), so fixed-work scaling is capped at ~#cores no matter the mesh
width — on this 2-core CI box the curve saturates around 2×. What the rows
demonstrate is the *structural* serving win that multiplies whatever
hardware curve a real mesh provides: per-shard early exit plus straggler
isolation (only the shard holding the hard burst pays the long tail, and the
fused single-device batch additionally pays the stragglers' backtracking on
every row's matmuls), against a per-shard cost floor set by the Φ stream
each shard re-reads (sharding de-amortizes the batch's operator traffic —
the paper's bandwidth law cuts both ways).

A final single-device stage compares **scheduling policies** on the bursty
single-request trace of ``serve-continuous`` (``repro.parallel.scheduler``):
``continuous`` (mid-flight slot refill) vs ``lockstep`` (full-table drains —
the chunked baseline in the same engine, same executable, same request set).
Rows carry p50/p99 request latency, items/sec, slot occupancy, and the
``speedup_vs_lockstep`` ratio; quality columns must match across policies
because every answer is bitwise its standalone solve (docs/serving.md).

Every run rewrites ``BENCH_batch.json`` (override via ``BENCH_BATCH_JSON``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

JSON_PATH = os.environ.get("BENCH_BATCH_JSON", "BENCH_batch.json")
DEVICE_COUNTS = (1, 2, 4, 8)


def _best_wall(fn, reps):
    """Best-of-N wall time (the timeit convention: the minimum is the run
    least perturbed by scheduler noise — applied to every configuration
    equally)."""
    import jax

    jax.block_until_ready(fn())  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def worker(ndev: int, fast: bool) -> None:
    """Runs inside the subprocess with the forced device count; prints one
    JSON line per measured row."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.serve_batch import CONFIG
    from repro.core import qniht_batch, qniht_batch_sharded, relative_error
    from repro.launch.serve import build_stream
    from repro.sensing import make_mri_problem, brain_phantom, mri_observations

    reps = 5
    cfg = CONFIG
    tol = cfg.exit_tol
    key = jax.random.PRNGKey(cfg.seed)
    phi, chunks, truths = build_stream(dataclasses.replace(cfg, n_chunks=1), key)
    Y, X_true = chunks[0], truths[0]
    kw = dict(with_trace=False)

    rows = []
    if ndev == 1:
        w = _best_wall(lambda: qniht_batch(phi, Y, cfg.s, cfg.n_iters, **kw), reps)
        res = qniht_batch(phi, Y, cfg.s, cfg.n_iters, **kw)
        rel = [float(relative_error(res.x[b], X_true[b])) for b in range(cfg.chunk)]
        rows.append({
            "name": "batch/gaussian_B64_singledev_baseline", "devices": 1,
            "wall_ms": round(w * 1e3, 1), "items_per_s": round(cfg.chunk / w, 1),
            "rel_error_mean": round(sum(rel) / len(rel), 4),
        })

    w = _best_wall(
        lambda: qniht_batch_sharded(phi, Y, cfg.s, cfg.n_iters, n_devices=ndev,
                                    exit_tol=tol, **kw), reps)
    res = qniht_batch_sharded(phi, Y, cfg.s, cfg.n_iters, n_devices=ndev,
                              exit_tol=tol, **kw)
    # grouping-invariance: identical to the single-device path at the same
    # early-exit configuration, whatever the mesh width — bitwise when the
    # batched ops are batching-invariant at this shape, else ULP-level f32
    # accumulation differences (max_dev records the worst element)
    ref = qniht_batch(phi, Y, cfg.s, cfg.n_iters, early_exit=True, exit_tol=tol, **kw)
    rel = [float(relative_error(res.x[b], X_true[b])) for b in range(cfg.chunk)]
    rows.append({
        "name": f"batch/gaussian_B64_sharded_{ndev}dev", "devices": ndev,
        "wall_ms": round(w * 1e3, 1), "items_per_s": round(cfg.chunk / w, 1),
        "rel_error_mean": round(sum(rel) / len(rel), 4),
        "exit_tol": tol,
        "bitident_vs_singledev": bool(jnp.all(res.x == ref.x)),
        "max_dev_vs_singledev": float(jnp.max(jnp.abs(res.x - ref.x))),
    })

    # MRI: operator-generic sharding (matrix-free Fourier Φ, int8 k-space)
    r, B = 32 if fast else 64, 8
    prob = make_mri_problem(r, 4 * r, 0.4, key, snr_db=None)
    Img = jnp.stack([brain_phantom(r, jax.random.fold_in(key, b)).ravel()
                     for b in range(B)])
    from repro.sensing import sparsify_image
    Img = jnp.stack([sparsify_image(Img[b], 4 * r) for b in range(B)])
    Ym, _ = mri_observations(prob.op, Img, None, jax.random.fold_in(key, 99))
    w = _best_wall(
        lambda: qniht_batch_sharded(prob.op, Ym, 4 * r, 25, n_devices=ndev,
                                    bits_y=8, key=key, exit_tol=tol,
                                    real_signal=True, nonneg=True,
                                    with_trace=False), reps)
    res = qniht_batch_sharded(prob.op, Ym, 4 * r, 25, n_devices=ndev, bits_y=8,
                              key=key, exit_tol=tol, real_signal=True,
                              nonneg=True, with_trace=False)
    ref = qniht_batch(prob.op, Ym, 4 * r, 25, bits_y=8, key=key, early_exit=True,
                      exit_tol=tol, real_signal=True, nonneg=True, with_trace=False)
    rows.append({
        "name": f"batch/mri_{r}px_B8_sharded_{ndev}dev", "devices": ndev,
        "wall_ms": round(w * 1e3, 1), "items_per_s": round(B / w, 1),
        "exit_tol": tol,
        "bitident_vs_singledev": bool(jnp.all(res.x == ref.x)),
        "max_dev_vs_singledev": float(jnp.max(jnp.abs(res.x - ref.x))),
    })
    for row in rows:
        print("ROW " + json.dumps(row), flush=True)


def sched_worker(fast: bool) -> None:
    """Continuous vs lockstep scheduling on the bursty request trace
    (:mod:`repro.parallel.scheduler`), single process.

    Both policies run the SAME engine, executable, and request set — only the
    refill rule differs — so the items/sec ratio isolates the scheduling
    policy. Each policy runs twice and reports the second (warm) pass: the
    compile-once contract means a deployed scheduler pays tracing exactly
    once, and a cold wall would just measure XLA's compiler. Quality columns
    (rel error means) must match across policies: continuous reorders *when*
    rows run, never *what* they compute (every answer is bitwise its
    standalone solve — pinned by tests/test_scheduler.py and the ``sched`` CI
    tier, so this worker spends its wall on throughput, not re-verification).
    """
    import dataclasses

    from repro.configs.serve_batch import CONTINUOUS
    from repro.launch.serve import serve_scheduled

    cfg = (dataclasses.replace(CONTINUOUS, m=128, n=256, s=16, n_requests=48)
           if fast else CONTINUOUS)
    for policy in ("lockstep", "continuous"):
        serve_scheduled(cfg, policy)  # warm: trace + compile the segment step
        out = max((serve_scheduled(cfg, policy) for _ in range(3)),
                  key=lambda o: o["items_per_s"])  # best-of-N, timeit-style
        row = {
            "name": f"batch/continuous_sched_{policy}", "devices": 1,
            "wall_ms": round(out["wall_s"] * 1e3, 1),
            **{k: out[k] for k in (
                "scheduler", "requests", "completed", "slots", "seg_len",
                "segments_run", "slot_occupancy", "items_per_s",
                "latency_p50_s", "latency_p99_s", "queue_wait_ticks_mean",
                "iters_used_mean", "rel_error_easy_mean",
                "rel_error_hard_mean")},
        }
        print("ROW " + json.dumps(row), flush=True)


def run(fast: bool = True):
    """Parent: one subprocess per device count (XLA_FLAGS is read once, at
    backend init, so each count needs a fresh process). Yields CSV rows."""
    from repro.parallel.batch import force_host_devices

    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    records = []
    for ndev in DEVICE_COUNTS:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        force_host_devices(ndev, env)
        cmd = [sys.executable, os.path.join(here, "fig_batch_scaling.py"),
               "--worker", str(ndev)] + (["--fast"] if fast else [])
        res = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                             text=True, timeout=1800)
        if res.returncode != 0:
            raise RuntimeError(
                f"scaling worker ndev={ndev} failed:\n{res.stderr[-2000:]}")
        for line in res.stdout.splitlines():
            if line.startswith("ROW "):
                records.append(json.loads(line[4:]))

    # scheduling-policy comparison: continuous vs lockstep refill on the
    # bursty heterogeneous request trace (fresh subprocess: single device)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.join(here, "fig_batch_scaling.py"),
           "--sched-worker"] + (["--fast"] if fast else [])
    res = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                         text=True, timeout=1800)
    if res.returncode != 0:
        raise RuntimeError(
            f"scheduling worker failed:\n{res.stderr[-2000:]}")
    sched_rows = [json.loads(line[4:]) for line in res.stdout.splitlines()
                  if line.startswith("ROW ")]
    lock = next(r for r in sched_rows if r["scheduler"] == "lockstep")
    for r in sched_rows:
        if r["scheduler"] == "continuous":
            r["speedup_vs_lockstep"] = round(
                r["items_per_s"] / lock["items_per_s"], 2)
    records.extend(sched_rows)

    base = next(r for r in records if r["name"].endswith("singledev_baseline"))
    out_rows = []
    for r in records:
        # the artifact must self-describe its hardware: forced host devices
        # timeshare the physical cores, which cap fixed-work scaling
        r["host_cores"] = os.cpu_count()
        if "gaussian" in r["name"] and "sharded" in r["name"]:
            r["speedup_vs_single_device"] = round(
                r["items_per_s"] / base["items_per_s"], 2)
        derived = " ".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "wall_ms"))
        out_rows.append(f"{r['name']},{r['wall_ms'] * 1e3:.1f},{derived}")

    from benchmarks.common import write_json

    write_json(records, JSON_PATH)
    yield from out_rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        i = sys.argv.index("--worker")
        worker(int(sys.argv[i + 1]), "--fast" in sys.argv)
    elif "--sched-worker" in sys.argv:
        sched_worker("--fast" in sys.argv)
    else:
        for row in run(fast="--full" not in sys.argv):
            print(row)
