"""Fig. 5 (end-to-end): dense vs fake vs packed QNIHT recovery on the Gaussian toy.

The paper's headline systems claim is that recovery time is bound by
``size(Φ̂)/bandwidth`` (suppl. §8.1), so streaming packed 2/4/8-bit codes
instead of f32 should cut the hot loop's traffic by 32/bits×. This suite times
the three solver backends end-to-end (traces disabled — the loop is pure
algorithm traffic) and reports the streamed-bytes model alongside wall time;
wall-clock speedups require the Pallas kernels on real TPU HBM, the bytes
column is the hardware-independent law. A batched run (B observations of one
Φ̂) shows the amortization of the heavy-traffic serving mode.

Rows double as the perf trajectory: every run rewrites ``BENCH_recovery.json``
(list of row dicts for THIS run; override the path with the
``BENCH_RECOVERY_JSON`` env var) — the committed file tracks one run per PR,
so the trajectory lives in its git history without unbounded growth.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import measure, row, write_json
from repro.configs.gaussian_toy import CONFIG, SMOKE
from repro.core import qniht, qniht_batch, relative_error
from repro.sensing import make_gaussian_problem

JSON_PATH = os.environ.get("BENCH_RECOVERY_JSON", "BENCH_recovery.json")
BATCH = 8


def _streamed_bytes_per_iter(m: int, n: int, bits) -> int:
    """Operator bytes one NIHT iteration streams (no backtracks): 3 forward
    applications (residual, µ, acceptance) + 1 adjoint, each size(Φ̂)."""
    per_app = m * n * 4 if bits is None else m * ((n * bits + 7) // 8)
    return 4 * per_app


def run(fast: bool = True):
    g = SMOKE if fast else CONFIG
    key = jax.random.PRNGKey(0)
    prob = make_gaussian_problem(g.m, g.n, g.s, 20.0, key)
    Y = jnp.stack([prob.y] * BATCH)
    f32_bytes = _streamed_bytes_per_iter(g.m, g.n, None)
    rows, records = [], []

    def add(name, us, stream_bits, rel_err, extra="", bits_phi=None):
        # stream_bits: width of the bytes actually streamed (None → f32; the
        # fake backend quantizes VALUES but still streams f32). bits_phi: the
        # quantization level of Φ̂'s values, recorded separately.
        streamed = _streamed_bytes_per_iter(g.m, g.n, stream_bits)
        ratio = f32_bytes / streamed
        derived = (f"streamed_bytes={streamed} vs_f32={ratio:.1f}x_fewer "
                   f"rel_error={rel_err:.4f}" + (f" {extra}" if extra else ""))
        rows.append(row(name, us, derived))
        records.append({
            "name": name, "us_per_call": round(us, 1), "bits_phi": bits_phi,
            "stream_bits": stream_bits, "streamed_bytes": streamed,
            "bytes_vs_f32": round(ratio, 2), "rel_error": round(rel_err, 5),
            "m": g.m, "n": g.n, "s": g.s, "n_iters": g.n_iters, "extra": extra,
        })

    # dense f32 baseline
    us_dense, res = measure(
        lambda: qniht(prob.phi, prob.y, g.s, g.n_iters, with_trace=False))
    rel = float(relative_error(res.x, prob.x_true))
    add("fig5b/recover_dense_f32", us_dense, None, rel, "speedup=1.00x")

    us_single_packed = {}
    for bits in (8, 4, 2):
        # fake: quantized values, dense f32 compute + traffic
        us, res = measure(
            lambda b=bits: qniht(prob.phi, prob.y, g.s, g.n_iters, bits_phi=b,
                                 bits_y=8, key=key, requantize="fixed",
                                 with_trace=False))
        rel = float(relative_error(res.x, prob.x_true))
        add(f"fig5b/recover_fake_int{bits}", us, None, rel, bits_phi=bits)

        # packed: stream uint8 codes through the qmm kernels
        us, res = measure(
            lambda b=bits: qniht(prob.phi, prob.y, g.s, g.n_iters, bits_phi=b,
                                 bits_y=8, key=key, requantize="fixed",
                                 backend="packed", with_trace=False))
        us_single_packed[bits] = us
        rel = float(relative_error(res.x, prob.x_true))
        add(f"fig5b/recover_packed_int{bits}", us, bits, rel,
            f"bw_model_speedup={32 / bits:.2f}x", bits_phi=bits)

    # batched serving: B observations amortize one packed Φ̂ stream
    for bits in (8, 2):
        us, res = measure(
            lambda b=bits: qniht_batch(prob.phi, Y, g.s, g.n_iters, bits_phi=b,
                                       bits_y=8, key=key, requantize="fixed",
                                       backend="packed", with_trace=False))
        rel = float(relative_error(res.x[0], prob.x_true))
        amort = us / (BATCH * us_single_packed[bits])
        add(f"fig5b/recover_packed_int{bits}_batch{BATCH}", us, bits, rel,
            f"batch={BATCH} vs_{BATCH}_singles={amort:.2f}x", bits_phi=bits)

    write_json(records, JSON_PATH)
    return rows
