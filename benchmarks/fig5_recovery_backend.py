"""Fig. 5 (end-to-end): dense vs fake vs packed QNIHT recovery on the Gaussian toy.

The paper's headline systems claim is that recovery time is bound by
``size(Φ̂)/bandwidth`` (suppl. §8.1), so streaming packed 2/4/8-bit codes
instead of f32 should cut the hot loop's traffic by 32/bits×. This suite times
the solver backends end-to-end (traces disabled — the loop is pure algorithm
traffic) in the paper's **serving scenario**: B observations of one Φ̂
recovered per call (``qniht_batch``, the deployed heavy-traffic mode). That is
where the bandwidth law pays on wall clock — every backend streams its
operator once per application for all B rows, so the packed backends' 32/bits×
byte advantage survives while the per-row compute is amortized; the fused CPU
path additionally runs the batch as canonical-layout gemms on the shared
transposed codes. The primary ``recover_*`` rows are this batched mode;
``recover_*_single`` rows report the same solvers on one observation for
transparency — and honestly lose to dense there at bench scale: a 256×512 f32
Φ is cache-resident, so the single-vector gemv pays no memory-traffic cost
for the packed path's unpack arithmetic to buy back. The bandwidth law needs
either a Φ̂ that doesn't fit in cache or a batch to amortize the unpack over;
the batched rows show the latter.

Every row carries ``extra: "speedup=…"`` **measured** against the dense-f32
row of the same mode, plus the model numbers kept deliberately separate:
``predicted_speedup`` (machine-roofline model ratio), ``bytes_vs_f32`` (the
pure stream ratio), and the ``measured_us`` / ``predicted_us`` /
``roofline_frac`` triple from ``benchmarks.roofline``'s measured machine
peaks.

Rows double as the perf trajectory: every run rewrites ``BENCH_recovery.json``
(list of row dicts for THIS run; override the path with the
``BENCH_RECOVERY_JSON`` env var) — the committed file tracks one run per PR,
so the trajectory lives in its git history without unbounded growth.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import measure, roofline_fields, row, write_json
from benchmarks.roofline import machine_peaks, predict_recovery_us
from repro.configs.gaussian_toy import CONFIG, SMOKE
from repro.core import qniht, qniht_batch, relative_error
from repro.sensing import make_gaussian_problem

JSON_PATH = os.environ.get("BENCH_RECOVERY_JSON", "BENCH_recovery.json")
BATCH = 8


def _streamed_bytes_per_iter(m: int, n: int, bits) -> int:
    """Operator bytes one NIHT iteration streams (no backtracks): 3 forward
    applications (residual, µ, acceptance) + 1 adjoint, each size(Φ̂)."""
    per_app = m * n * 4 if bits is None else m * ((n * bits + 7) // 8)
    return 4 * per_app


def run(fast: bool = True):
    g = SMOKE if fast else CONFIG
    key = jax.random.PRNGKey(0)
    prob = make_gaussian_problem(g.m, g.n, g.s, 20.0, key)
    Y = jnp.stack([prob.y] * BATCH)
    f32_bytes = _streamed_bytes_per_iter(g.m, g.n, None)
    peaks = machine_peaks()
    rows, records = [], []
    us_dense = {}          # per batch-size: the measured dense-f32 reference
    pred_dense = {}

    def add(name, us, stream_bits, rel_err, batch, extra="", bits_phi=None):
        # stream_bits: width of the bytes actually streamed (None → f32; the
        # fake backend quantizes VALUES but still streams f32). bits_phi: the
        # quantization level of Φ̂'s values, recorded separately.
        streamed = _streamed_bytes_per_iter(g.m, g.n, stream_bits)
        ratio = f32_bytes / streamed
        pred = predict_recovery_us(g.m, g.n, g.n_iters, stream_bits, batch, peaks)
        speedup = us_dense[batch] / us if batch in us_dense else 1.0
        pred_speedup = pred_dense[batch] / pred if batch in pred_dense else 1.0
        derived = (f"speedup={speedup:.2f}x streamed_bytes={streamed} "
                   f"vs_f32={ratio:.1f}x_fewer rel_error={rel_err:.4f}"
                   + (f" {extra}" if extra else ""))
        rows.append(row(name, us, derived))
        records.append({
            "name": name, "us_per_call": round(us, 1), "bits_phi": bits_phi,
            "stream_bits": stream_bits, "streamed_bytes": streamed,
            "bytes_vs_f32": round(ratio, 2), "rel_error": round(rel_err, 5),
            "measured_speedup": round(speedup, 3),
            "predicted_speedup": round(pred_speedup, 3),
            "batch": batch,
            "m": g.m, "n": g.n, "s": g.s, "n_iters": g.n_iters, "extra": extra,
            **roofline_fields(us, pred),
        })
        return us

    # ---- primary rows: batched serving (B observations of one Φ̂) ----------
    us, res = measure(
        lambda: qniht_batch(prob.phi, Y, g.s, g.n_iters, with_trace=False))
    us_dense[BATCH] = us
    pred_dense[BATCH] = predict_recovery_us(g.m, g.n, g.n_iters, None, BATCH, peaks)
    rel = float(relative_error(res.x[0], prob.x_true))
    add("fig5b/recover_dense_f32", us, None, rel, BATCH, f"batch={BATCH}")

    us_batch_packed = {}
    for bits in (8, 4, 2):
        us, res = measure(
            lambda b=bits: qniht_batch(prob.phi, Y, g.s, g.n_iters, bits_phi=b,
                                       bits_y=8, key=key, requantize="fixed",
                                       with_trace=False))
        rel = float(relative_error(res.x[0], prob.x_true))
        add(f"fig5b/recover_fake_int{bits}", us, None, rel, BATCH,
            f"batch={BATCH}", bits_phi=bits)

        us, res = measure(
            lambda b=bits: qniht_batch(prob.phi, Y, g.s, g.n_iters, bits_phi=b,
                                       bits_y=8, key=key, requantize="fixed",
                                       backend="packed", with_trace=False))
        us_batch_packed[bits] = us
        rel = float(relative_error(res.x[0], prob.x_true))
        add(f"fig5b/recover_packed_int{bits}", us, bits, rel, BATCH,
            f"batch={BATCH}", bits_phi=bits)

    # ---- single-observation rows (transparency: the one-vector gemv mode) --
    us, res = measure(
        lambda: qniht(prob.phi, prob.y, g.s, g.n_iters, with_trace=False))
    us_dense[1] = us
    pred_dense[1] = predict_recovery_us(g.m, g.n, g.n_iters, None, 1, peaks)
    rel = float(relative_error(res.x, prob.x_true))
    add("fig5b/recover_dense_f32_single", us, None, rel, 1)

    for bits in (8, 4, 2):
        us, res = measure(
            lambda b=bits: qniht(prob.phi, prob.y, g.s, g.n_iters, bits_phi=b,
                                 bits_y=8, key=key, requantize="fixed",
                                 with_trace=False))
        rel = float(relative_error(res.x, prob.x_true))
        add(f"fig5b/recover_fake_int{bits}_single", us, None, rel, 1,
            bits_phi=bits)

        us, res = measure(
            lambda b=bits: qniht(prob.phi, prob.y, g.s, g.n_iters, bits_phi=b,
                                 bits_y=8, key=key, requantize="fixed",
                                 backend="packed", with_trace=False))
        rel = float(relative_error(res.x, prob.x_true))
        amort = us_batch_packed[bits] / (BATCH * us)
        add(f"fig5b/recover_packed_int{bits}_single", us, bits, rel, 1,
            f"batch_vs_{BATCH}_singles={amort:.2f}x", bits_phi=bits)

    write_json(records, JSON_PATH)
    return rows
