"""Fig. 3: the error coefficients √L/β_2s and L/β̂_2s that scale σ_n and ε_sky
in Corollary 1, monitored over antenna count and sparsity ratio."""
from __future__ import annotations

import jax

from benchmarks.common import row, time_fn
from repro.core import rics_sampled
from repro.quant import fake_quantize
from repro.sensing import Station, measurement_matrix


def run(fast: bool = True):
    key = jax.random.PRNGKey(3)
    res = 24 if fast else 64
    antennas = [10, 20, 30] if fast else [10, 15, 20, 25, 30]
    ratios = [0.02, 0.05] if fast else [0.01, 0.02, 0.05, 0.1]
    rows = []
    for la in antennas:
        st = Station(n_antennas=la)
        phi = measurement_matrix(st, res, extent=1.5)
        phi_hat = fake_quantize(phi, 2, key)
        m = phi.shape[0]
        for ratio in ratios:
            s2 = max(2, int(2 * ratio * m))
            us = time_fn(lambda: rics_sampled(phi, s2, 8, key), warmup=1, iters=1)
            _, beta = rics_sampled(phi, s2, 8, key)
            _, beta_hat = rics_sampled(phi_hat, s2, 8, key)
            c_noise = la**0.5 / float(beta)
            c_sky = la / float(beta_hat)
            rows.append(row(
                f"fig3/L{la}_ratio{ratio}", us,
                f"sqrtL_over_beta={c_noise:.4f} L_over_beta_hat={c_sky:.4f}"
            ))
    return rows
