"""MRI (paper §5): recovery quality + wall time vs observation precision b_y.

The MRI workload quantizes the *acquired k-space samples* (Φ itself is the
implicit unit-modulus Fourier operator — there is nothing to quantize on the
operator side, and nothing dense to stream: ``SubsampledFourierOperator``
stores only the sampling pattern). The sweep recovers the s-sparse brain
phantom at b_y ∈ {32, 8, 4, 2} and reports PSNR / relative error / wall time
per precision — each quantized width twice: with the paper's single per-tensor
scale c_y, and with per-band radial k-space scaling (one f32 scale per
concentric band, the group-scaled quantizer that keeps b_y < 8 usable against
k-space's dynamic range; overhead = ``4·n_bands`` bytes, reported as
``y_scale_bytes``). A batched run (B phantoms sharing one mask) shows the
serving-mode amortization with *per-item* PSNR / rel_error.

The ``mri/full_*`` rows are the paper's actual §5 scenario: the **full,
unsparsified** phantom, recovered once in the pixel basis (Φ = P_Ω F — the
anatomy is not pixel-sparse, so this is the floor) and once in the Haar
wavelet basis (the composed Φ = P_Ω F W†), at b_y ∈ {32, 8, 4, 2} ×
{per-tensor, per-band}. PSNR is always measured in image space against the
full phantom.

The ``phi_nbytes`` column is the point of the matrix-free seam: the dense
partial-Fourier Φ this replaces would be ``16 · fraction · N²`` bytes
(complex64) — reported as ``dense_phi_bytes`` for contrast.

Rows double as the perf trajectory: every run rewrites ``BENCH_mri.json``
(override the path with the ``BENCH_MRI_JSON`` env var); the committed file
tracks one run per PR, so the trajectory lives in its git history.
``run_groupscale`` is the same sweep restricted to the group-scaled rows
(``benchmarks/run.py --suite mri-groupscale``).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import measure, roofline_fields, row, write_json
from benchmarks.roofline import predict_fft_recovery_us
from repro.configs.mri_brain import BENCH, SMOKE, WAVELET_BENCH, WAVELET_SMOKE
from repro.core import psnr, qniht, qniht_batch, relative_error
from repro.sensing import (
    brain_phantom,
    make_mri_problem,
    mri_observations,
    quantize_observations,
    sparsify_image,
)

JSON_PATH = os.environ.get("BENCH_MRI_JSON", "BENCH_mri.json")
BATCH = 4
N_BANDS = 16


def _sweep(fast: bool, per_tensor: bool, per_band: bool):
    cfg = SMOKE if fast else BENCH
    r = cfg.resolution
    key = jax.random.PRNGKey(cfg.seed)
    prob = make_mri_problem(r, cfg.n_sparse, cfg.fraction, key,
                            density=cfg.density,
                            center_fraction=cfg.center_fraction,
                            snr_db=cfg.snr_db, phantom=cfg.phantom)
    dense_phi_bytes = prob.op.shape[0] * prob.op.shape[1] * 8  # complex64 Φ it replaces
    rows, records = [], []

    def add(name, us, bits_y, res_x, extra="", **fields):
        ps = float(psnr(res_x.reshape(r, r), prob.x_true.reshape(r, r)))
        rel = float(relative_error(res_x, prob.x_true))
        derived = (f"psnr_db={ps:.2f} rel_error={rel:.4f} "
                   f"phi_nbytes={prob.op.nbytes} vs_dense={dense_phi_bytes}"
                   + (f" {extra}" if extra else ""))
        rows.append(row(name, us, derived))
        records.append({
            "name": name, "us_per_call": round(us, 1), "bits_y": bits_y,
            "psnr_db": round(ps, 2), "rel_error": round(rel, 5),
            "resolution": r, "m": prob.op.shape[0], "s": cfg.n_sparse,
            "n_iters": cfg.n_iters, "phi_nbytes": prob.op.nbytes,
            "dense_phi_bytes": dense_phi_bytes, "extra": extra,
            **roofline_fields(us, predict_fft_recovery_us(r, cfg.n_iters)),
            **fields,
        })

    def solve(bits_y, granularity="per_tensor"):
        kw = dict(real_signal=True, nonneg=True, with_trace=False)
        y = prob.y
        if bits_y and granularity == "per_band":
            # group-scaled observations are materialized up front (the bytes a
            # scanner would actually transmit); the solver sees ŷ directly
            y = quantize_observations(prob.y, bits_y, key, granularity="per_band",
                                      op=prob.op, n_bands=N_BANDS)
        elif bits_y:
            kw.update(bits_y=bits_y, key=key)
        return qniht(prob.op, y, cfg.n_sparse, cfg.n_iters, **kw)

    us32, res = measure(lambda: solve(None))
    if per_tensor:
        add("mri/recover_y_f32", us32, None, res.x, "speedup=1.00x")
        for bits in (8, 4, 2):
            us, res = measure(lambda b=bits: solve(b))
            add(f"mri/recover_y_int{bits}", us, bits, res.x,
                f"vs_f32={us32 / us:.2f}x granularity=per_tensor")
    if per_band:
        for bits in (8, 4, 2):
            us, res = measure(lambda b=bits: solve(b, "per_band"))
            add(f"mri/recover_y_int{bits}_band{N_BANDS}", us, bits, res.x,
                f"vs_f32={us32 / us:.2f}x granularity=per_band:{N_BANDS}",
                y_scale_bytes=4 * N_BANDS)

    if per_tensor:
        # batched serving: B randomized phantoms share one sampling mask.
        # The phantoms' skull rings saturate at exactly 1.0 over more than
        # n_sparse pixels, so a bare top-k would tie-break every row to the
        # SAME support (degenerate batch — all rows one problem); per-row
        # jitter far below the intensity quantum keeps the rows distinct.
        def sparse_truth(b):
            img = brain_phantom(r, jax.random.fold_in(key, b))
            jitter = 1e-3 * jax.random.uniform(jax.random.fold_in(key, 100 + b),
                                               img.shape)
            return sparsify_image(img + jitter, cfg.n_sparse)

        X_true = jnp.stack([sparse_truth(b) for b in range(BATCH)])
        Y, _ = mri_observations(prob.op, X_true, cfg.snr_db,
                                jax.random.fold_in(key, BATCH))
        us, res_b = measure(
            lambda: qniht_batch(prob.op, Y, cfg.n_sparse, cfg.n_iters, bits_y=8,
                                key=key, real_signal=True, nonneg=True,
                                with_trace=False))
        ps = [float(psnr(res_b.x[b].reshape(r, r), X_true[b].reshape(r, r)))
              for b in range(BATCH)]
        rel = [float(relative_error(res_b.x[b], X_true[b])) for b in range(BATCH)]
        rows.append(row(f"mri/recover_y_int8_batch{BATCH}", us,
                        f"psnr_db_min={min(ps):.2f} psnr_db_mean={sum(ps)/BATCH:.2f} "
                        f"rel_error_max={max(rel):.4f} batch={BATCH}"))
        records.append({
            "name": f"mri/recover_y_int8_batch{BATCH}", "us_per_call": round(us, 1),
            "bits_y": 8, "psnr_db": round(min(ps), 2),
            "rel_error": round(max(rel), 5),
            "psnr_db_per_item": [round(p, 2) for p in ps],
            "rel_error_per_item": [round(e, 5) for e in rel],
            "resolution": r, "m": prob.op.shape[0], "s": cfg.n_sparse,
            "n_iters": cfg.n_iters, "phi_nbytes": prob.op.nbytes,
            "dense_phi_bytes": dense_phi_bytes, "extra": f"batch={BATCH}",
            **roofline_fields(us, predict_fft_recovery_us(r, cfg.n_iters, BATCH)),
        })
    return rows, records


def _full_image_sweep(fast: bool):
    """The unsparsified phantom: pixel basis (Φ = P_Ω F, the mismatch floor)
    vs Haar wavelet basis (Φ = P_Ω F W†), sharing one mask, one set of
    observations, and one image-space ground truth."""
    cfg = WAVELET_SMOKE if fast else WAVELET_BENCH
    r = cfg.resolution
    key = jax.random.PRNGKey(cfg.seed)
    prob = make_mri_problem(r, cfg.n_sparse, cfg.fraction, key,
                            density=cfg.density,
                            center_fraction=cfg.center_fraction,
                            snr_db=cfg.snr_db, phantom=cfg.phantom,
                            sparsity_basis=cfg.sparsity_basis)
    img_true = prob.image_true.reshape(r, r)
    wavelet = cfg.sparsity_basis  # "haar"/"db4" per the config
    ops = {wavelet: prob.op, "pixel": prob.op.kspace_op}
    rows, records = [], []

    def solve(basis, bits_y, granularity):
        y = prob.y
        if bits_y:
            y = quantize_observations(prob.y, bits_y, key, granularity=granularity,
                                      op=prob.op, n_bands=N_BANDS)
        return qniht(ops[basis], y, cfg.n_sparse, cfg.n_iters,
                     real_signal=True, nonneg=basis == "pixel", with_trace=False)

    for basis in ("pixel", wavelet):
        runs = [("f32", None, "per_tensor")]
        for bits in (8, 4, 2):
            runs.append((f"int{bits}", bits, "per_tensor"))
            runs.append((f"int{bits}_band{N_BANDS}", bits, "per_band"))
        for tag, bits, gran in runs:
            us, res = measure(lambda b=bits, g=gran, ba=basis: solve(ba, b, g))
            img = (prob.to_image(res.x) if basis != "pixel"
                   else jnp.real(res.x)).reshape(r, r)
            ps = float(psnr(img, img_true))
            rel = float(relative_error(img.ravel(), prob.image_true))
            name = f"mri/full_{basis}_y_{tag}"
            extra = (f"psnr_db={ps:.2f} rel_error={rel:.4f} basis={basis} "
                     f"granularity={gran} phi_nbytes={ops[basis].nbytes}")
            rows.append(row(name, us, extra))
            rec = {"name": name, "us_per_call": round(us, 1), "bits_y": bits,
                   "psnr_db": round(ps, 2), "rel_error": round(rel, 5),
                   "basis": basis, "resolution": r, "m": prob.op.shape[0],
                   "s": cfg.n_sparse, "n_iters": cfg.n_iters,
                   "phi_nbytes": ops[basis].nbytes,
                   "extra": f"granularity={gran} full_image=True",
                   **roofline_fields(us, predict_fft_recovery_us(r, cfg.n_iters))}
            if gran == "per_band":
                rec["y_scale_bytes"] = 4 * N_BANDS
            records.append(rec)
    return rows, records


def run(fast: bool = True):
    rows, records = _sweep(fast, per_tensor=True, per_band=True)
    rows_f, records_f = _full_image_sweep(fast)
    write_json(records + records_f, JSON_PATH)
    return rows + rows_f


def run_fullimage(fast: bool = True):
    """The full-image (unsparsified phantom) rows only
    (``benchmarks/run.py --suite mri-fullimage``); does NOT touch
    BENCH_mri.json so the committed trajectory stays one-run-per-PR."""
    rows, _ = _full_image_sweep(fast)
    return rows


def run_groupscale(fast: bool = True):
    """The group-scaled rows only (``--suite mri-groupscale``); does NOT touch
    BENCH_mri.json so the committed trajectory stays one-run-per-PR."""
    rows, _ = _sweep(fast, per_tensor=False, per_band=True)
    return rows
