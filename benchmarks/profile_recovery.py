"""Per-op profiling driver for the recovery hot loop (the tentpole's step 1).

Times each component of a QNIHT iteration on the fig5 geometry — operator
forward (``mv``), adjoint (``rmv``), the threshold kernel, and the end-to-end
solve — for the dense and packed backends, and reports the share of an
iteration each accounts for (model: 3 forwards + 1 adjoint + 1 threshold per
no-backtrack iteration). ``accounted`` is model-iteration-time / measured
per-iteration solve time: well below 1.0 means the loop is losing time
*between* kernels (dispatch, requantize, fan-out) rather than in them — that
gap, not the kernels, is then the optimization target. Well above 1.0 (small
shapes) means in-loop fusion makes components cheaper than their standalone
dispatch cost — the loop is dispatch-bound, not kernel-bound.

    PYTHONPATH=src:. python -m benchmarks.profile_recovery [--full]
        [--batch B] [--bits 8] [--profile-dir DIR]

``--profile-dir`` additionally captures a JAX profiler trace of one warm
end-to-end solve per backend (open with TensorBoard / Perfetto; see
docs/performance.md). The same flag exists on ``repro.launch.recover`` and
``repro.launch.serve`` for tracing full driver runs.
"""
from __future__ import annotations

import argparse
import contextlib

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.configs.gaussian_toy import CONFIG, SMOKE
from repro.core import qniht_batch
from repro.core.operators import DenseOperator, PackedStreamingOperator
from repro.kernels import hsthresh
from repro.sensing import make_gaussian_problem


def profile_backend(name, op, Y, X, s, n_iters, solve, profile_dir=None):
    """Rows of per-op µs + share-of-iteration for one backend's operators."""
    rows = []
    mv = jax.jit(op.mv)
    rmv = jax.jit(op.rmv)
    thresh = jax.jit(jax.vmap(lambda u: hsthresh(jnp.abs(u), s, use_pallas=False)))
    us_mv = time_fn(mv, X, warmup=2, iters=5)
    us_rmv = time_fn(rmv, Y, warmup=2, iters=5)
    us_th = time_fn(thresh, X, warmup=2, iters=5)
    us_solve = time_fn(solve, warmup=1, iters=3)
    us_iter = us_solve / n_iters
    model = 3 * us_mv + us_rmv + us_th
    for comp, us, mult in (("mv", us_mv, 3), ("rmv", us_rmv, 1),
                           ("threshold", us_th, 1)):
        rows.append(row(f"profile/{name}/{comp}", us,
                        f"share_of_iter={mult * us / us_iter:.2f} x{mult}/iter"))
    rows.append(row(f"profile/{name}/solve", us_solve,
                    f"per_iter={us_iter:.1f}us accounted={model / us_iter:.2f}"))
    if profile_dir:
        with jax.profiler.trace(f"{profile_dir}/{name}"):
            jax.block_until_ready(solve())
        rows.append(row(f"profile/{name}/trace", 0.0,
                        f"written={profile_dir}/{name}"))
    return rows


def run(fast: bool = True, batch: int = 8, bits: int = 8, profile_dir=None):
    g = SMOKE if fast else CONFIG
    key = jax.random.PRNGKey(0)
    prob = make_gaussian_problem(g.m, g.n, g.s, 20.0, key)
    Y = jnp.stack([prob.y] * batch)
    X = jnp.stack([prob.x_true] * batch)
    rows = []

    dense = DenseOperator(prob.phi)
    rows += profile_backend(
        "dense_f32", dense, Y, X, g.s, g.n_iters,
        lambda: qniht_batch(prob.phi, Y, g.s, g.n_iters, with_trace=False),
        profile_dir)

    packed = PackedStreamingOperator.pack(prob.phi, bits, key)
    rows += profile_backend(
        f"packed_int{bits}", packed, Y, X, g.s, g.n_iters,
        lambda: qniht_batch(prob.phi, Y, g.s, g.n_iters, bits_phi=bits,
                            bits_y=8, key=key, requantize="fixed",
                            backend="packed", with_trace=False),
        profile_dir)

    # one-time pack cost, for amortization context (not part of the loop)
    us_pack = time_fn(
        lambda: jax.block_until_ready(
            PackedStreamingOperator.pack(prob.phi, bits, key).packed.fwd_re.packed),
        warmup=1, iters=3)
    rows.append(row(f"profile/pack_int{bits}", us_pack,
                    f"one_time amortized_over={g.n_iters}_iters"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="CONFIG geometry instead of SMOKE")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--bits", type=int, default=8, choices=[2, 4, 8])
    ap.add_argument("--profile-dir", default=None,
                    help="capture a JAX profiler trace of one warm solve per "
                         "backend under this directory")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in run(fast=not args.full, batch=args.batch, bits=args.bits,
                 profile_dir=args.profile_dir):
        print(r)


if __name__ == "__main__":
    main()
