"""Fig. 7/8: RIP tunability — γ vs grid extent d, γ vs antenna count, and the
Lemma-1 minimum bit width for each setting."""
from __future__ import annotations

import jax

from benchmarks.common import row, time_fn
from repro.core import gamma_from_rics, gamma_full, min_bits_lemma1, rics_sampled
from repro.sensing import Station, measurement_matrix


def run(fast: bool = True):
    key = jax.random.PRNGKey(7)
    res = 24 if fast else 48
    extents = [0.5, 1.0, 2.0] if fast else [0.25, 0.5, 1.0, 1.5, 2.0, 3.0]
    rows = []

    # Fig 7: gamma vs grid extent d (30 antennas), sampled-RIC gamma_2s + bits
    st = Station(n_antennas=30)
    for d in extents:
        phi = measurement_matrix(st, res, extent=d)
        us = time_fn(lambda: gamma_full(phi), warmup=0, iters=1)
        g_full = float(gamma_full(phi))
        al, be = rics_sampled(phi, 16, 12, key)
        g_2s = float(gamma_from_rics(al, be))
        bits = min_bits_lemma1(g_2s, float(al), 16)
        rows.append(row(
            f"fig7/extent_{d}", us,
            f"gamma_full={g_full:.3g} gamma_2s={g_2s:.4f} lemma1_min_bits={bits}"
        ))

    # Fig 8: gamma vs antenna count (extent fixed)
    for la in ([20, 40] if fast else [10, 20, 30, 50, 70]):
        st = Station(n_antennas=la)
        phi = measurement_matrix(st, res, extent=1.5)
        al, be = rics_sampled(phi, 16, 12, key)
        g_2s = float(gamma_from_rics(al, be))
        bits = min_bits_lemma1(g_2s, float(al), 16)
        rows.append(row(
            f"fig8/antennas_{la}", 0.0,
            f"gamma_2s={g_2s:.4f} lemma1_min_bits={bits}"
        ))
    return rows
