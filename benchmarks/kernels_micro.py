"""Kernel microbenchmarks: the packed-qmm streamed-bytes law (the paper's
central systems claim) measured at the kernel-contract level, plus interpret-
mode sanity timings for the other kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels import hsthresh, pack_operator, pack_weights, packed_matvec, qmm, sqround
from repro.kernels.qmm.ref import qmm_ref


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    m, k, n = (16, 2048, 1024) if fast else (64, 8192, 4096)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
    rows = []
    f32_bytes = w.size * 4

    for bits in (8, 4, 2):
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        fn = jax.jit(lambda xx, pp=pw: qmm(xx, pp, use_pallas=False))
        us = time_fn(fn, x, warmup=2, iters=5)
        rows.append(row(
            f"kernels/qmm_int{bits}_ref", us,
            f"streamed_bytes={pw.nbytes} vs_f32={f32_bytes / pw.nbytes:.1f}x_fewer"
        ))

    # packed-operator matvec, single vector vs a served batch: one kernel call
    # streams Φ̂ once for all B rows (the qniht_batch amortization primitive)
    batch = 8
    phi = w  # (n, k) as a real measurement matrix
    v1 = jax.random.normal(jax.random.fold_in(key, 3), (k,), jnp.float32)
    vb = jax.random.normal(jax.random.fold_in(key, 4), (batch, k), jnp.float32)
    for bits in (8, 2):
        op = pack_operator(phi, bits, jax.random.fold_in(key, 5), shared=True)
        f1 = jax.jit(lambda v, oo=op: packed_matvec(oo, v, use_pallas=False))
        us1 = time_fn(f1, v1, warmup=2, iters=5)
        usb = time_fn(f1, vb, warmup=2, iters=5)
        rows.append(row(
            f"kernels/qmm_opmv_int{bits}_batch{batch}", usb,
            f"single_us={us1:.1f} amortized={usb / (batch * us1):.2f}x_of_{batch}_singles"
        ))

    v = jax.random.normal(jax.random.fold_in(key, 6), (512, 512), jnp.float32)
    us = time_fn(jax.jit(lambda vv: sqround(vv, 8, key, use_pallas=False)[0]), v,
                 warmup=2, iters=5)
    rows.append(row("kernels/sqround_ref", us, "elems=262144"))

    xv = jax.random.normal(jax.random.fold_in(key, 7), (65536,))
    us = time_fn(jax.jit(lambda a: hsthresh(a, 1024, use_pallas=False)), xv,
                 warmup=2, iters=5)
    rows.append(row("kernels/hsthresh_ref", us, "n=65536 s=1024"))
    return rows
