"""Kernel microbenchmarks: the packed-qmm streamed-bytes law (the paper's
central systems claim) measured at the kernel-contract level, plus interpret-
mode sanity timings for the other kernels.

``perf_smoke()`` is the CI guard (``scripts/ci.sh perf``): it times the fused
packed batched matvec against the dense-f32 gemm on one tiny serving shape and
fails if the packed-vs-dense us/call ratio regresses past the threshold pinned
in ``BENCH_thresholds.json`` (updated deliberately, never automatically)."""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels import hsthresh, pack_operator, pack_weights, packed_matvec, qmm, sqround
from repro.kernels.qmm.ref import qmm_ref

THRESHOLDS_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                               "BENCH_thresholds.json")


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    m, k, n = (16, 2048, 1024) if fast else (64, 8192, 4096)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
    rows = []
    f32_bytes = w.size * 4

    for bits in (8, 4, 2):
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        fn = jax.jit(lambda xx, pp=pw: qmm(xx, pp, use_pallas=False))
        us = time_fn(fn, x, warmup=2, iters=5)
        rows.append(row(
            f"kernels/qmm_int{bits}_ref", us,
            f"streamed_bytes={pw.nbytes} vs_f32={f32_bytes / pw.nbytes:.1f}x_fewer"
        ))

    # packed-operator matvec, single vector vs a served batch: one kernel call
    # streams Φ̂ once for all B rows (the qniht_batch amortization primitive)
    batch = 8
    phi = w  # (n, k) as a real measurement matrix
    v1 = jax.random.normal(jax.random.fold_in(key, 3), (k,), jnp.float32)
    vb = jax.random.normal(jax.random.fold_in(key, 4), (batch, k), jnp.float32)
    for bits in (8, 2):
        op = pack_operator(phi, bits, jax.random.fold_in(key, 5), shared=True)
        # shared=True routes the batched call through the canonical-layout
        # gemm on the transposed codes (the same path the solver takes)
        f1 = jax.jit(
            lambda v, oo=op: packed_matvec(oo, v, shared=True, use_pallas=False))
        us1 = time_fn(f1, v1, warmup=2, iters=5)
        usb = time_fn(f1, vb, warmup=2, iters=5)
        rows.append(row(
            f"kernels/qmm_opmv_int{bits}_batch{batch}", usb,
            f"single_us={us1:.1f} amortized={usb / (batch * us1):.2f}x_of_{batch}_singles"
        ))

    v = jax.random.normal(jax.random.fold_in(key, 6), (512, 512), jnp.float32)
    us = time_fn(jax.jit(lambda vv: sqround(vv, 8, key, use_pallas=False)[0]), v,
                 warmup=2, iters=5)
    rows.append(row("kernels/sqround_ref", us, "elems=262144"))

    xv = jax.random.normal(jax.random.fold_in(key, 7), (65536,))
    us = time_fn(jax.jit(lambda a: hsthresh(a, 1024, use_pallas=False)), xv,
                 warmup=2, iters=5)
    rows.append(row("kernels/hsthresh_ref", us, "n=65536 s=1024"))
    return rows


def perf_smoke(bits: int = 8):
    """Tiny-shape packed-vs-dense ratio on the fig5 serving geometry.

    Returns ``{"packed_us", "dense_us", "ratio", ...}``; ratio < 1 means the
    fused packed batched matvec beats the dense-f32 gemm. Shape is the fig5
    CONFIG operator (256×512) at the serving batch (B=8) — small enough for a
    sub-second CI check, big enough that the stream-bytes advantage is real.
    """
    key = jax.random.PRNGKey(0)
    m, k, batch = 256, 512, 8  # Φ is (m, k); packed operator rows = m
    phi = jax.random.normal(key, (m, k), jnp.float32)
    vb = jax.random.normal(jax.random.fold_in(key, 1), (batch, k), jnp.float32)
    op = pack_operator(phi, bits, jax.random.fold_in(key, 2), shared=True)
    f_packed = jax.jit(
        lambda v: packed_matvec(op, v, shared=True, use_pallas=False))
    f_dense = jax.jit(lambda v: jax.lax.dot_general(
        v, phi, (((1,), (1,)), ((), ()))))
    us_p = time_fn(f_packed, vb, warmup=3, iters=9)
    us_d = time_fn(f_dense, vb, warmup=3, iters=9)
    return {"name": f"kernels/perf_smoke_int{bits}_batch{batch}",
            "packed_us": round(us_p, 1), "dense_us": round(us_d, 1),
            "ratio": round(us_p / us_d, 3),
            "m": m, "k": k, "batch": batch, "bits": bits}


def check_perf_smoke(thresholds_path: str = THRESHOLDS_PATH) -> int:
    """CI entry: fail (exit 1) if packed-vs-dense ratio exceeds the pinned
    threshold. The threshold lives in ``BENCH_thresholds.json`` next to
    ``BENCH_recovery.json`` and is updated deliberately, never by CI."""
    with open(thresholds_path) as f:
        thresholds = json.load(f)
    status = 0
    for entry in thresholds["perf_smoke"]:
        res = perf_smoke(bits=entry["bits"])
        limit = entry["max_ratio"]
        ok = res["ratio"] <= limit
        status |= 0 if ok else 1
        print(f"[perf-smoke] {res['name']}: packed={res['packed_us']}us "
              f"dense={res['dense_us']}us ratio={res['ratio']} "
              f"max_ratio={limit} {'ok' if ok else 'REGRESSION'}")
    return status


if __name__ == "__main__":
    if "--perf-smoke" in sys.argv:
        sys.exit(check_perf_smoke())
    print("name,us_per_call,derived")
    for r in run(fast="--full" not in sys.argv):
        print(r)
