"""The paper's own problem: LOFAR CS302-like station sky recovery (§4).

Full experiment: 30 LBA antennas (M = 870 cross-correlation baselines),
256×256-pixel sky (N = 65536), 30 strong sources, 0 dB antenna SNR,
b_Φ ∈ {2,4,8,32}, b_y = 8. ``bench`` is the CI-sized version."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class CSConfig:
    name: str
    n_antennas: int
    resolution: int
    n_sources: int
    extent: float
    snr_db: float
    bits_phi: int
    bits_y: int
    n_iters: int
    min_sep: int = 4
    seed: int = 302


CONFIG = CSConfig(
    name="lofar-cs302",
    n_antennas=30,
    resolution=256,
    n_sources=30,
    extent=1.5,
    snr_db=0.0,
    bits_phi=2,
    bits_y=8,
    n_iters=60,
)

# CI-sized (same physics, smaller grid)
BENCH = dataclasses.replace(CONFIG, name="lofar-cs302-bench", resolution=64,
                            n_sources=15, n_iters=40)
SMOKE = dataclasses.replace(CONFIG, name="lofar-cs302-smoke", resolution=32,
                            n_sources=8, n_iters=20)
