"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865 — enc-dec; conv audio frontend is a STUB (input_specs provides
precomputed frame embeddings, T_enc = 1500). [arXiv:2212.04356]

Vocab padded 51865 → 52096. Every decoder layer: self-attn + cross-attn."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    n_encoder_layers=4,
    encoder_seq=1500,
    pad_heads_to=1,        # tiny attention: replicate rather than pad/shard
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="gelu",
    norm_type="layernorm",
    n_encoder_layers=2,
    encoder_seq=64,
    attn_chunk=64,
    vocab_pad_multiple=16,
)
