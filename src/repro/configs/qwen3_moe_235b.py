"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536/expert
vocab=151936 — 128 experts, top-8. [hf:Qwen/Qwen3 family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    n_experts=128,
    experts_per_token=8,
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-smoke",
    family="moe",
    n_layers=3,           # odd depth exercises the scan+tail split (94 = 94x1)
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=32,
    vocab_size=512,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    n_experts=8,
    experts_per_token=2,
    attn_chunk=64,
    vocab_pad_multiple=16,
)
