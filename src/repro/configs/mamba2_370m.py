"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]

d_inner = 2×1024 = 2048; headdim 64 → 32 SSD heads.
Vocab padded 50280 → 50432 for 16-way TP divisibility (see repro.parallel.sharding).
Supports long_500k (O(1) recurrent state)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=64,
    ssm_conv=4,
    norm_type="rmsnorm",
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_chunk=16,
    ssm_conv=4,
    norm_type="rmsnorm",
    vocab_pad_multiple=16,
)
