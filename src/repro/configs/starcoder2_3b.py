"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, GELU MLP + LayerNorm. [arXiv:2402.19173]

TP note: 24 q-heads padded to 32 for the 16-way model axis; kv=2 does not
divide 16 → kv projections replicated (see repro.parallel.sharding)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    qkv_bias=True,
    mlp_type="gelu",
    norm_type="layernorm",
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    mlp_type="gelu",
    norm_type="layernorm",
    attn_chunk=64,
    vocab_pad_multiple=16,
)
