"""qwen1.5-32b [dense]: 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064 — QKV bias. [hf:Qwen/Qwen1.5 family]

TP note: 40 heads do not divide the 16-way model axis → q/kv heads padded to
48 (see repro.parallel.sharding)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="qwen1.5-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    attn_chunk=64,
    vocab_pad_multiple=16,
)
