"""Per-architecture configs (assigned pool + the paper's own problems)."""
from repro.configs.registry import ALIASES, ARCH_IDS, get_config, get_smoke_config, resolve
from repro.configs.shapes import ALL_SHAPES, BY_NAME, ShapeSuite, applicable

__all__ = [
    "ALIASES", "ARCH_IDS", "get_config", "get_smoke_config", "resolve",
    "ALL_SHAPES", "BY_NAME", "ShapeSuite", "applicable",
]
