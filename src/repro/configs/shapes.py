"""The four assigned input-shape suites and the (arch × shape) applicability map.

  train_4k     seq_len=4096    global_batch=256   → train_step
  prefill_32k  seq_len=32768   global_batch=32    → serve prefill
  decode_32k   seq_len=32768   global_batch=128   → serve_step (1 token, 32k cache)
  long_500k    seq_len=524288  global_batch=1     → serve_step, sub-quadratic only

``long_500k`` runs only for SSM/hybrid archs (O(1) state / bounded local
window); pure full-attention archs skip it (window-vs-full attention asymptotics).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSuite("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSuite("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSuite("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSuite("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable(cfg, shape: ShapeSuite) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 512k dense KV cache is beyond "
                       "design envelope; paper technique does not change attention "
                       "asymptotics")
    return True, ""
