"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936 — 128 experts, top-8. [hf:Qwen/Qwen3-30B-A3B]

head_dim 128 (q dim 4096 > d_model, Qwen3 style). Experts sharded over the
16-way model axis (8 experts/device)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    n_experts=128,
    experts_per_token=8,
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    n_experts=8,
    experts_per_token=2,
    attn_chunk=64,
    vocab_pad_multiple=16,
)
