"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned Nemotron (squared-ReLU MLP). [arXiv:2407.14679]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="relu2",
    norm_type="layernorm",
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    mlp_type="relu2",
    norm_type="layernorm",
    attn_chunk=64,
    vocab_pad_multiple=16,
)
