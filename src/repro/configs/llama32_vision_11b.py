"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer; the vision tower is a
STUB (input_specs provides precomputed patch embeddings, 1600 tokens).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    cross_attn_every=5,
    n_image_tokens=1600,
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    cross_attn_every=5,
    n_image_tokens=16,
    attn_chunk=64,
    vocab_pad_multiple=16,
)
