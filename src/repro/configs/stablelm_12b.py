"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2 family]

head_dim = 5120/32 = 160."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    mlp_type="swiglu",
    norm_type="layernorm",
    pad_heads_to=16,
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    norm_type="layernorm",
    attn_chunk=64,
    vocab_pad_multiple=16,
)
