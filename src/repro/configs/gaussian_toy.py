"""The paper's Gaussian toy (supplementary §10 / Fig. 11):
Φ ∈ R^{256×512}, s-sparse x, SNR sweep, 100 realizations."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GaussianConfig:
    name: str
    m: int = 256
    n: int = 512
    s: int = 16
    n_iters: int = 50
    n_realizations: int = 100
    snr_grid: tuple = (-5.0, 0.0, 5.0, 10.0, 15.0, 20.0, 25.0, 30.0)
    bits_phi: int = 2
    bits_y: int = 8


CONFIG = GaussianConfig(name="gaussian-toy")
SMOKE = GaussianConfig(name="gaussian-toy-smoke", m=64, n=128, s=6, n_iters=25,
                       n_realizations=5, snr_grid=(0.0, 20.0))
