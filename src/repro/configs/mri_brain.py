"""The paper's MRI problem (§5): brain-image recovery from quantized
subsampled-Fourier (k-space) samples.

Full experiment: 256×256 image (N = 65536 — a dense partial-Fourier Φ would be
~2 GB complex64, so this config only runs on the matrix-free
``SubsampledFourierOperator`` path), 35 % variable-density Cartesian sampling,
b_y ∈ {2,4,8,32}. ``BENCH`` is the CI-sized 128×128 version (N = 16384, still
far beyond what the dense solver path could hold as fake-quantized f32 pairs),
``SMOKE`` a 64×64 sanity size.

``scale_granularity``/``n_bands`` select the observation quantizer scale:
``"per_tensor"`` is the paper's single c_y; ``"per_band"`` carries one scale
per concentric radial k-space band (see ``repro.sensing.quantize_observations``)
— the 4-byte-per-band overhead that keeps b_y < 8 usable against k-space's
dynamic range.

``sparsity_basis`` picks the recovery model: ``"pixel"`` thresholds the
phantom to its ``n_sparse`` largest pixels (the exact-sparsity toy);
``"haar"``/``"db4"`` keeps the **full** anatomy and recovers its wavelet
coefficients through the composed Φ = P_Ω F W† — the paper's actual brain
scenario. The ``WAVELET*`` configs are that mode with ``n_sparse`` sized for
approximate wavelet sparsity (~12% of N) and per-band observation scaling.
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MRIConfig:
    name: str
    resolution: int       # image is resolution × resolution (N = resolution²)
    n_sparse: int         # s: kept pixels (pixel basis) / wavelet coefficients
    fraction: float       # sampled fraction of k-space (M = fraction · N)
    density: str          # "uniform" | "variable" Cartesian sampling
    center_fraction: float
    snr_db: Optional[float]
    bits_y: int
    n_iters: int
    phantom: str = "shepp-logan"
    seed: int = 5
    scale_granularity: str = "per_tensor"   # "per_tensor" | "per_band"
    n_bands: int = 16                        # radial bands when per_band
    sparsity_basis: str = "pixel"            # "pixel" | "haar" | "db4"
    wavelet_levels: Optional[int] = None     # None → deepest valid pyramid


CONFIG = MRIConfig(
    name="mri-brain",
    resolution=256,
    n_sparse=2000,
    fraction=0.35,
    density="variable",
    center_fraction=0.04,
    snr_db=None,          # quantization is the noise under study (paper §5)
    bits_y=8,
    n_iters=60,
)

# CI-sized (same physics, smaller grid)
BENCH = dataclasses.replace(CONFIG, name="mri-brain-bench", resolution=128,
                            n_sparse=500, n_iters=40)
SMOKE = dataclasses.replace(CONFIG, name="mri-brain-smoke", resolution=64,
                            n_sparse=120, n_iters=25)

# Full-image wavelet recovery (Φ = P_Ω F W†): the unsparsified phantom,
# s ≈ 12% of N wavelet coefficients, per-band k-space scaling by default.
WAVELET = dataclasses.replace(CONFIG, name="mri-brain-wavelet",
                              sparsity_basis="haar", n_sparse=8000,
                              scale_granularity="per_band")
WAVELET_BENCH = dataclasses.replace(WAVELET, name="mri-brain-wavelet-bench",
                                    resolution=128, n_sparse=2000, n_iters=40)
WAVELET_SMOKE = dataclasses.replace(WAVELET, name="mri-brain-wavelet-smoke",
                                    resolution=64, n_sparse=500, n_iters=25)
