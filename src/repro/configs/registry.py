"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from repro.models.config import ModelConfig

ARCH_IDS = (
    "qwen1_5_32b",
    "starcoder2_3b",
    "minitron_4b",
    "stablelm_12b",
    "mamba2_370m",
    "whisper_tiny",
    "recurrentgemma_2b",
    "llama32_vision_11b",
    "qwen3_moe_30b",
    "qwen3_moe_235b",
)

# public --arch aliases (match the assignment's spelling)
ALIASES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "starcoder2-3b": "starcoder2_3b",
    "minitron-4b": "minitron_4b",
    "stablelm-12b": "stablelm_12b",
    "mamba2-370m": "mamba2_370m",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    # the paper's own problems
    "lofar-cs302": "lofar_cs302",
    "gaussian-toy": "gaussian_toy",
    "mri-brain": "mri_brain",
}


def resolve(arch: str) -> str:
    return ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{resolve(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: small layers/width/experts, tiny vocab."""
    mod = importlib.import_module(f"repro.configs.{resolve(arch)}")
    return mod.SMOKE


def all_model_archs():
    return ARCH_IDS
