"""Serving-workload configs for the sharded batch recovery path.

Models the heavy-traffic scenario the ROADMAP's north star describes: a
stream of fixed-shape observation chunks (instrument-rate data from many
users/stations) recovered against ONE measurement operator by
:class:`repro.parallel.batch.BatchServer` over a ``batch`` device mesh.

The workload is deliberately *heterogeneous*: real streams are. Each chunk
carries a leading burst of ``hard_fraction`` hard rows — geometrically
decaying (near-compressible) coefficients at lower SNR, the kind of item
whose support NIHT resolves slowly — followed by clean flat s-sparse rows.
``n_iters`` is the serving horizon, sized for the hard rows; the per-row
freeze rule (``exit_tol``) is what keeps that horizon cheap for everyone
else. That is exactly why per-shard early exit matters: in a single fused
batch every easy row rides along for the hardest row's iterations, while on
a mesh only the shard holding the burst keeps working (see
``docs/architecture.md`` and ``benchmarks/fig_batch_scaling.py``).
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    name: str
    m: int = 512
    n: int = 1024
    s: int = 64
    chunk: int = 64              # rows per incoming (B, M) chunk
    n_chunks: int = 4            # chunks per measured stream
    n_iters: int = 96            # the serving horizon: sized for the hard rows
    snr_easy_db: float = 30.0
    snr_hard_db: float = 15.0
    hard_decay: float = 0.85     # hard rows: amplitudes decay^j (compressible)
    hard_fraction: float = 1.0 / 8.0    # leading burst of hard rows per chunk
    exit_tol: float = 1e-5       # per-row freeze tolerance (0 → exact rule)
    bits_phi: Optional[int] = None      # None → f32 operator; set for packed
    bits_y: Optional[int] = None
    backend: str = "dense"              # "dense" | "packed"
    seed: int = 0
    # run under repro.analysis.sanitize: debug_nans/debug_infs tripwires plus
    # the backend-compile counter (forces with_trace=True — see serve.py)
    sanitize: bool = False

    @property
    def n_hard(self) -> int:
        """Hard rows at the head of each chunk (at least 1 when fraction > 0)."""
        if self.hard_fraction <= 0:
            return 0
        return max(1, int(round(self.chunk * self.hard_fraction)))


CONFIG = ServeConfig(name="serve-gaussian")

# Packed-operator serving: Φ̂ packed once at server construction, every chunk
# streams the same int4 codes (bits_y=8 observation quantization per chunk).
PACKED = ServeConfig(name="serve-gaussian-packed", bits_phi=4, bits_y=8,
                     backend="packed")

SMOKE = ServeConfig(name="serve-gaussian-smoke", m=64, n=128, s=8, chunk=8,
                    n_chunks=2, n_iters=40)

# Fault-injection harness stream: small chunks but enough of them that a
# kill -TERM reliably lands mid-stream (tests/test_fault_injection.py kills
# after the first chunk's progress line and the restarted run must drain the
# journaled prefix and replay the rest bit-identically).
# sanitize=True: resumed runs are NaN-checked too — a restart that drains a
# torn or garbage journal entry should trip the sanitizer, not serve it.
FAULT = ServeConfig(name="serve-gaussian-fault", m=48, n=96, s=5, chunk=8,
                    n_chunks=5, n_iters=30, sanitize=True)

# Same stream through the packed-operator server (the restart must rebuild
# the identical packed codes from the construction key).
FAULT_PACKED = ServeConfig(name="serve-gaussian-fault-packed", m=48, n=96, s=5,
                           chunk=8, n_chunks=5, n_iters=30, bits_phi=4,
                           bits_y=8, backend="packed", sanitize=True)
