"""Serving-workload configs for the sharded batch recovery path.

Models the heavy-traffic scenario the ROADMAP's north star describes: a
stream of fixed-shape observation chunks (instrument-rate data from many
users/stations) recovered against ONE measurement operator by
:class:`repro.parallel.batch.BatchServer` over a ``batch`` device mesh.

The workload is deliberately *heterogeneous*: real streams are. Each chunk
carries a leading burst of ``hard_fraction`` hard rows — geometrically
decaying (near-compressible) coefficients at lower SNR, the kind of item
whose support NIHT resolves slowly — followed by clean flat s-sparse rows.
``n_iters`` is the serving horizon, sized for the hard rows; the per-row
freeze rule (``exit_tol``) is what keeps that horizon cheap for everyone
else. That is exactly why per-shard early exit matters: in a single fused
batch every easy row rides along for the hardest row's iterations, while on
a mesh only the shard holding the burst keeps working (see
``docs/architecture.md`` and ``benchmarks/fig_batch_scaling.py``).
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    name: str
    m: int = 512
    n: int = 1024
    s: int = 64
    chunk: int = 64              # rows per incoming (B, M) chunk
    n_chunks: int = 4            # chunks per measured stream
    n_iters: int = 96            # the serving horizon: sized for the hard rows
    snr_easy_db: float = 30.0
    snr_hard_db: float = 15.0
    hard_decay: float = 0.85     # hard rows: amplitudes decay^j (compressible)
    hard_fraction: float = 1.0 / 8.0    # leading burst of hard rows per chunk
    exit_tol: float = 1e-5       # per-row freeze tolerance (0 → exact rule)
    bits_phi: Optional[int] = None      # None → f32 operator; set for packed
    bits_y: Optional[int] = None
    backend: str = "dense"              # "dense" | "packed"
    seed: int = 0
    # run under repro.analysis.sanitize: debug_nans/debug_infs tripwires plus
    # the backend-compile counter (forces with_trace=True — see serve.py)
    sanitize: bool = False

    @property
    def n_hard(self) -> int:
        """Hard rows at the head of each chunk (at least 1 when fraction > 0)."""
        if self.hard_fraction <= 0:
            return 0
        return max(1, int(round(self.chunk * self.hard_fraction)))


@dataclasses.dataclass(frozen=True)
class ContinuousServeConfig:
    """Bursty single-request arrival workload for the continuous-batching
    scheduler (:mod:`repro.parallel.scheduler`).

    Where :class:`ServeConfig` streams pre-cut ``(B, M)`` chunks, this models
    the request-level reality underneath: individual observations arriving on
    a Poisson clock with periodic bursts, a mix of hard (slow-converging
    compressible, low SNR) and easy rows, and ``priority_classes`` priority
    levels assigned round-robin (class 0 most urgent). The hard/easy mix is
    what makes horizons *heterogeneous* — easy requests freeze after a few
    segments while hard ones run the full ``n_iters`` — which is exactly the
    regime where mid-flight refill beats lockstep chunking
    (``benchmarks/fig_batch_scaling.py``).

    ``deadline_slack`` (per priority class, optional): class ``p`` requests
    get ``deadline = arrival_tick + deadline_slack * (p + 1)``; ``None``
    disables deadlines (the benchmark workload, so continuous and lockstep
    answer the identical request set and quality comparisons are apples to
    apples — deadline shedding is exercised by the property tests).
    """

    name: str
    m: int = 512
    n: int = 1024
    s: int = 64
    n_requests: int = 64         # total arrivals in the trace
    slots: int = 8               # rows of the live SolverState
    seg_len: int = 8             # iterations per segment (refill granularity)
    n_iters: int = 96            # horizon, sized for the hard requests
    queue_depth: int = 64
    age_every: int = 8           # aging window (anti-starvation); 0 disables
    arrival_rate: float = 1.5    # mean Poisson arrivals per tick
    burst_every: int = 12        # every k-th tick also lands a burst ...
    burst_size: int = 6          # ... of this many extra requests
    priority_classes: int = 3
    deadline_slack: Optional[int] = None
    # per-request horizon (iteration budget): easy requests carry this,
    # hard ones the full n_iters — the heterogeneous-horizon regime where
    # mid-flight refill pays (None → every request gets n_iters). Keep both
    # multiples of seg_len so the horizon clamp never shortens a segment.
    n_iters_easy: Optional[int] = 24
    snr_easy_db: float = 30.0
    snr_hard_db: float = 15.0
    hard_decay: float = 0.85
    hard_fraction: float = 1.0 / 8.0
    exit_tol: float = 1e-5
    bits_phi: Optional[int] = None
    bits_y: Optional[int] = None
    backend: str = "dense"
    seed: int = 0
    sanitize: bool = False


CONFIG = ServeConfig(name="serve-gaussian")

# Packed-operator serving: Φ̂ packed once at server construction, every chunk
# streams the same int4 codes (bits_y=8 observation quantization per chunk).
PACKED = ServeConfig(name="serve-gaussian-packed", bits_phi=4, bits_y=8,
                     backend="packed")

SMOKE = ServeConfig(name="serve-gaussian-smoke", m=64, n=128, s=8, chunk=8,
                    n_chunks=2, n_iters=40)

# Fault-injection harness stream: small chunks but enough of them that a
# kill -TERM reliably lands mid-stream (tests/test_fault_injection.py kills
# after the first chunk's progress line and the restarted run must drain the
# journaled prefix and replay the rest bit-identically).
# sanitize=True: resumed runs are NaN-checked too — a restart that drains a
# torn or garbage journal entry should trip the sanitizer, not serve it.
FAULT = ServeConfig(name="serve-gaussian-fault", m=48, n=96, s=5, chunk=8,
                    n_chunks=5, n_iters=30, sanitize=True)

# Same stream through the packed-operator server (the restart must rebuild
# the identical packed codes from the construction key).
FAULT_PACKED = ServeConfig(name="serve-gaussian-fault-packed", m=48, n=96, s=5,
                           chunk=8, n_chunks=5, n_iters=30, bits_phi=4,
                           bits_y=8, backend="packed", sanitize=True)

# Continuous-batching benchmark workload: 64 heterogeneous requests against
# an 8-slot table. seg_len | n_iters keeps the horizon clamp from ever
# shortening a segment → one compiled executable for the whole run.
# exit_tol=0 (the exact bitwise-fixed-point rule) on purpose: the 1e-5 freeze
# would stop the hard rows almost as early as the easy ones, hiding the
# heterogeneous-horizon regime this benchmark exists to measure; arrival_rate
# 2/tick keeps a queue backlog so throughput is service-limited, not
# arrival-limited.
CONTINUOUS = ContinuousServeConfig(name="serve-continuous", exit_tol=0.0,
                                   arrival_rate=2.0)

CONTINUOUS_PACKED = ContinuousServeConfig(name="serve-continuous-packed",
                                          exit_tol=0.0, arrival_rate=2.0,
                                          bits_phi=4, bits_y=8,
                                          backend="packed")

# CI-sized: small enough for the sched smoke, still heterogeneous enough
# that continuous visibly out-admits lockstep.
CONTINUOUS_SMOKE = ContinuousServeConfig(name="serve-continuous-smoke", m=64,
                                         n=128, s=8, n_requests=20, slots=4,
                                         seg_len=8, n_iters=40,
                                         n_iters_easy=16, arrival_rate=1.0,
                                         burst_every=6, burst_size=3)
