"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427]

head_dim 256; local window 2048 → supports long_500k (bounded state).
Attention is small (MQA) → heads replicated on the model axis (pad_heads_to=1);
TP shards the MLP and RG-LRU width instead (see repro.parallel.sharding)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    rnn_width=2560,
    ssm_conv=4,
    pad_heads_to=1,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    block_pattern=("rec", "rec", "attn"),
    local_window=32,
    rnn_width=64,
    ssm_conv=4,
    attn_chunk=32,
    vocab_pad_multiple=16,
)
