"""Restricted-isometry machinery: RICs, γ, Lemma 1 bit bounds, Theorem 3 terms.

The paper's verification strategy (§3.2, supplementary §7.3):

* the singular values of any column submatrix Φ_Γ interlace inside the extreme
  (nonzero) singular values of Φ, so ``γ̄ = σ_max/σ_min − 1`` computed on the full
  matrix *upper-bounds* every γ_|Γ| (paper Fig. 7 plots exactly this γ̄);
* Lemma 1 then converts a margin ε = 1/16 − γ̄ into a minimum bit width
  ``b ≥ log₂(2√|Γ| / (ε·α))`` that preserves γ̂ ≤ 1/16 after quantization;
* Theorem 3's error terms ε_s, ε_q are computed from the RICs.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def singular_values(phi: jax.Array) -> jax.Array:
    """Nonzero-part singular values via the (M×M) Gram eigendecomposition
    (M ≪ N for compressive sensing, so this is the cheap direction)."""
    gram = phi @ jnp.conj(phi.T)
    ev = jnp.linalg.eigvalsh(gram)
    return jnp.sqrt(jnp.maximum(jnp.real(ev), 0.0))[::-1]  # descending


def gamma_full(phi: jax.Array) -> jax.Array:
    """Paper Fig. 7's γ = σ_max/σ_min − 1 over the full matrix's nonzero spectrum."""
    sv = singular_values(phi)
    smax = sv[0]
    smin = sv[min(phi.shape) - 1]
    return smax / jnp.maximum(smin, 1e-30) - 1.0


@partial(jax.jit, static_argnames=("s", "n_samples"))
def rics_sampled(phi: jax.Array, s: int, n_samples: int = 32, key=None):
    """Empirical RICs: extreme singular values of Φ_Γ over random supports |Γ| = s.

    Returns (α̂_s, β̂_s) = (min over samples of σ_min, max of σ_max). A *sampled*
    estimate (exact RICs are NP-hard, §2 "Step Size Determination").
    """
    key = key if key is not None else jax.random.PRNGKey(3)
    n = phi.shape[1]

    def one(k):
        idx = jax.random.choice(k, n, (s,), replace=False)
        sub = jnp.take(phi, idx, axis=1)
        sv = jnp.linalg.svd(sub, compute_uv=False)
        return sv[-1], sv[0]

    keys = jax.random.split(key, n_samples)
    mins, maxs = jax.vmap(one)(keys)
    return jnp.min(mins), jnp.max(maxs)


def gamma_from_rics(alpha: jax.Array, beta: jax.Array) -> jax.Array:
    """γ_s = max(1 − α/β, β/α − 1)."""
    return jnp.maximum(1.0 - alpha / beta, beta / alpha - 1.0)


def effective_scale(scale) -> float:
    """Collapse a quantizer scale spec to the c_Φ entering Lemma 1's bounds.

    ``scale`` is either the paper's single per-tensor scale (a scalar) or a
    vector of per-group scales (e.g. the per-row scales of a ``per_channel``
    quantization, or per-block scales along the measurement axis — any
    grouping that partitions each column's entries uniformly). The
    quantization perturbation Δ then satisfies |Δ_ij| ≤ s_g(i)/2^{b−1}
    *groupwise*, so the Frobenius-norm step of Eqn. 48 prices each column at
    the root-mean-square of the group scales instead of their max:

        ‖Δ_Γ‖ ≤ ‖Δ_Γ‖_F ≤ √|Γ| · rms(s) · (√M / 2^{b−1})-normalized,

    exactly the per-tensor expression with c_Φ → rms(s). Since the per-tensor
    scale is by construction max(s) ≥ rms(s), group scaling always yields the
    SAME OR SMALLER γ̂ inflation — and hence the same or fewer bits from
    :func:`min_bits_lemma1` — quantifying why group-scaled streams buy
    accuracy at high dynamic range (the ROADMAP's granularity-aware RIP item).
    """
    arr = jnp.asarray(scale, jnp.float32)
    if arr.ndim == 0:
        return float(arr)
    if arr.size == 0:
        raise ValueError("scale vector must be non-empty")
    return float(jnp.sqrt(jnp.mean(arr * arr)))


def min_bits_lemma1(gamma: float, alpha: float, support_size: int,
                    target: float = 1.0 / 16.0, scale=1.0) -> int:
    """Lemma 1: smallest b with  b ≥ log₂(2·c_Φ·√|Γ| / (ε·α)),  ε = target − γ.

    ``scale`` is the quantizer scale: the paper's per-tensor c_Φ (scalar,
    default 1 — entries confined to [-1, 1] a priori) or a per-group scale
    vector, which enters through its RMS (see :func:`effective_scale`) and so
    never *raises* the returned bit width relative to the per-tensor bound.

    Returns a large sentinel (64) when γ already exceeds the target (no bit
    width can help — the full-precision matrix itself violates the condition).
    """
    eps = target - float(gamma)
    if eps <= 0:
        return 64
    c = effective_scale(scale)
    b = math.log2(2.0 * c * math.sqrt(support_size) / (eps * float(alpha)))
    return max(2, math.ceil(b))


def gamma_hat_bound(gamma: float, alpha: float, support_size: int, bits: int,
                    scale=1.0) -> float:
    """Lemma 1's Eqn. 48:  γ̂_|Γ| ≤ γ_|Γ| + c_Φ·√|Γ| / (2^{b−1} · α), with
    ``scale`` a per-tensor scalar or per-group vector (RMS-collapsed;
    see :func:`effective_scale`)."""
    c = effective_scale(scale)
    return float(gamma) + c * math.sqrt(support_size) / (2 ** (bits - 1) * float(alpha))


def eps_s(x: jax.Array, s: int, e_norm: float, beta_2s: float) -> jax.Array:
    """Theorem 2/3's ε_s = ||x − xˢ||₂ + ||x − xˢ||₁/√s + ||e||₂/β_2s."""
    from repro.core.threshold import hard_threshold

    xs = hard_threshold(x, s)
    tail = x - xs
    return (
        jnp.sqrt(jnp.real(jnp.vdot(tail, tail)))
        + jnp.sum(jnp.abs(tail)) / jnp.sqrt(jnp.asarray(float(s)))
        + e_norm / beta_2s
    )


def eps_q(
    m: int,
    beta_2s_hat: float,
    xs_norm: float,
    bits_phi: int,
    bits_y: int,
    c_phi: float = 1.0,
    c_y: float = 1.0,
) -> float:
    """Theorem 3's quantization penalty
    ε_q = √M/β̂_2s · (c_Φ‖xˢ‖₂/2^{bΦ−1} + c_y/2^{b_y−1})."""
    return (math.sqrt(m) / beta_2s_hat) * (
        c_phi * xs_norm / 2 ** (bits_phi - 1) + c_y / 2 ** (bits_y - 1)
    )


def corollary1_coeffs(n_antennas: int, beta_2s: float, beta_2s_hat: float):
    """Radio-astronomy error coefficients (Fig. 3): (√L/β_2s, L/β̂_2s)."""
    return math.sqrt(n_antennas) / beta_2s, n_antennas / beta_2s_hat


def theorem3_bound(n_iter: int, xs_norm: float, eps_s_val: float, eps_q_val: float) -> float:
    """E||x̂ⁿ⁺¹ − xˢ|| ≤ 2⁻ⁿ‖xˢ‖ + 10ε_s + 5ε_q."""
    return 2.0 ** (-n_iter) * xs_norm + 10.0 * eps_s_val + 5.0 * eps_q_val
