"""Recovery-quality metrics used across experiments (paper Fig. 4 metrics).

* relative recovery error  ||x̂ − xˢ||₂ / ||xˢ||₂,
* exact (support) recovery ratio  |supp(x̂) ∩ supp(x)| / s,
* source recovery with tolerance radius (radio-astronomy metric: true-positive
  celestial sources resolved within a pixel radius),
* PSNR on images.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def relative_error(x_hat: jax.Array, x_true: jax.Array) -> jax.Array:
    num = jnp.linalg.norm(x_hat - x_true.astype(x_hat.dtype))
    den = jnp.maximum(jnp.linalg.norm(x_true), 1e-30)
    return jnp.real(num) / jnp.real(den)


def support_recovery(x_hat: jax.Array, x_true: jax.Array, s: int) -> jax.Array:
    """Fraction of the true top-s support recovered in the estimate's top-s."""
    _, idx_t = jax.lax.top_k(jnp.abs(x_true), s)
    _, idx_h = jax.lax.top_k(jnp.abs(x_hat), s)
    mask_t = jnp.zeros(x_true.shape, bool).at[idx_t].set(True)
    mask_h = jnp.zeros(x_hat.shape, bool).at[idx_h].set(True)
    return jnp.sum(mask_t & mask_h) / s


def source_recovery(
    img_hat: jax.Array, img_true: jax.Array, n_sources: int, tol_radius: int = 1
) -> jax.Array:
    """True-positive rate of sources: a true source counts as resolved if the
    recovered image has one of its top-n peaks within ``tol_radius`` pixels
    (Chebyshev). This is the astronomer's metric from §4 (higher error
    tolerance than exact support recovery)."""
    r = img_true.shape[0]
    _, idx_t = jax.lax.top_k(jnp.abs(img_true).ravel(), n_sources)
    _, idx_h = jax.lax.top_k(jnp.abs(img_hat).ravel(), n_sources)
    ti, tj = idx_t // r, idx_t % r
    hi, hj = idx_h // r, idx_h % r
    # (n_true, n_hat) Chebyshev distances
    d = jnp.maximum(
        jnp.abs(ti[:, None] - hi[None, :]), jnp.abs(tj[:, None] - hj[None, :])
    )
    hit = jnp.any(d <= tol_radius, axis=1)
    return jnp.mean(hit.astype(jnp.float32))


def psnr(img_hat: jax.Array, img_true: jax.Array) -> jax.Array:
    mse = jnp.mean(jnp.abs(img_hat - img_true) ** 2)
    peak = jnp.max(jnp.abs(img_true))
    return 10.0 * jnp.log10(peak**2 / jnp.maximum(mse, 1e-30))


def snr_db(signal: jax.Array, noise: jax.Array) -> jax.Array:
    ps = jnp.real(jnp.vdot(signal, signal))
    pn = jnp.real(jnp.vdot(noise, noise))
    return 10.0 * jnp.log10(ps / jnp.maximum(pn, 1e-30))
