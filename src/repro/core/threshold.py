"""Hard thresholding operator H_s: keep the s largest-magnitude entries.

Two implementations:

* :func:`hard_threshold` — exact, via ``jax.lax.top_k`` on magnitudes (the core
  solver's default).
* :func:`hard_threshold_bisect` — the FPGA-style sort-free variant (paper §8: after
  each epoch "perform a binary search on the updated model to find the threshold
  value satisfying that only top S values are larger"). A fixed-iteration bisection
  on the magnitude range converges geometrically and is TPU-friendly (no data-
  dependent control flow, VMEM-resident); it backs the Pallas ``hsthresh`` kernel.

Both support complex inputs (threshold on |x|).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hard_threshold(x: jax.Array, s: int) -> jax.Array:
    """Exact H_s(x): zero all but the s largest |x_i| (vector input; vmap batches)."""
    if x.ndim != 1:
        raise ValueError("hard_threshold expects a vector; vmap for batches")
    if s >= x.shape[-1]:
        return x
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, s)
    mask = jnp.zeros(x.shape, dtype=bool).at[idx].set(True)
    return jnp.where(mask, x, jnp.zeros_like(x))


def support(x: jax.Array) -> jax.Array:
    """Boolean support mask of x."""
    return jnp.abs(x) > 0


def top_s_mask(x: jax.Array, s: int) -> jax.Array:
    """Boolean mask of the s largest-magnitude entries (vector input)."""
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, s)
    return jnp.zeros(x.shape, dtype=bool).at[idx].set(True)


def _bisect_bracket(mag: jax.Array, s: int, iters: int) -> tuple[jax.Array, jax.Array]:
    """Final bisection bracket (lo, hi): count(mag > hi) <= s, and every
    magnitude tied at the threshold lies in (lo, hi]."""
    hi = jnp.max(mag)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(mag > mid)
        # Too many survivors -> raise the floor; else lower the ceiling.
        lo = jnp.where(cnt > s, mid, lo)
        hi = jnp.where(cnt > s, hi, mid)
        return lo, hi

    return jax.lax.fori_loop(0, iters, body, (lo, hi))


def find_threshold_bisect(mag: jax.Array, s: int, iters: int = 32) -> jax.Array:
    """Binary search t such that count(mag > t) <= s, count(mag >= t-) tight.

    Returns the threshold (scalar). After ``iters`` halvings of the initial
    range [0, max(mag)], the bracket width is max(mag) / 2^iters — below f32
    resolution for iters=32, so the result is exact up to magnitude ties.
    """
    return _bisect_bracket(mag, s, iters)[1]


def hard_threshold_bisect(x: jax.Array, s: int, iters: int = 32) -> jax.Array:
    """H_s via bisection threshold: entries with |x| > t, plus threshold ties
    (the final-bracket magnitudes) in deterministic ascending-index order up
    to support size s.

    With distinct magnitudes this equals :func:`hard_threshold`. On ties the
    kept *magnitudes* still match :func:`hard_threshold` (which tie-breaks by
    ``top_k``'s ordering instead) — crucially the support can no longer
    collapse to empty, the degeneracy that made flat phantoms re-enter the
    NIHT init path every iteration.
    """
    # The tie-fill guard: a strict |x| > t cut drops EVERY entry when
    # magnitudes tie at the threshold (flat/piecewise-constant phantoms),
    # handing the solver an empty iterate that re-triggers its init branch.
    from repro.kernels.hsthresh.ref import tie_fill_mask

    mag = jnp.abs(x)
    lo, hi = _bisect_bracket(mag, s, iters)
    strict = mag > hi
    tied = (mag > lo) & ~strict
    keep = strict | tie_fill_mask(strict, tied, s)
    return jnp.where(keep, x, jnp.zeros_like(x))
