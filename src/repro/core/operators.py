"""Linear-operator backends for the (Q)NIHT hot loop.

Blumensath & Davies' IHT analysis only ever touches the sensing matrix through
``Φ̂ x`` / ``Φ̂† r`` products, so the solver needs nothing but a matvec pair —
that is the seam that lets one NIHT loop run on three physically different
representations of Φ̂:

* :class:`DenseOperator`        — f32/c64 matrix, XLA dot. Full precision, and
  also the ``requantize="fixed"`` *fake-quantized* carrier (quantized values
  stored as dense floats: same math as deployment, same bytes as f32).
* :class:`FakeQuantPairOperator`— the per-iteration fresh pair
  (Φ̂_{2n-1}, Φ̂_{2n}) of Algorithm 1's ``requantize="pair"`` mode, each member
  a fake-quantized :class:`DenseOperator`.
* :class:`PackedStreamingOperator` — packed uint8 codes streamed through the
  Pallas ``qmm`` kernels: 4/8/16× fewer operator bytes per application at
  8/4/2 bits. The paper's systems claim (`T = size(Φ̂)/bandwidth`, suppl. §8.1)
  lives here.
* :class:`SubsampledFourierOperator` — *matrix-free* Φ: an implicit 2D FFT
  followed by a k-space sampling mask (the MRI workload, paper §5's brain
  images). No (M, N) array ever exists — at 256×256 the dense partial-Fourier
  matrix would be ~2 GB; the implicit form stores only the sample indices.

Protocol: ``mv(x)`` computes Φ̂ x, ``rmv(r)`` computes Φ̂† r, ``nbytes`` is the
bytes of operator data streamed by ONE application (mv ≈ rmv), ``shape`` is
(M, N) and ``dtype`` the measurement dtype. All operators accept a single
vector ``(n,)`` or a batch ``(B, n)``; a batch is served by one matmul/kernel
invocation, amortizing the Φ̂ stream across B problems (the "heavy traffic"
scenario exploited by ``qniht_batch``).

Operators are registered pytrees (config in aux_data) so they both close over
``lax.scan`` bodies and cross jit boundaries as arguments —
``qniht(phi_op, y, ...)`` takes any of them directly.

:func:`make_iteration_operators` is the solver's factory seam: it turns
whatever the caller handed in (dense array or operator) plus the
``bits_phi``/``requantize``/``backend`` knobs into the per-iteration
(gradient, residual) operator pair Algorithm 1 consumes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qmm.ops import (
    PackedOperator,
    pack_operator,
    packed_matvec,
    packed_rmatvec,
)
from repro.quant.formats import Granularity, as_granularity
from repro.quant.quantize import fake_quantize


@jax.tree_util.register_pytree_node_class
class DenseOperator:
    """Φ̂ as a dense (m, n) array; streams itemsize bytes/entry per application."""

    def __init__(self, mat: jax.Array):
        self.mat = mat

    @property
    def shape(self):
        return self.mat.shape

    @property
    def dtype(self):
        return self.mat.dtype

    @property
    def nbytes(self) -> int:
        return self.mat.size * self.mat.dtype.itemsize

    def mv(self, x: jax.Array) -> jax.Array:
        return x @ self.mat.T

    def rmv(self, r: jax.Array) -> jax.Array:
        m = self.mat
        return r @ (jnp.conj(m) if jnp.iscomplexobj(m) else m)

    def tree_flatten(self):
        return (self.mat,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
class FakeQuantPairOperator:
    """Algorithm 1's fresh stochastic pair (Φ̂_{2n-1}, Φ̂_{2n}) per iteration.

    ``at_iteration(i)`` fake-quantizes Φ twice with iteration-folded keys and
    returns the (gradient, residual) operators. Compute and traffic are dense
    f32 — this backend models the paper's *statistical* algorithm, not the
    deployed streaming system (that is :class:`PackedStreamingOperator`).
    """

    def __init__(self, phi: jax.Array, bits: int, key: jax.Array):
        self.phi = phi
        self.bits = int(bits)
        self.key = key

    @property
    def shape(self):
        return self.phi.shape

    @property
    def dtype(self):
        return self.phi.dtype

    @property
    def nbytes(self) -> int:
        return self.phi.size * self.phi.dtype.itemsize

    def at_iteration(self, i: jax.Array) -> tuple[DenseOperator, DenseOperator]:
        k1 = jax.random.fold_in(self.key, 2 * i)
        k2 = jax.random.fold_in(self.key, 2 * i + 1)
        return (
            DenseOperator(fake_quantize(self.phi, self.bits, k1)),
            DenseOperator(fake_quantize(self.phi, self.bits, k2)),
        )

    def tree_flatten(self):
        return (self.phi, self.key), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        phi, key = children
        return cls(phi, aux[0], key)


@jax.tree_util.register_pytree_node_class
class PackedStreamingOperator:
    """Φ̂ as packed uint8 codes, applied via the Pallas ``qmm`` kernels.

    With the default ``per_tensor`` granularity both orientations are packed
    ONCE (shared codes — the same quantized data a fixed-precision system
    streams), so every NIHT iteration moves ``bits/32`` of the f32 bytes.
    ``per_channel``/``per_block`` granularities scale each orientation along
    its own axes, so each is quantized separately (shared codes cannot carry
    orientation-local scales — see :func:`repro.kernels.qmm.ops.pack_operator`);
    the adjoint identity then holds to within quantization error and the f32
    scale vectors add ``scale_nbytes`` of (documented) stream overhead.
    ``interpret``/``use_pallas`` plumb through to the kernel dispatch (pure-jnp
    oracle off-TPU).
    """

    def __init__(self, packed: PackedOperator, use_pallas: Optional[bool] = None,
                 interpret: bool = False):
        self.packed = packed
        self.use_pallas = use_pallas
        self.interpret = bool(interpret)

    @classmethod
    def pack(cls, phi: jax.Array, bits: int, key: Optional[jax.Array] = None,
             granularity=None, **kw) -> "PackedStreamingOperator":
        """Quantize + pack Φ. Per-tensor granularity (default) shares one set
        of codes across both orientations (matches ``fake_quantize(phi, bits,
        key)`` bit-for-bit); group granularities quantize per orientation."""
        gran = as_granularity(granularity)
        if gran.is_per_tensor:
            return cls(pack_operator(phi, bits, key, shared=True), **kw)
        return cls(pack_operator(phi, bits, key, shared=False, granularity=gran), **kw)

    @property
    def bits(self) -> int:
        return self.packed.fwd_re.bits

    @property
    def granularity(self) -> Granularity:
        return self.packed.fwd_re.granularity

    @property
    def scale_nbytes(self) -> int:
        """f32 scale bytes streamed per application (fwd orientation)."""
        n = self.packed.fwd_re.scale_nbytes
        if self.packed.is_complex:
            n += self.packed.fwd_im.scale_nbytes
        return n

    @property
    def shape(self):
        return (self.packed.fwd_re.packed.shape[0], self.packed.adj_re.packed.shape[0])

    @property
    def dtype(self):
        return jnp.complex64 if self.packed.is_complex else jnp.float32

    @property
    def nbytes(self) -> int:
        n = self.packed.fwd_re.nbytes
        if self.packed.is_complex:
            n += self.packed.fwd_im.nbytes
        return n

    def mv(self, x: jax.Array) -> jax.Array:
        return packed_matvec(self.packed, x, use_pallas=self.use_pallas,
                             interpret=self.interpret)

    def rmv(self, r: jax.Array) -> jax.Array:
        return packed_rmatvec(self.packed, r, use_pallas=self.use_pallas,
                              interpret=self.interpret)

    def tree_flatten(self):
        return (self.packed,), (self.use_pallas, self.interpret)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


@jax.tree_util.register_pytree_node_class
class SubsampledFourierOperator:
    """Matrix-free Φ = P_Ω F: orthonormal 2D DFT of an r×r image, subsampled at
    the k-space positions Ω (the MRI acquisition model, paper §5).

    ``mv`` is ``fft2(norm="ortho")`` + gather at the flat sample indices;
    ``rmv`` is the exact adjoint: zero-fill scatter + ``ifft2(norm="ortho")``
    (F is unitary, so (P_Ω F)† = F† P_Ωᵀ). Nothing of size M×N is ever built —
    ``nbytes`` counts only the stored sampling pattern (int32 indices + the
    1-bit/pixel mask an acquisition system would keep), which is why a 256×256
    problem (dense Φ ≈ 2 GB complex64) costs ~100 KB here.

    Build from a boolean k-space mask with :meth:`from_mask` (concrete, outside
    jit — the sample count M becomes the static output shape).
    """

    def __init__(self, indices: jax.Array, resolution: int):
        self.indices = indices          # (M,) int32, flat positions in the r×r grid
        self.resolution = int(resolution)

    @classmethod
    def from_mask(cls, mask) -> "SubsampledFourierOperator":
        m = np.asarray(mask, bool)
        if m.ndim != 2 or m.shape[0] != m.shape[1]:
            raise ValueError(f"mask must be a square (r, r) boolean array, got {m.shape}")
        if not m.any():
            raise ValueError("empty sampling mask: no k-space positions selected")
        return cls(jnp.asarray(np.flatnonzero(m.ravel()), jnp.int32), m.shape[0])

    @property
    def shape(self):
        return (self.indices.shape[0], self.resolution * self.resolution)

    @property
    def dtype(self):
        return jnp.dtype(jnp.complex64)

    @property
    def nbytes(self) -> int:
        # sampling pattern only: int32 sample indices + the packed boolean mask
        return self.indices.shape[0] * 4 + math.ceil(self.resolution**2 / 8)

    def mask(self) -> jax.Array:
        """(r, r) boolean k-space sampling mask (recomputed from the indices)."""
        r = self.resolution
        return jnp.zeros((r * r,), bool).at[self.indices].set(True).reshape(r, r)

    def mv(self, x: jax.Array) -> jax.Array:
        r = self.resolution
        img = x.reshape(*x.shape[:-1], r, r)
        k = jnp.fft.fft2(img, norm="ortho").astype(jnp.complex64)
        return jnp.take(k.reshape(*x.shape[:-1], r * r), self.indices, axis=-1)

    def rmv(self, v: jax.Array) -> jax.Array:
        r = self.resolution
        full = jnp.zeros((*v.shape[:-1], r * r), jnp.complex64)
        full = full.at[..., self.indices].set(v.astype(jnp.complex64))
        img = jnp.fft.ifft2(full.reshape(*v.shape[:-1], r, r), norm="ortho")
        return img.reshape(*v.shape[:-1], r * r).astype(jnp.complex64)

    def tree_flatten(self):
        return (self.indices,), (self.resolution,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


def is_linear_operator(phi) -> bool:
    """True when ``phi`` follows the operator protocol rather than being a
    dense array (ndarray-likes expose ``mv``/``rmv`` never, operators always)."""
    return hasattr(phi, "mv") and hasattr(phi, "rmv")


def as_operator(phi):
    """Dense (M, N) array → :class:`DenseOperator`; operators pass through."""
    return phi if is_linear_operator(phi) else DenseOperator(phi)


def make_iteration_operators(phi, bits_phi, requantize, backend, key,
                             granularity=None):
    """The solver's backend/requantize factory seam.

    Maps the caller's Φ — dense array or operator — plus the quantization knobs
    onto ``(phi_true, get_ops)`` where ``phi_true`` applies full-precision Φ
    (for true-residual traces) and ``get_ops(i)`` yields the (gradient,
    residual) operator pair Algorithm 1 uses at iteration ``i``.

    Dense arrays reproduce the historical dispatch (and its key folding)
    bit-for-bit — ``granularity`` (per_tensor default) only reaches the packed
    backend, where non-per-tensor scales switch the pack to per-orientation
    group-scaled codes. Operator inputs are matrix-free: they are used as-is
    for every iteration — any quantization of the operator's data is the
    operator's own representation choice, so ``bits_phi``/``backend`` must be
    left at their defaults (enforced in the solver's validation).
    """
    if is_linear_operator(phi):
        return phi, lambda i: (phi, phi)
    phi_true = DenseOperator(phi)
    if backend == "packed":
        op = PackedStreamingOperator.pack(phi, bits_phi, jax.random.fold_in(key, 0),
                                          granularity=granularity)
        return phi_true, lambda i: (op, op)
    if bits_phi and requantize == "pair":
        return phi_true, FakeQuantPairOperator(phi, bits_phi, key).at_iteration
    if bits_phi:
        op = DenseOperator(fake_quantize(phi, bits_phi, jax.random.fold_in(key, 0)))
        return phi_true, lambda i: (op, op)
    return phi_true, lambda i: (phi_true, phi_true)
