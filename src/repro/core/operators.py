"""Linear-operator backends for the (Q)NIHT hot loop.

Blumensath & Davies' IHT analysis only ever touches the sensing matrix through
``Φ̂ x`` / ``Φ̂† r`` products, so the solver needs nothing but a matvec pair —
that is the seam that lets one NIHT loop run on three physically different
representations of Φ̂:

* :class:`DenseOperator`        — f32/c64 matrix, XLA dot. Full precision, and
  also the ``requantize="fixed"`` *fake-quantized* carrier (quantized values
  stored as dense floats: same math as deployment, same bytes as f32).
* :class:`FakeQuantPairOperator`— the per-iteration fresh pair
  (Φ̂_{2n-1}, Φ̂_{2n}) of Algorithm 1's ``requantize="pair"`` mode, each member
  a fake-quantized :class:`DenseOperator`.
* :class:`PackedStreamingOperator` — packed uint8 codes streamed through the
  Pallas ``qmm`` kernels: 4/8/16× fewer operator bytes per application at
  8/4/2 bits. The paper's systems claim (`T = size(Φ̂)/bandwidth`, suppl. §8.1)
  lives here.

Protocol: ``mv(x)`` computes Φ̂ x, ``rmv(r)`` computes Φ̂† r, ``nbytes`` is the
bytes of operator data streamed by ONE application (mv ≈ rmv). All operators
accept a single vector ``(n,)`` or a batch ``(B, n)``; a batch is served by one
matmul/kernel invocation, amortizing the Φ̂ stream across B problems (the
"heavy traffic" scenario exploited by ``qniht_batch``).

Operators are pytrees (config in aux_data) so they close over ``lax.scan``
bodies; they are built *inside* a jit trace, not passed across jit boundaries.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.qmm.ops import (
    PackedOperator,
    pack_operator,
    packed_matvec,
    packed_rmatvec,
)
from repro.quant.quantize import fake_quantize


@jax.tree_util.register_pytree_node_class
class DenseOperator:
    """Φ̂ as a dense (m, n) array; streams itemsize bytes/entry per application."""

    def __init__(self, mat: jax.Array):
        self.mat = mat

    @property
    def shape(self):
        return self.mat.shape

    @property
    def nbytes(self) -> int:
        return self.mat.size * self.mat.dtype.itemsize

    def mv(self, x: jax.Array) -> jax.Array:
        return x @ self.mat.T

    def rmv(self, r: jax.Array) -> jax.Array:
        m = self.mat
        return r @ (jnp.conj(m) if jnp.iscomplexobj(m) else m)

    def tree_flatten(self):
        return (self.mat,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
class FakeQuantPairOperator:
    """Algorithm 1's fresh stochastic pair (Φ̂_{2n-1}, Φ̂_{2n}) per iteration.

    ``at_iteration(i)`` fake-quantizes Φ twice with iteration-folded keys and
    returns the (gradient, residual) operators. Compute and traffic are dense
    f32 — this backend models the paper's *statistical* algorithm, not the
    deployed streaming system (that is :class:`PackedStreamingOperator`).
    """

    def __init__(self, phi: jax.Array, bits: int, key: jax.Array):
        self.phi = phi
        self.bits = int(bits)
        self.key = key

    @property
    def shape(self):
        return self.phi.shape

    @property
    def nbytes(self) -> int:
        return self.phi.size * self.phi.dtype.itemsize

    def at_iteration(self, i: jax.Array) -> tuple[DenseOperator, DenseOperator]:
        k1 = jax.random.fold_in(self.key, 2 * i)
        k2 = jax.random.fold_in(self.key, 2 * i + 1)
        return (
            DenseOperator(fake_quantize(self.phi, self.bits, k1)),
            DenseOperator(fake_quantize(self.phi, self.bits, k2)),
        )

    def tree_flatten(self):
        return (self.phi, self.key), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        phi, key = children
        return cls(phi, aux[0], key)


@jax.tree_util.register_pytree_node_class
class PackedStreamingOperator:
    """Φ̂ as packed uint8 codes, applied via the Pallas ``qmm`` kernels.

    Both orientations are packed ONCE (shared codes — the same quantized data a
    fixed-precision system streams), so every NIHT iteration moves
    ``bits/32`` of the f32 bytes. ``interpret``/``use_pallas`` plumb through to
    the kernel dispatch (pure-jnp oracle off-TPU).
    """

    def __init__(self, packed: PackedOperator, use_pallas: Optional[bool] = None,
                 interpret: bool = False):
        self.packed = packed
        self.use_pallas = use_pallas
        self.interpret = bool(interpret)

    @classmethod
    def pack(cls, phi: jax.Array, bits: int, key: Optional[jax.Array] = None,
             **kw) -> "PackedStreamingOperator":
        """Quantize + pack Φ with shared codes (matches fake_quantize(phi, bits, key))."""
        return cls(pack_operator(phi, bits, key, shared=True), **kw)

    @property
    def bits(self) -> int:
        return self.packed.fwd_re.bits

    @property
    def nbytes(self) -> int:
        n = self.packed.fwd_re.nbytes
        if self.packed.is_complex:
            n += self.packed.fwd_im.nbytes
        return n

    def mv(self, x: jax.Array) -> jax.Array:
        return packed_matvec(self.packed, x, use_pallas=self.use_pallas,
                             interpret=self.interpret)

    def rmv(self, r: jax.Array) -> jax.Array:
        return packed_rmatvec(self.packed, r, use_pallas=self.use_pallas,
                              interpret=self.interpret)

    def tree_flatten(self):
        return (self.packed,), (self.use_pallas, self.interpret)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)
