"""Linear-operator backends for the (Q)NIHT hot loop.

Blumensath & Davies' IHT analysis only ever touches the sensing matrix through
``Φ̂ x`` / ``Φ̂† r`` products, so the solver needs nothing but a matvec pair —
that is the seam that lets one NIHT loop run on three physically different
representations of Φ̂:

* :class:`DenseOperator`        — f32/c64 matrix, XLA dot. Full precision, and
  also the ``requantize="fixed"`` *fake-quantized* carrier (quantized values
  stored as dense floats: same math as deployment, same bytes as f32).
* :class:`FakeQuantPairOperator`— the per-iteration fresh pair
  (Φ̂_{2n-1}, Φ̂_{2n}) of Algorithm 1's ``requantize="pair"`` mode, each member
  a fake-quantized :class:`DenseOperator`.
* :class:`PackedStreamingOperator` — packed uint8 codes streamed through the
  Pallas ``qmm`` kernels: 4/8/16× fewer operator bytes per application at
  8/4/2 bits. The paper's systems claim (`T = size(Φ̂)/bandwidth`, suppl. §8.1)
  lives here.
* :class:`SubsampledFourierOperator` — *matrix-free* Φ: an implicit 2D FFT
  followed by a k-space sampling mask (the MRI workload, paper §5's brain
  images). No (M, N) array ever exists — at 256×256 the dense partial-Fourier
  matrix would be ~2 GB; the implicit form stores only the sample indices.
* :class:`WaveletSynthesisOperator` — the orthonormal synthesis W† mapping
  wavelet coefficients to image pixels (implicit multi-level DWT, see
  :mod:`repro.transforms.wavelet`).
* :class:`ComposedOperator` — the algebra: ``B ∘ A`` with the exact adjoint
  ``A† ∘ B†``. Composing the two above yields the full CS-MRI model
  Φ = P_Ω F W†, still matrix-free.

Operator protocol (the contract every backend implements, and what a new
operator must provide to slot into ``qniht``/``qniht_batch``/
``qniht_batch_sharded`` — ``docs/operator-protocol.md`` walks through writing
one):

* ``mv(x)`` — apply Φ̂: ``(n,) → (m,)``, and batched ``(B, n) → (B, m)``. A
  batch MUST be served by one vectorized application (one matmul / kernel
  call / batched FFT), since amortizing the operator stream across B problems
  is the "heavy traffic" scenario ``qniht_batch`` exploits.
* ``rmv(r)`` — apply the adjoint Φ̂†: ``(m,) → (n,)``, batched likewise.
  **Adjoint contract**: ``⟨mv(x), r⟩ == ⟨x, rmv(r)⟩`` must hold exactly (to
  float tolerance) — NIHT's step size µ = ‖g_Γ‖²/‖Φ̂ g_Γ‖² and its acceptance
  test both assume Φ̂† is the true adjoint, and a systematic mismatch breaks
  the monotone-descent guarantee. Quantized backends are the one sanctioned
  relaxation: per-orientation scales hold the identity only to within
  quantization error (documented on :class:`PackedStreamingOperator`).
* ``shape`` — ``(m, n)`` as ints; ``dtype`` — the measurement dtype (what
  ``mv`` returns).
* ``nbytes`` — bytes of operator data streamed by ONE application (mv ≈ rmv):
  the quantity the paper's bandwidth model ``T = size(Φ̂)/BW`` (suppl. §8.1)
  prices. Dense: the full matrix. Packed: the packed codes (+ documented
  ``scale_nbytes``). Matrix-free: only the parameters actually read — the
  sampling pattern for P_Ω F, the filter taps for W†. Composition sums the
  factors' nbytes (each factor's data is streamed once per application).

Operators are registered pytrees (config in aux_data) so they both close over
``lax.scan`` bodies and cross jit boundaries as arguments —
``qniht(phi_op, y, ...)`` takes any of them directly. Composition preserves
this: a :class:`ComposedOperator` of pytree operators is a pytree.

:func:`make_iteration_operators` is the solver's factory seam: it turns
whatever the caller handed in (dense array or operator) plus the
``bits_phi``/``requantize``/``backend`` knobs into the per-iteration
(gradient, residual) operator pair Algorithm 1 consumes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qmm.ops import (
    PackedOperator,
    pack_operator,
    packed_matvec,
    packed_rmatvec,
)
from repro.quant.formats import Granularity, as_granularity
from repro.quant.quantize import fake_quantize


@jax.tree_util.register_pytree_node_class
class DenseOperator:
    """Φ̂ as a dense (m, n) array; streams itemsize bytes/entry per application."""

    def __init__(self, mat: jax.Array):
        self.mat = mat

    @property
    def shape(self):
        return self.mat.shape

    @property
    def dtype(self):
        return self.mat.dtype

    @property
    def nbytes(self) -> int:
        return self.mat.size * self.mat.dtype.itemsize

    def mv(self, x: jax.Array) -> jax.Array:
        # Contract x's minor axis against mat's minor axis directly: `x @ mat.T`
        # makes XLA:CPU materialize the transpose as a physical copy of Φ every
        # application (~100× at serving shapes).
        m = self.mat
        dt = jnp.result_type(x.dtype, m.dtype)
        return jax.lax.dot_general(
            x.astype(dt), m.astype(dt),
            (((x.ndim - 1,), (1,)), ((), ())),
        )

    def rmv(self, r: jax.Array) -> jax.Array:
        m = self.mat
        return r @ (jnp.conj(m) if jnp.iscomplexobj(m) else m)

    def tree_flatten(self):
        return (self.mat,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
class FakeQuantPairOperator:
    """Algorithm 1's fresh stochastic pair (Φ̂_{2n-1}, Φ̂_{2n}) per iteration.

    ``at_iteration(i)`` fake-quantizes Φ twice with iteration-folded keys and
    returns the (gradient, residual) operators. Compute and traffic are dense
    f32 — this backend models the paper's *statistical* algorithm, not the
    deployed streaming system (that is :class:`PackedStreamingOperator`).
    """

    def __init__(self, phi: jax.Array, bits: int, key: jax.Array):
        self.phi = phi
        self.bits = int(bits)
        self.key = key

    @property
    def shape(self):
        return self.phi.shape

    @property
    def dtype(self):
        return self.phi.dtype

    @property
    def nbytes(self) -> int:
        return self.phi.size * self.phi.dtype.itemsize

    def at_iteration(self, i: jax.Array) -> tuple[DenseOperator, DenseOperator]:
        k1 = jax.random.fold_in(self.key, 2 * i)
        k2 = jax.random.fold_in(self.key, 2 * i + 1)
        return (
            DenseOperator(fake_quantize(self.phi, self.bits, k1)),
            DenseOperator(fake_quantize(self.phi, self.bits, k2)),
        )

    def tree_flatten(self):
        return (self.phi, self.key), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        phi, key = children
        return cls(phi, aux[0], key)


@jax.tree_util.register_pytree_node_class
class PackedStreamingOperator:
    """Φ̂ as packed uint8 codes, applied via the Pallas ``qmm`` kernels.

    With the default ``per_tensor`` granularity both orientations are packed
    ONCE (shared codes — the same quantized data a fixed-precision system
    streams), so every NIHT iteration moves ``bits/32`` of the f32 bytes.
    ``per_channel``/``per_block`` granularities scale each orientation along
    its own axes, so each is quantized separately (shared codes cannot carry
    orientation-local scales — see :func:`repro.kernels.qmm.ops.pack_operator`);
    the adjoint identity then holds to within quantization error and the f32
    scale vectors add ``scale_nbytes`` of (documented) stream overhead.
    ``interpret``/``use_pallas`` plumb through to the kernel dispatch (pure-jnp
    oracle off-TPU).
    """

    def __init__(self, packed: PackedOperator, use_pallas: Optional[bool] = None,
                 interpret: bool = False, shared: bool = False):
        self.packed = packed
        self.use_pallas = use_pallas
        self.interpret = bool(interpret)
        # True iff `packed` came from pack_operator(shared=True): the adjoint's
        # bytes are then the forward codes transposed, which the fused CPU path
        # exploits as a pre-transposed canonical layout for batched calls.
        self.shared = bool(shared)

    @classmethod
    def pack(cls, phi: jax.Array, bits: int, key: Optional[jax.Array] = None,
             granularity=None, **kw) -> "PackedStreamingOperator":
        """Quantize + pack Φ. Per-tensor granularity (default) shares one set
        of codes across both orientations (matches ``fake_quantize(phi, bits,
        key)`` bit-for-bit); group granularities quantize per orientation."""
        gran = as_granularity(granularity)
        if gran.is_per_tensor:
            return cls(pack_operator(phi, bits, key, shared=True), shared=True, **kw)
        return cls(pack_operator(phi, bits, key, shared=False, granularity=gran), **kw)

    @property
    def bits(self) -> int:
        return self.packed.fwd_re.bits

    @property
    def granularity(self) -> Granularity:
        return self.packed.fwd_re.granularity

    @property
    def scale_nbytes(self) -> int:
        """f32 scale bytes streamed per application (fwd orientation)."""
        n = self.packed.fwd_re.scale_nbytes
        if self.packed.is_complex:
            n += self.packed.fwd_im.scale_nbytes
        return n

    @property
    def shape(self):
        return (self.packed.fwd_re.packed.shape[0], self.packed.adj_re.packed.shape[0])

    @property
    def dtype(self):
        return jnp.complex64 if self.packed.is_complex else jnp.float32

    @property
    def nbytes(self) -> int:
        n = self.packed.fwd_re.nbytes
        if self.packed.is_complex:
            n += self.packed.fwd_im.nbytes
        return n

    def mv(self, x: jax.Array) -> jax.Array:
        return packed_matvec(self.packed, x, shared=self.shared,
                             use_pallas=self.use_pallas, interpret=self.interpret)

    def rmv(self, r: jax.Array) -> jax.Array:
        return packed_rmatvec(self.packed, r, shared=self.shared,
                              use_pallas=self.use_pallas, interpret=self.interpret)

    def tree_flatten(self):
        return (self.packed,), (self.use_pallas, self.interpret, self.shared)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


@jax.tree_util.register_pytree_node_class
class SubsampledFourierOperator:
    """Matrix-free Φ = P_Ω F: orthonormal 2D DFT of an r×r image, subsampled at
    the k-space positions Ω (the MRI acquisition model, paper §5).

    ``mv`` is ``fft2(norm="ortho")`` + gather at the flat sample indices;
    ``rmv`` is the exact adjoint: zero-fill scatter + ``ifft2(norm="ortho")``
    (F is unitary, so (P_Ω F)† = F† P_Ωᵀ). Nothing of size M×N is ever built —
    ``nbytes`` counts only the stored sampling pattern (int32 indices + the
    1-bit/pixel mask an acquisition system would keep), which is why a 256×256
    problem (dense Φ ≈ 2 GB complex64) costs ~100 KB here.

    Build from a boolean k-space mask with :meth:`from_mask` (concrete, outside
    jit — the sample count M becomes the static output shape).
    """

    def __init__(self, indices: jax.Array, resolution: int):
        self.indices = indices          # (M,) int32, flat positions in the r×r grid
        self.resolution = int(resolution)

    @classmethod
    def from_mask(cls, mask) -> "SubsampledFourierOperator":
        m = np.asarray(mask, bool)
        if m.ndim != 2 or m.shape[0] != m.shape[1]:
            raise ValueError(f"mask must be a square (r, r) boolean array, got {m.shape}")
        if not m.any():
            raise ValueError("empty sampling mask: no k-space positions selected")
        return cls(jnp.asarray(np.flatnonzero(m.ravel()), jnp.int32), m.shape[0])

    @property
    def shape(self):
        return (self.indices.shape[0], self.resolution * self.resolution)

    @property
    def dtype(self):
        return jnp.dtype(jnp.complex64)

    @property
    def nbytes(self) -> int:
        # sampling pattern only: int32 sample indices + the packed boolean mask
        return self.indices.shape[0] * 4 + math.ceil(self.resolution**2 / 8)

    def mask(self) -> jax.Array:
        """(r, r) boolean k-space sampling mask (recomputed from the indices)."""
        r = self.resolution
        return jnp.zeros((r * r,), bool).at[self.indices].set(True).reshape(r, r)

    @property
    def kspace_op(self) -> "SubsampledFourierOperator":
        """The factor owning the k-space sampling geometry (self). Exists so
        band-geometry consumers (``kspace_radial_bands``) can unwrap either a
        bare Fourier operator or a composition uniformly."""
        return self

    def mv(self, x: jax.Array) -> jax.Array:
        r = self.resolution
        img = x.reshape(*x.shape[:-1], r, r)
        k = jnp.fft.fft2(img, norm="ortho").astype(self.dtype)
        return jnp.take(k.reshape(*x.shape[:-1], r * r), self.indices, axis=-1)

    def rmv(self, v: jax.Array) -> jax.Array:
        r = self.resolution
        full = jnp.zeros((*v.shape[:-1], r * r), jnp.complex64)
        full = full.at[..., self.indices].set(v.astype(self.dtype))
        img = jnp.fft.ifft2(full.reshape(*v.shape[:-1], r, r), norm="ortho")
        return img.reshape(*v.shape[:-1], r * r).astype(self.dtype)

    def tree_flatten(self):
        return (self.indices,), (self.resolution,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


@jax.tree_util.register_pytree_node_class
class WaveletSynthesisOperator:
    """Matrix-free orthonormal wavelet synthesis W†: coefficients → image.

    ``mv(c)`` runs the inverse multi-level periodized 2D DWT
    (:func:`repro.transforms.wavelet.idwt2`) on the ``(r²,)`` coefficient
    vector; W is unitary, so ``rmv`` — the exact adjoint (W†)† = W — is simply
    the *forward* transform. Square (r², r²) and real, but applied to complex
    residuals component-wise (the transform is linear over ℂ), which is what
    the composed CS-MRI adjoint W F† P_Ωᵀ feeds it.

    ``nbytes`` counts the only operator data an application reads: the 2·L
    f32 filter taps — the reason a transform-domain Φ costs nothing over the
    pixel-domain one on the stream model.
    """

    def __init__(self, resolution: int, wavelet: str = "haar",
                 levels: Optional[int] = None):
        from repro.transforms.wavelet import _resolve_levels, wavelet_filters

        self.resolution = int(resolution)
        self.wavelet = str(wavelet)
        wavelet_filters(self.wavelet)  # validate the spelling eagerly
        self.levels = _resolve_levels(self.resolution, self.wavelet, levels)

    @property
    def shape(self):
        n = self.resolution * self.resolution
        return (n, n)

    @property
    def dtype(self):
        return jnp.dtype(jnp.float32)

    @property
    def nbytes(self) -> int:
        from repro.transforms.wavelet import wavelet_filters

        lo, hi = wavelet_filters(self.wavelet)
        return 4 * (len(lo) + len(hi))

    def mv(self, c: jax.Array) -> jax.Array:
        from repro.transforms.wavelet import idwt2

        r = self.resolution
        img = idwt2(c.reshape(*c.shape[:-1], r, r), self.wavelet, self.levels)
        return img.reshape(*c.shape[:-1], r * r)

    def rmv(self, x: jax.Array) -> jax.Array:
        from repro.transforms.wavelet import dwt2

        r = self.resolution
        co = dwt2(x.reshape(*x.shape[:-1], r, r), self.wavelet, self.levels)
        return co.reshape(*x.shape[:-1], r * r)

    def tree_flatten(self):
        return (), (self.resolution, self.wavelet, self.levels)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del children
        return cls(*aux)


@jax.tree_util.register_pytree_node_class
class ComposedOperator:
    """The operator algebra's product: ``ComposedOperator(B, A)`` applies
    x ↦ B(A x), with the exact adjoint r ↦ A†(B† r).

    Exactness is compositional — if each factor satisfies the adjoint
    contract, so does the product: ⟨BAx, r⟩ = ⟨Ax, B†r⟩ = ⟨x, A†B†r⟩. Shapes
    must chain (``B.shape[1] == A.shape[0]``); ``shape`` is
    (B.shape[0], A.shape[1]), ``dtype`` is the outer factor's measurement
    dtype, and ``nbytes`` is the sum of the factors' (each factor's data is
    streamed once per application).

    The CS-MRI model Φ = P_Ω F W† is
    ``ComposedOperator(SubsampledFourierOperator, WaveletSynthesisOperator)``;
    the ``kspace_op`` property surfaces whichever factor owns the k-space
    sampling geometry so per-band observation quantization keeps working on
    the composition.
    """

    def __init__(self, outer, inner):
        if outer.shape[1] != inner.shape[0]:
            raise ValueError(
                f"cannot compose: outer expects inputs of size {outer.shape[1]}, "
                f"inner produces size {inner.shape[0]}")
        self.outer = outer
        self.inner = inner

    @property
    def shape(self):
        return (self.outer.shape[0], self.inner.shape[1])

    @property
    def dtype(self):
        return self.outer.dtype

    @property
    def nbytes(self) -> int:
        return self.outer.nbytes + self.inner.nbytes

    @property
    def kspace_op(self):
        """The (unique) factor exposing k-space geometry, unwrapped through
        nested compositions."""
        for f in (self.outer, self.inner):
            op = getattr(f, "kspace_op", None)
            if op is not None:
                return op
        raise AttributeError("no factor of this composition owns k-space geometry")

    def mv(self, x: jax.Array) -> jax.Array:
        return self.outer.mv(self.inner.mv(x))

    def rmv(self, r: jax.Array) -> jax.Array:
        return self.inner.rmv(self.outer.rmv(r))

    def tree_flatten(self):
        return (self.outer, self.inner), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def is_linear_operator(phi) -> bool:
    """True when ``phi`` follows the operator protocol rather than being a
    dense array (ndarray-likes expose ``mv``/``rmv`` never, operators always)."""
    return hasattr(phi, "mv") and hasattr(phi, "rmv")


def as_operator(phi):
    """Dense (M, N) array → :class:`DenseOperator`; operators pass through."""
    return phi if is_linear_operator(phi) else DenseOperator(phi)


def make_iteration_operators(phi, bits_phi, requantize, backend, key,
                             granularity=None):
    """The solver's backend/requantize factory seam.

    Maps the caller's Φ — dense array or operator — plus the quantization knobs
    onto ``(phi_true, get_ops)`` where ``phi_true`` applies full-precision Φ
    (for true-residual traces) and ``get_ops(i)`` yields the (gradient,
    residual) operator pair Algorithm 1 uses at iteration ``i``.

    Dense arrays reproduce the historical dispatch (and its key folding)
    bit-for-bit — ``granularity`` (per_tensor default) only reaches the packed
    backend, where non-per-tensor scales switch the pack to per-orientation
    group-scaled codes. Operator inputs are matrix-free: they are used as-is
    for every iteration — any quantization of the operator's data is the
    operator's own representation choice, so ``bits_phi``/``backend`` must be
    left at their defaults (enforced in the solver's validation).
    """
    if is_linear_operator(phi):
        return phi, lambda i: (phi, phi)
    phi_true = DenseOperator(phi)
    if backend == "packed":
        op = PackedStreamingOperator.pack(phi, bits_phi, jax.random.fold_in(key, 0),
                                          granularity=granularity)
        return phi_true, lambda i: (op, op)
    if bits_phi and requantize == "pair":
        return phi_true, FakeQuantPairOperator(phi, bits_phi, key).at_iteration
    if bits_phi:
        op = DenseOperator(fake_quantize(phi, bits_phi, jax.random.fold_in(key, 0)))
        return phi_true, lambda i: (op, op)
    return phi_true, lambda i: (phi_true, phi_true)
