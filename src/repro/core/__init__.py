"""The paper's primary contribution: low-precision normalized IHT (QNIHT)
with recovery guarantees, plus the baselines and RIP theory around it."""
from repro.core.baselines import clean, cosamp, fista_l1, iht, spectral_norm
from repro.core.niht import (
    IHTResult,
    IHTTrace,
    SolverState,
    niht,
    niht_iteration,
    qniht,
    qniht_batch,
    qniht_batch_sharded,
    solver_init,
    solver_result,
    solver_segment,
    stopping_iterations,
)
from repro.core.operators import (
    ComposedOperator,
    DenseOperator,
    FakeQuantPairOperator,
    PackedStreamingOperator,
    SubsampledFourierOperator,
    WaveletSynthesisOperator,
    as_operator,
    is_linear_operator,
    make_iteration_operators,
)
from repro.core.recovery import (
    psnr,
    relative_error,
    snr_db,
    source_recovery,
    support_recovery,
)
from repro.core.rip import (
    corollary1_coeffs,
    effective_scale,
    eps_q,
    eps_s,
    gamma_from_rics,
    gamma_full,
    gamma_hat_bound,
    min_bits_lemma1,
    rics_sampled,
    singular_values,
    theorem3_bound,
)
from repro.core.threshold import (
    find_threshold_bisect,
    hard_threshold,
    hard_threshold_bisect,
    support,
    top_s_mask,
)

__all__ = [
    "clean", "cosamp", "fista_l1", "iht", "spectral_norm",
    "IHTResult", "IHTTrace", "SolverState", "niht", "niht_iteration", "qniht",
    "qniht_batch", "qniht_batch_sharded", "solver_init", "solver_result",
    "solver_segment", "stopping_iterations",
    "ComposedOperator", "DenseOperator", "FakeQuantPairOperator",
    "PackedStreamingOperator", "SubsampledFourierOperator",
    "WaveletSynthesisOperator", "as_operator", "is_linear_operator",
    "make_iteration_operators",
    "psnr", "relative_error", "snr_db", "source_recovery", "support_recovery",
    "corollary1_coeffs", "effective_scale", "eps_q", "eps_s",
    "gamma_from_rics", "gamma_full",
    "gamma_hat_bound", "min_bits_lemma1", "rics_sampled", "singular_values",
    "theorem3_bound",
    "find_threshold_bisect", "hard_threshold", "hard_threshold_bisect", "support",
    "top_s_mask",
]
