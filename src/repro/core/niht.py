"""Normalized Iterative Hard Thresholding — full precision and quantized (QNIHT).

Implements the paper's Algorithm 1 faithfully:

* adaptive step size  ``µ = ||g_Γ||² / ||Φ̂ g_Γ||²``  on the current support Γ,
* proposal ``x⁺ = H_s(x + µ g)`` with ``g = Φ̂₁†(ŷ − Φ̂₂ x)``,
* if the support changes, accept only when ``µ ≤ (1−c)·ω`` with
  ``ω = ||x⁺−x||² / ||Φ̂₁(x⁺−x)||²``; otherwise shrink ``µ ← µ/(k(1−c))`` and
  re-propose (``lax.while_loop`` backtracking),
* fresh unbiased stochastic quantizations ``Φ̂_{2n-1}, Φ̂_{2n}`` per iteration
  (``requantize="pair"``) or a single fixed quantization (``requantize="fixed"`` —
  what the CPU/FPGA systems actually stream, since data arrives pre-quantized).

The loop only touches Φ̂ through ``mv``/``rmv`` products, so it is generic over
the :mod:`repro.core.operators` backends:

* ``backend="dense"`` — dense XLA dots. With ``bits_phi`` set this is
  *fake quantization*: Φ̂'s values are quantized but carried as f32/c64, so the
  math matches deployment while the memory traffic stays full-precision.
  Faithful to Algorithm 1 in both ``requantize`` modes.
* ``backend="packed"`` — ``requantize="fixed"`` only: Φ̂ and Φ̂† are quantized
  ONCE (shared codes, identical to the dense fixed path bit-for-bit) and packed
  to uint8; every iteration streams the packed codes through the Pallas ``qmm``
  kernels — 4/8/16× fewer operator bytes at 8/4/2 bits, the paper's headline
  systems result (Fig. 5/6, suppl. §8.1).
* **matrix-free** — pass an *operator* (anything with ``mv``/``rmv``/``shape``/
  ``dtype``, e.g. ``SubsampledFourierOperator``) instead of the dense array;
  the loop never materializes Φ. This is how the MRI workload (§5) runs at
  sizes where a dense partial-Fourier Φ would be gigabytes. ``bits_y`` still
  quantizes the observations; ``bits_phi``/``backend`` stay at their defaults.

``qniht_batch`` recovers B observation vectors of the SAME Φ̂ at once: every
matvec lifts to one (B, ·) matmul / kernel call, amortizing the Φ̂ stream
across the batch (the heavy-traffic serving scenario). Key contract: row ``b``
of ``qniht_batch(phi, Y, key=k)`` computes exactly what ``qniht(phi, Y[b],
key=k)`` computes (same quantization draws), up to f32 batching accumulation.

``qniht_batch_sharded`` splits that batch over a 1-D ``batch`` device mesh
(:mod:`repro.parallel.batch`): Y and all per-item solver state sharded, the
packed operator codes/scales replicated, every item bit-identical to the
single-device path. Combined with ``early_exit`` (skip iterations once a
shard's rows all hit a bitwise fixed point) this is the heavy-traffic serving
mode — see ``docs/architecture.md``.

``threshold="hsthresh"`` (real-signal path) swaps the exact ``top_k`` H_s for
the streaming histogram-select-mask kernel (paper §8's FPGA top-S search);
support size stays ≤ s by construction.

Everything is a ``lax.scan`` over iterations → one compiled program, traces out.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import is_linear_operator, make_iteration_operators
from repro.core.threshold import hard_threshold, top_s_mask
from repro.kernels.hsthresh.ops import hsthresh
from repro.quant.formats import as_granularity
from repro.quant.quantize import fake_quantize


class IHTTrace(NamedTuple):
    """Per-iteration diagnostics (length n_iters; batched runs add a B axis)."""

    resid_q: jax.Array        # ||ŷ − Φ̂ x||₂ (the cost the algorithm minimizes)
    resid_true: jax.Array     # ||y − Φ x||₂ against full-precision data
    mu: jax.Array             # accepted step size
    support_changed: jax.Array
    backtracks: jax.Array


class IHTResult(NamedTuple):
    x: jax.Array
    trace: IHTTrace


# consecutive sub-tol updates required before the early-exit freeze rule
# (exit_tol > 0) declares a row stalled — see _qniht_core
_EXIT_PATIENCE = 3


def _sqnorm(v: jax.Array) -> jax.Array:
    return jnp.real(jnp.vdot(v, v))


def _rows_sqnorm(v: jax.Array) -> jax.Array:
    """Squared l2 norm along the last axis (per problem)."""
    return jnp.real(jnp.sum(v * jnp.conj(v), axis=-1))


def _project(a: jax.Array, real_signal: bool, nonneg: bool) -> jax.Array:
    if real_signal:
        a = jnp.real(a)
        if nonneg:
            a = jnp.maximum(a, 0.0)
    return a


def _make_hs(threshold: str, s: int):
    """Batched H_s: (B, N) → (B, N) with per-row support size ≤ s."""
    if threshold == "topk":
        return jax.vmap(lambda v: hard_threshold(v, s))
    if threshold == "hsthresh":
        return jax.vmap(lambda v: hsthresh(v, s))
    raise ValueError(f"unknown threshold {threshold!r} (use 'topk' or 'hsthresh')")


def _niht_iteration_batch(
    X: jax.Array,
    Yhat: jax.Array,
    op1,
    op2,
    s: int,
    c: float,
    shrink_k: float,
    max_backtracks: int,
    real_signal: bool,
    nonneg: bool,
    hs,
):
    """One NIHT step (Algorithm 1 body) on a batch of problems sharing Φ̂.

    ``op1`` is Φ̂_{2n-1} (gradient / step-size / acceptance), ``op2`` is Φ̂_{2n}
    (residual), matching the paper's pairing. Every operator application serves
    the whole batch in one matmul; support logic and backtracking are per-row
    (a row stops shrinking µ as soon as its own acceptance test passes).
    Returns (X_new, mu, changed, n_backtracks), all leading-axis B.
    """
    eps = jnp.asarray(1e-30, jnp.float32)
    R = Yhat - op2.mv(X)
    G = op1.rmv(R)

    # Γ: support of x, or (first iteration, x = 0) the top-s of the first gradient.
    on_init = _rows_sqnorm(X) == 0.0
    mask_x = jnp.abs(X) > 0
    mask_g = jax.vmap(lambda g: top_s_mask(g, s))(G)
    gamma = jnp.where(on_init[:, None], mask_g, mask_x)

    Gg = jnp.where(gamma, G, jnp.zeros_like(G))
    mu0 = _rows_sqnorm(Gg) / (_rows_sqnorm(op1.mv(Gg)) + eps)

    def propose(mu):
        A = X.astype(G.dtype) + mu[:, None] * G
        A = _project(A, real_signal, nonneg).astype(X.dtype)
        return hs(A)

    def accept(mu, Xp):
        new_mask = jnp.abs(Xp) > 0
        same = jnp.all(new_mask == gamma, axis=-1)
        D = Xp - X
        omega = _rows_sqnorm(D) / (_rows_sqnorm(op1.mv(D)) + eps)
        return same | (mu <= (1.0 - c) * omega)

    X0 = propose(mu0)
    active0 = ~accept(mu0, X0)
    nbt0 = jnp.zeros(X.shape[0], jnp.int32)

    def cond(carry):
        _, _, nbt, active = carry
        return jnp.any(active & (nbt < max_backtracks))

    def body(carry):
        mu, Xp, nbt, active = carry
        act = active & (nbt < max_backtracks)
        mu_new = jnp.where(act, mu / (shrink_k * (1.0 - c)), mu)
        Xp_new = jnp.where(act[:, None], propose(mu_new), Xp)
        nbt_new = nbt + act.astype(jnp.int32)
        still_rejected = act & ~accept(mu_new, Xp_new)
        return mu_new, Xp_new, nbt_new, still_rejected

    mu, X_new, n_bt, _ = jax.lax.while_loop(cond, body, (mu0, X0, nbt0, active0))
    changed = ~jnp.all((jnp.abs(X_new) > 0) == gamma, axis=-1)
    return X_new, mu, changed, n_bt


def niht_iteration(
    x: jax.Array,
    y_hat: jax.Array,
    op1,
    op2,
    s: int,
    c: float,
    shrink_k: float,
    max_backtracks: int,
    real_signal: bool,
    nonneg: bool,
    threshold: str = "topk",
):
    """One NIHT step on a single problem. ``op1``/``op2`` follow the
    :mod:`repro.core.operators` protocol (``mv``/``rmv`` accepting a batch
    axis); see :func:`_niht_iteration_batch` for the paper's pairing.
    Returns (x_new, mu, changed, n_backtracks)."""
    X, mu, ch, nbt = _niht_iteration_batch(
        x[None, :], y_hat[None, :], op1, op2, s, c, shrink_k, max_backtracks,
        real_signal, nonneg, _make_hs(threshold, s),
    )
    return X[0], mu[0], ch[0], nbt[0]


def _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold, real_signal,
              scale_granularity="per_tensor", group_size=None, early_exit=False,
              exit_tol=0.0, unroll=1):
    if (bits_phi or bits_y) and key is None:
        raise ValueError("quantized NIHT needs a PRNG key")
    if requantize not in ("pair", "fixed"):
        raise ValueError(f"unknown requantize {requantize!r}")
    if early_exit and bits_phi and requantize == "pair":
        raise ValueError(
            "early_exit skips iterations once x reaches a bitwise fixed point, "
            "which is only absorbing when every iteration applies the SAME "
            "operators; requantize='pair' redraws Φ̂ each iteration — use "
            "requantize='fixed' (or full precision) with early_exit")
    if exit_tol < 0.0:
        raise ValueError(f"exit_tol must be >= 0, got {exit_tol}")
    if exit_tol > 0.0 and not early_exit:
        raise ValueError("exit_tol is the early_exit freeze tolerance; set early_exit=True")
    if unroll < 1:
        raise ValueError(f"unroll must be a positive int, got {unroll}")
    if unroll > 1 and early_exit:
        raise ValueError(
            "unroll amortizes dispatch of the fixed-trip lax.scan; the "
            "early_exit path is a lax.while_loop with a data-dependent trip "
            "count, which cannot unroll — use unroll with early_exit=False")
    if backend not in ("dense", "packed"):
        raise ValueError(f"unknown backend {backend!r} (use 'dense' or 'packed')")
    gran = as_granularity(scale_granularity, group_size)  # validates the spelling
    if not gran.is_per_tensor and backend != "packed":
        raise ValueError(
            "scale_granularity selects the Φ̂ scale layout of the packed "
            "streaming backend; use backend='packed' (for per-band observation "
            "scaling quantize y up front — see repro.sensing.quantize_observations)")
    if is_linear_operator(phi):
        if bits_phi:
            raise ValueError(
                "bits_phi only applies to dense Φ arrays; a matrix-free operator "
                "owns its representation (quantize inside the operator instead)")
        if backend != "dense":
            raise ValueError(
                "backend='packed' packs a dense Φ array; matrix-free operators "
                "are already their own streaming representation")
    if backend == "packed":
        if not bits_phi:
            raise ValueError("backend='packed' needs bits_phi (it streams packed codes)")
        if requantize != "fixed":
            raise ValueError(
                "backend='packed' is the requantize='fixed' deployment mode; "
                "re-packing fresh codes per iteration would stream MORE bytes "
                "than it saves — use backend='dense' for requantize='pair'")
    if threshold == "hsthresh" and not real_signal:
        raise ValueError("threshold='hsthresh' is the real-signal streaming H_s")


def _solver_setup(
    phi, Y, s, bits_phi, bits_y, key, requantize, backend, threshold,
    c, shrink_k, max_backtracks, real_signal, nonneg, with_trace,
    scale_granularity, group_size,
):
    """Shared prologue of the one-shot core and the segmented runner.

    Returns ``(X0, iteration)`` where ``iteration(X, i)`` is one Algorithm 1
    step at global iteration index ``i``. Everything stochastic — the ŷ draw
    and the per-iteration Φ̂ pair factory — is derived deterministically from
    ``key``, and ``iteration`` consumes the *global* index, so running the
    range [0, n) in one scan or in segments produces bit-identical iterates.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    ky, kphi = jax.random.split(key)

    # One stochastic draw ŷ per problem, all rows folding the same ky so that
    # batch row b reproduces the single-problem run with the same key.
    Yhat = jax.vmap(lambda yy: fake_quantize(yy, bits_y, ky))(Y) if bits_y else Y

    n = phi.shape[1]
    x_dtype = jnp.float32 if real_signal else (
        phi.dtype if jnp.issubdtype(jnp.dtype(phi.dtype), jnp.complexfloating)
        else jnp.float32
    )
    X0 = jnp.zeros((Y.shape[0], n), dtype=x_dtype)
    hs = _make_hs(threshold, s)
    phi_true, get_ops = make_iteration_operators(
        phi, bits_phi, requantize, backend, kphi,
        granularity=as_granularity(scale_granularity, group_size))

    def iteration(X, i):
        op1, op2 = get_ops(i)
        X_new, mu, changed, n_bt = _niht_iteration_batch(
            X, Yhat, op1, op2, s, c, shrink_k, max_backtracks,
            real_signal, nonneg, hs,
        )
        if with_trace:
            rq = jnp.sqrt(_rows_sqnorm(Yhat - op2.mv(X_new)))
            rt = jnp.sqrt(_rows_sqnorm(Y - phi_true.mv(X_new)))
        else:
            # skip the residual matvecs (one of them streams dense f32 Φ —
            # benchmarks disable the trace so the loop is pure algorithm traffic)
            # np-built so the intentional NaN marker is a transfer,
            # not an op that trips jax_debug_nans (see analysis.sanitize)
            # jaxlint: allow=JX104 -- trace-time np constant: XLA folds the device_put and hoists it out of the loop
            rq = rt = jnp.asarray(np.full(X.shape[0], np.nan, np.float32))
        return X_new, (rq, rt, mu, changed, n_bt)

    return X0, iteration


def _qniht_core(
    phi, Y, s, n_iters, bits_phi, bits_y, key, requantize, backend, threshold,
    c, shrink_k, max_backtracks, real_signal, nonneg, with_trace,
    scale_granularity="per_tensor", group_size=None, early_exit=False,
    exit_tol=0.0, unroll=1,
):
    """Shared batched implementation behind qniht / qniht_batch (Y is (B, M)).

    ``early_exit=True`` tracks a per-row convergence flag and, once EVERY row
    of this batch is converged, stops executing iteration bodies: the loop
    over iterations becomes a ``lax.while_loop`` that terminates early and
    the remaining trace rows are broadcast-filled with the stationary row
    (NOT a scan of ``lax.cond`` — under SPMD partitioning XLA rewrites a
    cond into a select that executes both branches, which would silently
    undo the skip; see the comment in the implementation). Two flavours,
    selected by ``exit_tol``:

    * ``exit_tol == 0.0`` (lossless): a row is converged when ``x`` reached a
      bitwise fixed point of the iteration map. Because the map is a
      deterministic function of ``x`` when the per-iteration operators are
      stationary (``requantize="fixed"``, packed, matrix-free, or full
      precision), a bitwise fixed point is absorbing and the recomputed
      ``(mu, changed, backtracks, resid)`` would be identical — so the output
      is bit-for-bit the same as ``early_exit=False``, only cheaper.
    * ``exit_tol > 0.0`` (freeze): a row is *frozen* — its state masked to
      stop updating — once its relative update stalls:
      ``‖x⁺−x‖ ≤ exit_tol·‖x⁺‖`` for ``_EXIT_PATIENCE`` consecutive
      iterations (a single tiny step can be a backtracking artefact, not a
      stall). This catches rows orbiting tiny limit cycles (low-order bits
      oscillating around the noise floor) that never hit an exact fixed
      point. It is a *heuristic* serving trade-off: a row drifting slowly
      toward a support change (a long saddle plateau) can be frozen short of
      the escape the full run would eventually make, so frozen results match
      the full run only up to the quality the stall point already reached —
      the scaling benchmark records recovery error for both paths to keep
      that trade visible. No longer bit-identical to
      ``early_exit=False``, but the rule is deterministic and **row-local**
      (it reads only the row's own trajectory), so results are bit-identical
      across ANY row grouping — single device, any mesh width — at the same
      tolerance.

    This per-row flag is the solver state the sharded serving path splits
    over the device mesh: a shard whose rows all converged stops paying for
    iterations while other shards keep working (:mod:`repro.parallel.batch`).

    ``unroll`` is handed to ``lax.scan`` (identical numerics, fewer dispatch
    boundaries — matters for small per-shard programs on CPU). It applies
    only to the fixed-trip scan: the early-exit while_loop's trip count is
    data-dependent and cannot unroll (validated as mutually exclusive).
    """
    B = Y.shape[0]
    X0, iteration = _solver_setup(
        phi, Y, s, bits_phi, bits_y, key, requantize, backend, threshold,
        c, shrink_k, max_backtracks, real_signal, nonneg, with_trace,
        scale_granularity, group_size)

    if not early_exit:
        X_final, (rq, rt, mus, ch, bt) = jax.lax.scan(
            lambda X, i: iteration(X, i), X0, jnp.arange(n_iters), unroll=unroll)
    else:
        # A while_loop, NOT a scan-of-cond: under SPMD partitioning
        # (shard_map) XLA rewrites a cond into a select that executes BOTH
        # branches, which would silently undo the skip; a loop's trip count
        # cannot be select-ified, so converged shards genuinely stop paying.
        # Trace rows are written into preallocated buffers as iterations
        # execute; the stationary tail is broadcast-filled after the loop.
        def body(st):
            if exit_tol == 0.0:
                # a done row recomputes itself identically (fixed point) —
                # no masking needed, and the no-early-exit output is
                # reproduced bit-for-bit. The lossless carry has no streak
                # component: streak feeds only the stall heuristic below, and
                # carrying it here hauls dead bytes every iteration (JX103).
                k, X, done, prev, bufs = st
                X_new, outs = iteration(X, k)
                bufs = jax.tree_util.tree_map(
                    lambda buf, o: jax.lax.dynamic_update_index_in_dim(buf, o, k, 0),
                    bufs, outs)
                newly = jnp.all(X_new == X, axis=-1)
                return k + 1, X_new, done | newly, outs, bufs
            k, X, done, streak, prev, bufs = st
            X_c, outs_c = iteration(X, k)
            # frozen rows stop updating; their trace re-emits the last
            # live row (deterministic + row-local → grouping-invariant)
            X_new = jnp.where(done[:, None], X, X_c)
            outs = jax.tree_util.tree_map(
                lambda p, n_: jnp.where(done, p, n_), prev, outs_c)
            bufs = jax.tree_util.tree_map(
                lambda buf, o: jax.lax.dynamic_update_index_in_dim(buf, o, k, 0),
                bufs, outs)
            # one sub-tol step can be a backtracking artefact (µ shrunk to
            # a tiny accepted step), not a stall — require _EXIT_PATIENCE
            # consecutive sub-tol updates before freezing the row
            small = _rows_sqnorm(X_new - X) <= (
                exit_tol * exit_tol) * _rows_sqnorm(X_new)
            streak = jnp.where(small, streak + 1, 0)
            newly = streak >= _EXIT_PATIENCE
            return k + 1, X_new, done | newly, streak, outs, bufs

        def cond(st):
            return (st[0] < n_iters) & ~jnp.all(st[2])

        nanrow = jnp.asarray(np.full(B, np.nan, np.float32))  # np-built: see sanitize note above
        prev0 = (nanrow, nanrow, jnp.zeros((B,), jnp.float32),
                 jnp.zeros((B,), bool), jnp.zeros((B,), jnp.int32))
        bufs0 = jax.tree_util.tree_map(
            lambda o: jnp.zeros((n_iters,) + o.shape, o.dtype), prev0)
        init = (jnp.asarray(0, jnp.int32), X0, jnp.zeros((B,), bool),
                jnp.zeros((B,), jnp.int32), prev0, bufs0)
        if exit_tol == 0.0:
            init = init[:3] + init[4:]
        out = jax.lax.while_loop(cond, body, init)
        k_end, X_final, last, bufs = out[0], out[1], out[-2], out[-1]
        # iterations k_end.. would all re-emit the stationary trace row (every
        # row is at a fixed point / frozen), so fill instead of compute
        tail = jnp.arange(n_iters)[:, None] >= k_end
        (rq, rt, mus, ch, bt) = jax.tree_util.tree_map(
            lambda buf, o: jnp.where(tail, o[None, :], buf), bufs, last)
    return IHTResult(
        x=X_final,
        trace=IHTTrace(resid_q=rq, resid_true=rt, mu=mus, support_changed=ch, backtracks=bt),
    )


class SolverState(NamedTuple):
    """Complete solver state at an iteration boundary — the checkpoint unit.

    A registered pytree (NamedTuple of arrays) holding everything the
    iteration map consumes, so ``solver_segment`` can stop after any iteration
    and a later process can resume **bit-identically** — the acceptance bar of
    the preemption-safe recovery path (:mod:`repro.launch.resilience`):

    * ``k``       — () int32, the next iteration index (segments resume here).
    * ``X``       — (B, N) iterate. The support Γ is implicit: ``|X| > 0``
      (plus the top-s-of-gradient rule at ``X == 0``), exactly as the
      iteration body derives it.
    * ``done``    — (B,) per-row convergence flags (the ``early_exit`` state).
    * ``streak``  — (B,) consecutive sub-``exit_tol`` update counters (the
      freeze rule's patience state; all-zero when ``exit_tol == 0``).
    * ``last``    — the last emitted per-row trace row (µ, backtrack counts,
      residuals): what frozen rows re-emit and the stationary tail-fill uses.
    * ``trace``   — (n_iters, B) per-iteration buffers, written for
      iterations ``< k``.
    * ``Y``       — (B, M) raw observations. ŷ and the Φ̂ draws are
      *recomputed* from (``Y``, ``key``) each segment rather than stored —
      they are deterministic functions of both, which keeps the checkpoint
      minimal and the bit-identity contract trivially segmentation-invariant.
    * ``key``     — the run's PRNG key, replicated.

    Every per-row leaf has the batch axis leading (``trace`` second), so the
    sharded path splits the whole state by rows with one spec tree, and a
    checkpoint written at one mesh width restores onto any other (elastic
    resume — pad rows are bitwise fixed points, see
    :func:`repro.parallel.batch.pad_state`).
    """

    k: jax.Array
    X: jax.Array
    done: jax.Array
    streak: jax.Array
    last: IHTTrace
    trace: IHTTrace
    Y: jax.Array
    key: jax.Array


def solver_init(
    phi, Y: jax.Array, s: int, n_iters: int = 50, *,
    bits_phi: Optional[int] = None, bits_y: Optional[int] = None,
    key: Optional[jax.Array] = None, requantize: str = "pair",
    backend: str = "dense", threshold: str = "topk", c: float = 0.01,
    shrink_k: float = 2.0, max_backtracks: int = 30, real_signal: bool = False,
    nonneg: bool = False, with_trace: bool = True,
    scale_granularity: str = "per_tensor", group_size: Optional[int] = None,
    early_exit: bool = False, exit_tol: float = 0.0,
) -> SolverState:
    """Fresh :class:`SolverState` for ``qniht_batch(phi, Y, s, n_iters, ...)``
    run in segments. Same validation and defaults as :func:`qniht_batch`
    (``unroll`` excepted: segments run a ``lax.while_loop``, which cannot
    unroll). Composable under :func:`jax.eval_shape` — that is how the
    checkpoint restore target is built without touching data."""
    if Y.ndim != 2:
        raise ValueError("solver_init expects Y of shape (B, M); wrap one y as y[None]")
    _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold,
              real_signal, scale_granularity, group_size, early_exit, exit_tol)
    B = Y.shape[0]
    x_dtype = jnp.float32 if real_signal else (
        phi.dtype if jnp.issubdtype(jnp.dtype(phi.dtype), jnp.complexfloating)
        else jnp.float32
    )
    # np-built NaN marker: a transfer, not an op, so eager solver_init
    # does not trip jax_debug_nans (repro.analysis.sanitize)
    nanrow = jnp.asarray(np.full(B, np.nan, np.float32))
    last = IHTTrace(resid_q=nanrow, resid_true=nanrow,
                    mu=jnp.zeros((B,), jnp.float32),
                    support_changed=jnp.zeros((B,), bool),
                    backtracks=jnp.zeros((B,), jnp.int32))
    return SolverState(
        k=jnp.zeros((), jnp.int32),
        X=jnp.zeros((B, phi.shape[1]), x_dtype),
        done=jnp.zeros((B,), bool),
        streak=jnp.zeros((B,), jnp.int32),
        last=last,
        trace=jax.tree_util.tree_map(
            lambda o: jnp.zeros((n_iters,) + o.shape, o.dtype), last),
        Y=Y,
        key=key if key is not None else jax.random.PRNGKey(0),
    )


# solver_segment statics: n_iters lives in the trace buffer shape and unroll
# is scan-only, otherwise identical to _STATIC (shared spelling, not copied)
_SEG_STATIC = (
    "n_steps", "s", "bits_phi", "bits_y", "requantize", "backend", "threshold",
    "c", "shrink_k", "max_backtracks", "real_signal", "nonneg", "with_trace",
    "scale_granularity", "group_size", "early_exit", "exit_tol",
)

# one source of truth for the solver-config defaults of the segmented entry
# points (solver_segment keyword defaults and the sharded/resilient drivers)
_SEG_DEFAULTS = dict(
    bits_phi=None, bits_y=None, requantize="pair", backend="dense",
    threshold="topk", c=0.01, shrink_k=2.0, max_backtracks=30,
    real_signal=False, nonneg=False, with_trace=True,
    scale_granularity="per_tensor", group_size=None, early_exit=False,
    exit_tol=0.0,
)


def _segment_core(
    phi, state: SolverState, *, n_steps, s, bits_phi, bits_y, requantize,
    backend, threshold, c, shrink_k, max_backtracks, real_signal, nonneg,
    with_trace, scale_granularity, group_size, early_exit, exit_tol,
) -> SolverState:
    """Advance ``state`` by up to ``n_steps`` iterations (fewer only at the
    horizon). The loop body is the same ``iteration`` closure the one-shot
    core runs — segment boundaries are exact restart points because every
    stochastic input is re-derived from (``Y``, ``key``) and the body consumes
    the global index ``k``.

    Early exit inside a segment: once every row is done, the remaining rows of
    the segment's trace range are *filled* with the stationary row instead of
    computed — bit-identical by the fixed-point/freeze argument in
    :func:`_qniht_core` — so ``k`` always lands on ``min(k + n_steps,
    n_iters)``, uniformly across shards. That keeps ``k`` replicated (the
    sharded path's out-spec) and the state independent of the mesh width it
    was computed on, which is what makes elastic resume possible.
    """
    n_iters = state.trace.mu.shape[0]
    _, iteration = _solver_setup(
        phi, state.Y, s, bits_phi, bits_y, state.key, requantize, backend,
        threshold, c, shrink_k, max_backtracks, real_signal, nonneg, with_trace,
        scale_granularity, group_size)
    k_end = jnp.minimum(state.k + n_steps, n_iters)

    def body(st):
        k, X, done, streak, last, bufs = st
        X_c, outs_c = iteration(X, k)
        if exit_tol == 0.0:
            # a done row recomputes itself identically (fixed point) — no
            # masking needed; see _qniht_core
            X_new, outs = X_c, outs_c
        else:
            X_new = jnp.where(done[:, None], X, X_c)
            outs = jax.tree_util.tree_map(
                lambda p, n_: jnp.where(done, p, n_), tuple(last), outs_c)
        bufs = jax.tree_util.tree_map(
            lambda buf, o: jax.lax.dynamic_update_index_in_dim(buf, o, k, 0),
            tuple(bufs), outs)
        if not early_exit:
            newly = jnp.zeros_like(done)
        elif exit_tol == 0.0:
            newly = jnp.all(X_new == X, axis=-1)
        else:
            small = _rows_sqnorm(X_new - X) <= (
                exit_tol * exit_tol) * _rows_sqnorm(X_new)
            streak = jnp.where(small, streak + 1, 0)
            newly = streak >= _EXIT_PATIENCE
        return k + 1, X_new, done | newly, streak, IHTTrace(*outs), IHTTrace(*bufs)

    def cond(st):
        k, _, done, _, _, _ = st
        live = k < k_end
        return live & ~jnp.all(done) if early_exit else live

    k_stop, X, done, streak, last, bufs = jax.lax.while_loop(
        cond, body,
        (state.k, state.X, state.done, state.streak, state.last, state.trace))
    if early_exit:
        # rows the early exit skipped would all re-emit the stationary row
        rows = jnp.arange(n_iters)[:, None]
        fill = (rows >= k_stop) & (rows < k_end)
        bufs = jax.tree_util.tree_map(
            lambda buf, o: jnp.where(fill, o[None, :], buf), bufs, last)
    return SolverState(k=k_end, X=X, done=done, streak=streak, last=last,
                       trace=bufs, Y=state.Y, key=state.key)


_segment_jit = partial(jax.jit, static_argnames=_SEG_STATIC)(_segment_core)


def solver_segment(
    phi, state: SolverState, n_steps: int, *, s: int,
    bits_phi: Optional[int] = None, bits_y: Optional[int] = None,
    requantize: str = "pair", backend: str = "dense", threshold: str = "topk",
    c: float = 0.01, shrink_k: float = 2.0, max_backtracks: int = 30,
    real_signal: bool = False, nonneg: bool = False, with_trace: bool = True,
    scale_granularity: str = "per_tensor", group_size: Optional[int] = None,
    early_exit: bool = False, exit_tol: float = 0.0,
) -> SolverState:
    """Run one segment of ``n_steps`` iterations (single-process path).

    Contract: for any split of ``[0, n_iters)`` into segments,
    ``solver_result`` of the final state is **bit-identical** to
    ``qniht_batch(phi, Y, ...)`` with the same arguments — the deterministic
    iteration map makes every segment boundary an exact restart point. The
    solver configuration must be passed identically to every call (it is
    static; :mod:`repro.launch.resilience` owns that bookkeeping and persists
    the state between segments through :mod:`repro.train.checkpoint`). The
    sharded equivalent is :func:`repro.parallel.batch.sharded_segment_run`.
    """
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    _validate(phi, bits_phi, bits_y, state.key, requantize, backend, threshold,
              real_signal, scale_granularity, group_size, early_exit, exit_tol)
    return _segment_jit(
        phi, state, n_steps=n_steps, s=s, bits_phi=bits_phi, bits_y=bits_y,
        requantize=requantize, backend=backend, threshold=threshold, c=c,
        shrink_k=shrink_k, max_backtracks=max_backtracks,
        real_signal=real_signal, nonneg=nonneg, with_trace=with_trace,
        scale_granularity=scale_granularity, group_size=group_size,
        early_exit=early_exit, exit_tol=exit_tol)


def solver_result(state: SolverState) -> IHTResult:
    """Wrap a :class:`SolverState` as the usual :class:`IHTResult`.

    Trace rows at iterations ``>= state.k`` (a run finalized before the
    horizon — e.g. a preempted partial result) are filled with the stationary
    last row, matching the early-exit tail-fill convention."""
    n_iters = state.trace.mu.shape[0]
    tail = jnp.arange(n_iters)[:, None] >= state.k
    trace = jax.tree_util.tree_map(
        lambda buf, o: jnp.where(tail, o[None, :], buf), state.trace, state.last)
    return IHTResult(x=state.X, trace=trace)


_STATIC = (
    "s", "n_iters", "bits_phi", "bits_y", "requantize", "backend", "threshold",
    "c", "shrink_k", "max_backtracks", "real_signal", "nonneg", "with_trace",
    "scale_granularity", "group_size", "early_exit", "exit_tol", "unroll",
)


@partial(jax.jit, static_argnames=_STATIC)
def qniht(
    phi: jax.Array,
    y: jax.Array,
    s: int,
    n_iters: int = 50,
    *,
    bits_phi: Optional[int] = None,
    bits_y: Optional[int] = None,
    key: Optional[jax.Array] = None,
    requantize: str = "pair",
    backend: str = "dense",
    threshold: str = "topk",
    c: float = 0.01,
    shrink_k: float = 2.0,
    max_backtracks: int = 30,
    real_signal: bool = False,
    nonneg: bool = False,
    with_trace: bool = True,
    scale_granularity: str = "per_tensor",
    group_size: Optional[int] = None,
    early_exit: bool = False,
    exit_tol: float = 0.0,
    unroll: int = 1,
) -> IHTResult:
    """Low-precision NIHT (Algorithm 1). ``bits_phi=bits_y=None`` → plain NIHT.

    Args:
      phi: (M, N) measurement matrix (real or complex), or any matrix-free
        operator following the :mod:`repro.core.operators` protocol
        (``mv``/``rmv``/``shape``/``dtype``) — e.g.
        :class:`~repro.core.operators.SubsampledFourierOperator` for MRI, where
        a dense Φ would be gigabytes. Operator inputs require the default
        ``bits_phi=None``/``backend="dense"`` (the operator owns its own data
        representation); ``bits_y`` still quantizes the observations.
      y: (M,) observations.
      s: sparsity level.
      bits_phi / bits_y: data precision (2/4/8) or None for full precision.
      key: PRNG key for stochastic quantization (required when quantizing).
      requantize: "pair" (fresh Φ̂_{2n-1}, Φ̂_{2n} each iteration — Algorithm 1) or
        "fixed" (quantize once; what a deployed system streaming pre-quantized
        data does).
      backend: "dense" (fake-quantized f32 compute) or "packed" (stream packed
        uint8 codes through the Pallas qmm kernels; requires bits_phi and
        requantize="fixed" — same codes as the dense fixed path, 32/bits× fewer
        operator bytes per application). See the module docstring.
      threshold: "topk" (exact H_s) or "hsthresh" (streaming histogram H_s,
        real-signal path; support ≤ s).
      real_signal / nonneg: optional projections (sky images are real, >= 0).
      with_trace: compute per-iteration residual norms (costs one extra Φ̂ and
        one dense Φ matvec per iteration; disable for timing runs).
      scale_granularity / group_size: scale layout of the packed Φ̂ stream
        ("per_tensor" — the paper's single c_Φ, bit-identical to the historical
        behaviour; "per_channel"; "per_block" with ``group_size``). Group
        granularities quantize each orientation separately (packed backend
        only); see :mod:`repro.quant.formats` for layout and overhead.
      early_exit: skip remaining iteration bodies once x reaches a bitwise
        fixed point (stationary operators only — bit-identical output, see
        :func:`_qniht_core`).
    """
    if y.ndim != 1:
        raise ValueError(
            f"qniht expects y of shape (M,), got ndim={y.ndim}; "
            "use qniht_batch for a (B, M) stack of observations")
    _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold, real_signal,
              scale_granularity, group_size, early_exit, exit_tol, unroll)
    res = _qniht_core(
        phi, y[None, :], s, n_iters, bits_phi, bits_y, key, requantize, backend,
        threshold, c, shrink_k, max_backtracks, real_signal, nonneg, with_trace,
        scale_granularity, group_size, early_exit, exit_tol, unroll,
    )
    return IHTResult(
        x=res.x[0],
        trace=jax.tree_util.tree_map(lambda t: t[:, 0], res.trace),
    )


@partial(jax.jit, static_argnames=_STATIC)
def qniht_batch(
    phi: jax.Array,
    Y: jax.Array,
    s: int,
    n_iters: int = 50,
    *,
    bits_phi: Optional[int] = None,
    bits_y: Optional[int] = None,
    key: Optional[jax.Array] = None,
    requantize: str = "pair",
    backend: str = "dense",
    threshold: str = "topk",
    c: float = 0.01,
    shrink_k: float = 2.0,
    max_backtracks: int = 30,
    real_signal: bool = False,
    nonneg: bool = False,
    with_trace: bool = True,
    scale_granularity: str = "per_tensor",
    group_size: Optional[int] = None,
    early_exit: bool = False,
    exit_tol: float = 0.0,
    unroll: int = 1,
) -> IHTResult:
    """Recover B observation vectors of the same Φ at once (heavy-traffic mode).

    ``Y`` is (B, M); returns x of shape (B, N) and trace arrays (n_iters, B).
    ``phi`` may be a dense (M, N) array or a matrix-free operator, exactly as
    in :func:`qniht` (operator ``mv``/``rmv`` batch over the leading axis).
    One quantized/packed Φ̂ serves the whole batch: each iteration's matvecs are
    single (B, ·) matmuls / qmm kernel calls, so the Φ̂ bytes stream ONCE per
    application for all B problems — with ``backend="packed"`` the amortized
    traffic per problem is ``size(Φ̂_packed)/B``. Per-problem step sizes,
    acceptance tests, and backtracking are vmapped row logic. Row ``b`` matches
    ``qniht(phi, Y[b], ..., key=key)`` up to f32 accumulation order (defaults
    included: both sides default to ``requantize="pair"``; the packed backend
    requires ``requantize="fixed"`` explicitly, same as ``qniht``).

    ``early_exit=True`` skips remaining iteration bodies once EVERY row has
    reached a bitwise fixed point — bit-identical output, cheaper tail
    (stationary operators only; see :func:`_qniht_core`). Most valuable
    through :func:`qniht_batch_sharded`, where the all-rows condition is per
    shard rather than per batch.
    """
    if Y.ndim != 2:
        raise ValueError("qniht_batch expects Y of shape (B, M); use qniht for one y")
    _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold, real_signal,
              scale_granularity, group_size, early_exit, exit_tol, unroll)
    return _qniht_core(
        phi, Y, s, n_iters, bits_phi, bits_y, key, requantize, backend,
        threshold, c, shrink_k, max_backtracks, real_signal, nonneg, with_trace,
        scale_granularity, group_size, early_exit, exit_tol, unroll,
    )


def qniht_batch_sharded(
    phi,
    Y: jax.Array,
    s: int,
    n_iters: int = 50,
    *,
    mesh=None,
    n_devices: Optional[int] = None,
    bits_phi: Optional[int] = None,
    bits_y: Optional[int] = None,
    key: Optional[jax.Array] = None,
    requantize: str = "pair",
    backend: str = "dense",
    threshold: str = "topk",
    c: float = 0.01,
    shrink_k: float = 2.0,
    max_backtracks: int = 30,
    real_signal: bool = False,
    nonneg: bool = False,
    with_trace: bool = True,
    scale_granularity: str = "per_tensor",
    group_size: Optional[int] = None,
    early_exit: bool = True,
    exit_tol: float = 0.0,
    unroll: int = 1,
) -> IHTResult:
    """:func:`qniht_batch` with the B axis split over a 1-D ``batch`` device
    mesh — the multi-device serving mode.

    ``mesh`` is a 1-D :class:`jax.sharding.Mesh` whose sole axis is named
    ``"batch"`` (default: all local devices via
    :func:`repro.parallel.batch.make_batch_mesh`; ``n_devices`` limits the
    count). ``Y`` is sharded by rows, Φ̂'s codes/scales (or the matrix-free
    operator's parameters) are replicated, and every piece of per-item solver
    state — ``x``, support, step size µ, backtrack counters, convergence
    flags — lives with its rows. B need not divide the mesh: rows are
    zero-padded to the next multiple (an all-zero row converges at iteration
    0, so padding never delays a shard) and the padding is stripped from the
    result.

    Contract: item ``b`` computes exactly what ``qniht_batch(phi, Y, ...)``
    computes on one device — same quantization draws (the key is replicated
    and every row folds it exactly as the single-device path does), same
    per-item iterates, up to f32 batching accumulation (the hedge the
    ``qniht_batch`` ↔ ``qniht`` row contract has always carried: results are
    bitwise identical whenever XLA's batched ops are batching-invariant at
    the problem shape, which the test suite pins on an 8-device mesh, and
    differ by ULPs otherwise). Sharding changes only WHERE rows are
    computed, plus the ``early_exit`` default (True here: per-shard
    convergence is the point — a shard of converged rows stops iterating
    instead of riding along with the slowest item in the global batch; see
    :func:`_qniht_core`).

    All other arguments exactly as :func:`qniht_batch`, and every backend
    works sharded: dense, fake-quant, packed (all scale granularities), and
    matrix-free operators (Fourier, composed wavelet) — dispatch goes through
    :func:`repro.core.operators.make_iteration_operators` inside each shard.
    """
    if Y.ndim != 2:
        raise ValueError("qniht_batch_sharded expects Y of shape (B, M)")
    _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold, real_signal,
              scale_granularity, group_size, early_exit, exit_tol, unroll)
    from repro.parallel.batch import sharded_qniht_run

    return sharded_qniht_run(
        phi, Y, key, mesh=mesh, n_devices=n_devices, s=s, n_iters=n_iters,
        bits_phi=bits_phi, bits_y=bits_y, requantize=requantize, backend=backend,
        threshold=threshold, c=c, shrink_k=shrink_k, max_backtracks=max_backtracks,
        real_signal=real_signal, nonneg=nonneg, with_trace=with_trace,
        scale_granularity=scale_granularity, group_size=group_size,
        early_exit=early_exit, exit_tol=exit_tol, unroll=unroll,
    )


def niht(phi, y, s, n_iters=50, **kw) -> IHTResult:
    """Full-precision NIHT (the paper's baseline, Theorem 2 algorithm)."""
    return qniht(phi, y, s, n_iters, bits_phi=None, bits_y=None, **kw)


def stopping_iterations(xs_norm: float, eps_s: float) -> int:
    """Paper's natural stopping criterion n* = ceil(log2(||x^s|| / eps_s))."""
    import math

    if eps_s <= 0 or xs_norm <= 0:
        return 1
    return max(1, math.ceil(math.log2(xs_norm / eps_s)))
