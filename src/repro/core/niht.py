"""Normalized Iterative Hard Thresholding — full precision and quantized (QNIHT).

Implements the paper's Algorithm 1 faithfully:

* adaptive step size  ``µ = ||g_Γ||² / ||Φ̂ g_Γ||²``  on the current support Γ,
* proposal ``x⁺ = H_s(x + µ g)`` with ``g = Φ̂₁†(ŷ − Φ̂₂ x)``,
* if the support changes, accept only when ``µ ≤ (1−c)·ω`` with
  ``ω = ||x⁺−x||² / ||Φ̂₁(x⁺−x)||²``; otherwise shrink ``µ ← µ/(k(1−c))`` and
  re-propose (``lax.while_loop`` backtracking),
* fresh unbiased stochastic quantizations ``Φ̂_{2n-1}, Φ̂_{2n}`` per iteration
  (``requantize="pair"``) or a single fixed quantization (``requantize="fixed"`` —
  what the CPU/FPGA systems actually stream, since data arrives pre-quantized).

Everything is a ``lax.scan`` over iterations → one compiled program, traces out.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.threshold import hard_threshold, top_s_mask
from repro.quant.quantize import fake_quantize


class IHTTrace(NamedTuple):
    """Per-iteration diagnostics (arrays of length n_iters)."""

    resid_q: jax.Array        # ||ŷ − Φ̂ x||₂ (the cost the algorithm minimizes)
    resid_true: jax.Array     # ||y − Φ x||₂ against full-precision data
    mu: jax.Array             # accepted step size
    support_changed: jax.Array
    backtracks: jax.Array


class IHTResult(NamedTuple):
    x: jax.Array
    trace: IHTTrace


def _sqnorm(v: jax.Array) -> jax.Array:
    return jnp.real(jnp.vdot(v, v))


def _project(a: jax.Array, real_signal: bool, nonneg: bool) -> jax.Array:
    if real_signal:
        a = jnp.real(a)
        if nonneg:
            a = jnp.maximum(a, 0.0)
    return a


def niht_iteration(
    x: jax.Array,
    y_hat: jax.Array,
    phi1_mv: Callable[[jax.Array], jax.Array],
    phi1_rmv: Callable[[jax.Array], jax.Array],
    phi2_mv: Callable[[jax.Array], jax.Array],
    s: int,
    c: float,
    shrink_k: float,
    max_backtracks: int,
    real_signal: bool,
    nonneg: bool,
):
    """One NIHT step (Algorithm 1 body). Returns (x_new, mu, changed, n_backtracks).

    ``phi1_*`` is Φ̂_{2n-1} (gradient / step-size / acceptance matrix), ``phi2_mv``
    is Φ̂_{2n} (residual matrix), matching the paper's pairing.
    """
    eps = jnp.asarray(1e-30, jnp.float32)
    r = y_hat - phi2_mv(x)
    g = phi1_rmv(r)

    # Γ: support of x, or (first iteration, x = 0) the top-s of the first gradient.
    on_init = _sqnorm(x) == 0.0
    mask_x = jnp.abs(x) > 0
    mask_g = top_s_mask(g, s)
    gamma_mask = jnp.where(on_init, mask_g, mask_x)

    g_gamma = jnp.where(gamma_mask, g, jnp.zeros_like(g))
    mu0 = _sqnorm(g_gamma) / (_sqnorm(phi1_mv(g_gamma)) + eps)

    def propose(mu):
        a = x.astype(g.dtype) + mu * g
        a = _project(a, real_signal, nonneg).astype(x.dtype)
        return hard_threshold(a, s)

    def accept(mu, x_prop):
        new_mask = jnp.abs(x_prop) > 0
        same = jnp.all(new_mask == gamma_mask)
        diff = x_prop - x
        omega = _sqnorm(diff) / (_sqnorm(phi1_mv(diff)) + eps)
        return same | (mu <= (1.0 - c) * omega)

    x0 = propose(mu0)

    def cond(carry):
        mu, x_prop, it = carry
        return (~accept(mu, x_prop)) & (it < max_backtracks)

    def body(carry):
        mu, _, it = carry
        mu = mu / (shrink_k * (1.0 - c))
        return mu, propose(mu), it + 1

    mu, x_new, n_bt = jax.lax.while_loop(cond, body, (mu0, x0, jnp.asarray(0)))
    changed = ~jnp.all((jnp.abs(x_new) > 0) == gamma_mask)
    return x_new, mu, changed, n_bt


def _dense_ops(mat: jax.Array):
    mv = lambda v: mat @ v
    rmv = lambda r: jnp.conj(mat.T) @ r if jnp.iscomplexobj(mat) else mat.T @ r
    return mv, rmv


@partial(
    jax.jit,
    static_argnames=(
        "s", "n_iters", "bits_phi", "bits_y", "requantize", "c", "shrink_k",
        "max_backtracks", "real_signal", "nonneg",
    ),
)
def qniht(
    phi: jax.Array,
    y: jax.Array,
    s: int,
    n_iters: int = 50,
    *,
    bits_phi: Optional[int] = None,
    bits_y: Optional[int] = None,
    key: Optional[jax.Array] = None,
    requantize: str = "pair",
    c: float = 0.01,
    shrink_k: float = 2.0,
    max_backtracks: int = 30,
    real_signal: bool = False,
    nonneg: bool = False,
) -> IHTResult:
    """Low-precision NIHT (Algorithm 1). ``bits_phi=bits_y=None`` → plain NIHT.

    Args:
      phi: (M, N) measurement matrix (real or complex).
      y: (M,) observations.
      s: sparsity level.
      bits_phi / bits_y: data precision (2/4/8) or None for full precision.
      key: PRNG key for stochastic quantization (required when quantizing).
      requantize: "pair" (fresh Φ̂_{2n-1}, Φ̂_{2n} each iteration — Algorithm 1) or
        "fixed" (quantize once; what a deployed system streaming pre-quantized
        data does).
      real_signal / nonneg: optional projections (sky images are real, >= 0).
    """
    if (bits_phi or bits_y) and key is None:
        raise ValueError("quantized NIHT needs a PRNG key")
    key = key if key is not None else jax.random.PRNGKey(0)
    ky, kphi = jax.random.split(key)

    y_hat = fake_quantize(y, bits_y, ky) if bits_y else y
    phi_fixed = (
        fake_quantize(phi, bits_phi, jax.random.fold_in(kphi, 0))
        if (bits_phi and requantize == "fixed")
        else phi
    )

    n = phi.shape[1]
    x_dtype = jnp.float32 if real_signal else (
        phi.dtype if jnp.iscomplexobj(phi) else jnp.float32
    )
    x0 = jnp.zeros((n,), dtype=x_dtype)
    phi_mv_true, _ = _dense_ops(phi)

    def step(x, i):
        if bits_phi and requantize == "pair":
            k1 = jax.random.fold_in(kphi, 2 * i)
            k2 = jax.random.fold_in(kphi, 2 * i + 1)
            phi1 = fake_quantize(phi, bits_phi, k1)
            phi2 = fake_quantize(phi, bits_phi, k2)
        else:
            phi1 = phi2 = phi_fixed
        p1_mv, p1_rmv = _dense_ops(phi1)
        p2_mv, _ = _dense_ops(phi2)
        x_new, mu, changed, n_bt = niht_iteration(
            x, y_hat, p1_mv, p1_rmv, p2_mv, s, c, shrink_k, max_backtracks,
            real_signal, nonneg,
        )
        tr = (
            jnp.sqrt(_sqnorm(y_hat - p2_mv(x_new))),
            jnp.sqrt(_sqnorm(y - phi_mv_true(x_new))),
            mu,
            changed,
            n_bt,
        )
        return x_new, tr

    x_final, (rq, rt, mus, ch, bt) = jax.lax.scan(step, x0, jnp.arange(n_iters))
    return IHTResult(
        x=x_final,
        trace=IHTTrace(resid_q=rq, resid_true=rt, mu=mus, support_changed=ch, backtracks=bt),
    )


def niht(phi, y, s, n_iters=50, **kw) -> IHTResult:
    """Full-precision NIHT (the paper's baseline, Theorem 2 algorithm)."""
    return qniht(phi, y, s, n_iters, bits_phi=None, bits_y=None, **kw)


def stopping_iterations(xs_norm: float, eps_s: float) -> int:
    """Paper's natural stopping criterion n* = ceil(log2(||x^s|| / eps_s))."""
    import math

    if eps_s <= 0 or xs_norm <= 0:
        return 1
    return max(1, math.ceil(math.log2(xs_norm / eps_s)))
