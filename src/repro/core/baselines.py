"""Baseline sparse-recovery algorithms the paper compares against (Fig. 4, Fig. 9).

* :func:`iht` — classic IHT, unit step on a spectrally-normalized matrix.
* :func:`cosamp` — Compressive Sampling Matching Pursuit (Needell & Tropp).
* :func:`fista_l1` — ℓ1 convex relaxation via FISTA (complex soft thresholding).
* :func:`clean` — Högbom CLEAN (radio-astronomy deconvolution, supplementary §7.5).

All are jit-compiled ``lax.scan`` loops so they benchmark on equal footing.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.threshold import hard_threshold, top_s_mask


def _sqnorm(v):
    return jnp.real(jnp.vdot(v, v))


def _rmv(phi, r):
    return jnp.conj(phi.T) @ r if jnp.iscomplexobj(phi) else phi.T @ r


def spectral_norm(phi: jax.Array, iters: int = 30, key=None) -> jax.Array:
    """||Φ||₂ by power iteration on Φ†Φ."""
    key = key if key is not None else jax.random.PRNGKey(7)
    v = jax.random.normal(key, (phi.shape[1],), dtype=jnp.float32)
    if jnp.iscomplexobj(phi):
        v = v.astype(phi.dtype)

    def body(v, _):
        w = _rmv(phi, phi @ v)
        return w / (jnp.sqrt(_sqnorm(w)) + 1e-30), None

    v, _ = jax.lax.scan(body, v / jnp.sqrt(_sqnorm(v)), None, length=iters)
    return jnp.sqrt(_sqnorm(phi @ v))


@partial(jax.jit, static_argnames=("s", "n_iters", "real_signal"))
def iht(phi, y, s, n_iters=50, real_signal=False):
    """Traditional IHT (µ = 1). Requires ||Φ||₂ < 1 — we rescale internally
    (Remark 1: rescaling Φ and y together leaves the problem unchanged)."""
    nrm = spectral_norm(phi)
    scale = 1.0 / (nrm * 1.01)
    phi_s = phi * scale
    y_s = y * scale
    x0 = jnp.zeros((phi.shape[1],), dtype=jnp.float32 if real_signal else y.dtype)

    def step(x, _):
        g = _rmv(phi_s, y_s - phi_s @ x)
        a = x.astype(g.dtype) + g
        if real_signal:
            a = jnp.real(a)
        x_new = hard_threshold(a.astype(x.dtype), s)
        return x_new, jnp.sqrt(_sqnorm(y - phi @ x_new))

    x, resid = jax.lax.scan(step, x0, None, length=n_iters)
    return x, resid


@partial(jax.jit, static_argnames=("s", "n_iters", "real_signal"))
def cosamp(phi, y, s, n_iters=20, real_signal=False):
    """CoSaMP with fixed-size candidate supports (jit-friendly).

    Candidate set = top-2s of the proxy ∪ current support (as 3s gathered
    columns; duplicated columns are resolved by scatter-add after the ridge
    least-squares, which preserves the fitted contribution).
    """
    m, n = phi.shape
    x0 = jnp.zeros((n,), dtype=jnp.float32 if real_signal else y.dtype)

    def step(x, _):
        r = y - phi @ x
        g = _rmv(phi, r)
        _, idx_g = jax.lax.top_k(jnp.abs(g), 2 * s)
        _, idx_x = jax.lax.top_k(jnp.abs(x), s)
        idx = jnp.concatenate([idx_g, idx_x])          # (3s,) may contain dups
        cols = jnp.take(phi, idx, axis=1)               # (M, 3s)
        a = jnp.conj(cols.T) @ cols
        a = a + 1e-6 * jnp.trace(a).real / (3 * s) * jnp.eye(3 * s, dtype=a.dtype)
        b = jnp.linalg.solve(a, jnp.conj(cols.T) @ y)
        full = jnp.zeros((n,), dtype=b.dtype).at[idx].add(b)
        if real_signal:
            full = jnp.real(full)
        x_new = hard_threshold(full.astype(x.dtype), s)
        return x_new, jnp.sqrt(_sqnorm(y - phi @ x_new))

    x, resid = jax.lax.scan(step, x0, None, length=n_iters)
    return x, resid


@partial(jax.jit, static_argnames=("n_iters", "real_signal"))
def fista_l1(phi, y, lam=None, n_iters=100, real_signal=False):
    """FISTA on  ½||y − Φx||² + λ||x||₁  (complex soft-thresholding)."""
    l_lip = spectral_norm(phi) ** 2
    g0 = _rmv(phi, y)
    if lam is None:
        lam = 0.01 * jnp.max(jnp.abs(g0))
    step_t = 1.0 / (l_lip + 1e-30)
    n = phi.shape[1]
    dtype = jnp.float32 if real_signal else (g0.dtype)
    x0 = jnp.zeros((n,), dtype=dtype)

    def soft(w, t):
        mag = jnp.abs(w)
        return w * jnp.maximum(mag - t, 0.0) / jnp.maximum(mag, 1e-30)

    def step(carry, _):
        x, z, t = carry
        grad = _rmv(phi, phi @ z - y)
        w = z.astype(grad.dtype) - step_t * grad
        if real_signal:
            w = jnp.real(w)
        x_new = soft(w, step_t * lam).astype(dtype)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = x_new + ((t - 1.0) / t_new) * (x_new - x)
        return (x_new, z_new, t_new), jnp.sqrt(_sqnorm(y - phi @ x_new))

    (x, _, _), resid = jax.lax.scan(step, (x0, x0, jnp.float32(1.0)), None, length=n_iters)
    return x, resid


@partial(jax.jit, static_argnames=("n_iters",))
def clean(dirty_image, dirty_beam, gain=0.1, n_iters=200, threshold=0.0):
    """Högbom CLEAN on an (r, r) dirty image with an (r, r) dirty beam
    (beam peak at the center pixel; shifts are periodic via roll — standard
    for the synthetic benchmark). Returns the CLEAN component image.

    The paper's supplementary (Fig. 9) shows CLEAN ≈ the first IHT iteration
    and that it picks up noise artifacts as sources at 0 dB SNR.
    """
    r = dirty_image.shape[0]
    beam = dirty_beam / jnp.max(jnp.abs(dirty_beam))
    center = r // 2

    def step(carry, _):
        resid, comps = carry
        flat = jnp.abs(resid).ravel()
        p = jnp.argmax(flat)
        pi, pj = p // r, p % r
        peak = resid[pi, pj]
        active = jnp.abs(peak) > threshold
        amount = jnp.where(active, gain * peak, 0.0)
        shifted = jnp.roll(beam, (pi - center, pj - center), axis=(0, 1))
        resid = resid - amount * shifted
        comps = comps.at[pi, pj].add(amount)
        return (resid, comps), jnp.max(jnp.abs(resid))

    (resid, comps), peaks = jax.lax.scan(
        step, (dirty_image, jnp.zeros_like(dirty_image)), None, length=n_iters
    )
    return comps, resid, peaks
