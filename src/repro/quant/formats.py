"""Numeric formats for low-precision data representation.

The paper (Remark 3) uses a symmetric grid with an *odd* number of levels so that
zero is exactly representable and FPGA fixed-point arithmetic stays symmetric:

    levels  L(b) = 2^(b-1) + 1     equally spaced on [-1, 1]
    half-range steps  K(b) = 2^(b-2) ... more precisely K = (L-1)/2 = 2^(b-2) * 2 / 2

i.e. integer code ``k`` in ``[-K, +K]`` with value ``scale * k / K`` where
``K = 2^(b-1) / 2 = 2^(b-2+1)/2``.  Concretely::

    b=2 -> L=3,   K=1,  codes {-1, 0, +1}          (ternary)
    b=4 -> L=9,   K=4,  codes {-4 ... +4}
    b=8 -> L=129, K=64, codes {-64 ... +64}

The inter-level spacing is ``Delta = scale / K = scale / 2^(b-2) / 2`` and matches
Lemma 4's bound ``E||Q(v)-v||_2 <= c_v * sqrt(M) / 2^(b-1)`` exactly
(per-element worst expected error = Delta/2 = scale/2^(b-1)).

Codes always fit two's-complement ``b`` bits (|k| <= 2^(b-2)*2 <= 2^(b-1)-? ...
b=2: |k|<=1 < 2; b=4: |k|<=4 < 8; b=8: |k|<=64 < 128), so packed storage uses
exactly ``b`` bits per value.
"""
from __future__ import annotations

import dataclasses

SUPPORTED_BITS = (2, 4, 8)


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """A symmetric odd-level integer format with ``bits`` bits per value."""

    bits: int

    def __post_init__(self):
        if self.bits not in SUPPORTED_BITS:
            raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {self.bits}")

    @property
    def levels(self) -> int:
        """Number of representable levels (odd)."""
        return 2 ** (self.bits - 1) + 1

    @property
    def half_steps(self) -> int:
        """K: number of positive steps; codes live in [-K, K] (K = 2^(b-1)/2)."""
        return 2 ** (self.bits - 1) // 2

    @property
    def values_per_byte(self) -> int:
        return 8 // self.bits

    @property
    def code_min(self) -> int:
        return -self.half_steps

    @property
    def code_max(self) -> int:
        return self.half_steps

    def expected_error_bound(self, scale: float, m: int) -> float:
        """Lemma 4: E||Q(v) - v||_2 <= c_v * sqrt(M) / 2^(b-1)."""
        return scale * (m ** 0.5) / (2 ** (self.bits - 1))


INT2 = QuantFormat(2)
INT4 = QuantFormat(4)
INT8 = QuantFormat(8)

BY_BITS = {2: INT2, 4: INT4, 8: INT8}
