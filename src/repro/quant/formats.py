"""Numeric formats for low-precision data representation.

The paper (Remark 3) uses a symmetric grid with an *odd* number of levels so that
zero is exactly representable and FPGA fixed-point arithmetic stays symmetric:

    levels  L(b) = 2^(b-1) + 1     equally spaced on [-1, 1]
    half-range steps  K(b) = 2^(b-2) ... more precisely K = (L-1)/2 = 2^(b-2) * 2 / 2

i.e. integer code ``k`` in ``[-K, +K]`` with value ``scale * k / K`` where
``K = 2^(b-1) / 2 = 2^(b-2+1)/2``.  Concretely::

    b=2 -> L=3,   K=1,  codes {-1, 0, +1}          (ternary)
    b=4 -> L=9,   K=4,  codes {-4 ... +4}
    b=8 -> L=129, K=64, codes {-64 ... +64}

The inter-level spacing is ``Delta = scale / K = scale / 2^(b-2) / 2`` and matches
Lemma 4's bound ``E||Q(v)-v||_2 <= c_v * sqrt(M) / 2^(b-1)`` exactly
(per-element worst expected error = Delta/2 = scale/2^(b-1)).

Codes always fit two's-complement ``b`` bits (|k| <= 2^(b-2)*2 <= 2^(b-1)-? ...
b=2: |k|<=1 < 2; b=4: |k|<=4 < 8; b=8: |k|<=64 < 128), so packed storage uses
exactly ``b`` bits per value.

Scaling granularity & storage layout
------------------------------------
(guide with examples: ``docs/quantization.md``)


The paper's Q_b uses ONE scale per tensor (c_Φ, c_y). That single scale is what
collapses aggressive bit-widths on high-dynamic-range data (k-space: huge DC
energy, tiny high frequencies — see BENCH_mri.json int4/int2), so the scale may
instead be carried at three :class:`Granularity` levels, always along the
**last axis** (the contraction/packing axis of the matmuls):

* ``per_tensor``            — scale is a scalar. Bit-identical to the historical
  behaviour; what the paper's Lemma 4 / Theorem 3 constants (c_v) assume.
* ``per_channel`` (per_row) — one scale per leading index, i.e. the scale array
  has the tensor's shape with the last axis reduced to 1 (keepdims). For an
  (N, K) weight matrix this is one scale per output channel N.
* ``per_block(g)``          — the last axis is split into ⌈n/g⌉ contiguous
  groups of ``g`` elements (the final group may be short); the scale array has
  shape ``(..., ⌈n/g⌉)``. Element ``v[..., j]`` dequantizes with
  ``scale[..., j // g]``.

Storage layout for packed per_block data: codes are packed along the last axis
exactly as per_tensor (``pack_codes``), and the scale vector rides alongside as
f32 — ``4·⌈n/g⌉`` extra bytes per row, i.e. a ``32/(g·bits)`` relative stream
overhead (g=64 @ 4 bits: +1.6%). ``g`` must be a multiple of the packing word
(``8//bits`` values per byte) so no packed byte straddles two scale groups.

Lemma 4's per-element bound sharpens per block: ``|Q(v)-v| <= scale_blk /
2^(b-1)`` with ``scale_blk = max|v_blk|`` the *local* dynamic range.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

SUPPORTED_BITS = (2, 4, 8)

GRANULARITY_KINDS = ("per_tensor", "per_channel", "per_block")


@dataclasses.dataclass(frozen=True)
class Granularity:
    """How many quantization scales a tensor carries (see module docstring).

    ``kind`` is one of ``per_tensor`` / ``per_channel`` / ``per_block``;
    ``group_size`` is required (and only meaningful) for ``per_block``.
    Hashable and immutable so it can travel as a jit-static argument and as
    pytree aux data.
    """

    kind: str = "per_tensor"
    group_size: Optional[int] = None

    def __post_init__(self):
        if self.kind not in GRANULARITY_KINDS:
            raise ValueError(
                f"granularity kind must be one of {GRANULARITY_KINDS}, got {self.kind!r}")
        if self.kind == "per_block":
            if not isinstance(self.group_size, int) or self.group_size < 1:
                raise ValueError(
                    f"per_block needs a positive integer group_size, got {self.group_size!r}")
        elif self.group_size is not None:
            raise ValueError(f"group_size only applies to per_block, got kind={self.kind!r}")

    @property
    def is_per_tensor(self) -> bool:
        return self.kind == "per_tensor"

    def n_groups(self, n: int) -> int:
        """Number of scale entries along a last axis of length ``n``."""
        if self.kind == "per_tensor":
            return 1
        if self.kind == "per_channel":
            return 1  # per leading index; the last axis itself holds one group
        return (n + self.group_size - 1) // self.group_size

    def scale_nbytes(self, shape) -> int:
        """Bytes of f32 scale data carried for a tensor of ``shape``."""
        if self.kind == "per_tensor":
            return 4
        lead = 1
        for d in shape[:-1]:
            lead *= d
        return 4 * lead * self.n_groups(shape[-1])

    def __str__(self) -> str:
        if self.kind == "per_block":
            return f"per_block:{self.group_size}"
        return self.kind


PER_TENSOR = Granularity("per_tensor")
PER_CHANNEL = Granularity("per_channel")


def per_block(group_size: int) -> Granularity:
    return Granularity("per_block", group_size)


def as_granularity(
    g: Union[Granularity, str, None],
    group_size: Optional[int] = None,
) -> Granularity:
    """Coerce CLI/config spellings into a :class:`Granularity`.

    Accepts a Granularity (passed through), ``None`` (per_tensor), or a string:
    ``"per_tensor"``, ``"per_channel"`` / ``"per_row"``, ``"per_block"``
    (``group_size`` then required, either via the argument or the
    ``"per_block:64"`` inline form).
    """
    if g is None:
        return PER_TENSOR
    if isinstance(g, Granularity):
        return g
    name = str(g)
    if ":" in name:
        name, _, gs = name.partition(":")
        group_size = int(gs)
    if name == "per_row":
        name = "per_channel"
    if name == "per_block":
        return Granularity("per_block", group_size)
    if group_size is not None:
        raise ValueError(f"group_size given but granularity is {name!r}, not per_block")
    return Granularity(name)


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """A symmetric odd-level integer format with ``bits`` bits per value."""

    bits: int

    def __post_init__(self):
        if self.bits not in SUPPORTED_BITS:
            raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {self.bits}")

    @property
    def levels(self) -> int:
        """Number of representable levels (odd)."""
        return 2 ** (self.bits - 1) + 1

    @property
    def half_steps(self) -> int:
        """K: number of positive steps; codes live in [-K, K] (K = 2^(b-1)/2)."""
        return 2 ** (self.bits - 1) // 2

    @property
    def values_per_byte(self) -> int:
        return 8 // self.bits

    @property
    def code_min(self) -> int:
        return -self.half_steps

    @property
    def code_max(self) -> int:
        return self.half_steps

    def expected_error_bound(self, scale: float, m: int) -> float:
        """Lemma 4: E||Q(v) - v||_2 <= c_v * sqrt(M) / 2^(b-1)."""
        return scale * (m ** 0.5) / (2 ** (self.bits - 1))


INT2 = QuantFormat(2)
INT4 = QuantFormat(4)
INT8 = QuantFormat(8)

BY_BITS = {2: INT2, 4: INT4, 8: INT8}
