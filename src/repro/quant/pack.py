"""Bit-packing of integer quantization codes into dense uint8 words.

The whole point of the paper's systems result is that *packed* low-precision data
moves fewer bytes: 2-bit codes pack 4-to-a-byte (16x fewer bytes than f32), 4-bit
2-to-a-byte (8x), 8-bit 1-to-a-byte (4x). On TPU the packed array is what streams
HBM->VMEM; the Pallas `qmm` kernel unpacks in-register.

Packing is along the **last axis** (the contraction axis of the matmuls), which
keeps unpacked values contiguous along the TPU minor (lane) dimension.
Codes are stored biased by +K so they are non-negative in ``b`` bits.

Group-scaled (``per_block``) data packs identically — the scale vector is NOT
interleaved with the codes but carried as a separate f32 array (see
:mod:`repro.quant.formats` for the layout and overhead accounting). The only
packing-level constraint is :func:`validate_group_packing`: the group size must
be a multiple of ``8//bits`` so no packed byte straddles two scale groups.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.formats import BY_BITS


def validate_group_packing(group_size: int, bits: int) -> None:
    """Group-scaled packed storage needs every byte inside one scale group."""
    vpb = 8 // bits
    if group_size % vpb:
        raise ValueError(
            f"per_block group_size {group_size} must be a multiple of the "
            f"packing word ({vpb} values/byte at {bits} bits) so packed bytes "
            f"do not straddle scale groups")


def packed_len(n: int, bits: int) -> int:
    vpb = 8 // bits
    return (n + vpb - 1) // vpb


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack int8 codes in [-K, K] into uint8 words along the last axis.

    The last axis is zero-padded (code 0 -> biased K) to a multiple of 8//bits.
    Output last axis has length ``packed_len(codes.shape[-1], bits)``.
    """
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    k = fmt.half_steps
    n = codes.shape[-1]
    pad = (-n) % vpb
    if pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    biased = (codes.astype(jnp.int32) + k).astype(jnp.uint8)  # in [0, 2K] < 2^bits
    if vpb == 1:
        return biased
    new_shape = codes.shape[:-1] + ((n + pad) // vpb, vpb)
    groups = biased.reshape(new_shape)
    out = jnp.zeros(new_shape[:-1], dtype=jnp.uint8)
    for i in range(vpb):
        out = out | (groups[..., i] << (bits * i)).astype(jnp.uint8)
    return out


def unpack_codes(packed: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`; returns int8 codes with last axis length n."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    k = fmt.half_steps
    if vpb == 1:
        biased = packed.astype(jnp.int32)
    else:
        mask = (1 << bits) - 1
        parts = [
            ((packed.astype(jnp.int32) >> (bits * i)) & mask) for i in range(vpb)
        ]
        biased = jnp.stack(parts, axis=-1).reshape(packed.shape[:-1] + (packed.shape[-1] * vpb,))
    codes = biased - k
    return codes[..., :n].astype(jnp.int8)
