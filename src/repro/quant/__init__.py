"""Low-precision data representation: formats, stochastic quantization, packing."""
from repro.quant.formats import BY_BITS, INT2, INT4, INT8, SUPPORTED_BITS, QuantFormat
from repro.quant.pack import pack_codes, packed_len, unpack_codes
from repro.quant.policy import (
    FULL_PRECISION,
    PAPER_2_8,
    PAPER_4_8,
    PAPER_8_8,
    W2KV8,
    W4,
    W4KV8,
    W8,
    QuantPolicy,
)
from repro.quant.quantize import (
    QTensor,
    dequantize_codes,
    fake_quantize,
    quantize,
    quantize_codes,
)

__all__ = [
    "BY_BITS",
    "INT2",
    "INT4",
    "INT8",
    "SUPPORTED_BITS",
    "QuantFormat",
    "pack_codes",
    "packed_len",
    "unpack_codes",
    "FULL_PRECISION",
    "PAPER_2_8",
    "PAPER_4_8",
    "PAPER_8_8",
    "W2KV8",
    "W4",
    "W4KV8",
    "W8",
    "QuantPolicy",
    "QTensor",
    "dequantize_codes",
    "fake_quantize",
    "quantize",
    "quantize_codes",
]
