"""Stochastic quantization Q_b (the paper's Section 3 operator), in pure JAX.

``quantize`` maps a float (or complex) tensor onto the symmetric odd-level integer
grid described in :mod:`repro.quant.formats`.  With ``key`` given it performs
*stochastic rounding* (unbiased: ``E[Q_b(v)] = v``); without a key it rounds to
nearest (biased but deterministic — used where reproducibility beats unbiasedness).

The scale may be carried at any :class:`~repro.quant.formats.Granularity`:
``per_tensor`` (the paper's single c_v — the default, bit-identical to the
historical behaviour), ``per_channel`` (one scale per leading index), or
``per_block(g)`` (one scale per ``g`` contiguous elements of the last axis; see
the storage-layout notes in :mod:`repro.quant.formats`).

Complex tensors are quantized component-wise (real & imaginary parts share one
scale per group), matching how the paper treats the complex measurement matrix
entries.

The returned :class:`QTensor` stores integer codes in ``int8`` (unpacked). Packed
2-/4-bit storage lives in :mod:`repro.quant.pack`; the Pallas kernels consume the
packed form.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.quant.formats import (
    BY_BITS,
    PER_TENSOR,
    Granularity,
    QuantFormat,
    as_granularity,
)


@jax.tree_util.register_pytree_node_class
class QTensor:
    """A quantized tensor: integer codes + scale(s) + bit-width + granularity.

    ``dequantize()`` returns ``codes * (scale / K)`` in the original dtype,
    expanding blockwise scales along the last axis as needed.
    For complex tensors, codes have a leading axis of size 2 (real, imag).
    """

    def __init__(self, codes: jax.Array, scale: jax.Array, bits: int,
                 is_complex: bool = False,
                 granularity: Granularity = PER_TENSOR):
        self.codes = codes
        self.scale = scale
        self.bits = int(bits)
        self.is_complex = bool(is_complex)
        self.granularity = as_granularity(granularity)

    @property
    def fmt(self) -> QuantFormat:
        return BY_BITS[self.bits]

    @property
    def shape(self):
        return self.codes.shape[1:] if self.is_complex else self.codes.shape

    def elementwise_scale(self) -> jax.Array:
        """The scale each code dequantizes with, broadcastable to ``shape``."""
        if self.granularity.kind == "per_block":
            return expand_block_scale(self.scale, self.granularity.group_size,
                                      self.shape[-1])
        return self.scale

    def dequantize(self, dtype=None) -> jax.Array:
        k = self.fmt.half_steps
        step = self.elementwise_scale() / k
        vals = self.codes.astype(jnp.float32) * step
        if self.is_complex:
            if dtype is not None:
                # build the parts in the matching real dtype so the requested
                # complex width survives even when the stored scale is f32
                vals = vals.astype(jnp.finfo(dtype).dtype)
            out = jax.lax.complex(vals[0], vals[1])
            return out.astype(dtype) if dtype is not None else out
        return vals.astype(dtype) if dtype is not None else vals

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits, self.is_complex, self.granularity)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        bits, is_complex, granularity = aux
        return cls(codes, scale, bits, is_complex, granularity)


def _guard_zero(m: jax.Array) -> jax.Array:
    # Guard against all-zero groups: scale 0 would produce NaNs on dequant paths.
    return jnp.where(m > 0, m, jnp.ones_like(m))


def _max_abs(v: jax.Array, axis=None) -> jax.Array:
    return _guard_zero(jnp.max(jnp.abs(v), axis=axis, keepdims=axis is not None))


def block_scale(v: jax.Array, group_size: int) -> jax.Array:
    """Per-block max-abs along the last axis: (..., n) → (..., ⌈n/g⌉)."""
    n = v.shape[-1]
    nb = (n + group_size - 1) // group_size
    pad = nb * group_size - n
    if pad:
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    blocks = jnp.abs(v).reshape(*v.shape[:-1], nb, group_size)
    return _guard_zero(jnp.max(blocks, axis=-1))


def expand_block_scale(scale: jax.Array, group_size: int, n: int) -> jax.Array:
    """Inverse broadcast of :func:`block_scale`: (..., ⌈n/g⌉) → (..., n)."""
    return jnp.repeat(scale, group_size, axis=-1)[..., :n]


def _granular_scale(v: jax.Array, granularity: Granularity) -> jax.Array:
    if granularity.kind == "per_tensor":
        return _max_abs(v)
    if granularity.kind == "per_channel":
        return _max_abs(v, axis=v.ndim - 1)
    return block_scale(v, granularity.group_size)


def quantize_codes(
    v: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    channel_axis: Optional[int] = None,
    granularity: Union[Granularity, str, None] = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize a *real* tensor to integer codes in [-K, K]. Returns (codes, scale).

    scale: per-tensor max-abs by default; per-channel when ``channel_axis`` given
    (the scale then has keepdims shape); blockwise per ``granularity`` (the
    returned scale then has the compact per-group shape — ``(..., ⌈n/g⌉)`` for
    ``per_block(g)``). An explicit ``scale`` is used as-is: any shape
    broadcastable to ``v`` (per_tensor/per_channel/per-element), or the compact
    per-group shape when ``granularity`` is per_block. Values are clipped to
    [-scale, scale] before rounding (the paper assumes values confined to
    [-1, 1] a priori; the scale implements that normalization).
    """
    fmt = BY_BITS[bits]
    k = fmt.half_steps
    gran = as_granularity(granularity)
    if channel_axis is not None:
        if not gran.is_per_tensor:
            raise ValueError("pass either channel_axis or granularity, not both")
        if scale is None:
            axes = tuple(a for a in range(v.ndim) if a != channel_axis)
            scale = _max_abs(v, axis=axes)
        scale_elem = scale
    else:
        if scale is None:
            scale = _granular_scale(v, gran)
        scale_elem = (expand_block_scale(scale, gran.group_size, v.shape[-1])
                      if gran.kind == "per_block" else scale)
    scaled = jnp.clip(v / scale_elem, -1.0, 1.0) * k
    if key is None:
        codes = jnp.round(scaled)
    else:
        low = jnp.floor(scaled)
        p_up = scaled - low
        u = jax.random.uniform(key, v.shape, dtype=jnp.float32)
        codes = low + (u < p_up).astype(jnp.float32)
    codes = jnp.clip(codes, -k, k).astype(jnp.int8)
    return codes, scale


def quantize(
    v: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    channel_axis: Optional[int] = None,
    granularity: Union[Granularity, str, None] = None,
) -> QTensor:
    """Quantize a real or complex tensor into a :class:`QTensor`."""
    gran = as_granularity(granularity)
    if jnp.iscomplexobj(v):
        re, im = jnp.real(v), jnp.imag(v)
        if scale is None:
            if channel_axis is not None:
                raise NotImplementedError("per-channel complex quantization unused")
            # real & imaginary parts share one scale per group
            scale = jnp.maximum(_granular_scale(re, gran), _granular_scale(im, gran))
        if key is not None:
            kre, kim = jax.random.split(key)
        else:
            kre = kim = None
        cre, _ = quantize_codes(re, bits, kre, scale, granularity=gran)
        cim, _ = quantize_codes(im, bits, kim, scale, granularity=gran)
        return QTensor(jnp.stack([cre, cim]), scale, bits, is_complex=True,
                       granularity=gran)
    codes, scale = quantize_codes(v, bits, key, scale, channel_axis, gran)
    return QTensor(codes, scale, bits, is_complex=False, granularity=gran)


def dequantize_codes(codes: jax.Array, scale: jax.Array, bits: int,
                     dtype=jnp.float32,
                     granularity: Union[Granularity, str, None] = None) -> jax.Array:
    gran = as_granularity(granularity)
    if gran.kind == "per_block":
        scale = expand_block_scale(scale, gran.group_size, codes.shape[-1])
    fmt = BY_BITS[bits]
    return (codes.astype(jnp.float32) * (scale / fmt.half_steps)).astype(dtype)


def fake_quantize(
    v: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    channel_axis: Optional[int] = None,
    granularity: Union[Granularity, str, None] = None,
) -> jax.Array:
    """Quantize-dequantize round trip (the reference 'Q(v)' of the paper's math).
    Dtype-preserving: f32/f64/c64/c128 in → same dtype out (complex included —
    the round trip must not silently narrow c128 measurements to c64)."""
    return quantize(v, bits, key, scale, channel_axis, granularity).dequantize(v.dtype)
