"""Stochastic quantization Q_b (the paper's Section 3 operator), in pure JAX.

``quantize`` maps a float (or complex) tensor onto the symmetric odd-level integer
grid described in :mod:`repro.quant.formats`.  With ``key`` given it performs
*stochastic rounding* (unbiased: ``E[Q_b(v)] = v``); without a key it rounds to
nearest (biased but deterministic — used where reproducibility beats unbiasedness).

Complex tensors are quantized component-wise (real & imaginary parts share one
scale), matching how the paper treats the complex measurement matrix entries.

The returned :class:`QTensor` stores integer codes in ``int8`` (unpacked). Packed
2-/4-bit storage lives in :mod:`repro.quant.pack`; the Pallas kernels consume the
packed form.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import BY_BITS, QuantFormat


@jax.tree_util.register_pytree_node_class
class QTensor:
    """A quantized tensor: integer codes + scale + bit-width.

    ``dequantize()`` returns ``codes * (scale / K)`` in the original dtype.
    For complex tensors, codes have a leading axis of size 2 (real, imag).
    """

    def __init__(self, codes: jax.Array, scale: jax.Array, bits: int, is_complex: bool = False):
        self.codes = codes
        self.scale = scale
        self.bits = int(bits)
        self.is_complex = bool(is_complex)

    @property
    def fmt(self) -> QuantFormat:
        return BY_BITS[self.bits]

    @property
    def shape(self):
        return self.codes.shape[1:] if self.is_complex else self.codes.shape

    def dequantize(self, dtype=None) -> jax.Array:
        k = self.fmt.half_steps
        step = self.scale / k
        vals = self.codes.astype(jnp.float32) * step
        if self.is_complex:
            out = jax.lax.complex(vals[0], vals[1])
            return out.astype(dtype) if dtype is not None else out
        return vals.astype(dtype) if dtype is not None else vals

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits, self.is_complex)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        bits, is_complex = aux
        return cls(codes, scale, bits, is_complex)


def _max_abs(v: jax.Array, axis=None) -> jax.Array:
    m = jnp.max(jnp.abs(v), axis=axis, keepdims=axis is not None)
    # Guard against all-zero tensors: scale 0 would produce NaNs on dequant paths.
    return jnp.where(m > 0, m, jnp.ones_like(m))


def quantize_codes(
    v: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    channel_axis: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize a *real* tensor to integer codes in [-K, K]. Returns (codes, scale).

    scale: per-tensor max-abs by default; per-channel when ``channel_axis`` given
    (the scale then has keepdims shape). Values are clipped to [-scale, scale]
    before rounding (the paper assumes values confined to [-1, 1] a priori; the
    scale implements that normalization).
    """
    fmt = BY_BITS[bits]
    k = fmt.half_steps
    if scale is None:
        if channel_axis is None:
            scale = _max_abs(v)
        else:
            axes = tuple(a for a in range(v.ndim) if a != channel_axis)
            scale = _max_abs(v, axis=axes)
    scaled = jnp.clip(v / scale, -1.0, 1.0) * k
    if key is None:
        codes = jnp.round(scaled)
    else:
        low = jnp.floor(scaled)
        p_up = scaled - low
        u = jax.random.uniform(key, v.shape, dtype=jnp.float32)
        codes = low + (u < p_up).astype(jnp.float32)
    codes = jnp.clip(codes, -k, k).astype(jnp.int8)
    return codes, scale


def quantize(
    v: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    channel_axis: Optional[int] = None,
) -> QTensor:
    """Quantize a real or complex tensor into a :class:`QTensor`."""
    if jnp.iscomplexobj(v):
        re, im = jnp.real(v), jnp.imag(v)
        if scale is None:
            if channel_axis is not None:
                raise NotImplementedError("per-channel complex quantization unused")
            scale = jnp.maximum(_max_abs(re), _max_abs(im))
        if key is not None:
            kre, kim = jax.random.split(key)
        else:
            kre = kim = None
        cre, _ = quantize_codes(re, bits, kre, scale)
        cim, _ = quantize_codes(im, bits, kim, scale)
        return QTensor(jnp.stack([cre, cim]), scale, bits, is_complex=True)
    codes, scale = quantize_codes(v, bits, key, scale, channel_axis)
    return QTensor(codes, scale, bits, is_complex=False)


def dequantize_codes(codes: jax.Array, scale: jax.Array, bits: int, dtype=jnp.float32) -> jax.Array:
    fmt = BY_BITS[bits]
    return (codes.astype(jnp.float32) * (scale / fmt.half_steps)).astype(dtype)


def fake_quantize(
    v: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    channel_axis: Optional[int] = None,
) -> jax.Array:
    """Quantize-dequantize round trip (the reference 'Q(v)' of the paper's math)."""
    return quantize(v, bits, key, scale, channel_axis).dequantize(
        v.dtype if not jnp.iscomplexobj(v) else None
    )
