"""Quantization policy: how the paper's Q_b is applied across the framework.

A :class:`QuantPolicy` travels with every model/config and controls which tensors
get the low-precision data representation:

* ``weight_bits``   — weight-only quantized matmuls (None = full precision). The
  direct analog of quantizing the measurement matrix ``Φ``: weights are the large,
  repeatedly-streamed operand of a bandwidth-bound iterative computation (decode).
* ``kv_bits``       — KV-cache / cross-attention-memory quantization. The analog of
  quantizing the observations ``y`` (a fixed vector consumed every iteration).
* ``grad_bits``     — gradient all-reduce compression for multi-pod training
  (stochastic rounding keeps it unbiased, per the paper's Q).
* ``stochastic``    — stochastic (unbiased) vs nearest rounding for weights.
* ``phi_bits`` / ``y_bits`` — the CS solver's own b_Φ and b_y.
* ``scale_granularity`` / ``group_size`` — how many scales the quantized data
  carries (see :mod:`repro.quant.formats`): ``"per_tensor"`` is the paper's
  single c_v; ``"per_channel"``/``"per_row"`` and ``"per_block"`` (with
  ``group_size``) match quantizer resolution to local statistics, which is
  what keeps sub-8-bit widths usable on high-dynamic-range data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.quant.formats import Granularity, as_granularity

VALID_BITS = (None, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    weight_bits: Optional[int] = None
    kv_bits: Optional[int] = None
    grad_bits: Optional[int] = None
    stochastic: bool = True
    # CS solver data precision (paper notation b_Phi & b_y)
    phi_bits: Optional[int] = None
    y_bits: Optional[int] = None
    # scaling granularity for the quantized data (string spelling so the
    # frozen dataclass stays trivially hashable/serializable)
    scale_granularity: str = "per_tensor"
    group_size: Optional[int] = None

    def __post_init__(self):
        for name in ("weight_bits", "kv_bits", "grad_bits", "phi_bits", "y_bits"):
            v = getattr(self, name)
            if v not in VALID_BITS:
                raise ValueError(f"{name} must be in {VALID_BITS}, got {v}")
        self.granularity  # validates the spelling eagerly

    @property
    def granularity(self) -> Granularity:
        return as_granularity(self.scale_granularity, self.group_size)

    @property
    def quantizes_weights(self) -> bool:
        return self.weight_bits is not None

    @property
    def quantizes_kv(self) -> bool:
        return self.kv_bits is not None

    @property
    def quantizes_grads(self) -> bool:
        return self.grad_bits is not None


FULL_PRECISION = QuantPolicy()
W8 = QuantPolicy(weight_bits=8)
W4 = QuantPolicy(weight_bits=4)
W4KV8 = QuantPolicy(weight_bits=4, kv_bits=8)
W2KV8 = QuantPolicy(weight_bits=2, kv_bits=8)
PAPER_2_8 = QuantPolicy(phi_bits=2, y_bits=8)   # the paper's headline "2&8 bit" IHT
PAPER_4_8 = QuantPolicy(phi_bits=4, y_bits=8)
PAPER_8_8 = QuantPolicy(phi_bits=8, y_bits=8)
