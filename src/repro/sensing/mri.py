"""MRI application substrate: the paper's second workload (§5, "samples of
brain images") — recovery from aggressively quantized subsampled-Fourier
measurements.

An MRI scanner acquires k-space (2D Fourier) coefficients of the image;
compressed sensing undersamples k-space to cut scan time, and the paper's
low-precision angle quantizes the acquired samples (``bits_y``) before
recovery. The sensing model is Φ = P_Ω F (orthonormal 2D DFT + sampling mask),
implemented matrix-free by
:class:`~repro.core.operators.SubsampledFourierOperator` — at 256×256 the
dense partial-Fourier matrix would be ~2 GB, so only the implicit form makes
this workload reachable.

Real anatomy is NOT pixel-sparse — the paper's brain images are sparse in a
*wavelet* basis. ``make_mri_problem(sparsity_basis="haar"|"db4")`` therefore
recovers the **full, unsparsified** phantom through the composed model
Φ = P_Ω F W†
(:class:`~repro.core.operators.ComposedOperator` of the Fourier factor with a
:class:`~repro.core.operators.WaveletSynthesisOperator`): the solver iterates
on the approximately-sparse wavelet coefficient vector, and image-space
quality is read off ``W† x̂`` (``MRIProblem.to_image``). The legacy
``sparsity_basis="pixel"`` keeps the s-sparsified phantom of the exact-sparsity
guarantees.

This module provides the non-operator half of the pipeline:

* phantoms — :func:`shepp_logan` (the standard modified Shepp–Logan head
  phantom) and :func:`brain_phantom` (randomized brain-like piecewise-constant
  images: skull ring + random elliptical "tissue" regions),
* :func:`sparsify_image` — the s-sparse phantom the pixel-basis solver
  recovers exactly; :func:`wavelet_coeffs` — the transform-domain signal the
  wavelet bases iterate on,
* sampling masks — :func:`cartesian_mask` with ``density="uniform"`` or
  ``"variable"`` (polynomial density concentrating samples at low frequencies,
  the standard CS-MRI pattern) and an always-sampled center block,
* :func:`mri_observations` / :func:`quantize_observations` — noisy k-space
  samples and the b_y-bit stochastic quantization applied to them. The
  quantizer scale is per-tensor (the paper's single c_y) by default, or
  **per-band**: concentric radial bands of k-space each carry their own scale
  (:func:`kspace_radial_bands` / :func:`kspace_band_scales`), matching
  quantizer resolution to the steeply decaying spectral energy of images —
  the single shared scale is what collapses b_y < 8 (huge DC coefficients
  force tiny high-frequency samples under the rounding step; see
  BENCH_mri.json int4/int2 rows),
* :func:`make_mri_problem` — one call bundling all of the above.

Masks are generated in *centered* coordinates (DC in the middle, how k-space
is drawn in the MRI literature) and ifft-shifted to the DC-at-[0,0] convention
``SubsampledFourierOperator``'s ``fft2`` uses.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.operators import (
    ComposedOperator,
    SubsampledFourierOperator,
    WaveletSynthesisOperator,
)
from repro.quant.formats import BY_BITS
from repro.quant.quantize import fake_quantize, quantize_codes
from repro.transforms.wavelet import dwt2, flatten_coeffs

# Modified Shepp–Logan (Toft): (intensity, a, b, x0, y0, angle_deg) per ellipse.
_SHEPP_LOGAN = (
    (1.00, 0.6900, 0.9200, 0.00, 0.0000, 0.0),
    (-0.80, 0.6624, 0.8740, 0.00, -0.0184, 0.0),
    (-0.20, 0.1100, 0.3100, 0.22, 0.0000, -18.0),
    (-0.20, 0.1600, 0.4100, -0.22, 0.0000, 18.0),
    (0.10, 0.2100, 0.2500, 0.00, 0.3500, 0.0),
    (0.10, 0.0460, 0.0460, 0.00, 0.1000, 0.0),
    (0.10, 0.0460, 0.0460, 0.00, -0.1000, 0.0),
    (0.10, 0.0460, 0.0230, -0.08, -0.6050, 0.0),
    (0.10, 0.0230, 0.0230, 0.00, -0.6060, 0.0),
    (0.10, 0.0230, 0.0460, 0.06, -0.6050, 0.0),
)


def _render_ellipses(resolution: int, ellipses) -> np.ndarray:
    """Sum of constant-intensity ellipses on the [-1, 1]² grid → (r, r) f32."""
    lin = np.linspace(-1.0, 1.0, resolution)
    xx, yy = np.meshgrid(lin, lin, indexing="xy")
    img = np.zeros((resolution, resolution), np.float32)
    for inten, a, b, x0, y0, ang in ellipses:
        th = np.deg2rad(ang)
        xr = (xx - x0) * np.cos(th) + (yy - y0) * np.sin(th)
        yr = -(xx - x0) * np.sin(th) + (yy - y0) * np.cos(th)
        img += np.float32(inten) * ((xr / a) ** 2 + (yr / b) ** 2 <= 1.0)
    return np.clip(img, 0.0, None)


def shepp_logan(resolution: int) -> jax.Array:
    """The modified Shepp–Logan head phantom, (r, r) float32 in [0, 1]."""
    return jnp.asarray(_render_ellipses(resolution, _SHEPP_LOGAN))


def brain_phantom(
    resolution: int,
    key: jax.Array,
    n_regions: int = 8,
) -> jax.Array:
    """A randomized brain-like piecewise-constant image, (r, r) float32.

    Skull: a bright outer ellipse ring (like Shepp–Logan's). Interior:
    ``n_regions`` random ellipses of random constant intensity — the
    piecewise-constant structure of anatomical images, with randomized
    geometry so experiments average over phantoms instead of overfitting the
    one canonical image.
    """
    seed = int(jax.random.randint(key, (), 0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    ellipses = [(1.0, 0.72, 0.92, 0.0, 0.0, 0.0),
                (-0.75, 0.67, 0.86, 0.0, 0.0, 0.0)]
    for _ in range(n_regions):
        a = rng.uniform(0.05, 0.35)
        b = rng.uniform(0.05, 0.35)
        # keep the region inside the skull interior
        x0 = rng.uniform(-0.45, 0.45)
        y0 = rng.uniform(-0.55, 0.55)
        ellipses.append((rng.uniform(-0.2, 0.4), a, b, x0, y0, rng.uniform(0, 180)))
    return jnp.asarray(np.clip(_render_ellipses(resolution, ellipses), 0.0, 1.0))


def sparsify_image(img: jax.Array, s: int) -> jax.Array:
    """Keep the s largest-magnitude pixels: the s-sparse phantom, as an (r²,)
    vector (the exact-sparsity signal model of the recovery guarantees)."""
    flat = img.ravel()
    vals, idx = jax.lax.top_k(jnp.abs(flat), s)
    del vals
    return jnp.zeros_like(flat).at[idx].set(flat[idx])


def wavelet_coeffs(img: jax.Array, wavelet: str = "haar",
                   levels: Optional[int] = None) -> jax.Array:
    """W img: the (approximately sparse) wavelet coefficient vector ``(r²,)``
    of an ``(r, r)`` image — the transform-domain signal the Φ = P_Ω F W†
    model recovers. No thresholding happens here: the anatomy is kept whole,
    and sparsity is a property the solver's H_s exploits, not one we impose."""
    return flatten_coeffs(dwt2(img, wavelet, levels))


def cartesian_mask(
    resolution: int,
    fraction: float,
    key: jax.Array,
    density: str = "variable",
    center_fraction: float = 0.04,
    power: float = 3.0,
) -> np.ndarray:
    """A Cartesian k-space sampling mask, (r, r) boolean, DC at [0, 0].

    ``fraction`` of the r² grid points are sampled: a fully-sampled center
    block covering ``center_fraction`` of k-space (low frequencies hold most
    image energy — every practical CS-MRI pattern keeps them), plus random
    points drawn ``density="uniform"``-ly or with ``"variable"`` density
    ∝ (1 − d/d_max)^power (more samples near the center, the standard
    variable-density scheme). Returned in the unshifted convention
    :class:`~repro.core.operators.SubsampledFourierOperator` expects.
    """
    if not 0.0 < fraction <= 1.0:
        raise ValueError(f"fraction must be in (0, 1], got {fraction}")
    if density not in ("uniform", "variable"):
        raise ValueError(f"unknown density {density!r} (use 'uniform' or 'variable')")
    r = resolution
    n_total = max(1, int(round(fraction * r * r)))

    # centered coordinates: distance of each grid point from DC
    lin = np.arange(r) - r // 2
    xx, yy = np.meshgrid(lin, lin, indexing="ij")
    dist = np.sqrt(xx**2 + yy**2) / np.sqrt(2.0) / (r // 2)

    mask = np.zeros((r, r), bool)
    half_c = max(1, int(round(np.sqrt(center_fraction) * r / 2)))
    c = r // 2
    mask[c - half_c:c + half_c, c - half_c:c + half_c] = True
    if int(mask.sum()) > n_total:
        raise ValueError(
            f"center block ({int(mask.sum())} samples) exceeds the requested "
            f"fraction ({n_total} samples); lower center_fraction below {fraction}")

    n_rand = n_total - int(mask.sum())
    if n_rand > 0:
        free = np.flatnonzero(~mask.ravel())
        if density == "uniform":
            p = np.ones(free.size)
        else:
            p = np.maximum(1.0 - np.clip(dist.ravel()[free], 0.0, 1.0), 1e-3) ** power
        p = p / p.sum()
        seed = int(jax.random.randint(key, (), 0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        pick = rng.choice(free, size=min(n_rand, free.size), replace=False, p=p)
        # .flat (not .ravel()): ravel() writes through only while the array
        # stays contiguous — .flat is the spelling that cannot silently become
        # a copy if the allocation above ever changes.
        mask.flat[pick] = True
    return np.fft.ifftshift(mask)


def kspace_radial_bands(
    op_or_indices,
    resolution: Optional[int] = None,
    n_bands: int = 8,
) -> jax.Array:
    """Radial band index (0 = DC … n_bands-1 = corners) per k-space sample.

    Accepts anything exposing the k-space geometry — a
    :class:`~repro.core.operators.SubsampledFourierOperator` or a composition
    Φ = P_Ω F W† (unwrapped through its ``kspace_op`` property) — or a flat
    index array (with ``resolution``). Indices follow the unshifted
    DC-at-[0,0] convention the operator's ``fft2`` uses; bands are concentric
    annuli of equal radial width on the centered grid.
    """
    op_or_indices = getattr(op_or_indices, "kspace_op", op_or_indices)
    if isinstance(op_or_indices, SubsampledFourierOperator):
        idx, r = op_or_indices.indices, op_or_indices.resolution
    else:
        if resolution is None:
            raise ValueError("resolution required when passing raw indices")
        idx, r = jnp.asarray(op_or_indices, jnp.int32), int(resolution)
    if n_bands < 1:
        raise ValueError(f"n_bands must be >= 1, got {n_bands}")
    row, col = idx // r, idx % r
    # unshifted index -> signed frequency in [-r/2, r/2)
    fr = ((row + r // 2) % r) - r // 2
    fc = ((col + r // 2) % r) - r // 2
    dist = jnp.sqrt((fr.astype(jnp.float32)) ** 2 + (fc.astype(jnp.float32)) ** 2)
    d_max = jnp.sqrt(2.0) * (r / 2.0)
    band = jnp.floor(dist / d_max * n_bands).astype(jnp.int32)
    return jnp.clip(band, 0, n_bands - 1)


def kspace_band_scales(y: jax.Array, bands: jax.Array, n_bands: int) -> jax.Array:
    """Per-band quantizer scale: max component magnitude within each radial
    band (real & imaginary share one scale, like the per-tensor quantizer).
    ``y`` is (M,) or batched (..., M); returns (..., n_bands) f32, with empty
    or all-zero bands guarded to scale 1."""
    mag = jnp.maximum(jnp.abs(jnp.real(y)), jnp.abs(jnp.imag(y)))

    def one(m):
        s = jax.ops.segment_max(m, bands, num_segments=n_bands)
        return jnp.where(s > 0, s, jnp.ones_like(s))  # also clears -inf empties

    flat = mag.reshape(-1, mag.shape[-1])
    return jax.vmap(one)(flat).reshape(*mag.shape[:-1], n_bands)


def quantize_observations(
    y: jax.Array,
    bits_y: int,
    key: jax.Array,
    granularity: str = "per_tensor",
    op=None,
    n_bands: int = 8,
) -> jax.Array:
    """The paper's b_y-bit stochastic quantization of acquired k-space samples
    (complex: real/imag quantized component-wise on a shared scale).
    ``op`` is the sensing operator owning the k-space geometry — a bare
    :class:`~repro.core.operators.SubsampledFourierOperator` or the composed
    Φ = P_Ω F W† (its ``kspace_op`` factor is used).

    ``granularity="per_tensor"`` (default) is the paper's single c_y — one
    scale for all of k-space, identical to ``fake_quantize``.
    ``granularity="per_band"`` carries one scale per concentric radial band
    (``n_bands`` of them, geometry from ``op``): each sample rounds with the
    step of its *local* dynamic range, so the huge low-frequency coefficients
    no longer force the quantization step of the tiny high frequencies. Stream
    overhead is ``4 * n_bands`` bytes of f32 scales (band indices are derivable
    from the sampling mask the acquisition already stores).
    """
    if granularity == "per_tensor":
        return fake_quantize(y, bits_y, key)
    if granularity != "per_band":
        raise ValueError(
            f"unknown observation granularity {granularity!r} "
            "(use 'per_tensor' or 'per_band')")
    if op is None:
        raise ValueError("per_band quantization needs the sensing operator "
                         "(op=...) for the k-space band geometry")
    bands = kspace_radial_bands(op, n_bands=n_bands)
    scales = kspace_band_scales(y, bands, n_bands)          # (..., n_bands)
    kre, kim = jax.random.split(key)

    def one(y_row, scale_row):
        """One acquisition; every batch row folds the same key so that row b
        of a batched call reproduces the single-row call bit-for-bit (the
        qniht batching contract)."""
        s = scale_row[bands]
        cre, _ = quantize_codes(jnp.real(y_row), bits_y, kre, scale=s)
        cim, _ = quantize_codes(jnp.imag(y_row), bits_y, kim, scale=s)
        step = s / BY_BITS[bits_y].half_steps
        return jax.lax.complex(cre.astype(jnp.float32) * step,
                               cim.astype(jnp.float32) * step)

    if y.ndim == 1:
        return one(y, scales).astype(y.dtype)
    flat_y = y.reshape(-1, y.shape[-1])
    flat_s = scales.reshape(-1, n_bands)
    return jax.vmap(one)(flat_y, flat_s).reshape(y.shape).astype(y.dtype)


def mri_observations(
    op: SubsampledFourierOperator,
    x: jax.Array,
    snr_db: Optional[float],
    key: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """y = Φx + e with circularly-symmetric complex Gaussian acquisition noise
    at the given per-problem SNR (None → noiseless). Returns (y, e).

    ``x`` may be a single (N,) image or a (B, N) batch — the batch is served by
    one batched FFT and gets independent per-row noise at the same SNR."""
    clean = op.mv(x)
    if snr_db is None:
        return clean, jnp.zeros_like(clean)
    m = clean.shape[-1]
    sig_pow = jnp.real(jnp.sum(clean * jnp.conj(clean), axis=-1, keepdims=True))
    sigma = jnp.sqrt(sig_pow / (10.0 ** (snr_db / 10.0)) / m / 2.0)
    kr, ki = jax.random.split(key)
    e = (sigma * (jax.random.normal(kr, clean.shape, jnp.float32)
                  + 1j * jax.random.normal(ki, clean.shape, jnp.float32))
         ).astype(clean.dtype)
    return clean + e, e


SPARSITY_BASES = ("pixel", "haar", "db4")


@dataclasses.dataclass
class MRIProblem:
    """One subsampled-Fourier recovery instance (matrix-free Φ throughout).

    ``op`` is the operator the solver sees: P_Ω F for the pixel basis, the
    composed P_Ω F W† for a wavelet basis — ``x_true`` correspondingly lives
    in pixel or wavelet-coefficient space. ``image_true`` is always the
    image-space ground truth (= the *full* phantom for wavelet bases, the
    s-sparsified one for pixel); judge recovered iterates against it via
    :meth:`to_image`, never against ``x_true`` in coefficient space.
    """

    op: object            # operator-protocol Φ (matrix-free)
    y: jax.Array          # (M,) complex64 k-space samples (noisy, unquantized)
    e: jax.Array          # (M,) acquisition noise actually added
    x_true: jax.Array     # (r²,) ground truth in the solver's basis
    resolution: int
    s: int
    sparsity_basis: str = "pixel"
    image_true: Optional[jax.Array] = None   # (r²,) image-space ground truth
    synthesis: Optional[WaveletSynthesisOperator] = None

    def __post_init__(self):
        if self.image_true is None:
            self.image_true = self.x_true

    def to_image(self, x: jax.Array) -> jax.Array:
        """Map solver-basis vector(s) ``(…, r²)`` to image space (W† x for
        wavelet bases; identity for pixel). Real part only — the recovered
        image is real by model."""
        if self.synthesis is not None:
            x = self.synthesis.mv(x)
        return jnp.real(x)


def make_mri_problem(
    resolution: int,
    s: int,
    fraction: float,
    key: jax.Array,
    density: str = "variable",
    center_fraction: float = 0.04,
    snr_db: Optional[float] = None,
    phantom: str = "shepp-logan",
    sparsity_basis: str = "pixel",
    wavelet_levels: Optional[int] = None,
) -> MRIProblem:
    """Phantom → truth in the chosen basis → mask → operator → observations.

    ``phantom="shepp-logan"`` uses the canonical head phantom;
    ``"brain"`` draws a randomized piecewise-constant brain-like image from
    ``key``.

    ``sparsity_basis="pixel"`` (default) is the exact-sparsity model: the
    phantom is thresholded to its s largest pixels and sensed through
    Φ = P_Ω F. ``"haar"``/``"db4"`` is the paper's actual §5 scenario: the
    **full** phantom is kept, ``x_true`` becomes its wavelet coefficient
    vector (approximately sparse — Shepp–Logan puts >99.99% of its energy in
    ~12% of its Haar coefficients at 128²), and the operator becomes the
    composition Φ = P_Ω F W†. Observations are always taken in k-space from
    the image the scanner would actually see.

    Quantization of ``y`` is left to the solver's ``bits_y`` (one stochastic
    draw inside ``qniht``, Algorithm-1-faithful); use
    :func:`quantize_observations` to materialize ŷ standalone.
    """
    if sparsity_basis not in SPARSITY_BASES:
        raise ValueError(
            f"unknown sparsity_basis {sparsity_basis!r} (use one of {SPARSITY_BASES})")
    kimg, kmask, knoise = jax.random.split(key, 3)
    if phantom == "shepp-logan":
        img = shepp_logan(resolution)
    elif phantom == "brain":
        img = brain_phantom(resolution, kimg)
    else:
        raise ValueError(f"unknown phantom {phantom!r} (use 'shepp-logan' or 'brain')")
    mask = cartesian_mask(resolution, fraction, kmask, density, center_fraction)
    fourier = SubsampledFourierOperator.from_mask(mask)
    if sparsity_basis == "pixel":
        x_true = sparsify_image(img, s)
        y, e = mri_observations(fourier, x_true, snr_db, knoise)
        return MRIProblem(op=fourier, y=y, e=e, x_true=x_true,
                          resolution=resolution, s=s)
    synthesis = WaveletSynthesisOperator(resolution, sparsity_basis, wavelet_levels)
    image_true = img.ravel()
    x_true = wavelet_coeffs(img, sparsity_basis, synthesis.levels)
    # the scanner samples k-space of the IMAGE; op.mv(x_true) equals this up
    # to the (exact) W†W round trip
    y, e = mri_observations(fourier, image_true, snr_db, knoise)
    return MRIProblem(op=ComposedOperator(fourier, synthesis), y=y, e=e,
                      x_true=x_true, resolution=resolution, s=s,
                      sparsity_basis=sparsity_basis, image_true=image_true,
                      synthesis=synthesis)
