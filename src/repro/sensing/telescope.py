"""Radio-interferometer pipeline: station geometry → measurement matrix Φ.

Follows the paper's supplementary §7 exactly:

* L antennas at positions p_i (meters); all L² ordered pairs (i,k) form baselines
  u_{ik} = (p_i − p_k)/λ₀  (so M = L², autocorrelations included),
* the sky is a r×r grid of direction cosines (l, m) ∈ [−d, d]²  (N = r²),
* Φ_{z,w} = exp(−j2π ⟨u_{ik}, r_{lm}⟩)    (Eq. 73–75),
* visibilities  y = Φ x + e  with e ~ CN(0, σ_n² I)  (thermal antenna noise).

The grid extent ``d`` is the *instrument-dependent tuning knob* of supplementary
§7.3: shrinking/growing d moves γ = σ_max/σ_min − 1, which is how the paper
engineers γ ≤ 1/16 before choosing the bit width via Lemma 1.

No external data needed: the station layout is a deterministic pseudo-LOFAR
low-band (LBA) layout — uniformly-filled disc, the standard model for LOFAR
core-station LBA fields (CS302-like, 15–80 MHz).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

C_LIGHT = 299_792_458.0  # m/s


@dataclasses.dataclass(frozen=True)
class Station:
    """An interferometer station configuration."""

    n_antennas: int = 30
    freq_hz: float = 50e6          # LOFAR low band (15–80 MHz)
    field_radius_m: float = 40.0   # LBA field radius
    seed: int = 302                # CS302 homage; deterministic layout

    @property
    def wavelength(self) -> float:
        return C_LIGHT / self.freq_hz

    include_autocorrelations: bool = False

    @property
    def n_baselines(self) -> int:
        l = self.n_antennas
        return l * l if self.include_autocorrelations else l * (l - 1)

    def antenna_positions(self) -> np.ndarray:
        """(L, 2) meters. Uniform-in-disc, deterministic in ``seed``."""
        rng = np.random.default_rng(self.seed)
        r = self.field_radius_m * np.sqrt(rng.uniform(size=self.n_antennas))
        th = rng.uniform(0, 2 * np.pi, size=self.n_antennas)
        return np.stack([r * np.cos(th), r * np.sin(th)], axis=1)

    def baselines(self) -> np.ndarray:
        """(M, 2) baselines in wavelengths: u_{ik} = (p_i − p_k)/λ.

        Autocorrelations (i = k, u = 0) are excluded by default: they are L
        duplicated zero rows of Φ (rank-deficient → γ = ∞) and in practice are
        discarded anyway (noise-dominated). The paper's M = L² counts them; set
        ``include_autocorrelations=True`` for the literal formulation.
        """
        p = self.antenna_positions()
        d = (p[:, None, :] - p[None, :, :]) / self.wavelength
        d = d.reshape(-1, 2)
        if not self.include_autocorrelations:
            l = self.n_antennas
            mask = ~np.eye(l, dtype=bool).ravel()
            d = d[mask]
        return d


def sky_grid(resolution: int, extent: float = 0.4) -> np.ndarray:
    """(r², 2) direction cosines (l, m) on a regular grid over [−d, d]²."""
    lin = np.linspace(-extent, extent, resolution)
    ll, mm = np.meshgrid(lin, lin, indexing="ij")
    return np.stack([ll.ravel(), mm.ravel()], axis=1)


def measurement_matrix(
    station: Station, resolution: int, extent: float = 0.4, dtype=jnp.complex64
) -> jax.Array:
    """Φ ∈ C^{L² × r²}: Φ_{z,w} = exp(−j2π ⟨u_z, r_w⟩)   (Eq. 75)."""
    uv = jnp.asarray(station.baselines(), dtype=jnp.float32)         # (M, 2)
    grid = jnp.asarray(sky_grid(resolution, extent), dtype=jnp.float32)  # (N, 2)
    phase = -2.0 * jnp.pi * (uv @ grid.T)                            # (M, N)
    return jnp.exp(1j * phase.astype(jnp.float32)).astype(dtype)


def visibilities(
    phi: jax.Array,
    x: jax.Array,
    snr_db: Optional[float],
    key: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """y = Φx + e with circularly-symmetric complex Gaussian noise at the given
    *antenna-level* SNR (paper §4 uses 0 dB). Returns (y, e)."""
    clean_y = phi @ x.astype(phi.dtype)
    if snr_db is None:
        return clean_y, jnp.zeros_like(clean_y)
    m = clean_y.shape[0]
    sig_pow = jnp.real(jnp.vdot(clean_y, clean_y))
    noise_pow = sig_pow / (10.0 ** (snr_db / 10.0))
    sigma = jnp.sqrt(noise_pow / m / 2.0)
    kr, ki = jax.random.split(key)
    e = sigma * (
        jax.random.normal(kr, (m,), jnp.float32)
        + 1j * jax.random.normal(ki, (m,), jnp.float32)
    ).astype(phi.dtype)
    return clean_y + e, e


def dirty_image(phi: jax.Array, y: jax.Array, resolution: int) -> jax.Array:
    """Least-squares/backprojection estimate Re(Φ†y) (the 'dirty image')."""
    x = jnp.real(jnp.conj(phi.T) @ y) / phi.shape[0]
    return x.reshape(resolution, resolution)


def dirty_beam(phi: jax.Array, resolution: int) -> jax.Array:
    """PSF: backprojection of the response to a unit source at the grid center."""
    n = resolution * resolution
    center = (resolution // 2) * resolution + resolution // 2
    delta = jnp.zeros((n,), dtype=phi.dtype).at[center].set(1.0)
    return dirty_image(phi, phi @ delta, resolution)


def tune_extent_for_gamma(
    station: Station,
    resolution: int,
    extents: np.ndarray,
    target: float = 1.0 / 16.0,
):
    """Supplementary §7.3 / Fig. 7: sweep the grid extent d and report γ(d).

    Returns a list of (d, gamma) and the largest d meeting γ ≤ target (or None).
    """
    from repro.core.rip import gamma_full

    results = []
    best = None
    for d in extents:
        phi = measurement_matrix(station, resolution, float(d))
        g = float(gamma_full(phi))
        results.append((float(d), g))
        if g <= target:
            best = float(d) if best is None else max(best, float(d))
    return results, best
