"""Sparse sky simulation: point-source skies and helpers (paper §4 setup:
30 strong sources on a 256×256 grid, recovered from one LOFAR station)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_sky(
    resolution: int,
    n_sources: int,
    key: jax.Array,
    flux_range: tuple[float, float] = (0.5, 1.0),
    margin: int = 2,
    min_sep: int = 3,
) -> jax.Array:
    """An (r²,) real non-negative sky vector with ``n_sources`` point sources.

    Sources are separated by at least ``min_sep`` pixels (celestial sources are
    resolved objects — support separation at the instrument-resolution scale is
    what makes the sampled RIP condition meaningful; see repro.sensing.telescope).
    Implemented by sampling distinct cells of the min_sep-coarsened grid and
    jittering inside each cell.
    """
    kpos, kflux, kjit = jax.random.split(key, 3)
    cells = max(1, (resolution - 2 * margin) // max(1, min_sep))
    if n_sources > cells * cells:
        raise ValueError("too many sources for this resolution/min_sep")
    flat = jax.random.choice(kpos, cells * cells, (n_sources,), replace=False)
    ci = flat // cells
    cj = flat % cells
    jit = jax.random.randint(kjit, (2, n_sources), 0, max(1, min_sep - 1))
    ii = jnp.clip(ci * min_sep + margin + jit[0], 0, resolution - 1)
    jj = jnp.clip(cj * min_sep + margin + jit[1], 0, resolution - 1)
    flux = jax.random.uniform(
        kflux, (n_sources,), minval=flux_range[0], maxval=flux_range[1]
    )
    img = jnp.zeros((resolution, resolution), jnp.float32)
    img = img.at[ii, jj].set(flux)
    return img.ravel()


def to_image(x: jax.Array, resolution: int) -> jax.Array:
    return jnp.real(x).reshape(resolution, resolution)


def ascii_render(img, width: int = 64, levels: str = " .:-=+*#%@") -> str:
    """Terminal rendering of a sky image (for examples' output)."""
    import numpy as np

    a = np.asarray(jnp.abs(img))
    r = a.shape[0]
    stride = max(1, r // width)
    a = a[::stride, ::stride]
    a = a / (a.max() + 1e-30)
    idx = (a * (len(levels) - 1)).astype(int)
    return "\n".join("".join(levels[v] for v in row) for row in idx)
