"""Radio-astronomy application substrate (paper §3.3 and supplementary §7)."""
from repro.sensing.gaussian import CSProblem, make_gaussian_problem
from repro.sensing.sky import ascii_render, make_sky, to_image
from repro.sensing.telescope import (
    Station,
    dirty_beam,
    dirty_image,
    measurement_matrix,
    sky_grid,
    tune_extent_for_gamma,
    visibilities,
)

__all__ = [
    "CSProblem",
    "make_gaussian_problem",
    "ascii_render",
    "make_sky",
    "to_image",
    "Station",
    "dirty_beam",
    "dirty_image",
    "measurement_matrix",
    "sky_grid",
    "tune_extent_for_gamma",
    "visibilities",
]
