"""Application substrates: radio astronomy (paper §3.3, suppl. §7) and MRI
(paper §5, quantized subsampled-Fourier brain imaging)."""
from repro.sensing.gaussian import CSProblem, make_gaussian_problem
from repro.sensing.mri import (
    MRIProblem,
    brain_phantom,
    cartesian_mask,
    kspace_band_scales,
    kspace_radial_bands,
    make_mri_problem,
    mri_observations,
    quantize_observations,
    shepp_logan,
    sparsify_image,
    wavelet_coeffs,
)
from repro.sensing.sky import ascii_render, make_sky, to_image
from repro.sensing.telescope import (
    Station,
    dirty_beam,
    dirty_image,
    measurement_matrix,
    sky_grid,
    tune_extent_for_gamma,
    visibilities,
)

__all__ = [
    "CSProblem",
    "make_gaussian_problem",
    "MRIProblem",
    "brain_phantom",
    "cartesian_mask",
    "kspace_band_scales",
    "kspace_radial_bands",
    "make_mri_problem",
    "mri_observations",
    "quantize_observations",
    "shepp_logan",
    "sparsify_image",
    "wavelet_coeffs",
    "ascii_render",
    "make_sky",
    "to_image",
    "Station",
    "dirty_beam",
    "dirty_image",
    "measurement_matrix",
    "sky_grid",
    "tune_extent_for_gamma",
    "visibilities",
]
