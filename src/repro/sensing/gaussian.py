"""Gaussian toy problem (paper supplementary §10 / Fig. 11):
Φ, e i.i.d. Gaussian; x s-sparse; sweep SNR; compare 2&8-bit vs 32-bit IHT."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CSProblem:
    phi: jax.Array
    y: jax.Array
    x_true: jax.Array
    e: jax.Array
    s: int


def make_gaussian_problem(
    m: int = 256,
    n: int = 512,
    s: int = 16,
    snr_db: Optional[float] = 10.0,
    key: Optional[jax.Array] = None,
    x_dist: str = "gaussian",
    phi: Optional[jax.Array] = None,
) -> CSProblem:
    """Random dense-Gaussian CS instance (Φ_{ij} ~ N(0, 1), unit variance as in
    supplementary §10; NIHT is scale-invariant so no column normalization).

    Pass ``phi`` to reuse one measurement matrix across problems (the batched
    serving scenario: many observations of the same Φ); only the sparse signal
    and noise are drawn from ``key`` then."""
    key = key if key is not None else jax.random.PRNGKey(0)
    kphi, kx, kflux, ke = jax.random.split(key, 4)
    if phi is None:
        phi = jax.random.normal(kphi, (m, n), jnp.float32)
    elif phi.shape != (m, n):
        raise ValueError(f"shared phi shape {phi.shape} != ({m}, {n})")
    idx = jax.random.choice(kx, n, (s,), replace=False)
    if x_dist == "gaussian":
        vals = jax.random.normal(kflux, (s,), jnp.float32)
    elif x_dist == "signs":
        vals = jnp.sign(jax.random.normal(kflux, (s,), jnp.float32))
    else:
        raise ValueError(x_dist)
    x = jnp.zeros((n,), jnp.float32).at[idx].set(vals)
    clean_y = phi @ x
    if snr_db is None:
        e = jnp.zeros((m,), jnp.float32)
    else:
        sig_pow = jnp.vdot(clean_y, clean_y)
        sigma = jnp.sqrt(sig_pow / (10.0 ** (snr_db / 10.0)) / m)
        e = sigma * jax.random.normal(ke, (m,), jnp.float32)
    return CSProblem(phi=phi, y=clean_y + e, x_true=x, e=e, s=s)
