"""Composable model zoo: dense GQA / MoE / SSM (Mamba-2 SSD) / hybrid
(RG-LRU + local attn) / enc-dec (Whisper) / VLM (cross-attn image layers)."""
from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.quantized import QWeight, QWeightStack, param_bytes, quantize_params

__all__ = [
    "ModelConfig",
    "decode_step",
    "encode",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
    "QWeight",
    "QWeightStack",
    "param_bytes",
    "quantize_params",
]
