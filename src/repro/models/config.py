"""Unified model configuration covering all assigned architecture families.

One frozen dataclass describes dense GQA transformers, MoE, SSM (Mamba-2 SSD),
hybrid (RG-LRU + local attention), encoder-decoder (Whisper) and VLM
(cross-attention image layers). Family-specific fields are zero/empty when
unused. ``src/repro/configs/<arch>.py`` instantiates one per assigned arch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention / block options ---
    qkv_bias: bool = False
    mlp_type: str = "swiglu"         # swiglu | gelu | relu2
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_chunk: int = 1024           # flash/chunked attention block size

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # --- hybrid (RecurrentGemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn"); () = all attn
    local_window: int = 0                 # sliding-window size for local attention
    rnn_width: int = 0                    # RG-LRU recurrent width (0 -> d_model)

    # --- encoder-decoder (Whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0                  # stub audio-frame tokens (post-conv)

    # --- VLM ---
    cross_attn_every: int = 0             # every k-th layer is a cross-attn layer
    n_image_tokens: int = 0               # stub patch-embedding tokens

    # --- numerics & padding ---
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    pad_heads_to: int = 1                 # pad q-heads to a multiple (TP divisibility)
    remat: bool = True                    # activation checkpointing in scan
    scan_unroll: bool = False             # fully unroll internal scans (dry-run
                                          # cost analysis: while bodies are
                                          # counted once by HloCostAnalysis)
    moe_group_size: int = 4096            # tokens per MoE dispatch group

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def padded_heads(self) -> int:
        return round_up(self.n_heads, self.pad_heads_to)

    @property
    def padded_kv_heads(self) -> int:
        """MHA (kv == q) must pad kv alongside q so GQA grouping stays exact;
        true-GQA kv counts are left as-is (replication decided by sharding)."""
        if self.n_kv_heads and self.n_kv_heads == self.n_heads:
            return self.padded_heads
        return self.n_kv_heads

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def rnn_width_(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM state / bounded local window)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            # attention layers must all be local (bounded window)
            return self.local_window > 0
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (whisper = enc-dec)

    def pattern_for_layers(self) -> Tuple[str, ...]:
        """Expanded per-layer block types of the *decoder* stack."""
        if self.family == "hybrid" and self.block_pattern:
            p = []
            while len(p) < self.n_layers:
                p.extend(self.block_pattern)
            return tuple(p[: self.n_layers])
        if self.family == "vlm" and self.cross_attn_every:
            return tuple(
                "xattn" if (i % self.cross_attn_every) == self.cross_attn_every - 2 else "attn"
                for i in range(self.n_layers)
            )
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.family == "encdec":
            # every decoder layer: self-attn + cross-attn to the encoder memory
            return tuple("xattn" for _ in range(self.n_layers))
        return tuple("attn" for _ in range(self.n_layers))

    # --- parameter counting (for roofline MODEL_FLOPS) ---
    def param_count(self) -> int:
        d, v = self.d_model, self.padded_vocab
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        pattern = self.pattern_for_layers()
        for kind in pattern:
            if kind in ("attn", "xattn"):
                qkv = d * self.padded_heads * hd + 2 * d * self.n_kv_heads * hd
                out = self.padded_heads * hd * d
                per_layer += qkv + out
            if kind == "ssm":
                din = self.d_inner
                in_p = d * (2 * din + 2 * self.ssm_state + self.ssm_heads)
                out_p = din * d
                per_layer += in_p + out_p
            if kind == "rec":
                w = self.rnn_width_
                # in-proj (2 branches), RG-LRU gates (r, i), conv, Λ, out-proj
                per_layer += d * 2 * w + 2 * w * w + self.ssm_conv * w + w + w * d
            # FFN
            if kind != "ssm":
                if self.n_experts:
                    per_layer += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
                elif self.mlp_type == "swiglu":
                    per_layer += 3 * d * self.d_ff
                else:
                    per_layer += 2 * d * self.d_ff
        enc = 0
        if self.n_encoder_layers:
            enc_attn = 4 * d * self.n_heads * hd
            enc_ffn = 2 * d * self.d_ff
            enc = self.n_encoder_layers * (enc_attn + enc_ffn)
        return emb + per_layer + enc

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.n_layers * self.experts_per_token * 3 * self.d_model * self.d_ff
        return total - all_experts + active
