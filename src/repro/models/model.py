"""Model assembly: decoder LMs (dense/MoE/SSM/hybrid/VLM) and enc-dec (Whisper).

Layers are stacked *by period slot* and iterated with ``lax.scan`` so the HLO
stays O(period) regardless of depth (94-layer MoE compiles as one scan):

  pattern  = cfg.pattern_for_layers()          e.g. ('rec','rec','attn')×8 + tail
  periods  = full repetitions  → scanned; tail = remainder → unrolled.

Three execution paths share the block code:
  * forward  — teacher-forced logits over (B, S) tokens (training),
  * prefill  — forward + KV/state cache construction (serving, long prompts),
  * decode   — one token against the cache (the bandwidth-bound loop the
               paper's technique accelerates via weight/KV quantization).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import rglru, ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    KVCache,
    apply_norm,
    cache_kv,
    cache_update,
    cache_update_window,
    chunked_attention,
    decode_attention,
    dense,
    dense_init,
    init_kv_cache,
    mlp_init,
    mlp_apply,
    norm_init,
    rope,
    sinusoidal_at,
    sinusoidal_positions,
    window_valid_length,
)
from repro.models.moe import moe_apply, moe_init
from repro.quant.policy import QuantPolicy

# ---------------------------------------------------------------------------
# init


def _attn_init(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.padded_heads, cfg.padded_kv_heads
    return {
        "wq": dense_init(ks[0], d, hq * hd, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, hkv * hd, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, hkv * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], hq * hd, d),
    }


def _ffn_init(key, cfg: ModelConfig):
    if cfg.n_experts:
        return moe_init(key, cfg.d_model, cfg.d_ff, cfg.n_experts)
    return mlp_init(key, cfg.d_model, cfg.d_ff, cfg.mlp_type)


def _block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": norm_init(d, cfg.norm_type)}
    if kind == "attn":
        p["attn"] = _attn_init(ks[0], cfg)
        p["ln2"] = norm_init(d, cfg.norm_type)
        p["ffn"] = _ffn_init(ks[1], cfg)
    elif kind == "xattn":
        p["attn"] = _attn_init(ks[0], cfg)
        p["ln_x"] = norm_init(d, cfg.norm_type)
        p["xattn"] = _attn_init(ks[2], cfg, cross=True)
        p["ln2"] = norm_init(d, cfg.norm_type)
        p["ffn"] = _ffn_init(ks[1], cfg)
    elif kind == "rec":
        p["rec"] = rglru.rglru_init(ks[0], d, cfg.rnn_width_, cfg.ssm_conv)
        p["ln2"] = norm_init(d, cfg.norm_type)
        p["ffn"] = _ffn_init(ks[1], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm.ssd_init(
            ks[0], d, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
        )
    else:
        raise ValueError(kind)
    return p


def _period_info(cfg: ModelConfig):
    pattern = cfg.pattern_for_layers()
    if cfg.family == "hybrid" and cfg.block_pattern:
        period = len(cfg.block_pattern)
    elif cfg.family == "vlm" and cfg.cross_attn_every:
        period = cfg.cross_attn_every
    else:
        period = 1
    n_full = cfg.n_layers // period
    slots = pattern[:period]
    tail = pattern[n_full * period :]
    return slots, n_full, tail


def init_params(cfg: ModelConfig, key: jax.Array):
    slots, n_full, tail = _period_info(cfg)
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.padded_vocab

    params: dict[str, Any] = {
        "embed": {"w": jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02},
        "final_norm": norm_init(d, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"w": jax.random.normal(keys[1], (v, d), jnp.float32) * 0.02}

    def stack_init(base_key, kind, n):
        ks = jax.random.split(base_key, n)
        return jax.vmap(lambda k: _block_init(k, cfg, kind))(ks)

    params["slots"] = {
        f"slot{j}": stack_init(jax.random.fold_in(keys[2], j), kind, n_full)
        for j, kind in enumerate(slots)
    }
    params["tail"] = [
        _block_init(jax.random.fold_in(keys[3], i), cfg, kind)
        for i, kind in enumerate(tail)
    ]
    if cfg.n_encoder_layers:
        ks = jax.random.split(keys[4], cfg.n_encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _block_init(k, cfg, "attn"))(ks),
            "final_norm": norm_init(d, cfg.norm_type),
        }
    return params


# ---------------------------------------------------------------------------
# block application


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    positions: jax.Array                    # (B, S) int32
    policy: QuantPolicy
    memory: Optional[jax.Array] = None      # encoder output / image embeds (B, T, d)
    causal: bool = True
    window: Optional[int] = None
    # activation-sharding hook (sequence parallelism): applied to the residual
    # stream at period boundaries — these are exactly the tensors remat stores,
    # so constraining them shards the activation footprint across TP.
    constrain: Optional[Any] = None
    # KV-cache sharding pin (decode): without it the SPMD partitioner may pick
    # a head-sharded internal layout for the scan-carried cache and pay a
    # full-cache all-gather at the loop boundary every token (§Perf H2-H4).
    constrain_kv: Optional[Any] = None


def _maybe_constrain(ctx, x):
    return ctx.constrain(x) if ctx.constrain is not None else x


def _qkv(p, x, cfg, positions, n_heads):
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = dense(p["wq"], x).reshape(b, s, n_heads, hd)
    k = dense(p["wk"], x).reshape(b, s, cfg.padded_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.padded_kv_heads, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # (B, H, S, D)
    return q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _self_attention(p, x, ctx: Ctx):
    cfg = ctx.cfg
    q, k, v = _qkv(p, x, cfg, ctx.positions if cfg.family != "encdec" else None,
                   cfg.padded_heads)
    out = chunked_attention(
        q, k, v, causal=ctx.causal, chunk=cfg.attn_chunk, window=ctx.window,
        unroll=cfg.scan_unroll,
    )
    b, h, s, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return dense(p["wo"], out)


def _cross_attention(p, x, ctx: Ctx):
    cfg = ctx.cfg
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = dense(p["wq"], x).reshape(b, s, cfg.padded_heads, hd).transpose(0, 2, 1, 3)
    mem = ctx.memory
    k = dense(p["wk"], mem).reshape(b, -1, cfg.padded_kv_heads, hd).transpose(0, 2, 1, 3)
    v = dense(p["wv"], mem).reshape(b, -1, cfg.padded_kv_heads, hd).transpose(0, 2, 1, 3)
    out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk,
                            unroll=cfg.scan_unroll)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.padded_heads * hd)
    return dense(p["wo"], out)


def _ffn_apply(p, x, cfg: ModelConfig):
    if cfg.n_experts:
        y, aux = moe_apply(
            p, x, top_k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor,
            group_size=cfg.moe_group_size,
            unroll=cfg.scan_unroll,
        )
        return y, aux
    return mlp_apply(p, x, cfg.mlp_type), {}


def apply_block_fwd(kind: str, p, x, ctx: Ctx):
    """Full-sequence forward (train / encoder). Returns (x, aux)."""
    cfg = ctx.cfg
    aux = {}
    h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    if kind in ("attn", "xattn"):
        x = x + _self_attention(p["attn"], h, ctx)
        if kind == "xattn":
            hx = apply_norm(p["ln_x"], x, cfg.norm_type, cfg.norm_eps)
            x = x + _cross_attention(p["xattn"], hx, ctx)
    elif kind == "rec":
        x = x + rglru.rglru_apply(p["rec"], h, cfg.rnn_width_)
    elif kind == "ssm":
        return x + ssm.ssd_apply(p["ssm"], h, cfg), aux
    h2 = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
    y, aux = _ffn_apply(p["ffn"], h2, cfg)
    return x + y, aux


def _empty_cache_entry(kind: str, cfg: ModelConfig, b: int, cache_len: int, dtype,
                       kv_bits, mem_len: int = 0):
    hd = cfg.head_dim_
    if kind == "attn":
        if cfg.family == "hybrid" and cfg.local_window:
            cache_len = min(cache_len, cfg.local_window)
        return init_kv_cache(b, cfg.padded_kv_heads, cache_len, hd, dtype, kv_bits)
    if kind == "xattn":
        return {
            "self": init_kv_cache(b, cfg.padded_kv_heads, cache_len, hd, dtype, kv_bits),
            "ck": jnp.zeros((b, cfg.padded_kv_heads, mem_len, hd), dtype),
            "cv": jnp.zeros((b, cfg.padded_kv_heads, mem_len, hd), dtype),
        }
    if kind == "rec":
        return rglru.init_rglru_state(b, cfg.rnn_width_, cfg.ssm_conv)
    if kind == "ssm":
        return ssm.init_ssm_state(b, cfg)
    raise ValueError(kind)


def apply_block_prefill(kind: str, p, x, cache_entry, ctx: Ctx):
    """Forward + cache fill. Returns (x, cache_entry)."""
    cfg = ctx.cfg
    if kind in ("attn", "xattn"):
        h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
        q, k, v = _qkv(p["attn"], h, cfg, ctx.positions, cfg.padded_heads)
        out = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                                window=ctx.window, unroll=cfg.scan_unroll)
        b, hh, s, hd = out.shape
        x = x + dense(p["attn"]["wo"], out.transpose(0, 2, 1, 3).reshape(b, s, hh * hd))
        if kind == "xattn":
            hx = apply_norm(p["ln_x"], x, cfg.norm_type, cfg.norm_eps)
            x = x + _cross_attention(p["xattn"], hx, ctx)
            mem = ctx.memory
            ck = dense(p["xattn"]["wk"], mem).reshape(b, -1, cfg.padded_kv_heads, hd).transpose(0, 2, 1, 3)
            cv = dense(p["xattn"]["wv"], mem).reshape(b, -1, cfg.padded_kv_heads, hd).transpose(0, 2, 1, 3)
            sc = cache_update(cache_entry["self"], k, v, ctx.policy.kv_bits)
            cache_entry = {"self": sc, "ck": ck.astype(x.dtype), "cv": cv.astype(x.dtype)}
        elif ctx.window is not None:
            cache_entry = cache_update_window(cache_entry, k, v, ctx.window,
                                              ctx.policy.kv_bits)
        else:
            cache_entry = cache_update(cache_entry, k, v, ctx.policy.kv_bits)
        h2 = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], h2, cfg)
        return x + y, cache_entry
    if kind == "rec":
        # run the sequence, then reconstruct the final recurrent state
        h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
        y, new_state = _rglru_prefill(p["rec"], h, cfg, cache_entry)
        x = x + y
        h2 = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        yf, _ = _ffn_apply(p["ffn"], h2, cfg)
        return x + yf, new_state
    if kind == "ssm":
        h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
        y, new_state = _ssd_prefill(p["ssm"], h, cfg, cache_entry)
        return x + y, new_state
    raise ValueError(kind)


def _rglru_prefill(p, u, cfg, state: rglru.RGLRUState):
    from repro.models.quantized import materialize as _mat

    x = u @ _mat(p["in_x"]["w"], u.dtype)
    gate = u @ _mat(p["in_gate"]["w"], u.dtype)
    xc, conv_new = rglru._conv(p, x, state.conv)
    a, b = rglru._gates(p, xc)

    def combine(l, r):
        return l[0] * r[0], r[0] * l[1] + r[1]

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(u.dtype) * jax.nn.gelu(gate)
    y = y @ _mat(p["out"]["w"], u.dtype)
    return y, rglru.RGLRUState(conv=conv_new, h=h[:, -1])


def _ssd_prefill(p, u, cfg, state: ssm.SSMState):
    """Chunked SSD that also returns the final recurrent state."""
    # reuse ssd_apply for outputs; recompute final state via one extra scan
    y = ssm.ssd_apply(p, u, cfg)
    # final state: run the decode recurrence over the last ssm_conv inputs is
    # insufficient; instead compute exactly with the chunked state recursion.
    final = _ssd_final_state(p, u, cfg)
    # conv state: last (d_conv - 1) pre-conv channels
    z, xr, bb, cc, dt = ssm._split_proj(p, u, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads)
    xbc = jnp.concatenate([xr, bb, cc], axis=-1)
    k = cfg.ssm_conv
    conv_state = xbc[:, -(k - 1):, :].astype(jnp.float32) if k > 1 else state.conv
    return y, ssm.SSMState(conv=conv_state, ssm=final)


def _ssd_final_state(p, u, cfg):
    b, s, _ = u.shape
    h, hd, ds, ck = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, min(cfg.ssm_chunk, u.shape[1])
    z, xr, bb, cc, dt = ssm._split_proj(p, u, cfg.d_inner, ds, h)
    xbc = jnp.concatenate([xr, bb, cc], axis=-1)
    xbc, _ = ssm._causal_conv(p, xbc)
    xr, bb, cc = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = (dt * a).reshape(b, s // ck, ck, h)
    cum = jnp.cumsum(da, axis=2)
    xh = xr.astype(jnp.float32).reshape(b, s // ck, ck, h, hd)
    bh = bb.astype(jnp.float32).reshape(b, s // ck, ck, ds)
    dth = dt.reshape(b, s // ck, ck, h)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bnsh,bnsh,bnshp,bnsd->bnhpd", decay_to_end, dth, xh, bh)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def step(carry, inp):
        st_new, dec = inp
        return carry * dec[:, :, None, None] + st_new, None

    final, _ = jax.lax.scan(
        step,
        jnp.zeros((b, h, hd, ds), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    return final


def apply_block_decode(kind: str, p, x, cache_entry, ctx: Ctx):
    """One-token step against the cache. x: (B, 1, d)."""
    cfg = ctx.cfg
    h = apply_norm(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    if kind in ("attn", "xattn"):
        entry = cache_entry["self"] if kind == "xattn" else cache_entry
        q, k_new, v_new = _qkv(p["attn"], h, cfg, ctx.positions, cfg.padded_heads)
        if kind == "attn" and ctx.window is not None:
            entry = cache_update_window(entry, k_new, v_new, ctx.window,
                                        ctx.policy.kv_bits)
            k_all, v_all = cache_kv(entry, ctx.policy.kv_bits, x.dtype)
            out = decode_attention(
                q, k_all, v_all, length=window_valid_length(entry, ctx.window)
            )
        else:
            entry = cache_update(entry, k_new, v_new, ctx.policy.kv_bits)
            if ctx.constrain_kv is not None:
                entry = entry._replace(k=ctx.constrain_kv(entry.k),
                                       v=ctx.constrain_kv(entry.v))
            k_all, v_all = cache_kv(entry, ctx.policy.kv_bits, x.dtype)
            out = decode_attention(q, k_all, v_all, length=entry.length)
        b, hh, _, hd = out.shape
        x = x + dense(p["attn"]["wo"], out.transpose(0, 2, 1, 3).reshape(b, 1, hh * hd))
        if kind == "xattn":
            hx = apply_norm(p["ln_x"], x, cfg.norm_type, cfg.norm_eps)
            qx = dense(p["xattn"]["wq"], hx).reshape(b, 1, cfg.padded_heads, hd).transpose(0, 2, 1, 3)
            ck, cv = cache_entry["ck"], cache_entry["cv"]
            ox = decode_attention(qx, ck, cv, length=jnp.asarray(ck.shape[2]))
            x = x + dense(p["xattn"]["wo"], ox.transpose(0, 2, 1, 3).reshape(b, 1, cfg.padded_heads * hd))
            cache_entry = {"self": entry, "ck": ck, "cv": cv}
        else:
            cache_entry = entry
        h2 = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], h2, cfg)
        return x + y, cache_entry
    if kind == "rec":
        y, new_state = rglru.rglru_decode_step(p["rec"], h, cache_entry, cfg.rnn_width_)
        x = x + y
        h2 = apply_norm(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        yf, _ = _ffn_apply(p["ffn"], h2, cfg)
        return x + yf, new_state
    if kind == "ssm":
        y, new_state = ssm.ssd_decode_step(p["ssm"], h, cache_entry, cfg)
        return x + y, new_state
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model paths


def _embed(cfg, params, tokens, dtype):
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(dtype)
    return x


def _unembed(cfg, params, x):
    w = params["embed"]["w"] if cfg.tie_embeddings else params["unembed"]["w"]
    from repro.models.quantized import materialize

    wt = materialize(w, x.dtype)
    if wt.shape[0] == cfg.padded_vocab:          # stored (V, d)
        return x @ wt.T
    return x @ wt


def encode(cfg: ModelConfig, params, frames: jax.Array, policy: QuantPolicy):
    """Whisper-style encoder over stub frame embeddings (B, T, d)."""
    dtype = jnp.dtype(cfg.dtype)
    x = frames.astype(dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]
    ctx = Ctx(cfg=cfg, positions=None, policy=policy, causal=False)

    def body(x, p):
        y, _ = apply_block_fwd("attn", p, x, ctx)
        return y, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"],
                        unroll=cfg.n_encoder_layers if cfg.scan_unroll else 1)
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm_type, cfg.norm_eps)


def forward(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,
    *,
    policy: QuantPolicy = QuantPolicy(),
    memory: Optional[jax.Array] = None,
    constrain=None,
):
    """Teacher-forced logits (B, S, V). ``memory`` = encoder output (enc-dec)
    or stub image embeddings (VLM)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    slots, n_full, tail = _period_info(cfg)
    x = _embed(cfg, params, tokens, dtype)
    if cfg.family == "encdec":
        x = x + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    window = cfg.local_window if cfg.family == "hybrid" else None
    ctx = Ctx(cfg=cfg, positions=positions, policy=policy, memory=memory,
              causal=True, window=window, constrain=constrain)
    aux_acc = {"moe_load_loss": jnp.zeros((), jnp.float32)}
    x = _maybe_constrain(ctx, x)

    def period_body(carry, slot_params):
        x, aux = carry
        for j, kind in enumerate(slots):
            x, a = apply_block_fwd(kind, slot_params[j], x, ctx)
            if "moe_load_loss" in a:
                aux = {"moe_load_loss": aux["moe_load_loss"] + a["moe_load_loss"]}
        x = _maybe_constrain(ctx, x)
        return (x, aux), None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    xs = tuple(params["slots"][f"slot{j}"] for j in range(len(slots)))
    (x, aux_acc), _ = jax.lax.scan(body, (x, aux_acc), xs,
                                   unroll=n_full if cfg.scan_unroll else 1)
    for i, kind in enumerate(tail):
        x, a = apply_block_fwd(kind, params["tail"][i], x, ctx)
        if "moe_load_loss" in a:
            aux_acc["moe_load_loss"] = aux_acc["moe_load_loss"] + a["moe_load_loss"]
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    return logits, aux_acc


def loss_fn(cfg, params, batch, policy: QuantPolicy = QuantPolicy(), constrain=None):
    """Mean next-token cross entropy. batch: tokens (B,S), labels (B,S) (-1=pad),
    optional memory (enc-dec: stub frontend *frames*, encoded here; VLM: stub
    patch embeddings, consumed directly by the cross-attn layers)."""
    memory = batch.get("memory")
    if cfg.family == "encdec" and memory is not None:
        memory = encode(cfg, params, memory, policy)
    logits, aux = forward(cfg, params, batch["tokens"], policy=policy,
                          memory=memory, constrain=constrain)
    labels = batch["labels"]
    mask = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    if cfg.n_experts:
        loss = loss + 0.01 * aux["moe_load_loss"] / max(cfg.n_layers, 1)
    return loss


def init_cache(cfg: ModelConfig, b: int, cache_len: int,
               policy: QuantPolicy = QuantPolicy(), mem_len: int = 0):
    """Stacked cache pytree matching the slot structure."""
    slots, n_full, tail = _period_info(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def stacked(kind):
        one = _empty_cache_entry(kind, cfg, b, cache_len, dtype, policy.kv_bits, mem_len)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_full,) + a.shape).copy(), one)

    return {
        "slots": {f"slot{j}": stacked(kind) for j, kind in enumerate(slots)},
        "tail": [
            _empty_cache_entry(kind, cfg, b, cache_len, dtype, policy.kv_bits, mem_len)
            for kind in tail
        ],
    }


def prefill(cfg: ModelConfig, params, tokens, cache, *,
            policy: QuantPolicy = QuantPolicy(), memory=None):
    """Run the prompt, fill the cache. Returns (last-position logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    slots, n_full, tail = _period_info(cfg)
    x = _embed(cfg, params, tokens, dtype)
    if cfg.family == "encdec":
        x = x + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    window = cfg.local_window if cfg.family == "hybrid" else None
    ctx = Ctx(cfg=cfg, positions=positions, policy=policy, memory=memory,
              causal=True, window=window)

    def period_body(x, scanned):
        slot_params, slot_caches = scanned
        new_caches = []
        for j, kind in enumerate(slots):
            x, c = apply_block_prefill(kind, slot_params[j], x, slot_caches[j], ctx)
            new_caches.append(c)
        return x, tuple(new_caches)

    xs = (
        tuple(params["slots"][f"slot{j}"] for j in range(len(slots))),
        tuple(cache["slots"][f"slot{j}"] for j in range(len(slots))),
    )
    x, new_slot_caches = jax.lax.scan(period_body, x, xs,
                                      unroll=n_full if cfg.scan_unroll else 1)
    new_cache = {
        "slots": {f"slot{j}": new_slot_caches[j] for j in range(len(slots))},
        "tail": [],
    }
    for i, kind in enumerate(tail):
        x, c = apply_block_prefill(kind, params["tail"][i], x, cache["tail"][i], ctx)
        new_cache["tail"].append(c)
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = _unembed(cfg, params, x[:, -1:, :])
    return logits[:, 0], new_cache


def decode_step(cfg: ModelConfig, params, token, cache, *,
                policy: QuantPolicy = QuantPolicy(), position=None,
                constrain_kv=None):
    """One serving step. token: (B,) int32 → logits (B, V), updated cache."""
    dtype = jnp.dtype(cfg.dtype)
    b = token.shape[0]
    slots, n_full, tail = _period_info(cfg)
    x = _embed(cfg, params, token[:, None], dtype)
    if position is None:
        position = _cache_length(cfg, cache)
    position = jnp.asarray(position, jnp.int32)
    positions = jnp.broadcast_to(position.reshape(1, 1), (b, 1)).astype(jnp.int32)
    if cfg.family == "encdec":
        x = x + sinusoidal_at(position, cfg.d_model).astype(dtype)[None, None]
    window = cfg.local_window if cfg.family == "hybrid" else None
    ctx = Ctx(cfg=cfg, positions=positions, policy=policy, causal=True, window=window,
              constrain_kv=constrain_kv)

    def period_body(x, scanned):
        slot_params, slot_caches = scanned
        new_caches = []
        for j, kind in enumerate(slots):
            x, c = apply_block_decode(kind, slot_params[j], x, slot_caches[j], ctx)
            new_caches.append(c)
        return x, tuple(new_caches)

    xs = (
        tuple(params["slots"][f"slot{j}"] for j in range(len(slots))),
        tuple(cache["slots"][f"slot{j}"] for j in range(len(slots))),
    )
    x, new_slot_caches = jax.lax.scan(period_body, x, xs,
                                      unroll=n_full if cfg.scan_unroll else 1)
    new_cache = {
        "slots": {f"slot{j}": new_slot_caches[j] for j in range(len(slots))},
        "tail": [],
    }
    for i, kind in enumerate(tail):
        x, c = apply_block_decode(kind, params["tail"][i], x, cache["tail"][i], ctx)
        new_cache["tail"].append(c)
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    return logits[:, 0], new_cache


def _cache_length(cfg, cache):
    """Current length from the first attention cache (or conv position proxy)."""
    slots_dict = cache["slots"]
    for v in slots_dict.values():
        if isinstance(v, KVCache):
            return v.length[0] if v.length.ndim else v.length
        if isinstance(v, dict) and "self" in v:
            return v["self"].length[0] if v["self"].length.ndim else v["self"].length
    # attention-free: caller must pass position explicitly for RoPE-free stacks
    return jnp.zeros((), jnp.int32)
