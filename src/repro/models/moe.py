"""Mixture-of-Experts FFN: top-k routing with capacity-factor dispatch einsums.

Token-group formulation (Switch/Mesh-TF lineage, MaxText-style): tokens are
processed in groups of ``group_size`` via ``lax.scan``; each group builds a
(g, E, C) dispatch tensor with per-group capacity C = g·k/E·cf. This bounds
live activation memory to O(g·k·cf·d) regardless of batch·seq, at the cost of
re-streaming the expert weights once per group — the group size is therefore a
first-order bandwidth/memory trade-off (exploited by the serving benchmarks).

Sharding: the expert dimension of the weights lives on the `model` mesh axis
(expert parallelism); dispatch/combine einsums then induce all-to-all-style
collectives under pjit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.quantized import QWeight, materialize


def moe_init(key, d: int, ff: int, n_experts: int):
    ks = jax.random.split(key, 4)
    scale = 0.02
    return {
        "router": dense_init(ks[0], d, n_experts),
        "wi_gate": jax.random.normal(ks[1], (n_experts, d, ff), jnp.float32) * scale,
        "wi_up": jax.random.normal(ks[2], (n_experts, d, ff), jnp.float32) * scale,
        "wo": jax.random.normal(ks[3], (n_experts, ff, d), jnp.float32) * scale,
    }


def _group_moe(p, xg: jax.Array, *, top_k: int, cap: int, dtype):
    """One token group. xg: (g, d) → (y (g, d), aux scalars)."""
    g, d = xg.shape
    rw = p["router"]["w"]
    e = rw.packed.shape[-2] if isinstance(rw, QWeight) else rw.shape[1]
    logits = xg.astype(jnp.float32) @ materialize(p["router"]["w"], jnp.float32)  # (g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)                 # (g, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)             # (g, k, E)
    flat = onehot.reshape(g * top_k, e)
    pos = (jnp.cumsum(flat, axis=0) * flat - 1).reshape(g, top_k, e)  # slot index
    within = (pos >= 0) & (pos < cap)

    slot = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=dtype)  # (g,k,E,C)
    keep = (within[..., None].astype(dtype)) * onehot[..., None].astype(dtype)
    disp = jnp.sum(slot * keep, axis=1)                                # (g, E, C)
    combine = jnp.sum(slot * keep * gate_vals[:, :, None, None].astype(dtype), axis=1)

    xe = jnp.einsum("td,tec->ecd", xg.astype(dtype), disp)            # (E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, materialize(p["wi_gate"], dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, materialize(p["wi_up"], dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, materialize(p["wo"], dtype))   # (E, C, d)
    y = jnp.einsum("ecd,tec->td", ye, combine)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(onehot.astype(jnp.float32), axis=1), axis=0)
    load_loss = e * jnp.sum(me * ce)
    return y, load_loss


def moe_apply(
    p,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 4096,
    unroll: bool = False,
):
    """x: (B, S, d) → (B, S, d), aux dict. B·S is padded to a group multiple."""
    b, s, d = x.shape
    n_tok = b * s
    rw = p["router"]["w"]
    e = rw.packed.shape[-2] if isinstance(rw, QWeight) else rw.shape[1]
    g = min(group_size, n_tok)
    n_groups = -(-n_tok // g)
    pad = n_groups * g - n_tok
    xf = x.reshape(n_tok, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    cap = max(1, int(g * top_k / e * capacity_factor))
    xg = xf.reshape(n_groups, g, d)

    if n_groups == 1:
        y, load = _group_moe(p, xg[0], top_k=top_k, cap=cap, dtype=x.dtype)
        ys = y[None]
    else:
        def step(_, xg_i):
            y, load = _group_moe(p, xg_i, top_k=top_k, cap=cap, dtype=x.dtype)
            return None, (y, load)

        # remat per group: a group's dispatch/combine tensors are rebuilt in
        # the backward instead of being stored for all n_groups at once —
        # O(group) live memory instead of O(tokens) (the memory-bound regime).
        step = jax.checkpoint(step)
        _, (ys, loads) = jax.lax.scan(step, None, xg,
                                      unroll=n_groups if unroll else 1)
        load = jnp.mean(loads)
    out = ys.reshape(n_groups * g, d)[:n_tok].reshape(b, s, d)
    return out, {"moe_load_loss": load}
