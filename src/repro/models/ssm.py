"""Mamba-2 (SSD — state-space duality) block, after Dao & Gu 2024 (arXiv
2405.21060), in the minimal chunked-discrete formulation:

  per head h, scalar decay a_t = exp(Δ_t · A_h)   (A_h = −exp(A_log_h) < 0)
  h_t = a_t · h_{t−1} + Δ_t · B_t xᵀ_t            (state: (headdim, d_state))
  y_t = C_t · h_t + D_h · x_t

Training/prefill uses the chunked algorithm (intra-chunk quadratic attention-
like term + inter-chunk state recurrence, chunk = cfg.ssm_chunk); decode is the
O(1) recurrent step — which is what makes the 500k-context shape tractable.

Layout: in_proj → (z, x, B, C, Δ); depthwise causal conv on (x, B, C);
gated RMSNorm on y·silu(z); out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.quantized import materialize


def ssd_init(key, d_model: int, d_inner: int, d_state: int, n_heads: int, d_conv: int):
    ks = jax.random.split(key, 5)
    conv_dim = d_inner + 2 * d_state
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner + 2 * d_state + n_heads),
        "conv_w": jax.random.normal(ks[1], (d_conv, conv_dim), jnp.float32) * 0.02,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, d_model),
    }


class SSMState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_dim) rolling conv inputs
    ssm: jax.Array    # (B, H, headdim, d_state) recurrent state


def init_ssm_state(b: int, cfg) -> SSMState:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return SSMState(
        conv=jnp.zeros((b, cfg.ssm_conv - 1, conv_dim), jnp.float32),
        ssm=jnp.zeros((b, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    )


def _split_proj(p, u, d_inner, d_state, n_heads):
    zxbcdt = u @ materialize(p["in_proj"]["w"], u.dtype)
    z, xr, bb, cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state], axis=-1
    )
    return z, xr, bb, cc, dt


def _causal_conv(p, xbc, conv_state=None):
    """Depthwise causal conv1d over (B, S, C); returns (out, new_state)."""
    w = p["conv_w"].astype(xbc.dtype)           # (K, C)
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)     # (B, S+K-1, C)
    out = sum(xp[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    out = jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))
    new_state = xp[:, -(k - 1) :, :] if k > 1 else pad
    return out, new_state


def _gated_norm(p, y, z, eps=1e-6):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps) * p["norm_scale"]).astype(y.dtype)


def ssd_apply(p, u: jax.Array, cfg) -> jax.Array:
    """Chunked SSD forward. u: (B, S, d_model) → (B, S, d_model).
    S is zero-padded to a chunk multiple internally (causal: trailing pad
    positions cannot affect real outputs)."""
    s_orig = u.shape[1]
    ck0 = min(cfg.ssm_chunk, s_orig)
    pad = (-s_orig) % ck0
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    b, s, _ = u.shape
    h, hd, ds, ck = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, ck0
    z, xr, bb, cc, dt = _split_proj(p, u, cfg.d_inner, ds, h)
    xbc = jnp.concatenate([xr, bb, cc], axis=-1)
    xbc, _ = _causal_conv(p, xbc)
    xr, bb, cc = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,S,H)
    a = -jnp.exp(p["a_log"])                                          # (H,)
    da = dt * a                                                       # (B,S,H) log-decay
    nc = s // ck
    xh = xr.astype(jnp.float32).reshape(b, nc, ck, h, hd)
    bh = bb.astype(jnp.float32).reshape(b, nc, ck, ds)
    chh = cc.astype(jnp.float32).reshape(b, nc, ck, ds)
    dah = da.reshape(b, nc, ck, h)
    dth = dt.reshape(b, nc, ck, h)

    # cumulative log-decay within chunk
    cum = jnp.cumsum(dah, axis=2)                                     # (B,nc,ck,H)
    # intra-chunk: L[t,τ] = exp(cum_t − cum_τ) for t >= τ.
    # Mask the EXPONENT (not the exp) — upper-triangle entries are large
    # positive and would overflow, poisoning gradients through jnp.where.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]               # (B,nc,t,τ,H)
    tri = jnp.tril(jnp.ones((ck, ck), bool))[None, None, :, :, None]
    l_mat = jnp.exp(jnp.where(tri, seg, -1e30))
    scores = jnp.einsum("bntd,bnsd->bnts", chh, bh)                   # (B,nc,t,τ)
    y_diag = jnp.einsum("bnts,bntsh,bnsh,bnshp->bnthp",
                        scores, l_mat, dth, xh)

    # chunk-final states: S_n = Σ_τ exp(cum_end − cum_τ)·Δ_τ·x_τ Bᵀ_τ
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,ck,H)
    states = jnp.einsum("bnsh,bnsh,bnshp,bnsd->bnhpd",
                        decay_to_end, dth, xh, bh)                    # (B,nc,H,hd,ds)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # (B,nc,H)

    def chunk_step(carry, inp):
        st_prev = carry                                               # (B,H,hd,ds)
        st_new, dec = inp
        st = st_prev * dec[:, :, None, None] + st_new
        return st, st_prev

    (final, prev_states) = jax.lax.scan(
        chunk_step,
        jnp.zeros((b, h, hd, ds), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=nc if getattr(cfg, "scan_unroll", False) else 1,
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)                # (B,nc,H,hd,ds)

    # inter-chunk contribution: y_t += C_t · exp(cum_t)·S_{n−1}
    decay_in = jnp.exp(cum)                                           # (B,nc,ck,H)
    y_off = jnp.einsum("bntd,bnth,bnhpd->bnthp", chh, decay_in, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, hd)
    y = y + xh.reshape(b, s, h, hd) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = _gated_norm(p, y.astype(u.dtype), z)
    y = y @ materialize(p["out_proj"]["w"], u.dtype)
    return y[:, :s_orig] if pad else y


def ssd_decode_step(p, u: jax.Array, state: SSMState, cfg):
    """One-token recurrent step. u: (B, 1, d_model) → (y (B,1,d_model), state)."""
    b = u.shape[0]
    h, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xr, bb, cc, dt = _split_proj(p, u, cfg.d_inner, ds, h)
    xbc = jnp.concatenate([xr, bb, cc], axis=-1)
    xbc, conv_new = _causal_conv(p, xbc, state.conv)
    xr, bb, cc = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a)                                              # (B,H)
    xh = xr.astype(jnp.float32).reshape(b, h, hd)
    bh = bb.astype(jnp.float32)[:, 0]                                  # (B,ds)
    chh = cc.astype(jnp.float32)[:, 0]                                 # (B,ds)

    ssm_new = state.ssm * dec[:, :, None, None] + jnp.einsum(
        "bh,bhp,bd->bhpd", dt, xh, bh
    )
    y = jnp.einsum("bd,bhpd->bhp", chh, ssm_new)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, cfg.d_inner).astype(u.dtype)
    y = _gated_norm(p, y, z)
    y = y @ materialize(p["out_proj"]["w"], u.dtype)
    return y, SSMState(conv=conv_new, ssm=ssm_new)
