"""Weight-only quantized parameters — the paper's low-precision data
representation applied to LM serving.

Decode is the LM analog of IHT: an iterative, HBM-bandwidth-bound loop that
re-streams a fixed large operand (weights ↔ measurement matrix) against a small
iterate (activations ↔ residual). Storing weights as packed 2/4/8-bit codes
cuts the streamed bytes by 16/8/4× — exactly the paper's FPGA/CPU mechanism.

* :class:`QWeight` — packed codes + per-channel scale for an (..., in, out)
  kernel; arbitrary leading dims are preserved, so scan-stacked layer weights
  (L, in, out) and MoE expert stacks (L, E, in, out) quantize uniformly AND
  slice correctly inside ``lax.scan`` (both leaves carry the leading dims).
* :func:`qdense`/:func:`materialize` — dequantize in-graph (ref/dry-run path;
  the Pallas ``qmm`` kernel consumes the same packed layout on TPU).
* :func:`quantize_params` — rewrite a param tree for serving.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import BY_BITS
from repro.quant.pack import pack_codes, unpack_codes
from repro.quant.quantize import quantize_codes


@jax.tree_util.register_pytree_node_class
class QWeight:
    """An (..., in, out) kernel stored as (..., out, packed_in) codes."""

    def __init__(self, packed: jax.Array, scale: jax.Array, bits: int, k_dim: int):
        self.packed = packed          # (..., out, packed_len(in, bits)) uint8
        self.scale = scale            # (..., out, 1) f32
        self.bits = int(bits)
        self.k_dim = int(k_dim)       # logical `in` (contraction) dimension

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Returns the (..., in, out) kernel."""
        codes = unpack_codes(self.packed, self.bits, self.k_dim)  # (..., out, in)
        k = BY_BITS[self.bits].half_steps
        w = codes.astype(jnp.float32) * (self.scale / k)
        return jnp.swapaxes(w, -1, -2).astype(dtype)

    def tree_flatten(self):
        return (self.packed, self.scale), (self.bits, self.k_dim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])


def quantize_weight(w: jax.Array, bits: int, key: Optional[jax.Array] = None) -> QWeight:
    """Quantize an (..., in, out) kernel; per-(leading dims × out-channel) scale;
    codes packed along the contraction (in) axis — the qmm kernel layout."""
    wt = jnp.swapaxes(w, -1, -2)             # (..., out, in)
    lead = wt.shape[:-1]
    k_dim = wt.shape[-1]
    flat = wt.reshape(-1, k_dim)
    codes, scale = quantize_codes(flat, bits, key, channel_axis=0)
    packed = pack_codes(codes, bits)
    return QWeight(
        packed.reshape(lead + (packed.shape[-1],)),
        scale.reshape(lead + (1,)).astype(jnp.float32),
        bits,
        k_dim,
    )


def materialize(w, dtype):
    """Dense kernel from either a plain array or a QWeight."""
    if isinstance(w, QWeight):
        return w.dequantize(dtype)
    return w.astype(dtype)


def qdense(p, x: jax.Array, dtype=None) -> jax.Array:
    dtype = dtype or x.dtype
    y = x @ materialize(p["w"], dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


_SKIP_SUBTREES = ("embed",)            # token-embedding gather stays dense
_QUANT_KEYS = ("w", "wi_gate", "wi_up", "wo")


def quantize_params(params, bits: int, key: Optional[jax.Array] = None,
                    stochastic: bool = False):
    """Rewrite eligible kernels (any >=2-D float 'w' / MoE expert stack outside
    norms and the token embedding) as packed Q-weights. Deterministic nearest
    rounding by default — serving wants reproducible weights; stochastic+key
    gives the unbiased variant."""
    counter = [0]

    def next_key():
        counter[0] += 1
        if stochastic and key is not None:
            return jax.random.fold_in(key, counter[0])
        return None

    def eligible(k, v):
        return (
            k in _QUANT_KEYS
            and hasattr(v, "ndim")
            and v.ndim >= 2
            and v.dtype in (jnp.float32, jnp.bfloat16)
        )

    def rewrite(path, sub):
        if isinstance(sub, (list, tuple)):
            return type(sub)(rewrite(path + (str(i),), e) for i, e in enumerate(sub))
        if not isinstance(sub, dict):
            return sub
        out = {}
        for k, v in sub.items():
            p = path + (k,)
            if any(s in p for s in _SKIP_SUBTREES):
                out[k] = v
            elif isinstance(v, (dict, list, tuple)):
                out[k] = rewrite(p, v)
            elif eligible(k, v):
                out[k] = quantize_weight(v, bits, next_key())
            else:
                out[k] = v
        return out

    return rewrite((), params)


# backwards-compat alias (expert stacks are plain QWeights now)
QWeightStack = QWeight


def param_bytes(params) -> int:
    """Total stored bytes of a (possibly quantized) param tree."""
    leaves = jax.tree_util.tree_leaves(params)
    return sum(l.size * l.dtype.itemsize for l in leaves)
