"""RecurrentGemma's recurrent block: temporal conv + RG-LRU (arXiv 2402.19427).

RG-LRU recurrence (per channel):
    r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
    a_t = exp(−c · softplus(Λ) · r_t)                    (c = 8)
    h_t = a_t · h_{t−1} + sqrt(1 − a_t²) · (i_t · x_t)

Block layout (Griffin): in-proj to two branches (x, gate); x-branch: conv1d →
RG-LRU; merged: h · gelu(gate) → out-proj.

Training/prefill evaluates the linear recurrence with an associative scan
(log-depth — this is the TPU-friendly formulation); decode is O(1) per step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.quantized import materialize

_C = 8.0


def rglru_init(key, d_model: int, width: int, d_conv: int):
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], d_model, width),
        "in_gate": dense_init(ks[1], d_model, width),
        "conv_w": jax.random.normal(ks[2], (d_conv, width), jnp.float32) * 0.02,
        "conv_b": jnp.zeros((width,), jnp.float32),
        "w_r": dense_init(ks[3], width, width),
        "w_i": dense_init(ks[4], width, width),
        # Λ init so that a^c is roughly in [0.9, 0.999]
        "lambda_raw": jnp.linspace(0.3, 1.5, width).astype(jnp.float32),
        "out": dense_init(ks[5], width, d_model),
    }


class RGLRUState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, width)
    h: jax.Array      # (B, width)


def init_rglru_state(b: int, width: int, d_conv: int) -> RGLRUState:
    return RGLRUState(
        conv=jnp.zeros((b, d_conv - 1, width), jnp.float32),
        h=jnp.zeros((b, width), jnp.float32),
    )


def _conv(p, x, conv_state=None):
    w = p["conv_w"].astype(x.dtype)
    k = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        if conv_state is None
        else conv_state.astype(x.dtype)
    )
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    out = out + p["conv_b"].astype(x.dtype)
    new_state = xp[:, -(k - 1) :, :] if k > 1 else pad
    return out, new_state


def _gates(p, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ materialize(p["w_r"]["w"], jnp.float32))
    i = jax.nn.sigmoid(xf @ materialize(p["w_i"]["w"], jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda_raw"]) * r          # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated_in


def rglru_apply(p, u: jax.Array, width: int) -> jax.Array:
    """u: (B, S, d_model) → (B, S, d_model) via associative scan over S."""
    x = u @ materialize(p["in_x"]["w"], u.dtype)
    gate = u @ materialize(p["in_gate"]["w"], u.dtype)
    x, _ = _conv(p, x)
    a, b = _gates(p, x)                                          # (B,S,W) each

    # h_t = a_t h_{t-1} + b_t  — associative: (a1,b1)∘(a2,b2) = (a1a2, a2 b1 + b2)
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(u.dtype) * jax.nn.gelu(gate)
    return y @ materialize(p["out"]["w"], u.dtype)


def rglru_decode_step(p, u: jax.Array, state: RGLRUState, width: int):
    """u: (B, 1, d_model) → (y, new_state)."""
    x = u @ materialize(p["in_x"]["w"], u.dtype)
    gate = u @ materialize(p["in_gate"]["w"], u.dtype)
    x, conv_new = _conv(p, x, state.conv)
    a, b = _gates(p, x)                                          # (B,1,W)
    h = a[:, 0] * state.h + b[:, 0]                              # (B,W)
    y = h[:, None, :].astype(u.dtype) * jax.nn.gelu(gate)
    y = y @ materialize(p["out"]["w"], u.dtype)
    return y, RGLRUState(conv=conv_new, h=h)
