"""Core neural layers (functional, framework-free): norms, RoPE, dense/GQA
attention with chunked online-softmax (32k-safe), MLP variants, KV caches
(float or int8-quantized — the paper's Q applied to the "observations").

Parameters are plain nested dicts of jax Arrays; initialization is explicit.
Sharding is attached later by path-based rules (repro.parallel.sharding), so
layer code stays mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import BY_BITS

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, in_dim: int, out_dim: int, bias: bool = False, scale: float = 0.02):
    p = {"w": (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def norm_init(d: int, norm_type: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# apply helpers


def dense(p, x, dtype=None):
    from repro.models.quantized import materialize

    y = x @ materialize(p["w"], dtype or x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def apply_norm(p, x, norm_type: str, eps: float):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq        # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(position: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding for one (traced) position — O(d), table-free."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = position.astype(jnp.float32) / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention (chunked online-softmax; pure XLA — Pallas flashattn is the TPU path)


import functools


def _pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (handles non-power-of-two seqs,
    e.g. Whisper's 1500-frame encoder memory)."""
    if s <= chunk:
        return s
    for c in range(chunk, 0, -1):
        if s % c == 0:
            return c
    return s


def _attn_mask(q_pos, k_pos, causal, window):
    mask = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos[None, None, :]
    if window is not None:
        mask &= q_pos[:, :, None] - k_pos[None, None, :] < window
    return mask


def _flash_fwd_scan(qf, kf, vf, q_pos, k_pos, scale, causal, window, unroll):
    b, h, nq, cq, d = qf.shape
    nk = kf.shape[2]

    def kv_step(carry, j):
        m_run, l_run, acc = carry
        kj = jax.lax.dynamic_index_in_dim(kf, j, axis=2, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vf, j, axis=2, keepdims=False)
        s = jnp.einsum("bhncd,bhkd->bhnck", qf, kj) * scale
        kp = jax.lax.dynamic_index_in_dim(k_pos, j, axis=0, keepdims=False)
        s = jnp.where(_attn_mask(q_pos, kp, causal, window)[None, None], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc + jnp.einsum("bhnck,bhkd->bhncd", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, nq, cq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, nq, cq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, nq, cq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk),
                                  unroll=nk if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)
    lse = m[..., 0] + jnp.log(jnp.maximum(l[..., 0], 1e-30))   # (b,h,nq,cq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(qf, kf, vf, scale, causal, window, q_offset, unroll):
    """Online-softmax attention over chunk grids with a flash-style backward:
    only (out, logsumexp) are saved — O(S·d) residuals instead of the O(S²/ck)
    scan carries a naive autodiff would store. This is what makes the 4k-train
    and 32k-prefill cells fit HBM (measured in benchmarks/roofline.py)."""
    b, h, nq, cq, d = qf.shape
    sq = nq * cq
    sk = kf.shape[2] * kf.shape[3]
    q_pos = q_offset + jnp.arange(sq).reshape(nq, cq)
    k_pos = jnp.arange(sk).reshape(kf.shape[2], kf.shape[3])
    out, _ = _flash_fwd_scan(qf, kf, vf, q_pos, k_pos, scale, causal, window, unroll)
    return out


def _flash_core_fwd(qf, kf, vf, scale, causal, window, q_offset, unroll):
    b, h, nq, cq, d = qf.shape
    sq = nq * cq
    q_pos = q_offset + jnp.arange(sq).reshape(nq, cq)
    k_pos = jnp.arange(kf.shape[2] * kf.shape[3]).reshape(kf.shape[2], kf.shape[3])
    out, lse = _flash_fwd_scan(qf, kf, vf, q_pos, k_pos, scale, causal, window, unroll)
    return out, (qf, kf, vf, out, lse)


def _flash_core_bwd(scale, causal, window, q_offset, unroll, res, dout):
    qf, kf, vf, out, lse = res
    b, h, nq, cq, d = qf.shape
    nk, ck = kf.shape[2], kf.shape[3]
    sq = nq * cq
    q_pos = q_offset + jnp.arange(sq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)
    delta = jnp.sum(dout * out, axis=-1, keepdims=True)        # (b,h,nq,cq,1)

    def kv_step(dq, j):
        kj = jax.lax.dynamic_index_in_dim(kf, j, axis=2, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vf, j, axis=2, keepdims=False)
        kp = jax.lax.dynamic_index_in_dim(k_pos, j, axis=0, keepdims=False)
        s = jnp.einsum("bhncd,bhkd->bhnck", qf, kj) * scale
        s = jnp.where(_attn_mask(q_pos, kp, causal, window)[None, None], s, -1e30)
        p = jnp.exp(s - lse[..., None])                        # (b,h,nq,cq,ck)
        dv_j = jnp.einsum("bhnck,bhncd->bhkd", p, dout)
        dp = jnp.einsum("bhncd,bhkd->bhnck", dout, vj)
        ds = p * (dp - delta) * scale
        dq = dq + jnp.einsum("bhnck,bhkd->bhncd", ds, kj)
        dk_j = jnp.einsum("bhnck,bhncd->bhkd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros_like(qf)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, jnp.arange(nk),
                                unroll=nk if unroll else 1)
    dk = jnp.moveaxis(dk, 0, 2)                                 # (b,h,nk,ck,d)
    dv = jnp.moveaxis(dv, 0, 2)
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def chunked_attention(
    q: jax.Array,                # (B, Hq, Sq, D)
    k: jax.Array,                # (B, Hkv, Sk, D)
    v: jax.Array,                # (B, Hkv, Sk, D)
    *,
    causal: bool,
    chunk: int = 1024,
    window: Optional[int] = None,   # sliding-window (local) attention
    q_offset: int = 0,              # global position of q[0] (cache decode/prefill)
    unroll: bool = False,
) -> jax.Array:
    """Memory-efficient attention: O(Sq·chunk) live scores, flash-style custom
    VJP (O(S·d) residuals). Masked chunk pairs are computed-and-discarded (XLA
    has no dynamic skip; the Pallas kernel does skip them on TPU)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = 1.0 / math.sqrt(d)
    cq = _pick_chunk(sq, chunk)
    ck = _pick_chunk(sk, chunk)
    nq, nk = sq // cq, sk // ck

    qf = q.astype(jnp.float32).reshape(b, hq, nq, cq, d)
    kf = k.astype(jnp.float32).reshape(b, hkv, nk, ck, d)
    vf = v.astype(jnp.float32).reshape(b, hkv, nk, ck, d)
    if rep > 1:
        kf = jnp.repeat(kf, rep, axis=1)
        vf = jnp.repeat(vf, rep, axis=1)

    out = _flash_core(qf, kf, vf, scale, causal, window, q_offset, unroll)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,               # (B, Hq, 1, D)
    k: jax.Array,               # (B, Hkv, S, D)
    v: jax.Array,
    *,
    length: jax.Array,          # valid cache length (scalar int) — masks the tail
    window: Optional[int] = None,
) -> jax.Array:
    """Grouped-GQA decode attention: q is reshaped to (B, Hkv, rep, D) and
    contracted against the UNREPEATED cache. Never materializes repeated K/V —
    critical under SPMD: a jnp.repeat over the head dim forces the partitioner
    to re-align (all-gather) the entire 32k cache every token (§Perf H1)."""
    b, hq, _, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q[:, :, 0, :].reshape(b, hkv, rep, d)
    # keep K/V in cache dtype; accumulate in f32 via preferred_element_type —
    # an explicit .astype(f32) on the cache gets HOISTED out of the layer scan
    # by XLA into a full-cache f32 materialization + reshard (§Perf H2).
    logits = jnp.einsum("bhrd,bhkd->bhrk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    mask = pos[None, None, None, :] < length
    if window is not None:
        mask &= pos[None, None, None, :] >= length - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrk,bhkd->bhrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (float or int8 codes — the paper's Q(y) analog)


class KVCache(NamedTuple):
    k: jax.Array                  # (B, Hkv, S, D) dtype or int8 codes
    v: jax.Array
    k_scale: Optional[jax.Array]  # (B, Hkv, S, 1) f32 when quantized
    v_scale: Optional[jax.Array]
    length: jax.Array             # scalar int32: tokens filled

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_kv_cache(b: int, hkv: int, s: int, d: int, dtype, kv_bits: Optional[int]) -> KVCache:
    if kv_bits:
        return KVCache(
            k=jnp.zeros((b, hkv, s, d), jnp.int8),
            v=jnp.zeros((b, hkv, s, d), jnp.int8),
            k_scale=jnp.ones((b, hkv, s, 1), jnp.float32),
            v_scale=jnp.ones((b, hkv, s, 1), jnp.float32),
            length=jnp.zeros((), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((b, hkv, s, d), dtype),
        v=jnp.zeros((b, hkv, s, d), dtype),
        k_scale=None,
        v_scale=None,
        length=jnp.zeros((), jnp.int32),
    )


def _quantize_kv(x: jax.Array, bits: int):
    """Per-(token, head) nearest-rounding quantization. x: (B, H, T, D)."""
    kk = BY_BITS[bits].half_steps
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-6)
    codes = jnp.clip(jnp.round(x / scale * kk), -kk, kk).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _dequantize_kv(codes: jax.Array, scale: jax.Array, bits: int, dtype):
    kk = BY_BITS[bits].half_steps
    return (codes.astype(jnp.float32) * (scale / kk)).astype(dtype)


def cache_update(
    cache: KVCache, k_new: jax.Array, v_new: jax.Array, kv_bits: Optional[int]
) -> KVCache:
    """Append T new tokens at cache.length. k_new: (B, Hkv, T, D)."""
    idx = cache.length
    if kv_bits:
        kc, ks = _quantize_kv(k_new.astype(jnp.float32), kv_bits)
        vc, vs = _quantize_kv(v_new.astype(jnp.float32), kv_bits)
        return KVCache(
            k=jax.lax.dynamic_update_slice_in_dim(cache.k, kc, idx, axis=2),
            v=jax.lax.dynamic_update_slice_in_dim(cache.v, vc, idx, axis=2),
            k_scale=jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks, idx, axis=2),
            v_scale=jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs, idx, axis=2),
            length=cache.length + k_new.shape[2],
        )
    return KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), idx, axis=2),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), idx, axis=2),
        k_scale=None,
        v_scale=None,
        length=cache.length + k_new.shape[2],
    )


def cache_update_window(
    cache: KVCache, k_new: jax.Array, v_new: jax.Array, window: int,
    kv_bits: Optional[int],
) -> KVCache:
    """Sliding-window (ring-semantics) cache of fixed size ``window``.

    Slots hold the last min(length, window) tokens in chronological order
    (RoPE is already applied at absolute positions, so order is all we need).
    Prefill (T >= 1): keeps the last ``window`` of the new tokens.
    Decode (T == 1): shift-left-by-one when full, then write at the end.
    """
    t = k_new.shape[2]
    if t >= window:
        # prefill: the cache is exactly the last `window` tokens
        kw, vw = k_new[:, :, -window:], v_new[:, :, -window:]
        fresh = KVCache(
            k=jnp.zeros_like(cache.k), v=jnp.zeros_like(cache.v),
            k_scale=cache.k_scale, v_scale=cache.v_scale,
            length=jnp.zeros((), jnp.int32),
        )
        out = cache_update(fresh, kw, vw, kv_bits)
        return out._replace(length=cache.length + t)
    if t != 1:
        # prefill shorter than the window: plain append (cache starts empty)
        return cache_update(cache, k_new, v_new, kv_bits)
    full = cache.length >= window

    def shift(a):
        return jnp.where(full, jnp.roll(a, -1, axis=2), a)

    idx = jnp.minimum(cache.length, window - 1)
    shifted = KVCache(
        k=shift(cache.k), v=shift(cache.v),
        k_scale=shift(cache.k_scale) if cache.k_scale is not None else None,
        v_scale=shift(cache.v_scale) if cache.v_scale is not None else None,
        length=idx,
    )
    out = cache_update(shifted, k_new, v_new, kv_bits)
    return out._replace(length=cache.length + 1)


def window_valid_length(cache: KVCache, window: int) -> jax.Array:
    return jnp.minimum(cache.length, window)


def cache_kv(cache: KVCache, kv_bits: Optional[int], dtype):
    if kv_bits:
        return (
            _dequantize_kv(cache.k, cache.k_scale, kv_bits, dtype),
            _dequantize_kv(cache.v, cache.v_scale, kv_bits, dtype),
        )
    return cache.k, cache.v


# ---------------------------------------------------------------------------
# MLP variants


def mlp_init(key, d: int, ff: int, mlp_type: str):
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "wi_gate": dense_init(ks[0], d, ff),
            "wi_up": dense_init(ks[1], d, ff),
            "wo": dense_init(ks[2], ff, d),
        }
    return {"wi": dense_init(ks[0], d, ff), "wo": dense_init(ks[1], ff, d)}


def mlp_apply(p, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(dense(p["wi_gate"], x, x.dtype)) * dense(p["wi_up"], x, x.dtype)
    elif mlp_type == "gelu":
        h = jax.nn.gelu(dense(p["wi"], x, x.dtype))
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(dense(p["wi"], x, x.dtype)))
    else:
        raise ValueError(mlp_type)
    return dense(p["wo"], h, x.dtype)
