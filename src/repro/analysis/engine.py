"""jaxlint engine: file discovery, parsing, rule dispatch, suppression.

Pure stdlib — parsing is ``ast``, no jax import — so ``python -m
repro.analysis`` starts in milliseconds and runs anywhere (CI, pre-commit,
a laptop without an accelerator stack).
"""
from __future__ import annotations

import ast
import dataclasses
import os

from repro.analysis import rules as _rules
from repro.analysis.findings import Baseline, Finding, pragma_suppresses

#: directories searched when no explicit paths are given (repo-relative)
DEFAULT_DIRS = ("src", "tests", "benchmarks", "examples")

#: directory names never descended into during discovery. ``jaxlint_fixtures``
#: holds the deliberately-bad rule fixtures — they are linted only when named
#: explicitly on the command line (which bypasses this exclusion).
EXCLUDED_DIR_NAMES = {"__pycache__", ".git", "jaxlint_fixtures",
                      ".pytest_cache", ".ruff_cache"}

BASELINE_NAME = ".jaxlint-baseline.json"


@dataclasses.dataclass
class Report:
    findings: list[Finding]
    suppressed: list[tuple[Finding, str]]  # (finding, "pragma"|"baseline")
    files: int
    parse_errors: list[tuple[str, str]]

    @property
    def ok(self) -> bool:
        return not self.findings and not self.parse_errors


def find_repo_root(start: str | None = None) -> str:
    """Nearest ancestor containing a .git dir or pyproject.toml."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(cur, ".git")) \
                or os.path.isfile(os.path.join(cur, "pyproject.toml")):
            return cur
        nxt = os.path.dirname(cur)
        if nxt == cur:
            return os.path.abspath(start or os.getcwd())
        cur = nxt


def iter_python_files(root: str, paths: list[str] | None = None):
    """Yield absolute paths of .py files to lint.

    Explicit ``paths`` entries (files or directories) are taken as given —
    naming a file skips the EXCLUDED_DIR_NAMES filter, which is how the
    self-tests and ``scripts/ci.sh`` lint the bad fixtures on purpose.
    """
    if paths:
        roots = [p if os.path.isabs(p) else os.path.join(root, p)
                 for p in paths]
        for p in roots:
            if os.path.isfile(p):
                yield p
            elif os.path.isdir(p):
                yield from _walk_dir(p)
    else:
        for d in DEFAULT_DIRS:
            full = os.path.join(root, d)
            if os.path.isdir(full):
                yield from _walk_dir(full)


def _walk_dir(d: str):
    for dirpath, dirnames, filenames in os.walk(d):
        dirnames[:] = sorted(x for x in dirnames
                             if x not in EXCLUDED_DIR_NAMES)
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _annotate_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._jaxlint_parent = node


def lint_file(abspath: str, relpath: str,
              rule_ids: list[str] | None = None) -> tuple[list[Finding], str | None]:
    """(raw findings, parse error) for one file. Suppression NOT applied."""
    with open(abspath, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as e:
        return [], f"{relpath}:{e.lineno}: syntax error: {e.msg}"
    _annotate_parents(tree)
    source_lines = source.splitlines()
    findings = []
    for rid, rule in _rules.ALL_RULES.items():
        if rule_ids and rid not in rule_ids:
            continue
        findings.extend(rule(tree, relpath, source_lines))
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings, None


def run_jaxlint(paths: list[str] | None = None, root: str | None = None,
                baseline: str | None = None,
                rule_ids: list[str] | None = None,
                respect_pragmas: bool = True) -> Report:
    """Lint the repo (or explicit paths) and apply suppressions.

    ``baseline`` — path to the suppression file; defaults to
    ``<root>/.jaxlint-baseline.json`` when present. Pass ``baseline="none"``
    to ignore it (used by --update-baseline and the self-tests).
    """
    root = find_repo_root(root)
    bl = Baseline()
    if baseline != "none":
        bl_path = baseline or os.path.join(root, BASELINE_NAME)
        if os.path.isfile(bl_path):
            bl = Baseline.load(bl_path)

    findings: list[Finding] = []
    suppressed: list[tuple[Finding, str]] = []
    parse_errors: list[tuple[str, str]] = []
    n_files = 0
    for abspath in iter_python_files(root, paths):
        relpath = os.path.relpath(abspath, root).replace(os.sep, "/")
        n_files += 1
        raw, err = lint_file(abspath, relpath, rule_ids)
        if err:
            parse_errors.append((relpath, err))
            continue
        if not raw:
            continue
        with open(abspath, encoding="utf-8") as f:
            source_lines = f.read().splitlines()
        for finding in raw:
            if respect_pragmas and pragma_suppresses(source_lines, finding):
                suppressed.append((finding, "pragma"))
            elif bl.matches(finding):
                suppressed.append((finding, "baseline"))
            else:
                findings.append(finding)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings=findings, suppressed=suppressed, files=n_files,
                  parse_errors=parse_errors)
