"""jaxlint rules JL001–JL007 — one per bug class this repo has shipped.

Every rule is a pure-``ast`` function ``(tree, path, source_lines) ->
list[Finding]``; the engine parses, annotates parent links
(``node._jaxlint_parent``), and applies pragma/baseline suppression. None of
this imports jax — rules reason about *names in source*, so they are fast and
runnable anywhere, at the cost of being lexical approximations. Each rule's
docstring names the historical bug it mechanizes; the calibration notes say
what is deliberately NOT flagged, because a linter the repo routinely
pragmas-around is worse than no linter.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding

# ---------------------------------------------------------------- helpers


def dotted(node: ast.AST) -> str | None:
    """'jax.random.normal' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_part(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def snippet_at(source_lines: list[str], line: int) -> str:
    if 1 <= line <= len(source_lines):
        return source_lines[line - 1].strip()
    return ""


def _mk(rule, path, node, message, hint, source_lines) -> Finding:
    return Finding(rule=rule, path=path, line=node.lineno, message=message,
                   hint=hint, snippet=snippet_at(source_lines, node.lineno))


def enclosing_functions(node: ast.AST):
    """Lexical chain of enclosing FunctionDefs, innermost first."""
    cur = getattr(node, "_jaxlint_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield cur
        cur = getattr(cur, "_jaxlint_parent", None)


# ---------------------------------------------------------------- JL001

# Narrow targets we flag in .astype()/casts. float32 is deliberately absent:
# it is this repo's working precision and ~30 legitimate sites use it; the
# shipped bug (PR 4) was complex128 observations silently demoted to
# complex64 inside dequantize, destroying the f64 reference path.
_NARROW_DTYPES = {"complex64", "float16", "bfloat16"}


def check_jl001_dtype_narrowing(tree, path, source_lines):
    """JL001 — casts that can silently demote c128/f64 operands.

    The PR 4 bug: ``QTensor.dequantize`` hard-cast to ``complex64``, so the
    complex128 reference pipeline quietly lost half its mantissa and the
    "exact" baseline wasn't. Flags (a) ``.astype(complex64|float16|bfloat16)``
    with a literal narrow dtype — a dtype derived from the operand
    (``x.astype(y.dtype)``) is the fix and is never flagged; (b)
    dtype-defaulting ``jnp.asarray(x)`` / ``jnp.array(x)`` on a bare variable,
    which canonicalizes float64 inputs down to float32 under JAX's default
    x64-disabled config (``np.asarray`` preserves dtype and is not flagged).
    """
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        # (a) .astype(<narrow literal>)
        if isinstance(fn, ast.Attribute) and fn.attr == "astype" and node.args:
            arg = node.args[0]
            name = last_part(arg)
            target = None
            if name in _NARROW_DTYPES:
                target = name
            elif isinstance(arg, ast.Constant) and arg.value in _NARROW_DTYPES:
                target = arg.value
            if target:
                out.append(_mk(
                    "JL001", path, node,
                    f"cast to literal {target} can silently demote wider "
                    "operands (the PR 4 c128->c64 dequantize bug)",
                    "derive the dtype from the operand (e.g. "
                    "`.astype(x.dtype)` or a dtype-promoting helper), or add "
                    "`# jaxlint: allow=JL001 -- <why narrowing is intended>`",
                    source_lines))
            continue
        # (b) dtype-defaulting jnp.asarray/jnp.array on a bare variable
        d = dotted(fn)
        if d in ("jnp.asarray", "jnp.array", "jax.numpy.asarray",
                 "jax.numpy.array"):
            # dtype may be the 2nd positional arg (jnp.asarray(x, jnp.f32))
            has_dtype = (len(node.args) >= 2
                         or any(kw.arg == "dtype" for kw in node.keywords))
            if (not has_dtype and node.args
                    and isinstance(node.args[0], ast.Name)):
                out.append(_mk(
                    "JL001", path, node,
                    f"`{d}` without dtype= canonicalizes float64/complex128 "
                    "input down to float32/complex64 under JAX's default "
                    "x64-disabled config",
                    "pass dtype= explicitly (e.g. `dtype=x.dtype`), or add "
                    "`# jaxlint: allow=JL001 -- <why canonicalization is "
                    "fine>`",
                    source_lines))
    return out


# ---------------------------------------------------------------- JL002

# jax.random attrs that DERIVE keys rather than consume them.
_KEY_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data",
                 "key_data", "clone", "key_impl"}
# jax.random attrs that CONSUME a key (first positional arg). Explicit list:
# matching any `*.random.*` attr would false-positive on numpy's np.random.
_KEY_CONSUMERS = {
    "normal", "uniform", "randint", "permutation", "rademacher", "bernoulli",
    "choice", "gamma", "beta", "exponential", "truncated_normal",
    "categorical", "bits", "laplace", "logistic", "gumbel", "dirichlet",
    "poisson", "orthogonal", "ball", "cauchy", "maxwell",
    "multivariate_normal", "t", "weibull_min", "binomial", "rayleigh",
    "triangular", "loggamma", "chisquare", "f", "geometric",
    "generalized_normal", "wald", "shuffle",
}


def _is_key_consumption(call: ast.Call) -> str | None:
    """Variable name whose key this call consumes, or None."""
    d = dotted(call.func)
    if d is None:
        return None
    parts = d.split(".")
    if len(parts) < 2 or parts[-2] != "random" or parts[0] in ("np", "numpy"):
        return None
    if parts[-1] in _KEY_DERIVERS or parts[-1] not in _KEY_CONSUMERS:
        return None
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


def _bound_names(stmts) -> set[str]:
    """Names (re)bound anywhere in ``stmts``, nested scopes excluded.

    Used at branch merges: a ``key, sub = jax.random.split(key)`` inside an
    if/for/while body re-binds ``key`` on at least one path, so the merged
    state must reset its draw counter (under-reporting when the branch is
    not taken beats a false positive on the refreshed key).
    """
    bound: set[str] = set()
    for stmt in stmts:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
                continue  # fresh scope; its bindings don't escape
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign,
                                   ast.NamedExpr)):
                targets = [node.target]
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                targets = [node.target]
            elif isinstance(node, ast.withitem) and node.optional_vars:
                targets = [node.optional_vars]
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        bound.add(n.id)
    return bound


class _KeyReuseScanner:
    """Order-aware scan of one function (or module) body.

    State maps variable name -> line of its first un-refreshed consumption.
    A reassignment of the name (including ``k, sub = split(k)`` unpacking)
    resets it. if/for/while/try branches are scanned on *copies* of the state
    that are then discarded: a key consumed once in each of two mutually
    exclusive branches (the ``sensing/gaussian.py`` kflux pattern) is NOT
    reuse, and under-reporting across merges beats crying wolf. Names the
    branch *re-binds* are reset in the merged state too (see
    :func:`_bound_names`) — consuming the fresh ``key`` after the merge is
    not reuse of the pre-branch one.
    """

    def __init__(self, path, source_lines):
        self.path = path
        self.source_lines = source_lines
        self.findings = []

    def scan_block(self, stmts, state: dict):
        for stmt in stmts:
            self.scan_stmt(stmt, state)
        return state

    def scan_stmt(self, stmt, state):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # fresh scope; the rule driver visits it separately
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self.scan_expr(value, state)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        state.pop(n.id, None)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.scan_expr(stmt.iter, state)
            branch = dict(state)
            for n in ast.walk(stmt.target):
                if isinstance(n, ast.Name):
                    branch.pop(n.id, None)
            self.scan_block(stmt.body, branch)
            self.scan_block(stmt.orelse, dict(state))
            self._merge_rebindings(state, stmt.body, stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self.scan_expr(stmt.test, state)
            self.scan_block(stmt.body, dict(state))
            self.scan_block(stmt.orelse, dict(state))
            self._merge_rebindings(state, stmt.body, stmt.orelse)
            return
        if isinstance(stmt, ast.If):
            self.scan_expr(stmt.test, state)
            self.scan_block(stmt.body, dict(state))
            self.scan_block(stmt.orelse, dict(state))
            self._merge_rebindings(state, stmt.body, stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self.scan_block(stmt.body, dict(state))
            for h in stmt.handlers:
                self.scan_block(h.body, dict(state))
            self.scan_block(stmt.orelse, dict(state))
            self.scan_block(stmt.finalbody, dict(state))
            self._merge_rebindings(state, stmt.body, stmt.orelse,
                                   stmt.finalbody,
                                   *[h.body for h in stmt.handlers])
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.scan_expr(item.context_expr, state)
            self.scan_block(stmt.body, state)
            return
        # Expr / Return / Assert / Raise / Delete / ...
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self.scan_expr(child, state)

    def _merge_rebindings(self, state, *blocks):
        """At a branch merge, reset names any branch re-bound (a refreshed
        ``key`` after ``key, sub = split(key)`` inside the branch is fresh)."""
        for block in blocks:
            for name in _bound_names(block):
                state.pop(name, None)

    def scan_expr(self, expr, state):
        # depth-first, left-to-right: source order within one expression
        for node in ast.iter_child_nodes(expr):
            if isinstance(node, (ast.Lambda, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                continue
            self.scan_expr(node, state)
        if isinstance(expr, ast.Call):
            name = _is_key_consumption(expr)
            if name is not None:
                if name in state:
                    self.findings.append(Finding(
                        rule="JL002", path=self.path, line=expr.lineno,
                        message=(f"PRNG key `{name}` already consumed on line "
                                 f"{state[name]} — reusing it makes the two "
                                 "draws correlated"),
                        hint=("`jax.random.split` the key (or `fold_in` a "
                              "fresh stream id) between consumptions"),
                        snippet=snippet_at(self.source_lines, expr.lineno)))
                else:
                    state[name] = expr.lineno


def check_jl002_prng_key_reuse(tree, path, source_lines):
    """JL002 — one key, two draws, no split in between.

    A JAX PRNG key is a value, not a stateful generator: sampling twice with
    the same key yields *correlated* streams (identical, for the same
    primitive+shape), which silently degrades every randomized guarantee the
    paper's recovery bounds rely on (Gaussian Φ RIP, noise draws, tie-break
    jitter). Flags a bare variable passed as the key to two ``jax.random``
    samplers in the same straight-line scope without an interleaving
    reassignment/split.
    """
    out = []
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
    for scope in scopes:
        sc = _KeyReuseScanner(path, source_lines)
        state = {}
        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            sc.scan_block(scope.body, state)
        else:
            sc.scan_block(scope.body, state)
        out.extend(sc.findings)
    return out


# ---------------------------------------------------------------- JL003

_VIEW_METHODS = {"ravel", "reshape", "flatten"}


def _is_view_producer(node: ast.AST) -> str | None:
    if isinstance(node, ast.Call):
        lp = last_part(node.func)
        if lp in _VIEW_METHODS:
            return lp
    return None


def check_jl003_view_write(tree, path, source_lines):
    """JL003 — assignment through ``.ravel()``/``.reshape()`` results.

    The PR 4 ``cartesian_mask`` gamble: ``mask.ravel()[idx] = 1`` only
    mutates ``mask`` when ravel happens to return a view — for
    non-contiguous inputs (and always for ``.flatten()``, which copies) the
    write lands in a temporary and is silently discarded. Flags subscript
    assignment (plain or augmented) whose base is a fresh
    ravel/reshape/flatten call.
    """
    out = []
    for node in ast.walk(tree):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Subscript):
                producer = _is_view_producer(t.value)
                if producer:
                    verb = ("always copies" if producer == "flatten"
                            else "may return a copy")
                    out.append(_mk(
                        "JL003", path, node,
                        f"writing through `.{producer}()` — it {verb}, so "
                        "the write can be silently discarded (the PR 4 "
                        "cartesian_mask bug)",
                        "index the original array (`a.flat[idx] = v`, or "
                        "functional `a = a.at[...].set(v)` for jax arrays)",
                        source_lines))
    return out


# ---------------------------------------------------------------- JL004

_SPMD_WRAPPERS = {"shard_map", "vmap", "pmap", "smap"}
_BRANCH_PRIMS = {"cond", "switch"}


def _wrapper_from_decorator(dec: ast.AST) -> str | None:
    """shard_map/vmap/... if this decorator marks an SPMD-traced function."""
    lp = last_part(dec)
    if lp in _SPMD_WRAPPERS:
        return lp
    if isinstance(dec, ast.Call):
        lp = last_part(dec.func)
        if lp in _SPMD_WRAPPERS:
            return lp
        if lp == "partial" and dec.args:
            inner = last_part(dec.args[0])
            if inner in _SPMD_WRAPPERS:
                return inner
    return None


def check_jl004_cond_under_spmd(tree, path, source_lines):
    """JL004 — ``lax.cond``/``lax.switch`` lexically inside shard_map/vmap.

    PR 5's hard-won rule: under SPMD transforms (and batching), ``cond`` is
    rewritten to ``select`` — BOTH branches execute on every element. A
    branch that is expensive, has side effects (checkpoint IO), or is only
    valid when its predicate holds (div-by-zero guard) breaks silently. The
    repo's fix was a ``lax.while_loop`` over iterations; this rule flags the
    pattern so the next author hits a lint, not a 3-day debug.

    Lexical scope only: marks functions decorated with shard_map/vmap/pmap
    (including ``partial(...)`` forms) or passed as the mapped callable to a
    shard_map/vmap/pmap *call* (named local defs and lambdas), then flags
    branch primitives inside their bodies.
    """
    # collect defs by name so `shard_map(f, ...)` can mark a local `def f`
    defs: dict[str, list] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    marked: dict[int, tuple[ast.AST, str]] = {}

    def mark(fn_node, wrapper):
        marked.setdefault(id(fn_node), (fn_node, wrapper))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                w = _wrapper_from_decorator(dec)
                if w:
                    mark(node, w)
        if isinstance(node, ast.Call):
            w = last_part(node.func)
            if w in _SPMD_WRAPPERS and node.args:
                f = node.args[0]
                if isinstance(f, ast.Lambda):
                    mark(f, w)
                elif isinstance(f, ast.Name):
                    for d in defs.get(f.id, []):
                        mark(d, w)

    out = []
    for fn_node, wrapper in marked.values():
        body = fn_node.body if not isinstance(fn_node, ast.Lambda) \
            else [ast.Expr(value=fn_node.body)]
        for sub in body:
            for node in ast.walk(sub):
                if isinstance(node, ast.Call):
                    lp = last_part(node.func)
                    d = dotted(node.func) or ""
                    if lp in _BRANCH_PRIMS and ("lax" in d.split(".")
                                                or d == lp):
                        out.append(_mk(
                            "JL004", path, node,
                            f"`{lp}` inside a {wrapper}-mapped function: "
                            "SPMD/batching rewrites it to `select`, so BOTH "
                            "branches execute on every element (PR 5's "
                            "while_loop-not-scan-of-cond rule)",
                            "restructure as `lax.while_loop` / masked "
                            "`jnp.where` arithmetic that is valid for all "
                            "elements, or hoist the branch outside the "
                            "mapped region",
                            source_lines))
    return out


# ---------------------------------------------------------------- JL005

# dict/list fields are NOT here: containers are pytree nodes and flatten
# fine. The hazard is hashable config riding along as a leaf — the PR 5
# PackedWeights granularity string.
_STATIC_ANNOTATIONS = {"str", "Granularity"}
_ARRAY_ANNOTATIONS = {"Array", "ndarray", "ArrayLike"}
_REGISTER_MARKERS = ("register_pytree_node", "register_pytree_node_class",
                     "register_dataclass", "register_static")
_JIT_MARKERS = {"jit", "shard_map", "pjit", "xmap"}


def _annotation_names(ann: ast.AST) -> set[str]:
    names = set()
    for node in ast.walk(ann):
        lp = last_part(node)
        if lp:
            names.add(lp)
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            names.add(node.value.rsplit(".", 1)[-1])
    return names


def check_jl005_unregistered_pytree(tree, path, source_lines):
    """JL005 — containers crossing jit/shard_map without pytree registration.

    The PR 5 bug: ``PackedWeights`` crossed the shard_map boundary as a
    NamedTuple whose *static* config fields (granularity string, group size)
    became pytree leaves — tracer errors at best, a silent retrace per config
    at worst; the fix registered it with config in aux_data. In a module that
    uses jit/shard_map/pjit and never mentions a ``register_pytree*`` helper,
    flags (a) ``@dataclass`` classes with array-annotated fields (dataclasses
    are not pytrees at all — jit treats the instance as one opaque leaf and
    fails), and (b) NamedTuple classes mixing in static-typed fields
    (str/bool/dict), which auto-pytree into leaves that cannot trace.
    All-array NamedTuples (``SolverState``, ``IHTResult``) are fine as-is
    and are not flagged.
    """
    src = "\n".join(source_lines)
    if not any(m in src for m in _JIT_MARKERS):
        return []
    if any(m in src for m in _REGISTER_MARKERS):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        base_names = {last_part(b) for b in node.bases}
        is_namedtuple = "NamedTuple" in base_names
        is_dataclass = any(
            last_part(d) == "dataclass"
            or (isinstance(d, ast.Call) and last_part(d.func) == "dataclass")
            for d in node.decorator_list)
        if not (is_namedtuple or is_dataclass):
            continue
        field_anns = [
            (stmt.target.id, _annotation_names(stmt.annotation))
            for stmt in node.body
            if isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)
        ]
        if is_dataclass:
            arrayish = [n for n, anns in field_anns
                        if anns & _ARRAY_ANNOTATIONS]
            if arrayish:
                out.append(_mk(
                    "JL005", path, node,
                    f"dataclass `{node.name}` holds array fields "
                    f"({', '.join(arrayish)}) in a module that jits, but is "
                    "not a registered pytree — jit sees one opaque leaf",
                    "decorate with @jax.tree_util.register_dataclass (or "
                    "register_pytree_node_class) splitting array children "
                    "from static metadata",
                    source_lines))
        elif is_namedtuple:
            staticish = [n for n, anns in field_anns
                         if anns & _STATIC_ANNOTATIONS
                         and not anns & _ARRAY_ANNOTATIONS]
            if staticish:
                out.append(_mk(
                    "JL005", path, node,
                    f"NamedTuple `{node.name}` auto-pytrees its static "
                    f"fields ({', '.join(staticish)}) into traced leaves "
                    "(the PR 5 PackedWeights bug)",
                    "register the class with register_pytree_node putting "
                    "static config in aux_data, or move static fields out "
                    "of the container",
                    source_lines))
    return out


# ---------------------------------------------------------------- JL006


def _is_jit_decorator(dec: ast.AST) -> bool:
    if last_part(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        lp = last_part(dec.func)
        if lp == "jit":
            return True
        if lp == "partial" and dec.args and last_part(dec.args[0]) == "jit":
            return True
    return False


def check_jl006_jit_static_hygiene(tree, path, source_lines):
    """JL006 — recompile hazards on jitted functions.

    Two patterns: (a) a jit-decorated function with a mutable/computed
    default (``def f(x, opts={}):``) — unhashable when static, and a fresh
    object identity per definition when not; (b) ``jax.jit(f)(x)`` called
    immediately inside a function body — a fresh wrapper every invocation,
    so the jit cache misses 100% of the time and the serving layer's
    compile-once amortization silently becomes compile-always. Assigning the
    wrapper (``g = jax.jit(f)``) or passing it to a timing harness is the
    correct idiom and is not flagged.
    """
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                all_defaults = (node.args.defaults
                                + [d for d in node.args.kw_defaults if d])
                for d in all_defaults:
                    if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.Call)):
                        out.append(_mk(
                            "JL006", path, d,
                            f"jitted `{node.name}` has a non-literal default "
                            "— unhashable as a static arg and a recompile "
                            "hazard as a traced one",
                            "use None + an in-body fallback, or a hashable "
                            "frozen constant",
                            source_lines))
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Call):
            if last_part(node.func.func) == "jit" \
                    and any(True for _ in enclosing_functions(node)):
                out.append(_mk(
                    "JL006", path, node,
                    "`jit(...)(...)` builds a fresh wrapper per call — the "
                    "compile cache misses every time",
                    "hoist the jitted wrapper to module scope (or cache it) "
                    "so repeated calls reuse the executable",
                    source_lines))
    return out


# ---------------------------------------------------------------- JL007

_DURABLE_SUFFIXES = ("parallel/journal.py", "train/checkpoint.py")


def _in_durable_path(path: str) -> bool:
    p = path.replace("\\", "/")
    return "launch/" in p or any(p.endswith(s) for s in _DURABLE_SUFFIXES)


def _writes_mode(call: ast.Call) -> str | None:
    """The write-ish mode string if this is open(..., 'w'/'a'/'x'...)."""
    lp = last_part(call.func)
    if lp != "open":
        return None
    mode = None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        mode = call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    if isinstance(mode, str) and any(c in mode for c in "wax"):
        return mode
    return None


def _chain_has_rename(node: ast.AST) -> bool:
    for fn in enclosing_functions(node):
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call) \
                    and last_part(sub.func) in ("rename", "replace") \
                    and dotted(sub.func) in ("os.rename", "os.replace"):
                return True
    return False


_PATHLIB_WRITERS = {"write_text", "write_bytes"}


def check_jl007_non_atomic_write(tree, path, source_lines):
    """JL007 — direct writes on durability-critical paths.

    The PR 6 lesson: a preempted ``open(p, 'w')`` leaves a torn file that a
    resumed run happily parses. On the paths whose whole job is surviving
    kill -9 (``launch/``, ``parallel/journal.py``, ``train/checkpoint.py``),
    every durable artifact must go tmp-file -> fsync -> ``os.replace``.
    Flags ``open(..., 'w'/'a'/'x')``, ``np.save``/``np.savez``, pathlib's
    ``Path.write_text``/``Path.write_bytes`` (a whole-file write with no
    commit point at all), and ``json.dump(obj, open(...))`` (anchored on the
    dump — the torn artifact is the JSON) unless some lexically-enclosing
    function also calls ``os.rename``/``os.replace`` (the atomic-commit
    shape — e.g. ``checkpoint.save`` writes into a tmp dir it renames at
    the end).
    """
    if not _in_durable_path(path):
        return []
    # open(...)-write calls inlined as a json.dump file argument: flag the
    # dump (one finding per site, anchored where the torn artifact is made)
    dump_inline_opens: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and dotted(node.func) == "json.dump":
            for a in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(a, ast.Call) and _writes_mode(a):
                    dump_inline_opens.add(id(a))
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        mode = _writes_mode(node)
        d = dotted(node.func)
        lp = last_part(node.func)
        is_npsave = d in ("np.save", "np.savez", "np.savez_compressed",
                          "numpy.save", "numpy.savez",
                          "numpy.savez_compressed")
        is_pathlib_write = (isinstance(node.func, ast.Attribute)
                            and lp in _PATHLIB_WRITERS)
        is_dump_on_open = (d == "json.dump" and any(
            isinstance(a, ast.Call) and id(a) in dump_inline_opens
            for a in list(node.args) + [kw.value for kw in node.keywords]))
        if mode is not None and id(node) in dump_inline_opens:
            continue  # reported at the enclosing json.dump instead
        if mode is None and not (is_npsave or is_pathlib_write
                                 or is_dump_on_open):
            continue
        if _chain_has_rename(node):
            continue
        if is_dump_on_open:
            what = "json.dump(..., open(...))"
        elif mode is not None:
            what = f"open(..., {mode!r})"
        elif is_pathlib_write:
            what = f".{lp}(...)"
        else:
            what = d
        out.append(_mk(
            "JL007", path, node,
            f"direct `{what}` on a durability-critical path — a preemption "
            "mid-write leaves a torn file that resume will read (the PR 6 "
            "checkpoint lesson)",
            "write to a tmp path, fsync, then os.replace() into place "
            "(see repro.parallel.journal.write_json_durable), or pragma "
            "with the reason the write is not a commit point",
            source_lines))
    return out


# ---------------------------------------------------------------- registry

ALL_RULES = {
    "JL001": check_jl001_dtype_narrowing,
    "JL002": check_jl002_prng_key_reuse,
    "JL003": check_jl003_view_write,
    "JL004": check_jl004_cond_under_spmd,
    "JL005": check_jl005_unregistered_pytree,
    "JL006": check_jl006_jit_static_hygiene,
    "JL007": check_jl007_non_atomic_write,
}

RULE_SUMMARIES = {
    "JL001": "dtype narrowing: literal narrow casts / dtype-defaulting "
             "jnp constructors (PR 4 c128->c64 dequantize)",
    "JL002": "PRNG key reuse: one key consumed by two samplers without a "
             "split/fold_in in between",
    "JL003": "view write: subscript assignment through ravel()/reshape()/"
             "flatten() results (PR 4 cartesian_mask)",
    "JL004": "cond under SPMD: lax.cond/switch lexically inside "
             "shard_map/vmap — both branches execute (PR 5)",
    "JL005": "unregistered pytree: dataclass/static-field NamedTuple "
             "crossing jit/shard_map (PR 5 PackedWeights)",
    "JL006": "jit static hygiene: non-literal defaults on jitted fns; "
             "jit(f)(x) fresh-wrapper-per-call",
    "JL007": "non-atomic write: open('w')/np.save/Path.write_text|bytes/"
             "json.dump(..., open(...)) on durable paths without an "
             "enclosing os.replace commit (PR 6)",
}
