"""repro.analysis — the repo's own static-analysis + runtime-sanitizer tier.

Two halves, one contract: the arithmetic discipline the paper's guarantees
rest on (no silent dtype narrowing, no PRNG reuse, no SPMD-unsafe control
flow, no torn writes on durable paths) is enforced by machine instead of by
post-hoc review.

* **jaxlint** (:mod:`repro.analysis.engine` / ``python -m repro.analysis``) —
  an AST pass over ``src/``, ``tests/``, ``benchmarks/``, ``examples/`` with
  one rule per bug class this repo has actually shipped a fix for
  (:mod:`repro.analysis.rules`, JL001–JL007). Suppressions are explicit:
  inline ``# jaxlint: allow=JLxxx -- reason`` pragmas or vetted entries in
  ``.jaxlint-baseline.json``. Wired as the blocking ``scripts/ci.sh analyze``
  tier; see ``docs/static-analysis.md`` for the rule catalog.

* **sanitize** (:mod:`repro.analysis.sanitize`) — a runtime context manager
  wiring ``jax_debug_nans``/``jax_debug_infs`` plus a compile counter (backend
  compiles observed via ``jax.monitoring``), so tests and the launchers'
  ``--sanitize`` flags can assert "no NaN anywhere, no recompile after
  warm-up" — the serving layer's pack-once/compile-once amortization as a
  regression-guarded contract rather than a claim.

This module intentionally does NOT import jax at package-import time: the
lint half is pure stdlib (``ast``) so the CI tier and the CLI stay fast.
``sanitize`` / ``CompileCounter`` are re-exported lazily.
"""
from __future__ import annotations

from repro.analysis.engine import run_jaxlint  # noqa: F401  (pure stdlib)
from repro.analysis.findings import Finding  # noqa: F401

__all__ = ["run_jaxlint", "Finding", "sanitize", "CompileCounter"]


def __getattr__(name):
    # lazy: importing the runtime sanitizer pulls in jax, which the static
    # analyzer must not pay for
    if name in ("sanitize", "CompileCounter"):
        from repro.analysis import sanitize as _s

        return getattr(_s, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
