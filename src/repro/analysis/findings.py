"""Findings, inline pragmas, and the vetted-suppression baseline.

A :class:`Finding` is one rule hit: ``rule`` id, repo-relative ``path``,
1-based ``line``, human ``message``, and a ``hint`` that says what the fix
looks like. ``snippet`` is the stripped source line — it is the identity used
by the baseline so vetted suppressions survive unrelated line drift.

Suppression is always explicit and always carries a reason:

* inline — ``# jaxlint: allow=JL001 -- reason`` on the flagged line or the
  line directly above. ``allow`` with no rule list allows every rule on that
  line (discouraged; prefer naming the rule).
* baseline — an entry in ``.jaxlint-baseline.json`` with a mandatory
  ``reason`` field, keyed on ``(rule, path, snippet)``.
"""
from __future__ import annotations

import dataclasses
import json
import re

PRAGMA_RE = re.compile(r"#\s*jaxlint:\s*allow(?:=([A-Za-z0-9_,\s]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str
    hint: str
    snippet: str = ""

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} {self.message}\n"
                f"    hint: {self.hint}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def pragma_rules_for_line(source_lines: list[str], line: int) -> set[str] | None:
    """Rules allowed at 1-based ``line`` by an inline pragma.

    Returns ``None`` when no pragma applies, the empty set for a bare
    ``# jaxlint: allow`` (allow everything), else the set of rule ids named
    on the flagged line or the line directly above it.
    """
    for ln in (line, line - 1):
        if 1 <= ln <= len(source_lines):
            m = PRAGMA_RE.search(source_lines[ln - 1])
            if m:
                if m.group(1) is None:
                    return set()
                return {r.strip().upper() for r in m.group(1).split(",")
                        if r.strip()}
    return None


def pragma_suppresses(source_lines: list[str], finding: Finding) -> bool:
    rules = pragma_rules_for_line(source_lines, finding.line)
    if rules is None:
        return False
    return not rules or finding.rule in rules


class Baseline:
    """Checked-in vetted suppressions, keyed on (rule, path, snippet).

    Keying on the stripped source line instead of the line number means a
    baseline entry keeps matching when unrelated edits shift the file, and
    stops matching (fails CI, forcing a re-review) the moment the flagged
    code itself changes.
    """

    def __init__(self, entries: list[dict] | None = None):
        self.entries = entries or []
        self._index: dict[tuple[str, str, str], dict] = {
            (e["rule"], e["path"], e["snippet"]): e for e in self.entries
        }

    @classmethod
    def load(cls, path) -> "Baseline":
        with open(path) as f:
            data = json.load(f)
        entries = data.get("entries", [])
        for e in entries:
            missing = {"rule", "path", "snippet", "reason"} - set(e)
            if missing:
                raise ValueError(
                    f"baseline entry {e!r} missing {sorted(missing)} — every "
                    "suppression must carry a justification")
        return cls(entries)

    def matches(self, finding: Finding) -> dict | None:
        return self._index.get((finding.rule, finding.path, finding.snippet))

    @staticmethod
    def dump_entries(findings: list[Finding], reason: str) -> str:
        entries = [
            {"rule": f.rule, "path": f.path, "snippet": f.snippet,
             "reason": reason}
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
        ]
        return json.dumps({"version": 1, "entries": entries}, indent=2) + "\n"
