"""jaxpr-tier static analysis: IR rules over traced entry points.

The AST tier (:mod:`repro.analysis.rules`) reads source; this tier reads
what XLA compiles. ``jax.make_jaxpr``/``jax.eval_shape`` trace a registry of
the system's real entry points (every solver backend × granularity, the
fused kernels, every LinearOperator, the serving chunk fn) with abstract
inputs — no data, no FLOPs — and rules JX101–JX106 walk the resulting IR.

Import cost: this package imports jax only when the tier runs. The AST
linter's ``python -m repro.analysis`` start-up stays jax-free.
"""

__all__ = ["run_jaxpr_tier", "build_registry", "JAXPR_RULE_SUMMARIES"]


def __getattr__(name):
    if name == "run_jaxpr_tier":
        from repro.analysis.jaxpr.runner import run_jaxpr_tier

        return run_jaxpr_tier
    if name == "build_registry":
        from repro.analysis.jaxpr.registry import build_registry

        return build_registry
    if name == "JAXPR_RULE_SUMMARIES":
        from repro.analysis.jaxpr.rules import JAXPR_RULE_SUMMARIES

        return JAXPR_RULE_SUMMARIES
    raise AttributeError(name)
