"""Entry-point registry for the jaxpr analysis tier.

Each :class:`EntryPoint` names one traced surface of the system — a solver
configuration, a fused-kernel formulation, a LinearOperator, the BatchServer
chunk fn — and a ``make()`` thunk that builds its :class:`TraceSpec` or
:class:`OperatorSpec` lazily (jax and the repro modules are imported only
when the tier actually runs, keeping ``python -m repro.analysis`` jax-free
for the AST tier).

Tracing is abstract: array inputs are ``jax.ShapeDtypeStruct``s at tiny
pinned shapes (M=16, N=32, B=4, s=4, n_iters=3) — ``make_jaxpr`` sees the
full iteration graph of every backend × granularity without moving data or
running a FLOP. The few concrete arrays that exist (operator construction
data, packed codes) are 16×32 toys built once at registry time; finding
identity is pinned to these shapes, so changing them invalidates baselines
on purpose.
"""
from __future__ import annotations

import dataclasses
import inspect
from functools import partial
from typing import Any, Callable, Optional

# pinned trace shapes — finding snippets embed these, keep them stable
M, N, B, S, N_ITERS = 16, 32, 4, 4, 3
RES = 8  # imaging resolution for Fourier/wavelet operators (RES² = 64)


@dataclasses.dataclass
class TraceSpec:
    """One function to ``jax.make_jaxpr``-trace with abstract inputs."""

    fn: Callable
    args: tuple
    anchor: tuple  # (abspath, 1-based line) of the underlying def
    #: second argument tuple at different abstract shapes; when set, JX102
    #: compares the two traces' primitive skeletons (a divergence means a
    #: Python branch keyed on shape → per-shape recompiles)
    alt_args: Optional[tuple] = None


@dataclasses.dataclass
class OperatorSpec:
    """LinearOperator(s) whose mv/rmv contract JX106 checks via eval_shape.

    ``ops`` usually holds one operator; the fake-quant pair entry checks the
    (gradient, residual) pair its factory returns. ``trace_mv=True`` also
    runs the IR rules over the mv/rmv jaxprs themselves.
    """

    ops: list
    anchor: tuple
    trace_mv: bool = True


@dataclasses.dataclass
class EntryPoint:
    name: str
    make: Callable[[], Any]  # () -> TraceSpec | OperatorSpec


def anchor_of(obj) -> tuple:
    """(source file, def line) of ``obj``, through jit/functools wrappers."""
    try:
        obj = inspect.unwrap(obj)
        path = inspect.getsourcefile(obj)
        _, line = inspect.getsourcelines(obj)
        return (path or "<unknown>", line)
    except (TypeError, OSError):
        mod = inspect.getmodule(obj)
        return (getattr(mod, "__file__", "<unknown>"), 1)


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _key_sds():
    # old-style PRNG keys are plain (2,) uint32 arrays — traceable abstractly
    import jax.numpy as jnp

    return _sds((2,), jnp.uint32)


def _qniht_spec(batch: bool, *, alt_batch: bool = False, **statics) -> TraceSpec:
    import jax.numpy as jnp

    from repro.core.niht import qniht, qniht_batch

    fn = qniht_batch if batch else qniht
    phi = _sds((M, N), jnp.float32)
    y = _sds((B, M) if batch else (M,), jnp.float32)
    kw = dict(s=S, n_iters=N_ITERS, with_trace=True, **statics)
    if statics.get("bits_phi") or statics.get("bits_y"):
        kw["key"] = _key_sds()
    args = (phi, y)
    alt = None
    if alt_batch:
        # +2 rows must be structure-preserving: row count is data layout,
        # not dispatch (JX102 flags it if a Python branch keys on B)
        alt = (phi, _sds((B + 2, M), jnp.float32))
    return TraceSpec(fn=partial(fn, **kw), args=args, anchor=anchor_of(fn),
                     alt_args=alt)


def _segment_spec(**statics) -> TraceSpec:
    import jax
    import jax.numpy as jnp

    from repro.core.niht import solver_init, solver_segment

    phi = _sds((M, N), jnp.float32)
    kw = dict(s=S, n_iters=N_ITERS, **statics)
    if statics.get("bits_phi") or statics.get("bits_y"):
        kw["key"] = jax.random.PRNGKey(0)
    # solver_init composes under eval_shape — the state arrives as a pytree
    # of ShapeDtypeStructs, exactly the checkpoint-restore construction
    state = jax.eval_shape(
        partial(solver_init, **kw), phi, _sds((B, M), jnp.float32))
    seg_kw = {k: v for k, v in kw.items() if k not in ("n_iters", "key")}
    return TraceSpec(
        fn=partial(solver_segment, n_steps=2, **seg_kw),
        args=(phi, state), anchor=anchor_of(solver_segment))


def _scheduler_segment_spec() -> TraceSpec:
    import jax
    import jax.numpy as jnp

    from repro.core.niht import solver_init
    from repro.parallel.scheduler import segment_step

    phi = _sds((M, N), jnp.float32)
    # the continuous scheduler's hot loop: k-reset segment over the slot
    # table, early_exit + freeze tolerance (its construction always sets
    # both — done flags drive the harvest, stationarity justifies the reset)
    kw = dict(s=S, early_exit=True, exit_tol=1e-5)
    state = jax.eval_shape(
        partial(solver_init, n_iters=N_ITERS, **kw),
        phi, _sds((B, M), jnp.float32))
    return TraceSpec(
        fn=partial(segment_step, n_steps=2, **kw),
        args=(phi, state), anchor=anchor_of(segment_step))


def _toy_phi():
    """Deterministic non-degenerate (M, N) f32 — packing needs real values."""
    import numpy as np

    g = np.cos(1.0 + 0.7 * np.arange(M * N, dtype=np.float64))
    return (g.reshape(M, N) / np.sqrt(M)).astype(np.float32)


def _packed_weights(granularity=None, group_size=None, transpose=False):
    import jax.numpy as jnp

    from repro.kernels.qmm.ops import pack_weights

    w = jnp.asarray(_toy_phi())
    if transpose:
        w = w.T
    gran = granularity
    if granularity == "per_block":
        from repro.quant.formats import Granularity

        gran = Granularity("per_block", group_size)
    return pack_weights(w, 8, granularity=gran)


def _qmm_fused_spec(formulation: str) -> TraceSpec:
    import jax.numpy as jnp

    from repro.kernels.qmm import ops

    if formulation == "matvec":
        w = _packed_weights()
        args = (_sds((1, w.k_dim), jnp.float32), w)
    elif formulation == "batch_minor":
        w = _packed_weights(granularity="per_channel")
        args = (_sds((B, w.k_dim), jnp.float32), w)
    elif formulation == "batch_canonical":
        w = _packed_weights()
        w_t = _packed_weights(transpose=True)
        args = (_sds((B, w.k_dim), jnp.float32), w, w_t)
    elif formulation == "per_block":
        w = _packed_weights(granularity="per_block", group_size=8)
        args = (_sds((B, w.k_dim), jnp.float32), w)
    else:  # pragma: no cover - registry bug
        raise ValueError(formulation)
    return TraceSpec(fn=ops.qmm_fused, args=args,
                     anchor=anchor_of(ops.qmm_fused))


def _operator_spec(which: str) -> OperatorSpec:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import operators as O

    phi = jnp.asarray(_toy_phi())
    key = jax.random.PRNGKey(0)
    if which == "dense_f32":
        ops = [O.DenseOperator(phi)]
        anchor = anchor_of(O.DenseOperator)
    elif which == "dense_c64":
        # jaxlint: allow=JL001 -- registry toy data pinned to c64 on purpose: the entry EXISTS to trace the complex operator path
        ops = [O.DenseOperator((phi + 0.5j * phi).astype(jnp.complex64))]
        anchor = anchor_of(O.DenseOperator)
    elif which == "fakequant_pair":
        g, r = O.FakeQuantPairOperator(phi, 8, key).at_iteration(0)
        ops = [g, r]
        anchor = anchor_of(O.FakeQuantPairOperator)
    elif which == "packed_per_tensor":
        ops = [O.PackedStreamingOperator.pack(phi, 8, key)]
        anchor = anchor_of(O.PackedStreamingOperator)
    elif which == "packed_per_channel":
        ops = [O.PackedStreamingOperator.pack(phi, 8, key,
                                              granularity="per_channel")]
        anchor = anchor_of(O.PackedStreamingOperator)
    elif which == "fourier":
        mask = np.zeros((RES, RES), bool)
        mask[::2, ::3] = True
        ops = [O.SubsampledFourierOperator.from_mask(mask)]
        anchor = anchor_of(O.SubsampledFourierOperator)
    elif which == "wavelet":
        ops = [O.WaveletSynthesisOperator(RES, "haar")]
        anchor = anchor_of(O.WaveletSynthesisOperator)
    elif which == "composed_mri":
        mask = np.zeros((RES, RES), bool)
        mask[::2, :] = True
        f = O.SubsampledFourierOperator.from_mask(mask)
        w = O.WaveletSynthesisOperator(RES, "haar")
        ops = [O.ComposedOperator(f, w)]
        anchor = anchor_of(O.ComposedOperator)
    else:  # pragma: no cover - registry bug
        raise ValueError(which)
    return OperatorSpec(ops=ops, anchor=anchor)


def _batch_server_spec() -> TraceSpec:
    import jax
    import jax.numpy as jnp

    from repro.parallel.batch import BatchServer, make_batch_mesh, sharded_qniht_run

    mesh = make_batch_mesh(1)
    server = BatchServer(
        jnp.asarray(_toy_phi()), s=S, n_iters=N_ITERS, mesh=mesh,
        bits_phi=8, bits_y=8, key=jax.random.PRNGKey(0),
        requantize="fixed", backend="packed")

    def chunk_fn(Y, key):
        # the exact expression BatchServer.submit dispatches per chunk
        return sharded_qniht_run(server.phi, Y, key, mesh=server.mesh,
                                 **server._statics)

    return TraceSpec(
        fn=chunk_fn,
        args=(_sds((B, M), jnp.float32), _key_sds()),
        anchor=anchor_of(BatchServer.submit),
        alt_args=(_sds((B + 4, M), jnp.float32), _key_sds()))


def build_registry() -> list[EntryPoint]:
    """The full entry-point registry: every backend × granularity the
    solver dispatches over, each fused-kernel formulation, every
    LinearOperator, the segmented solver, the continuous scheduler's segment
    step, and the serving chunk fn."""
    E = EntryPoint
    return [
        # --- one-shot solver: backends × requantize × granularity ---------
        E("qniht.dense.f32", lambda: _qniht_spec(False)),
        E("qniht.dense.q8.pair",
          lambda: _qniht_spec(False, bits_phi=8, bits_y=8, requantize="pair")),
        E("qniht.dense.q8.fixed",
          lambda: _qniht_spec(False, bits_phi=8, bits_y=8, requantize="fixed")),
        E("qniht.dense.hsthresh",
          lambda: _qniht_spec(False, threshold="hsthresh", real_signal=True)),
        E("qniht.packed.per_tensor",
          lambda: _qniht_spec(False, bits_phi=8, bits_y=8, requantize="fixed",
                              backend="packed")),
        E("qniht.packed.per_channel",
          lambda: _qniht_spec(False, bits_phi=8, bits_y=8, requantize="fixed",
                              backend="packed", scale_granularity="per_channel")),
        E("qniht.packed.per_block",
          lambda: _qniht_spec(False, bits_phi=8, bits_y=8, requantize="fixed",
                              backend="packed", scale_granularity="per_block",
                              group_size=8)),
        # --- batched solver (alt shapes probe recompile surface) ----------
        E("qniht_batch.dense.f32",
          lambda: _qniht_spec(True, alt_batch=True)),
        E("qniht_batch.packed.per_tensor",
          lambda: _qniht_spec(True, alt_batch=True, bits_phi=8, bits_y=8,
                              requantize="fixed", backend="packed")),
        E("qniht_batch.dense.early_exit",
          lambda: _qniht_spec(True, early_exit=True)),
        E("qniht_batch.packed.early_exit",
          lambda: _qniht_spec(True, bits_phi=8, bits_y=8, requantize="fixed",
                              backend="packed", early_exit=True)),
        E("qniht_batch.dense.freeze_tol",
          lambda: _qniht_spec(True, early_exit=True, exit_tol=1e-6)),
        # --- segmented (checkpointable) solver -----------------------------
        E("solver_segment.dense", lambda: _segment_spec()),
        E("solver_segment.packed",
          lambda: _segment_spec(bits_phi=8, bits_y=8, requantize="fixed",
                                backend="packed")),
        E("scheduler.segment_step", _scheduler_segment_spec),
        # --- fused packed kernels: every static dispatch path --------------
        E("qmm_fused.matvec", lambda: _qmm_fused_spec("matvec")),
        E("qmm_fused.batch_minor", lambda: _qmm_fused_spec("batch_minor")),
        E("qmm_fused.batch_canonical",
          lambda: _qmm_fused_spec("batch_canonical")),
        E("qmm_fused.per_block", lambda: _qmm_fused_spec("per_block")),
        # --- LinearOperator protocol: JX106 adjoint contracts ---------------
        E("op.dense.f32", lambda: _operator_spec("dense_f32")),
        E("op.dense.c64", lambda: _operator_spec("dense_c64")),
        E("op.fakequant_pair", lambda: _operator_spec("fakequant_pair")),
        E("op.packed.per_tensor", lambda: _operator_spec("packed_per_tensor")),
        E("op.packed.per_channel",
          lambda: _operator_spec("packed_per_channel")),
        E("op.fourier", lambda: _operator_spec("fourier")),
        E("op.wavelet", lambda: _operator_spec("wavelet")),
        E("op.composed.mri", lambda: _operator_spec("composed_mri")),
        # --- serving: the per-chunk program BatchServer.submit dispatches ---
        E("batch_server.chunk_fn", _batch_server_spec),
    ]
