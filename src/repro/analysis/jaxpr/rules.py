"""IR rules JX101–JX106 over traced jaxprs and operator contracts.

These see what the AST tier structurally cannot: the jaxpr is the graph XLA
actually compiles, *after* Python-level indirection (``make_iteration_
operators`` dispatch, pytree flattening, closures) has been resolved. Each
rule walks the closed jaxpr recursively — through pjit calls, scan/while
bodies, custom-call sub-jaxprs — so a narrowing convert eight frames deep in
a packed-backend iteration body is the same finding as one at top level.

JX106 is different in kind: it runs the operator protocol's documented
adjoint contract (mv/rmv shapes and dtypes mutually dual, composition dims
chaining) under ``jax.eval_shape`` — no data, no FLOPs, but a real trace of
both directions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

JAXPR_RULE_SUMMARIES = {
    "JX101": "IR dtype narrowing: convert_element_type demoting "
             "c128/f64/f32 anywhere in the traced call graph",
    "JX102": "recompile risk: weak_type leaking into entry outputs; "
             "primitive skeleton diverging between two abstract shapes",
    "JX103": "dead loop carry: while/scan carry component passed through "
             "unchanged and never read — dead bytes every iteration",
    "JX104": "host transfer in hot loop: callback/infeed/outfeed/device_put "
             "primitives inside a while/scan body",
    "JX105": "baked constant: array constant above threshold bytes closed "
             "over into the jaxpr instead of passed as an argument",
    "JX106": "adjoint contract: mv/rmv shapes+dtypes not mutually dual "
             "under eval_shape; ComposedOperator dims not chaining",
}

#: bytes above which a jaxpr constant is "large" (JX105). A (1, N) f32 scale
#: row is ~128 B at serving widths; a baked Φ is tens of KB even at toy shapes.
CONST_THRESHOLD_BYTES = 4096

_HOT_TRANSFER_PRIMS = {"infeed", "outfeed", "device_put", "copy_to_host_async"}


@dataclasses.dataclass
class Issue:
    """One raw rule hit, pre-Finding: the runner owns path/pragma/baseline."""

    rule: str
    message: str
    detail: str  # stable identity fragment (entry-relative, shape-pinned)
    site: Optional[tuple] = None  # (abs file, 1-based line) from source_info


# --------------------------------------------------------------------------
# jaxpr walking


def _sub_jaxprs(eqn):
    """(sub_jaxpr, is_loop_body) pairs reachable from one eqn's params."""
    loop = eqn.primitive.name in ("while", "scan", "fori_loop")
    for val in eqn.params.values():
        for sub in _as_jaxprs(val):
            yield sub, loop


def _as_jaxprs(val):
    if hasattr(val, "jaxpr") and hasattr(val, "consts"):  # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns") and hasattr(val, "invars"):  # open Jaxpr
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _as_jaxprs(v)


def iter_eqns(jaxpr, in_loop=False):
    """Yield (eqn, in_loop) over ``jaxpr`` and every reachable sub-jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn, in_loop
        for sub, is_loop in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, in_loop or is_loop)


def iter_closed(closed):
    """Yield every ClosedJaxpr reachable from ``closed`` (itself included)."""
    seen = set()

    def walk(cj):
        if id(cj) in seen:
            return
        seen.add(id(cj))
        yield cj
        for eqn in cj.jaxpr.eqns:
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else [val]
                for v in vals:
                    if hasattr(v, "jaxpr") and hasattr(v, "consts"):
                        yield from walk(v)

    yield from walk(closed)


def eqn_site(eqn):
    """(file, line) of the user frame that traced ``eqn``, or None.

    ``jax._src.source_info_util`` is private API — probe defensively and
    degrade to the entry anchor rather than crash the analyzer on a jax
    upgrade.
    """
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return (frame.file_name, frame.start_line)
    except Exception:
        pass
    return None


def _skeleton(closed):
    """The trace's primitive-name sequence — its compile-relevant shape."""
    return tuple(eqn.primitive.name for eqn, _ in iter_eqns(closed.jaxpr))


# --------------------------------------------------------------------------
# JX101 — dtype narrowing in the IR


def _is_inexact(dt) -> bool:
    # jnp's lattice, not np's: bfloat16/float8 are ml_dtypes extension types
    # that np.issubdtype does not classify as inexact
    import jax.numpy as jnp

    return jnp.issubdtype(jnp.dtype(dt), jnp.inexact)


def check_jx101_narrowing(name, closed):
    import numpy as np

    out = []
    for eqn, _ in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        old = getattr(eqn.invars[0].aval, "dtype", None)
        new = eqn.params.get("new_dtype")
        if old is None or new is None:
            continue
        if not (_is_inexact(old) and _is_inexact(new)):
            continue  # quantize/dequantize int hops are the product, not a bug
        if np.dtype(new).itemsize >= np.dtype(old).itemsize:
            continue
        out.append(Issue(
            "JX101",
            f"traced graph of `{name}` demotes {np.dtype(old).name} -> "
            f"{np.dtype(new).name} via convert_element_type",
            f"{name} :: convert {np.dtype(old).name}->{np.dtype(new).name}",
            site=eqn_site(eqn)))
    return out


# --------------------------------------------------------------------------
# JX102 — recompile-risk surface


def check_jx102_recompile(name, closed, alt_closed):
    out = []
    for i, var in enumerate(closed.jaxpr.outvars):
        aval = getattr(var, "aval", None)
        if aval is None or not getattr(aval, "weak_type", False):
            continue
        if not _is_inexact(getattr(aval, "dtype", "int32")):
            continue
        out.append(Issue(
            "JX102",
            f"`{name}` output[{i}] is weak-typed "
            f"({aval.dtype}) — mixing it with strong-typed "
            "arrays re-specializes downstream jits per call site",
            f"{name} :: weak_type output[{i}]"))
    if alt_closed is not None:
        sk_a, sk_b = _skeleton(closed), _skeleton(alt_closed)
        if sk_a != sk_b:
            div = next((j for j, (a, b) in enumerate(zip(sk_a, sk_b))
                        if a != b), min(len(sk_a), len(sk_b)))
            out.append(Issue(
                "JX102",
                f"`{name}` traces to a different primitive skeleton at a "
                f"second abstract shape ({len(sk_a)} vs {len(sk_b)} eqns, "
                f"first divergence at eqn {div}) — a Python branch keys on "
                "shape, so every serving shape pays a fresh XLA compile",
                f"{name} :: shape-dependent skeleton"))
    return out


# --------------------------------------------------------------------------
# JX103 — dead while/scan carry components


def _carry_views(eqn):
    """(body_jaxpr, carry_invars, carry_outvars, extra_reader_jaxprs)."""
    p = eqn.params
    if eqn.primitive.name == "while":
        body = p["body_jaxpr"].jaxpr
        nc = p["body_nconsts"]
        cond = p["cond_jaxpr"].jaxpr
        cond_carry = cond.invars[p["cond_nconsts"]:]
        return body, body.invars[nc:], body.outvars, [(cond, cond_carry)]
    if eqn.primitive.name == "scan":
        body = p["jaxpr"].jaxpr
        nc, ncar = p["num_consts"], p["num_carry"]
        return body, body.invars[nc:nc + ncar], body.outvars[:ncar], []
    return None


def _reads(jaxpr):
    """Vars read anywhere in ``jaxpr``: eqn inputs + jaxpr outputs."""
    used = set()
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if hasattr(v, "aval") and not _is_literal(v):
                used.add(id(v))
    return used


def _is_literal(v) -> bool:
    return type(v).__name__ == "Literal"


def check_jx103_dead_carry(name, closed):
    import numpy as np

    out = []
    for eqn, _ in iter_eqns(closed.jaxpr):
        views = _carry_views(eqn)
        if views is None:
            continue
        body, c_in, c_out, extra = views
        body_reads = _reads(body)
        for i, (vin, vout) in enumerate(zip(c_in, c_out)):
            if _is_literal(vout) or vout is not vin:
                continue  # rewritten each iteration — live
            if id(vin) in body_reads:
                continue
            # passthrough position read by another output slot → live
            if any(o is vin for j, o in enumerate(body.outvars) if j != i
                   and not _is_literal(o)):
                continue
            if any(id(extra_carry[i]) in _reads(sub)
                   or any(o is extra_carry[i] for o in sub.outvars)
                   for sub, extra_carry in extra if i < len(extra_carry)):
                continue
            aval = vin.aval
            nbytes = int(np.prod(aval.shape, dtype=np.int64)) * \
                np.dtype(aval.dtype).itemsize
            out.append(Issue(
                "JX103",
                f"`{name}`: {eqn.primitive.name} carry[{i}] "
                f"({np.dtype(aval.dtype).name}{list(aval.shape)}) is dead — "
                f"passed through unchanged and never read, hauling "
                f"{nbytes} B through every iteration",
                f"{name} :: {eqn.primitive.name} carry[{i}] "
                f"{np.dtype(aval.dtype).name}{list(aval.shape)}",
                site=eqn_site(eqn)))
    return out


# --------------------------------------------------------------------------
# JX104 — host↔device traffic inside the hot loop


def check_jx104_hot_transfer(name, closed):
    out = []
    for eqn, in_loop in iter_eqns(closed.jaxpr):
        if not in_loop:
            continue
        prim = eqn.primitive.name
        if prim in _HOT_TRANSFER_PRIMS or "callback" in prim:
            out.append(Issue(
                "JX104",
                f"`{name}`: `{prim}` primitive inside a while/scan body — a "
                "host round-trip (or device re-placement) every solver "
                "iteration serializes the loop on transfer latency",
                f"{name} :: {prim} in loop",
                site=eqn_site(eqn)))
    return out


# --------------------------------------------------------------------------
# JX105 — large constants baked into the jaxpr


def check_jx105_baked_const(name, closed,
                            threshold=CONST_THRESHOLD_BYTES):
    import numpy as np

    out = []
    seen = set()
    for cj in iter_closed(closed):
        for var, const in zip(cj.jaxpr.constvars, cj.consts):
            if id(const) in seen:
                continue
            seen.add(id(const))
            nbytes = getattr(const, "nbytes", 0)
            if not nbytes or nbytes <= threshold:
                continue
            dt = np.dtype(getattr(const, "dtype", "uint8")).name
            shape = list(getattr(const, "shape", ()))
            out.append(Issue(
                "JX105",
                f"`{name}` bakes a {nbytes}-byte constant ({dt}{shape}) "
                "into the jaxpr — it is re-hashed on every compile-cache "
                "lookup and silently pinned to trace-time values; pass it "
                "as an argument instead",
                f"{name} :: const {dt}{shape}"))
    return out


# --------------------------------------------------------------------------
# JX106 — adjoint-contract verification (eval_shape, no data)


def _eval_shape(fn, *args):
    import jax

    return jax.eval_shape(fn, *args)


def check_jx106_adjoint_contract(name, op, batch=4):
    """Statically verify mv/rmv duality for one operator instance.

    The contract (docs/operator-protocol): ``mv`` maps ``(..., n) ->
    (..., m)`` and ``rmv`` maps ``(..., m) -> (..., n)``, both preserving the
    operator dtype, batching over leading axes — exactly what the solver's
    ⟨mv(x), r⟩ == ⟨x, rmv(r)⟩ adjoint identity needs shape-wise.
    """
    import jax
    import numpy as np

    out = []

    def _issue(msg, frag):
        out.append(Issue("JX106", f"`{name}`: {msg}", f"{name} :: {frag}"))

    try:
        m, n = op.shape
        dt = np.dtype(op.dtype)
    except Exception as e:  # noqa: BLE001 - any protocol break is the finding
        out.append(Issue("JX106", f"`{name}`: shape/dtype protocol failed: "
                         f"{type(e).__name__}: {e}", f"{name} :: protocol"))
        return out

    checks = [
        ("mv", op.mv, (n,), (m,)),
        ("rmv", op.rmv, (m,), (n,)),
        ("mv batched", op.mv, (batch, n), (batch, m)),
        ("rmv batched", op.rmv, (batch, m), (batch, n)),
    ]
    for label, fn, in_shape, want_shape in checks:
        try:
            res = _eval_shape(fn, jax.ShapeDtypeStruct(in_shape, dt))
        except Exception as e:  # noqa: BLE001 - the trace failure IS the finding
            _issue(f"{label} failed to trace on {dt.name}{list(in_shape)}: "
                   f"{type(e).__name__}: {e}", f"{label} trace")
            continue
        if tuple(res.shape) != want_shape:
            _issue(f"{label} maps {list(in_shape)} -> {list(res.shape)}, "
                   f"contract requires {list(want_shape)} — adjoint pairing "
                   "⟨mv(x), r⟩ == ⟨x, rmv(r)⟩ cannot hold",
                   f"{label} shape")
        if np.dtype(res.dtype) != dt:
            _issue(f"{label} changes dtype {dt.name} -> "
                   f"{np.dtype(res.dtype).name} — mv/rmv must be mutually "
                   "dual in dtype or the inner products live in different "
                   "precisions", f"{label} dtype")

    outer = getattr(op, "outer", None)
    inner = getattr(op, "inner", None)
    if outer is not None and inner is not None:
        try:
            if outer.shape[1] != inner.shape[0]:
                _issue(f"composition does not chain: outer takes "
                       f"{outer.shape[1]}, inner produces {inner.shape[0]}",
                       "compose chain")
            if tuple(op.shape) != (outer.shape[0], inner.shape[1]):
                _issue(f"composed shape {list(op.shape)} != "
                       f"[{outer.shape[0]}, {inner.shape[1]}] from factors",
                       "compose shape")
        except Exception as e:  # noqa: BLE001
            _issue(f"composition introspection failed: {e}", "compose")
    return out


IR_RULES = {
    "JX101": check_jx101_narrowing,
    "JX103": check_jx103_dead_carry,
    "JX104": check_jx104_hot_transfer,
    "JX105": check_jx105_baked_const,
}
