"""jaxpr-tier driver: trace the registry, run IR rules, emit Findings.

Findings reuse the AST tier's :class:`~repro.analysis.findings.Finding` and
its suppression machinery unchanged. Identity works the same way — keyed on
``(rule, path, snippet)`` — with one twist: an issue that carries a concrete
trace site (``source_info`` of the offending eqn) anchors at that file/line
with the stripped source line as snippet, exactly like an AST finding, so
inline ``# jaxlint: allow=JX...`` pragmas work at the real site. Issues
without a site (contract violations, weak outputs, baked consts) anchor at
the entry point's ``def`` line with a ``"<entry> :: <detail>"`` snippet that
is stable across unrelated edits.
"""
from __future__ import annotations

import os

from repro.analysis.engine import Report, find_repo_root
from repro.analysis.findings import Baseline, Finding, pragma_suppresses
from repro.analysis.jaxpr import rules as _jx
from repro.analysis.jaxpr.registry import (EntryPoint, OperatorSpec,
                                           TraceSpec, build_registry)

_HINTS = {
    "JX101": "keep the iteration algebra in the operator dtype; narrow only "
             "at explicit quantization points (repro.quant), or pragma at "
             "the converting line with why the demotion is intended",
    "JX102": "return strongly-typed arrays (jnp.asarray(..., dtype=...)); "
             "hoist shape-dependent Python branches into static dispatch "
             "documented as separate compile units",
    "JX103": "drop the component from the carry (rebuild it after the loop "
             "if the schema needs it) — see _qniht_core's exit_tol==0 carry",
    "JX104": "move the callback/transfer outside the loop and batch it, or "
             "pragma with why a per-iteration host hop is unavoidable",
    "JX105": "thread the array through the entry's signature so it ships as "
             "an argument, not a compile-time constant",
    "JX106": "make mv/rmv shapes and dtypes mutually dual (see "
             "docs/operator-protocol semantics in core/operators.py); the "
             "solver's adjoint identity depends on it",
}


def _trace_entry(spec: TraceSpec):
    import jax

    closed = jax.make_jaxpr(spec.fn)(*spec.args)
    alt = jax.make_jaxpr(spec.fn)(*spec.alt_args) if spec.alt_args else None
    return closed, alt


def _issues_for(entry: EntryPoint, spec, rule_ids):
    def want(rid):
        return rule_ids is None or rid in rule_ids

    issues = []
    if isinstance(spec, TraceSpec):
        closed, alt = _trace_entry(spec)
        for rid, rule in _jx.IR_RULES.items():
            if want(rid):
                issues += rule(entry.name, closed)
        if want("JX102"):
            issues += _jx.check_jx102_recompile(entry.name, closed, alt)
    elif isinstance(spec, OperatorSpec):
        import jax
        import numpy as np

        for i, op in enumerate(spec.ops):
            sub = entry.name if len(spec.ops) == 1 else f"{entry.name}[{i}]"
            if want("JX106"):
                issues += _jx.check_jx106_adjoint_contract(sub, op)
            if spec.trace_mv:
                try:
                    n = op.shape[1]
                    dt = np.dtype(op.dtype)
                    closed = jax.make_jaxpr(op.mv)(
                        jax.ShapeDtypeStruct((n,), dt))
                    for rid, rule in _jx.IR_RULES.items():
                        if want(rid):
                            issues += rule(f"{sub}.mv", closed)
                except Exception:  # noqa: BLE001 - JX106 already reported it
                    pass
    else:  # pragma: no cover - registry bug
        raise TypeError(f"entry {entry.name}: unknown spec {type(spec)}")
    return issues


def _finding_from(issue: _jx.Issue, anchor, root, src_cache) -> Finding:
    path, line = anchor
    snippet = issue.detail
    if issue.site is not None:
        site_file, site_line = issue.site
        # only re-anchor at sites inside the repo — an eqn traced from jax
        # internals stays attributed to the registry entry
        if os.path.isfile(site_file) and \
                os.path.abspath(site_file).startswith(root + os.sep):
            path, line = site_file, site_line
            lines = _source_lines(site_file, src_cache)
            if 1 <= line <= len(lines):
                snippet = lines[line - 1].strip()
    rel = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
    return Finding(rule=issue.rule, path=rel, line=line,
                   message=issue.message, hint=_HINTS[issue.rule],
                   snippet=snippet)


def _source_lines(abspath, cache):
    if abspath not in cache:
        try:
            with open(abspath, encoding="utf-8") as f:
                cache[abspath] = f.read().splitlines()
        except OSError:
            cache[abspath] = []
    return cache[abspath]


def run_jaxpr_tier(root=None, registry=None, baseline=None,
                   rule_ids=None, respect_pragmas=True) -> Report:
    """Trace every registry entry and run the JX rules. Returns the same
    :class:`Report` shape as the AST tier (``files`` counts entries traced;
    an entry whose trace itself crashes lands in ``parse_errors``)."""
    root = find_repo_root(root)
    bl = Baseline()
    if baseline != "none":
        from repro.analysis.engine import BASELINE_NAME

        bl_path = baseline or os.path.join(root, BASELINE_NAME)
        if os.path.isfile(bl_path):
            bl = Baseline.load(bl_path)

    entries = registry if registry is not None else build_registry()
    findings, suppressed, parse_errors = [], [], []
    src_cache: dict = {}
    seen_keys = set()
    for entry in entries:
        try:
            spec = entry.make()
            issues = _issues_for(entry, spec, rule_ids)
        except Exception as e:  # noqa: BLE001 - a crashing trace must fail CI
            parse_errors.append(
                (entry.name, f"{entry.name}: trace failed: "
                             f"{type(e).__name__}: {e}"))
            continue
        for issue in issues:
            f = _finding_from(issue, spec.anchor, root, src_cache)
            key = (f.rule, f.path, f.snippet)
            if key in seen_keys:
                continue  # same site reached via several registry entries
            seen_keys.add(key)
            lines = _source_lines(os.path.join(root, f.path), src_cache)
            if respect_pragmas and pragma_suppresses(lines, f):
                suppressed.append((f, "pragma"))
            elif bl.matches(f):
                suppressed.append((f, "baseline"))
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings=findings, suppressed=suppressed,
                  files=len(entries), parse_errors=parse_errors)


def load_registry_file(path) -> list:
    """Load a registry module by file path; it must define ``ENTRIES``.

    This is how CI proves the tier still bites: a fixtures module of
    deliberately broken entries must keep producing findings.
    """
    import importlib.util

    spec = importlib.util.spec_from_file_location("jaxpr_fixture_registry",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return list(mod.ENTRIES)
