"""Runtime sanitizer: NaN/Inf tripwires + a backend-compile counter.

The static half of this package catches what source *looks like*; this half
asserts what a run actually *did*:

* :func:`sanitize` — context manager flipping ``jax_debug_nans`` /
  ``jax_debug_infs`` on (restoring the previous values on exit), so any NaN
  or Inf produced anywhere — inside jit, inside shard_map, in eager ops —
  raises ``FloatingPointError`` at the producing primitive instead of
  surfacing three layers later as a garbage recovery.

* :class:`CompileCounter` — counts *backend compiles* (actual XLA
  compilations, observed via ``jax.monitoring``'s
  ``/jax/core/compile/backend_compile_duration`` event), not Python-side
  trace entries. ``mark_warm()`` after warm-up lets callers assert the
  serving layer's contract literally: ``compiles_since_warm == 0`` means
  every later chunk reused the executable. Counting compiles rather than
  cache *hits* makes the assertion robust to jit caches pre-warmed by
  earlier tests in the same process.

Used by ``launch/serve.py --sanitize`` / ``launch/recover.py --sanitize``
and the compile-once regression tests (``tests/test_sanitize.py``).

NaN-placeholder caveat: ``jax_debug_nans`` flags NaN at the op that produces
it, so intentional NaN fills (e.g. trace buffers for skipped iterations)
must be built in numpy and transferred (``jnp.asarray(np.full(...))``) —
a transfer is not a computation and does not trip the check. The solver
cores were converted to that idiom in this PR.
"""
from __future__ import annotations

import contextlib

import jax

#: jax.monitoring duration event emitted once per backend (XLA) compilation.
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

# One module-level listener fan-outs to whichever counters are active:
# jax.monitoring has no unregister API, so registering per-counter would leak.
_ACTIVE: list["CompileCounter"] = []
_REGISTERED = False


def _listener(event: str, duration: float, **kwargs) -> None:
    if event == COMPILE_EVENT:
        for counter in _ACTIVE:
            counter._record(duration)


def _ensure_listener() -> None:
    global _REGISTERED
    if not _REGISTERED:
        jax.monitoring.register_event_duration_secs_listener(_listener)
        _REGISTERED = True


class CompileCounter:
    """Counts backend compiles while active (use as a context manager).

    >>> with CompileCounter() as cc:
    ...     f(x)            # warm-up: compiles
    ...     cc.mark_warm()
    ...     f(x); f(x)      # must hit the cache
    >>> assert cc.compiles_since_warm == 0
    """

    def __init__(self) -> None:
        self.compiles = 0
        self.compile_seconds = 0.0
        self._warm_at: int | None = None

    def __enter__(self) -> "CompileCounter":
        _ensure_listener()
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _ACTIVE.remove(self)

    def _record(self, duration: float) -> None:
        self.compiles += 1
        self.compile_seconds += duration

    def mark_warm(self) -> None:
        """Declare warm-up over; compiles after this point are regressions."""
        self._warm_at = self.compiles

    @property
    def compiles_since_warm(self) -> int:
        return self.compiles - (self._warm_at or 0)

    def summary(self) -> str:
        since = ("n/a" if self._warm_at is None
                 else str(self.compiles_since_warm))
        return (f"compiles={self.compiles} compiles_after_warmup={since} "
                f"compile_s={self.compile_seconds:.2f}")


@contextlib.contextmanager
def sanitize(nans: bool = True, infs: bool = True,
             counter: CompileCounter | None = None):
    """NaN/Inf tripwires + compile counting for the enclosed block.

    Yields the :class:`CompileCounter` (the one passed in, or a fresh one).
    Previous debug-flag values are restored on exit, so nesting and test
    isolation are safe.
    """
    prev_nans = jax.config.jax_debug_nans
    prev_infs = jax.config.jax_debug_infs
    jax.config.update("jax_debug_nans", bool(nans))
    jax.config.update("jax_debug_infs", bool(infs))
    own = counter if counter is not None else CompileCounter()
    try:
        with own:
            yield own
    finally:
        jax.config.update("jax_debug_nans", prev_nans)
        jax.config.update("jax_debug_infs", prev_infs)
