"""Analysis CLI — ``python -m repro.analysis [--tier ast|jaxpr|both]``.

Exit status 0 = clean (every finding fixed, pragma'd, or baselined),
1 = unsuppressed findings, trace/parse errors, stale baseline entries, or a
blown ``--budget``. This is the blocking contract ``scripts/ci.sh analyze``
enforces for BOTH tiers.

The default tier is ``ast`` (pure stdlib, millisecond start-up — safe for
pre-commit hooks); ``jaxpr`` imports jax and traces the entry-point
registry; ``both`` is what CI runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.analysis.engine import BASELINE_NAME, find_repo_root, run_jaxlint
from repro.analysis.findings import Baseline
from repro.analysis.rules import RULE_SUMMARIES

_PLACEHOLDER = "TODO: justify this suppression before merging"


def _jaxpr_summaries():
    from repro.analysis.jaxpr.rules import JAXPR_RULE_SUMMARIES

    return JAXPR_RULE_SUMMARIES


def _entry_key(e: dict) -> tuple:
    return (e["rule"], e["path"], e["snippet"])


def _emit(report, tier_name, fmt, extra_tail=""):
    """Print one tier's findings in the chosen format; return its tail line."""
    if fmt == "github":
        for f in report.findings:
            # '::error' annotation syntax: one line per finding, shown inline
            # on the PR diff by GitHub's checks UI
            msg = f"{f.message} | hint: {f.hint}"
            print(f"::error file={f.path},line={f.line},"
                  f"title={f.rule}::{msg}")
        for _, err in report.parse_errors:
            print(f"::error title={tier_name}::{err}")
    elif fmt == "text":
        for f in report.findings:
            print(f.format())
        for _, err in report.parse_errors:
            print(err)
    unit = "files" if tier_name == "jaxlint" else "entries traced"
    tail = (f"[{tier_name}] {report.files} {unit}, "
            f"{len(report.findings)} finding(s), "
            f"{len(report.suppressed)} suppressed")
    if report.parse_errors:
        noun = "parse" if tier_name == "jaxlint" else "trace"
        tail += f", {len(report.parse_errors)} {noun} error(s)"
    return tail + extra_tail


def _rule_counts(report, summaries) -> str:
    counts = {rid: 0 for rid in sorted(summaries)}
    for f in report.findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return " ".join(f"{rid}:{n}" for rid, n in counts.items())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help="files/dirs for the AST tier (default: src tests "
                         "benchmarks examples under the repo root; naming a "
                         "file bypasses the fixture-dir exclusion). The "
                         "jaxpr tier always traces its registry.")
    ap.add_argument("--tier", choices=["ast", "jaxpr", "both"], default="ast",
                    help="which analysis tier(s) to run (default: ast)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected from cwd)")
    ap.add_argument("--baseline", default=None,
                    help=f"suppression file (default: <root>/{BASELINE_NAME} "
                         "if present; pass 'none' to ignore)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run, JLxxx and/or "
                         "JXxxx (default: all)")
    ap.add_argument("--no-pragmas", action="store_true",
                    help="ignore inline '# jaxlint: allow' pragmas")
    ap.add_argument("--format", choices=["text", "json", "github"],
                    default="text")
    ap.add_argument("--budget", type=float, default=None, metavar="SECONDS",
                    help="fail if the jaxpr tier (trace + rules) exceeds "
                         "this wall-clock budget")
    ap.add_argument("--registry", default=None, metavar="FILE",
                    help="python file defining ENTRIES: replaces the "
                         "built-in jaxpr entry-point registry (fixture "
                         "self-checks)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings of the "
                         "tier(s) run; entries of tiers NOT run and reasons "
                         "of still-matching entries are preserved")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--list-entries", action="store_true",
                    help="print the jaxpr tier's entry-point registry")
    args = ap.parse_args(argv)

    if args.list_rules:
        both = dict(RULE_SUMMARIES)
        both.update(_jaxpr_summaries())
        for rid, summary in sorted(both.items()):
            print(f"{rid}  {summary}")
        return 0
    if args.list_entries:
        from repro.analysis.jaxpr.registry import build_registry

        for entry in build_registry():
            print(entry.name)
        return 0

    run_ast = args.tier in ("ast", "both")
    run_jx = args.tier in ("jaxpr", "both")

    jl_ids = jx_ids = None
    if args.rules:
        ids = [r.strip().upper() for r in args.rules.split(",")]
        known = set(RULE_SUMMARIES) | set(_jaxpr_summaries())
        unknown = set(ids) - known
        if unknown:
            ap.error(f"unknown rule ids: {sorted(unknown)}")
        jl_ids = [r for r in ids if r.startswith("JL")] or None
        jx_ids = [r for r in ids if r.startswith("JX")] or None
        # a JL-only filter makes the jaxpr tier a no-op and vice versa
        run_ast = run_ast and jl_ids is not None
        run_jx = run_jx and jx_ids is not None
        if not (run_ast or run_jx):
            ap.error(f"--rules {args.rules} selects no rule in --tier "
                     f"{args.tier}")

    root = find_repo_root(args.root)
    effective_baseline = "none" if args.update_baseline else args.baseline

    reports = []  # (tier_name, report)
    tails = []
    rc = 0
    if run_ast:
        rep = run_jaxlint(paths=args.paths or None, root=root,
                          baseline=effective_baseline, rule_ids=jl_ids,
                          respect_pragmas=not args.no_pragmas)
        reports.append(("jaxlint", rep))
        tails.append(_emit(rep, "jaxlint", args.format)
                     if not args.update_baseline else "")
    if run_jx:
        from repro.analysis.jaxpr.runner import (load_registry_file,
                                                 run_jaxpr_tier)

        registry = (load_registry_file(args.registry)
                    if args.registry else None)
        t0 = time.monotonic()
        rep = run_jaxpr_tier(root=root, registry=registry,
                             baseline=effective_baseline, rule_ids=jx_ids,
                             respect_pragmas=not args.no_pragmas)
        dt = time.monotonic() - t0
        reports.append(("jaxpr", rep))
        if not args.update_baseline:
            per_rule = _rule_counts(rep, _jaxpr_summaries())
            tails.append(_emit(rep, "jaxpr", args.format,
                               extra_tail=f" in {dt:.1f}s | {per_rule}"))
        if args.budget is not None and dt > args.budget:
            tails.append(f"[jaxpr] BUDGET EXCEEDED: tier took {dt:.1f}s "
                         f"(budget {args.budget:.0f}s) — the registry trace "
                         "must stay cheap enough to block every PR")
            rc = 1

    if args.update_baseline:
        return _update_baseline(args, root, reports)

    # stale-entry rejection: a vetted suppression whose finding no longer
    # occurs means the flagged code changed — force a re-review. Only
    # meaningful for a full default-scope run of a tier's every rule.
    stale = []
    if not args.paths and not args.rules and effective_baseline != "none":
        bl_path = args.baseline or os.path.join(root, BASELINE_NAME)
        if os.path.isfile(bl_path):
            entries = Baseline.load(bl_path).entries
            matched = {(f.rule, f.path, f.snippet)
                       for _, rep in reports
                       for f, how in rep.suppressed if how == "baseline"}
            prefixes = {"JL"} if not run_jx else (
                {"JX"} if not run_ast else {"JL", "JX"})
            stale = [e for e in entries if e["rule"][:2] in prefixes
                     and _entry_key(e) not in matched]

    if args.format == "json":
        merged = {
            "files": sum(r.files for _, r in reports),
            "findings": [f.to_json() for _, r in reports for f in r.findings],
            "suppressed": [{"how": how, **f.to_json()}
                           for _, r in reports for f, how in r.suppressed],
            "parse_errors": [e for _, r in reports for _, e in r.parse_errors],
            "stale_baseline_entries": stale,
            "tiers": [name for name, _ in reports],
        }
        print(json.dumps(merged, indent=2))
    else:
        for e in stale:
            line = (f"stale baseline entry: {e['rule']} {e['path']} "
                    f"{e['snippet']!r} no longer matches any finding — the "
                    "flagged code changed; remove or re-justify the entry")
            if args.format == "github":
                print(f"::error file={e['path']},title={e['rule']}::{line}")
            else:
                print(line)
        for tail in tails:
            print(tail)
    if stale or any(not rep.ok for _, rep in reports):
        rc = 1
    return rc


def _update_baseline(args, root, reports) -> int:
    out = args.baseline if args.baseline not in (None, "none") \
        else os.path.join(root, BASELINE_NAME)
    old_entries = []
    if os.path.isfile(out):
        old_entries = Baseline.load(out).entries
    ran_prefixes = {"jaxlint": "JL", "jaxpr": "JX"}
    executed = {ran_prefixes[name] for name, _ in reports}
    kept = [e for e in old_entries if e["rule"][:2] not in executed]
    by_key = {_entry_key(e): e for e in old_entries}
    fresh = []
    for _, rep in reports:
        for f in rep.findings:
            key = (f.rule, f.path, f.snippet)
            prev = by_key.get(key)
            fresh.append(prev if prev is not None else {
                "rule": f.rule, "path": f.path, "snippet": f.snippet,
                "reason": _PLACEHOLDER})
    merged = sorted(kept + fresh,
                    key=lambda e: (e["rule"], e["path"], e["snippet"]))
    with open(out, "w") as f:
        f.write(json.dumps({"version": 1, "entries": merged}, indent=2) + "\n")
    n_kept = len(kept)
    print(f"[analysis] wrote {len(merged)} entries to {out} "
          f"({len(fresh)} from this run, {n_kept} preserved from tiers "
          "not run)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
