"""jaxlint CLI — ``python -m repro.analysis [paths...]``.

Exit status 0 = clean (every finding fixed, pragma'd, or baselined),
1 = unsuppressed findings or parse errors. This is the blocking contract
``scripts/ci.sh analyze`` enforces.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.engine import BASELINE_NAME, find_repo_root, run_jaxlint
from repro.analysis.findings import Baseline
from repro.analysis.rules import RULE_SUMMARIES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src tests benchmarks "
                         "examples under the repo root; naming a file "
                         "bypasses the fixture-dir exclusion)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected from cwd)")
    ap.add_argument("--baseline", default=None,
                    help=f"suppression file (default: <root>/{BASELINE_NAME} "
                         "if present; pass 'none' to ignore)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--no-pragmas", action="store_true",
                    help="ignore inline '# jaxlint: allow' pragmas")
    ap.add_argument("--format", choices=["text", "json"], default="text")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write all current findings to the baseline file "
                         "with a placeholder reason (justify before merging)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, summary in sorted(RULE_SUMMARIES.items()):
            print(f"{rid}  {summary}")
        return 0

    rule_ids = ([r.strip().upper() for r in args.rules.split(",")]
                if args.rules else None)
    if rule_ids:
        unknown = set(rule_ids) - set(RULE_SUMMARIES)
        if unknown:
            ap.error(f"unknown rule ids: {sorted(unknown)}")

    root = find_repo_root(args.root)
    report = run_jaxlint(
        paths=args.paths or None, root=root,
        baseline="none" if args.update_baseline else args.baseline,
        rule_ids=rule_ids, respect_pragmas=not args.no_pragmas)

    if args.update_baseline:
        import os

        out = args.baseline if args.baseline not in (None, "none") \
            else os.path.join(root, BASELINE_NAME)
        with open(out, "w") as f:
            f.write(Baseline.dump_entries(
                report.findings,
                reason="TODO: justify this suppression before merging"))
        print(f"[jaxlint] wrote {len(report.findings)} entries to {out}")
        return 0

    if args.format == "json":
        print(json.dumps({
            "files": report.files,
            "findings": [f.to_json() for f in report.findings],
            "suppressed": [{"how": how, **f.to_json()}
                           for f, how in report.suppressed],
            "parse_errors": [e for _, e in report.parse_errors],
        }, indent=2))
    else:
        for f in report.findings:
            print(f.format())
        for _, err in report.parse_errors:
            print(err)
        tail = (f"[jaxlint] {report.files} files, "
                f"{len(report.findings)} finding(s), "
                f"{len(report.suppressed)} suppressed")
        if report.parse_errors:
            tail += f", {len(report.parse_errors)} parse error(s)"
        print(tail)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
