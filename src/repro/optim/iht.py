"""IHT as a training feature: projected-gradient sparsity via H_s.

The paper's hard-threshold operator, applied to model weights after each
optimizer update, is exactly iterative magnitude pruning as projected gradient
descent — ``w ← H_s(w − η∇L)``. Exposed as a wrapper so any arch can train
s-sparse weight matrices. (No Theorem-3 recovery guarantee transfers to LM
weights — this is the *mechanism* as a framework feature.)

Uses the streaming histogram threshold (kernels/hsthresh semantics) so the
projection is O(N) per matrix, never a sort.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.hsthresh.ref import hsthresh_ref


class IHTConfig(NamedTuple):
    sparsity: float = 0.5          # fraction of entries to ZERO per matrix
    min_size: int = 4096           # only project matrices at least this big
    every: int = 1                 # project every k optimizer steps


def _project_matrix(w: jax.Array, keep: int, nbins: int = 4096) -> jax.Array:
    # hsthresh_ref (not a bare strict |w| > t cut): its threshold-bin fill is
    # what keeps a tied plateau — e.g. a constant-initialized matrix — from
    # being zeroed ENTIRELY in one projection.
    flat = hsthresh_ref(w.astype(jnp.float32).ravel(), keep, nbins)
    return jnp.where(flat.reshape(w.shape) != 0, w, jnp.zeros_like(w))


def project_params(params, cfg: IHTConfig):
    """H_s on every large 2-D+ weight leaf (path key 'w' or expert stacks)."""

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        eligible = (
            hasattr(leaf, "ndim")
            and leaf.ndim >= 2
            and leaf.size >= cfg.min_size
            and name in ("w", "wi_gate", "wi_up", "wo")
            and leaf.dtype in (jnp.float32, jnp.bfloat16)
        )
        if not eligible:
            return leaf
        keep = max(1, int(leaf.size * (1.0 - cfg.sparsity)))
        return _project_matrix(leaf, keep)

    return jax.tree_util.tree_map_with_path(one, params)


def maybe_project(params, step: jax.Array, cfg: IHTConfig):
    """Project on schedule (every k steps) inside a jitted train step."""
    do = (step % cfg.every) == 0
    return jax.lax.cond(do, lambda p: project_params(p, cfg), lambda p: p, params)


def sparsity_report(params, cfg: IHTConfig):
    """Measured zero-fraction of eligible matrices (diagnostics)."""
    total = 0
    zeros = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2 and leaf.size >= cfg.min_size
                and name in ("w", "wi_gate", "wi_up", "wo")):
            total += leaf.size
            zeros += int(jnp.sum(leaf == 0))
    return zeros / max(total, 1)
