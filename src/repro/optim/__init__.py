"""Optimizers and the IHT sparsity projector."""
from repro.optim.adamw import AdamWState, Optimizer, adamw, cosine_schedule
from repro.optim.iht import IHTConfig, maybe_project, project_params, sparsity_report

__all__ = [
    "AdamWState", "Optimizer", "adamw", "cosine_schedule",
    "IHTConfig", "maybe_project", "project_params", "sparsity_report",
]
