"""AdamW, from scratch (no optax in this container), pytree-native.

Moments inherit the parameter sharding (ZeRO-style: FSDP-sharded params →
FSDP-sharded moments for free under pjit out_shardings)."""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _cast_tree(tree, fn):
    return jax.tree_util.tree_map(fn, tree)


def adamw(
    lr: Union[float, Callable],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    def init(params):
        zeros = _cast_tree(params, lambda p: jnp.zeros_like(p, dtype=jnp.float32))
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=_cast_tree(params, lambda p: jnp.zeros_like(p, jnp.float32)))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr

        # global-norm clip
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads))
        )
        clip = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32) * clip
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
            v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
            delta = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m_new, v_new

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree_util.tree_unflatten(treedef, [a for a, _, _ in new])
        new_m = jax.tree_util.tree_unflatten(treedef, [b for _, b, _ in new])
        new_v = jax.tree_util.tree_unflatten(treedef, [c for _, _, c in new])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v), {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init=init, update=update)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)

    return lr
