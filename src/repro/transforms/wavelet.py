"""Periodized orthonormal 2D discrete wavelet transform, in pure JAX.

The MRI workload (paper §5) recovers images that are sparse in a *transform*
domain: anatomical images are piecewise smooth, so their wavelet coefficients
decay fast even though the pixels do not. This module provides the W of the
CS-MRI model Φ = P_Ω F W† — an orthonormal multi-level DWT whose synthesis
(W†) maps the sparse coefficient vector the solver iterates on back to image
space.

Design constraints, and how they are met:

* **Orthonormal** — the analysis/synthesis pair must be an exact unitary so
  the sensing operator's adjoint stays exact (`rmv` of the synthesis operator
  is simply the forward transform; see
  :class:`repro.core.operators.WaveletSynthesisOperator`). We use conjugate
  quadrature mirror filters with *periodized* (circular) boundary handling,
  which keeps every level a square orthogonal matrix — no coefficient
  redundancy, no boundary distortion of the adjoint identity.
* **Pure JAX, fixed shapes** — the multi-level pyramid is driven by one
  ``lax.scan`` over levels. Each level transforms only the top-left ``m×m``
  approximation block (``m = r >> level``), but all arrays stay ``(r, r)``:
  the active block size enters only through *index arithmetic* (periodized
  gathers ``(2k+t) mod m`` and pass-through masks), never through shapes, so
  the whole transform is a single compiled scan with a static trip count.
* **Batched** — every function maps over arbitrary leading axes; a ``(B, r,
  r)`` stack is one vectorized transform (the shape contract of the operator
  protocol's ``mv``/``rmv``).

Filters: ``"haar"`` (2 taps) and ``"db4"`` (the 4-tap Daubechies filter —
"D4" in the classical numbering; pywt calls it ``db2``). High-pass taps are
the standard QMF mirror ``hi[t] = (−1)^t · lo[L−1−t]``.

Coefficient layout is the standard pyramid: after ``levels`` steps the
``(r, r)`` array holds the coarsest approximation in the top-left
``(r >> levels)``-square, with each level's (LH, HL, HH) detail blocks
filling out the quadrants around it. :func:`flatten_coeffs` /
:func:`unflatten_coeffs` move between that array and the ``(r²,)`` vector the
solver's H_s thresholding consumes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

_SQRT3 = math.sqrt(3.0)
_D4_NORM = 4.0 * math.sqrt(2.0)

# Orthonormal low-pass analysis taps (sum of squares = 1).
WAVELETS = {
    "haar": (1.0 / math.sqrt(2.0), 1.0 / math.sqrt(2.0)),
    "db4": (
        (1.0 + _SQRT3) / _D4_NORM,
        (3.0 + _SQRT3) / _D4_NORM,
        (3.0 - _SQRT3) / _D4_NORM,
        (1.0 - _SQRT3) / _D4_NORM,
    ),
}


def wavelet_filters(wavelet: str) -> tuple[tuple[float, ...], tuple[float, ...]]:
    """(lo, hi) analysis taps; ``hi`` is the QMF mirror of ``lo``."""
    if wavelet not in WAVELETS:
        raise ValueError(
            f"unknown wavelet {wavelet!r} (available: {sorted(WAVELETS)})")
    lo = WAVELETS[wavelet]
    n = len(lo)
    hi = tuple((-1.0) ** t * lo[n - 1 - t] for t in range(n))
    return lo, hi


def max_levels(resolution: int, wavelet: str = "haar") -> int:
    """Deepest valid pyramid: every transformed block must be even-sized and
    at least one filter length wide (periodization below that is not
    orthogonal)."""
    flen = len(WAVELETS[wavelet]) if wavelet in WAVELETS else len(
        wavelet_filters(wavelet)[0])
    lv = 0
    m = resolution
    while m % 2 == 0 and m >= flen and m > 1:
        lv += 1
        m //= 2
    return lv


def _resolve_levels(resolution: int, wavelet: str, levels: Optional[int]) -> int:
    cap = max_levels(resolution, wavelet)
    if cap < 1:
        raise ValueError(
            f"resolution {resolution} admits no {wavelet!r} level "
            "(needs an even size >= the filter length)")
    if levels is None:
        return cap
    if not 1 <= levels <= cap:
        raise ValueError(
            f"levels must be in [1, {cap}] for resolution {resolution} "
            f"and wavelet {wavelet!r}, got {levels}")
    return levels


def _analysis_axis(x: jax.Array, m: jax.Array, lo, hi) -> jax.Array:
    """One analysis step along the last axis of the active ``m``-prefix.

    ``x`` is ``(..., r)``; entries ``[0, m)`` are split into ``m/2``
    approximation then ``m/2`` detail coefficients (periodized decimating
    convolution ``a[k] = Σ_t lo[t]·x[(2k+t) mod m]``); entries ``[m, r)``
    pass through. ``m`` may be a traced scalar — it only feeds index math.
    """
    r = x.shape[-1]
    half = r // 2
    k = jnp.arange(half)
    m2 = m // 2
    a = jnp.zeros(x.shape[:-1] + (half,), x.dtype)
    d = jnp.zeros_like(a)
    for t, (lt, ht) in enumerate(zip(lo, hi)):
        g = jnp.take(x, (2 * k + t) % m, axis=-1)
        a = a + lt * g
        d = d + ht * g
    c = jnp.arange(r)
    approx = jnp.take(a, jnp.clip(c, 0, half - 1), axis=-1)
    detail = jnp.take(d, jnp.clip(c - m2, 0, half - 1), axis=-1)
    return jnp.where(c < m2, approx, jnp.where(c < m, detail, x))


def _synthesis_axis(x: jax.Array, m: jax.Array, lo, hi) -> jax.Array:
    """Exact transpose of :func:`_analysis_axis` (orthonormal taps ⇒ the
    inverse): scatter-add each (approx, detail) pair back through the
    periodized filter. Contributions from the inactive tail are masked to
    zero, so their wrapped indices are harmless."""
    r = x.shape[-1]
    half = r // 2
    k = jnp.arange(half)
    m2 = m // 2
    valid = (k < m2).astype(x.dtype)
    a = jnp.take(x, jnp.clip(k, 0, r - 1), axis=-1) * valid
    d = jnp.take(x, jnp.clip(k + m2, 0, r - 1), axis=-1) * valid
    rec = jnp.zeros_like(x)
    for t, (lt, ht) in enumerate(zip(lo, hi)):
        rec = rec.at[..., (2 * k + t) % m].add(lt * a + ht * d)
    c = jnp.arange(r)
    return jnp.where(c < m, rec, x)


def _both_axes(x: jax.Array, m: jax.Array, lo, hi, step_axis) -> jax.Array:
    """Apply a 1D step separably over the last two axes of the active
    ``m×m`` block (rows outside it pass through unchanged)."""
    rows = jnp.arange(x.shape[-2])[:, None]
    y = jnp.where(rows < m, step_axis(x, m, lo, hi), x)
    yt = y.swapaxes(-1, -2)
    cols = jnp.arange(yt.shape[-2])[:, None]
    z = jnp.where(cols < m, step_axis(yt, m, lo, hi), yt)
    return z.swapaxes(-1, -2)


def dwt2(img: jax.Array, wavelet: str = "haar",
         levels: Optional[int] = None) -> jax.Array:
    """Multi-level periodized 2D DWT: ``(..., r, r)`` image → same-shape
    pyramid coefficient array. Orthonormal: ``‖dwt2(x)‖₂ = ‖x‖₂``."""
    lo, hi = wavelet_filters(wavelet)
    r = img.shape[-1]
    if img.shape[-2] != r:
        raise ValueError(f"dwt2 expects square images, got {img.shape[-2:]}")
    lv = _resolve_levels(r, wavelet, levels)
    sizes = jnp.asarray([r >> l for l in range(lv)], jnp.int32)

    def step(x, m):
        return _both_axes(x, m, lo, hi, _analysis_axis), None

    out, _ = jax.lax.scan(step, img, sizes)
    return out


def idwt2(coeffs: jax.Array, wavelet: str = "haar",
          levels: Optional[int] = None) -> jax.Array:
    """Inverse of :func:`dwt2` (synthesis W†): coefficient pyramid → image.
    Being the transpose of an orthonormal map, it is also the exact adjoint."""
    lo, hi = wavelet_filters(wavelet)
    r = coeffs.shape[-1]
    if coeffs.shape[-2] != r:
        raise ValueError(f"idwt2 expects square arrays, got {coeffs.shape[-2:]}")
    lv = _resolve_levels(r, wavelet, levels)
    sizes = jnp.asarray([r >> l for l in reversed(range(lv))], jnp.int32)

    def step(x, m):
        return _both_axes(x, m, lo, hi, _synthesis_axis), None

    out, _ = jax.lax.scan(step, coeffs, sizes)
    return out


def flatten_coeffs(coeffs: jax.Array) -> jax.Array:
    """Pyramid array ``(..., r, r)`` → coefficient vector ``(..., r²)``."""
    r = coeffs.shape[-1]
    return coeffs.reshape(*coeffs.shape[:-2], r * r)


def unflatten_coeffs(vec: jax.Array, resolution: int) -> jax.Array:
    """Coefficient vector ``(..., r²)`` → pyramid array ``(..., r, r)``."""
    return vec.reshape(*vec.shape[:-1], resolution, resolution)
