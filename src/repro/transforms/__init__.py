"""Sparsifying transforms: the W of transform-domain compressed sensing."""
from repro.transforms.wavelet import (
    WAVELETS,
    dwt2,
    flatten_coeffs,
    idwt2,
    max_levels,
    unflatten_coeffs,
    wavelet_filters,
)

__all__ = [
    "WAVELETS",
    "dwt2",
    "flatten_coeffs",
    "idwt2",
    "max_levels",
    "unflatten_coeffs",
    "wavelet_filters",
]
