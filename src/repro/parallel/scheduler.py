"""Continuous batching for the serving layer: admission queue + slot refill.

:class:`repro.parallel.batch.BatchServer` is a *lockstep* driver: it solves
pre-cut ``(B, M)`` chunks to a uniform horizon, so one slow row holds ``B-1``
finished slots hostage. NIHT's per-iteration structure is exactly what makes
continuous batching possible for an iterative solver: rows are independent
between iterations (Blumensath & Davies, arXiv:0805.0510 — all cross-row
structure is the shared Φ̂ stream), so an early-exited row can be *harvested*
at any segment boundary and its slot *refilled* from a queue, the way LLM
serving systems refill sequence slots at token boundaries.

The moving parts:

* :class:`Request` — one observation vector plus scheduling metadata
  (priority class, deadline, request id).
* :class:`AdmissionQueue` — bounded depth with shed-on-overflow, strict
  priority order with FIFO inside a class, and an *aging* rule
  (``age_every``) that promotes long-waiting requests one class per window so
  sustained high-priority load cannot starve the low classes.
* :class:`ContinuousScheduler` — the refill loop. It owns a live
  :class:`~repro.core.niht.SolverState` of ``slots`` rows and repeatedly:
  harvests rows whose ``done`` flag is set (or whose horizon is reached),
  splices queued requests into the freed rows
  (:func:`repro.parallel.batch.refill_rows` — every untouched row keeps its
  exact bits), and advances the whole table one *segment* of up to
  ``seg_len`` iterations via :func:`segment_step` (the same
  ``solver_segment``/``sharded_segment_run`` engine the preemption-safe
  driver checkpoints, so one jitted executable serves the entire run).

Time is **logical**: one tick = one segment. Every scheduling decision —
admission, shed, refill order — is a pure function of (arrival trace,
config), pinned by the determinism property test; wall-clock enters only the
latency *observability* fields of each :class:`RequestReport`.

Bit-identity contract (the differential suite's anchor): every request's
answer equals its **standalone solve at the same slot width** —
``qniht_batch`` over ``[y, 0, ..., 0]`` of ``slots`` rows with the same key
and solver config (:meth:`ContinuousScheduler.reference_solve`) — regardless
of arrival order, co-tenants, priorities, or refill timing. Two ingredients
make that hold:

* **stationary operators** — the scheduler requires the ``early_exit``
  precondition (``requantize="fixed"``, packed, matrix-free, or full
  precision), so the iteration map does not depend on the global index and
  the segment engine can run every row at its own logical age with ``k``
  reset per segment;
* **fixed-width row independence** — XLA's batched ops at a fixed ``(slots,
  ·)`` shape compute row ``b`` from row ``b``'s data alone, so co-tenant
  contents and row position never perturb a result (pinned empirically by
  the fuzzed differential suite; note the reference is deliberately *not*
  the ``B = 1`` solve — XLA lowers a one-row batch through a different gemv
  path whose accumulation differs in the last ulp).

Per-request reporting: ``iters_used`` (segment-granular: ages advance a
whole segment at a time, so a row that hit its fixed point mid-segment
reports the segment boundary), queue wait in ticks, and wall-clock
enqueue→start→finish latency. See ``docs/serving.md``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.niht import _validate, qniht_batch, solver_init, solver_segment
from repro.core.operators import PackedStreamingOperator
from repro.parallel.batch import make_batch_mesh, refill_rows, sharded_segment_run
from repro.parallel.journal import ChunkJournal
from repro.quant.formats import as_granularity

__all__ = [
    "AdmissionQueue",
    "ContinuousScheduler",
    "Request",
    "RequestReport",
    "segment_step",
]

# Request terminal/lifecycle states. String values land in metrics JSON.
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
SHED_QUEUE_FULL = "shed_queue_full"
SHED_DEADLINE = "shed_deadline"


@dataclasses.dataclass(frozen=True)  # jaxlint: allow=JL005 -- host-side scheduling record; y enters jit only after refill_rows copies it into the state
class Request:
    """One recovery request: an (M,) observation plus scheduling metadata.

    ``priority`` is a class index — **lower is more urgent** (0 beats 2).
    ``deadline`` is the last *tick* at which the request may still be granted
    a slot; a request still queued when the tick passes it is shed with
    status ``shed_deadline`` instead of solved late (a request already in a
    slot always runs to completion). ``None`` = no deadline.

    ``n_iters`` is the request's own horizon (iteration budget), at most the
    scheduler's ``n_iters`` (which sizes the state buffers); ``None`` = the
    scheduler's. Heterogeneous horizons are the regime continuous batching
    exists for: a lockstep table pays every cohort's longest budget, a
    continuous one refills each row at its own.
    """

    rid: int
    y: np.ndarray
    priority: int = 0
    deadline: Optional[int] = None
    n_iters: Optional[int] = None


@dataclasses.dataclass  # jaxlint: allow=JL005 -- host-side observability record; x is a harvested numpy copy, never re-enters jit
class RequestReport:
    """Lifecycle record of one request — the scheduler's observable output."""

    rid: int
    status: str
    priority: int
    arrival_tick: int
    start_tick: Optional[int] = None
    finish_tick: Optional[int] = None
    #: iterations paid for, segment-granular (see module docstring); None for
    #: shed or journal-drained requests
    iters_used: Optional[int] = None
    queue_wait_ticks: Optional[int] = None
    x: Optional[np.ndarray] = None
    drained: bool = False
    # wall-clock observability (never feeds a scheduling decision)
    wall_enqueued: Optional[float] = None
    wall_started: Optional[float] = None
    wall_finished: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        """Wall-clock enqueue → result latency (None until finished)."""
        if self.wall_finished is None or self.wall_enqueued is None:
            return None
        return self.wall_finished - self.wall_enqueued


@dataclasses.dataclass
class _QueueEntry:
    seq: int       # global arrival sequence number (FIFO tiebreak)
    enq_tick: int
    req: Request


class AdmissionQueue:
    """Bounded priority queue with FIFO classes, aging, and deadline shed.

    Pop order is the minimum of ``(effective_priority, seq)``: strict
    priority between classes, FIFO inside one. ``effective_priority`` is the
    request's class minus one per ``age_every`` ticks waited, so under
    sustained load every request's wait is bounded by roughly
    ``priority * age_every`` ticks plus one service drain (the no-starvation
    property test pins a concrete bound); ``age_every=0`` disables aging
    (strict priorities, starvation possible — benchmark mode).

    Overflow policy: a full queue sheds the *incoming* request unless it is
    strictly more urgent than the least-urgent queued entry, in which case
    that entry is evicted instead (ties keep the incumbent — FIFO).

    Every method is a pure function of its arguments and prior calls — no
    clocks, no randomness — which is what makes scheduler decisions
    replayable from (seed, arrival trace).
    """

    def __init__(self, depth: int, age_every: int = 0):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        if age_every < 0:
            raise ValueError(f"age_every must be >= 0, got {age_every}")
        self.depth = depth
        self.age_every = age_every
        self.entries: list[_QueueEntry] = []

    def __len__(self) -> int:
        return len(self.entries)

    def effective_priority(self, entry: _QueueEntry, tick: int) -> int:
        waited = tick - entry.enq_tick
        aged = waited // self.age_every if self.age_every else 0
        return entry.req.priority - aged

    def offer(self, req: Request, tick: int, seq: int):
        """Try to enqueue; returns ``(admitted, shed_entry)`` where
        ``shed_entry`` is the evicted incumbent (admitted over it), the
        rejected incoming entry (not admitted), or None."""
        entry = _QueueEntry(seq=seq, enq_tick=tick, req=req)
        if len(self.entries) < self.depth:
            self.entries.append(entry)
            return True, None
        worst = max(self.entries,
                    key=lambda e: (self.effective_priority(e, tick), e.seq))
        if req.priority < self.effective_priority(worst, tick):
            self.entries.remove(worst)
            self.entries.append(entry)
            return True, worst
        return False, entry

    def pop(self, tick: int) -> Optional[_QueueEntry]:
        if not self.entries:
            return None
        best = min(self.entries,
                   key=lambda e: (self.effective_priority(e, tick), e.seq))
        self.entries.remove(best)
        return best

    def shed_expired(self, tick: int) -> list[_QueueEntry]:
        """Remove and return entries whose deadline tick has passed."""
        expired = [e for e in self.entries
                   if e.req.deadline is not None and tick > e.req.deadline]
        for e in expired:
            self.entries.remove(e)
        return expired


def segment_step(phi, state, n_steps: int, *, mesh=None, **statics):
    """One refill-loop segment: advance every live row of the slot table by
    up to ``n_steps`` iterations — the continuous scheduler's hot loop.

    This is :func:`repro.core.niht.solver_segment` (or the sharded
    :func:`repro.parallel.batch.sharded_segment_run` when a mesh is given)
    with the iteration counter **reset to zero**: the scheduler's rows sit at
    *different* logical ages, so the state's global ``k`` cannot mean "the
    iteration every row is at". Resetting it is sound exactly because the
    scheduler requires stationary operators (the ``early_exit``
    precondition): the iteration map never reads the index, so "iterations
    [k, k+L)" and "[0, L)" are the same program — verified bit-for-bit by
    the differential suite. Trace buffers are consequently segment-local
    scratch (rows [0, L) are overwritten each call); per-request traces are
    not part of the harvest contract.
    """
    state = state._replace(k=jnp.zeros((), jnp.int32))
    if mesh is not None:
        return sharded_segment_run(phi, state, n_steps, mesh=mesh, **statics)
    return solver_segment(phi, state, n_steps, **statics)


class ContinuousScheduler:
    """Continuous-batching recovery service over one measurement operator.

    Construction mirrors :class:`~repro.parallel.batch.BatchServer` (pack
    once, compile once, one PRNG key for the whole service) plus the
    scheduling knobs:

    * ``slots`` — rows of the live :class:`SolverState` (the batch width
      every segment solves; also the width of the standalone reference).
    * ``seg_len`` — iterations per segment: the refill granularity, the
      ``ckpt_every`` of this loop. Choosing ``seg_len | n_iters`` keeps the
      horizon clamp from ever shortening a segment, so ONE executable serves
      the whole run (``stats()['segment_lengths']`` shows what actually ran).
    * ``queue_depth`` / ``age_every`` — :class:`AdmissionQueue` behaviour.
    * ``policy`` — ``"continuous"`` refills freed slots mid-flight;
      ``"lockstep"`` refills only when EVERY slot is free (the chunked
      baseline expressed in the same engine, so benchmark comparisons
      isolate the scheduling policy, not the solver).

    ``journal_dir`` write-ahead journals each request under its **request
    id** (inputs at splice time, result at harvest) via
    :class:`~repro.parallel.journal.ChunkJournal`; a restarted scheduler with
    ``resume=True`` fed the same deterministic arrival trace drains completed
    requests from disk (bit-identical bytes, never occupying a slot) and
    re-solves in-flight ones — same classification the chunked server uses.
    """

    def __init__(self, phi, s: int, n_iters: int = 50, *, slots: int = 8,
                 seg_len: int = 8, policy: str = "continuous",
                 queue_depth: int = 64, age_every: int = 8,
                 mesh=None, n_devices: Optional[int] = None,
                 bits_phi: Optional[int] = None, bits_y: Optional[int] = None,
                 key: Optional[jax.Array] = None, requantize: str = "fixed",
                 backend: str = "dense", threshold: str = "topk",
                 c: float = 0.01, shrink_k: float = 2.0,
                 max_backtracks: int = 30, real_signal: bool = False,
                 nonneg: bool = False, with_trace: bool = False,
                 scale_granularity: str = "per_tensor",
                 group_size: Optional[int] = None, exit_tol: float = 0.0,
                 journal_dir: Optional[str] = None, resume: bool = False):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if seg_len < 1:
            raise ValueError(f"seg_len must be >= 1, got {seg_len}")
        if policy not in ("continuous", "lockstep"):
            raise ValueError(
                f"unknown policy {policy!r} (use 'continuous' or 'lockstep')")
        if resume and journal_dir is None:
            raise ValueError("resume=True needs a journal_dir to resume from")
        # early_exit=True is load-bearing twice over: harvest needs the done
        # flags, and its stationarity precondition is what makes segment_step's
        # k-reset sound (see module docstring)
        _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold,
                  real_signal, scale_granularity, group_size, True, exit_tol)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.phi = phi
        self._ref_phi = phi  # user-level operator, pre pack-once translation
        self.slots = slots
        self.seg_len = seg_len
        self.n_iters = n_iters
        self.policy = policy
        self.mesh = (mesh if mesh is not None else
                     (make_batch_mesh(n_devices) if n_devices is not None else None))
        self.journal = ChunkJournal(journal_dir) if journal_dir is not None else None
        self._resume = bool(resume)
        # the user-level solver config — what reference_solve replays
        self._ref_statics = dict(
            bits_phi=bits_phi, bits_y=bits_y, requantize=requantize,
            backend=backend, threshold=threshold, c=c, shrink_k=shrink_k,
            max_backtracks=max_backtracks, real_signal=real_signal,
            nonneg=nonneg, scale_granularity=scale_granularity,
            group_size=group_size, exit_tol=exit_tol)
        statics = dict(
            s=s, bits_phi=bits_phi, bits_y=bits_y, requantize=requantize,
            backend=backend, threshold=threshold, c=c, shrink_k=shrink_k,
            max_backtracks=max_backtracks, real_signal=real_signal,
            nonneg=nonneg, with_trace=with_trace,
            scale_granularity=scale_granularity, group_size=group_size,
            early_exit=True, exit_tol=exit_tol)
        if backend == "packed":
            # pack once with the exact key the in-loop pack would fold — the
            # same construction BatchServer uses, pinned equivalent to the
            # user-level backend="packed" solve by the parity tests
            _, kphi = jax.random.split(self.key)
            self.phi = PackedStreamingOperator.pack(
                phi, bits_phi, jax.random.fold_in(kphi, 0),
                granularity=as_granularity(scale_granularity, group_size))
            statics.update(bits_phi=None, backend="dense")
        self._statics = statics
        self.s = s

        m = self.phi.shape[0]
        self._m = m
        self._y_dtype = jnp.dtype(self.phi.dtype)
        state = solver_init(
            self.phi, jnp.zeros((slots, m), self._y_dtype), s,
            n_iters=n_iters, key=self.key,
            **{k: v for k, v in statics.items() if k != "s"})
        # blank every slot: pad rows (done=True) with zeroed last-trace —
        # solver_init's NaN "not recorded" markers would flow into the trace
        # of born-done rows and trip --sanitize
        self._state = refill_rows(
            state, list(range(slots)), np.zeros((slots, m), self._y_dtype),
            [False] * slots)
        self._ages = np.zeros(slots, np.int64)
        self._horizon = np.full(slots, n_iters, np.int64)  # per-slot budget
        self._slot_rid: list[Optional[int]] = [None] * slots
        self.tick = 0
        self.reports: dict[int, RequestReport] = {}
        #: (tick, event, rid_or_None, detail) decision log — every entry is a
        #: pure function of (arrival trace, config); the determinism property
        #: test replays a trace and asserts log equality
        self.log: list[tuple] = []
        self._queue = AdmissionQueue(queue_depth, age_every)
        self._seq = 0
        self.segments_run = 0
        self._segment_lengths: dict[int, int] = {}
        self._occupied_slot_segments = 0
        self.n_drained = 0

    # -- admission ---------------------------------------------------------
    def _admit(self, req: Request, arrival_tick: int) -> None:
        if req.rid in self.reports:
            raise ValueError(f"duplicate request id {req.rid}")
        y = np.asarray(req.y)
        if y.shape != (self._m,):
            raise ValueError(
                f"request {req.rid}: y shape {y.shape} != ({self._m},)")
        if req.n_iters is not None and not 1 <= req.n_iters <= self.n_iters:
            raise ValueError(
                f"request {req.rid}: n_iters {req.n_iters} outside "
                f"[1, {self.n_iters}] (the scheduler's buffers are sized for "
                "its own n_iters)")
        rep = RequestReport(rid=req.rid, status=QUEUED, priority=req.priority,
                            arrival_tick=arrival_tick,
                            wall_enqueued=time.perf_counter())
        self.reports[req.rid] = rep
        if (self.journal is not None and self._resume
                and self.journal.is_complete(req.rid)):
            self.journal.verify_submit(req.rid, y[None], np.asarray(self.key))
            rep.x = self.journal.load_result_full(req.rid)[0]
            rep.status = DONE
            rep.drained = True
            rep.finish_tick = arrival_tick
            rep.queue_wait_ticks = 0
            rep.wall_finished = time.perf_counter()
            self.n_drained += 1
            self.log.append((self.tick, "drain", req.rid, None))
            return
        admitted, shed = self._queue.offer(req, arrival_tick, self._seq)
        self._seq += 1
        if shed is not None:
            srep = self.reports[shed.req.rid] if admitted else rep
            srep.status = SHED_QUEUE_FULL
            srep.finish_tick = self.tick
            srep.wall_finished = time.perf_counter()
            self.log.append((self.tick, "shed_queue_full", shed.req.rid, None))
        if admitted:
            self.log.append((self.tick, "enqueue", req.rid, req.priority))

    # -- the refill loop ---------------------------------------------------
    def _occupied(self) -> list[int]:
        return [b for b in range(self.slots) if self._slot_rid[b] is not None]

    def _harvest_and_refill(self) -> None:
        # 1. harvest: rows whose done flag is set, or whose horizon arrived
        done_h = np.asarray(self._state.done)
        freed: list[int] = []
        harvested = [b for b in self._occupied()
                     if done_h[b] or self._ages[b] >= self._horizon[b]]
        if harvested:
            X_h = np.asarray(self._state.X)
            for b in harvested:
                rid = self._slot_rid[b]
                rep = self.reports[rid]
                rep.x = X_h[b].copy()
                rep.status = DONE
                rep.finish_tick = self.tick
                rep.iters_used = int(min(self._ages[b], self._horizon[b]))
                rep.wall_finished = time.perf_counter()
                if self.journal is not None:
                    self.journal.record_result(rid, rep.x[None])
                self._slot_rid[b] = None
                freed.append(b)
                self.log.append((self.tick, "finish", rid, rep.iters_used))
        # 2. shed queue entries whose deadline passed — expired requests are
        # reported, never solved late
        for e in self._queue.shed_expired(self.tick):
            rep = self.reports[e.req.rid]
            rep.status = SHED_DEADLINE
            rep.finish_tick = self.tick
            rep.queue_wait_ticks = self.tick - e.enq_tick
            rep.wall_finished = time.perf_counter()
            self.log.append((self.tick, "shed_deadline", e.req.rid, None))
        # 3. refill freed slots from the queue ("lockstep" waits for a full
        # drain: the chunked baseline in the same engine)
        free = [b for b in range(self.slots) if self._slot_rid[b] is None]
        rows, Y_rows, live = [], [], []
        if self.policy == "continuous" or len(free) == self.slots:
            for b in free:
                entry = self._queue.pop(self.tick)
                if entry is None:
                    break
                rep = self.reports[entry.req.rid]
                rep.status = RUNNING
                rep.start_tick = self.tick
                rep.queue_wait_ticks = self.tick - entry.enq_tick
                rep.wall_started = time.perf_counter()
                if self.journal is not None:
                    self.journal.record_submit(
                        entry.req.rid, np.asarray(entry.req.y)[None],
                        np.asarray(self.key),
                        extra={"rid": entry.req.rid,
                               "priority": entry.req.priority,
                               "deadline": entry.req.deadline,
                               "n_iters": entry.req.n_iters,
                               "arrival_tick": rep.arrival_tick})
                self._slot_rid[b] = entry.req.rid
                self._ages[b] = 0
                self._horizon[b] = (entry.req.n_iters
                                    if entry.req.n_iters is not None
                                    else self.n_iters)
                rows.append(b)
                Y_rows.append(np.asarray(entry.req.y))
                live.append(True)
                self.log.append((self.tick, "start", entry.req.rid, b))
        # 4. blank harvested slots that stayed empty (pad rows: bitwise fixed
        # points the segment never waits on)
        for b in freed:
            if self._slot_rid[b] is None and b not in rows:
                rows.append(b)
                Y_rows.append(np.zeros(self._m, np.asarray(self._state.Y).dtype))
                live.append(False)
        if rows:
            self._state = refill_rows(
                self._state, rows, np.stack(Y_rows).astype(
                    np.asarray(self._state.Y).dtype), live)

    def _run_segment(self) -> None:
        occ = self._occupied()
        # horizon clamp: no live row may overshoot its own budget inside a
        # segment (its standalone answer is the iterate AT the horizon)
        n_steps = min(self.seg_len,
                      int(min(self._horizon[b] - self._ages[b] for b in occ)))
        self._state = segment_step(self.phi, self._state, n_steps,
                                   mesh=self.mesh, **self._statics)
        jax.block_until_ready(self._state.X)
        for b in occ:
            self._ages[b] += n_steps
        self.segments_run += 1
        self._segment_lengths[n_steps] = self._segment_lengths.get(n_steps, 0) + 1
        self._occupied_slot_segments += len(occ)
        self.log.append((self.tick, "segment", None, (n_steps, len(occ))))

    def run(self, arrivals) -> dict[int, RequestReport]:
        """Drive an arrival trace to completion; returns ``{rid: report}``.

        ``arrivals`` is an iterable of ``(tick, Request)`` with nondecreasing
        ticks. The loop delivers arrivals due at the current tick, harvests +
        refills, runs one segment when any slot is live, and advances the
        tick; with nothing live it jumps straight to the next arrival.
        """
        arr = list(arrivals)
        for (t0, _), (t1, _) in zip(arr, arr[1:]):
            if t1 < t0:
                raise ValueError("arrival ticks must be nondecreasing")
        ai = 0
        while True:
            while ai < len(arr) and arr[ai][0] <= self.tick:
                self._admit(arr[ai][1], arrival_tick=arr[ai][0])
                ai += 1
            self._harvest_and_refill()
            if not self._occupied():
                if ai >= len(arr) and not self._queue:
                    break
                # idle (lockstep barrier aside, an empty table means an empty
                # queue): jump to the next arrival
                self.tick = max(self.tick + 1,
                                arr[ai][0] if ai < len(arr) else self.tick + 1)
                continue
            self._run_segment()
            self.tick += 1
        return self.reports

    # -- observability -----------------------------------------------------
    def slot_table(self) -> list[Optional[int]]:
        """Current slot → request-id mapping (None = pad row)."""
        return list(self._slot_rid)

    def stats(self) -> dict:
        occ = (self._occupied_slot_segments / (self.segments_run * self.slots)
               if self.segments_run else 0.0)
        by_status: dict[str, int] = {}
        for rep in self.reports.values():
            by_status[rep.status] = by_status.get(rep.status, 0) + 1
        return {
            "policy": self.policy,
            "ticks": self.tick,
            "segments_run": self.segments_run,
            "segment_lengths": dict(sorted(self._segment_lengths.items())),
            "slot_occupancy": round(occ, 4),
            "drained": self.n_drained,
            **{f"n_{k}": v for k, v in sorted(by_status.items())},
        }

    def reference_solve(self, y, n_iters: Optional[int] = None) -> jax.Array:
        """The standalone answer the scheduler must reproduce bit-for-bit:
        the request alone in the slot table — ``qniht_batch`` over
        ``[y, 0, ..., 0]`` of ``slots`` rows with the scheduler's key, the
        request's horizon (``n_iters``, defaulting to the scheduler's), and
        the solver config (zero rows are free-riding fixed points). Uses the
        *user-level* configuration (dense Φ + ``backend="packed"`` rather
        than the pre-packed operator), so the contract also covers the
        pack-once construction."""
        Yp = jnp.zeros((self.slots, self._m), self._y_dtype)
        Yp = Yp.at[0].set(jnp.asarray(y, self._y_dtype))
        phi = self.phi if self._ref_statics["backend"] != "packed" else self._ref_phi
        res = qniht_batch(phi, Yp, self.s,
                          n_iters if n_iters is not None else self.n_iters,
                          key=self.key, early_exit=True, with_trace=False,
                          **self._ref_statics)
        return res.x[0]
