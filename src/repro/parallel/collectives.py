"""Distributed-optimization collectives: quantized gradient all-reduce — the
MODEL-TRAINING half of the distribution layer.

The paper's stochastic quantizer applied to the *communication* side of
training (the authors' QSGD/ZipML lineage): gradients are compressed to b-bit
integer codes before the cross-replica sum. Two-phase protocol keeps the sum
exact over the integer grid:

    1. global scale  s  = pmax(max|g|)          (tiny collective)
    2. codes         c  = stochastic_round(g / s · K)   (int32)
    3. sum           C  = psum(c)               (the big collective, b-bit payload)
    4. result        ĝ  = C · s / (K · n)       (unbiased mean)

Intended placement: *inter-pod* gradient sync — intra-pod ICI runs
full-precision SPMD; the slower pod-to-pod links carry compressed codes.
Implemented with ``shard_map``; optional error-feedback residual accumulation
turns the per-step quantization error into a correction at the next step.

The SOLVER mesh story is intentionally different: the sharded recovery path
(:mod:`repro.parallel.batch`) contains NO collectives at all — independent
observations of one Φ̂ are row-sharded over a 1-D ``("batch",)`` mesh and
never communicate, which is why its results are per-item identical to the
single-device run rather than merely unbiased. These gradient collectives
apply only to the LM-twin training workloads (``docs/architecture.md`` maps
both halves).

(The HLO emitted on CPU carries int32 psum — the byte saving is realized by
the int8/int4 all-reduce path on real interconnects; the *numerics* here are
exactly what production would see, which is what the tests verify.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.quant.formats import BY_BITS

try:  # JAX ≤ 0.4.x ships shard_map under experimental
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # newer JAX promoted it to the top level
    _shard_map = jax.shard_map


def _quantize_shard(g: jax.Array, scale: jax.Array, bits: int, key: jax.Array):
    k = BY_BITS[bits].half_steps
    scaled = jnp.clip(g / scale, -1.0, 1.0) * k
    low = jnp.floor(scaled)
    p_up = scaled - low
    u = jax.random.uniform(key, g.shape, jnp.float32)
    return jnp.clip(low + (u < p_up), -k, k).astype(jnp.int32)


def quantized_allreduce_mean(
    g: jax.Array,
    *,
    axis_name: str,
    bits: int,
    key: jax.Array,
    residual: Optional[jax.Array] = None,
):
    """Inside shard_map/pmap: unbiased quantized mean over ``axis_name``.

    Returns (mean_grad, new_residual). With ``residual`` given, applies error
    feedback: the local quantization error is added back next round.
    """
    k = BY_BITS[bits].half_steps
    n = jax.lax.psum(1, axis_name)
    g_in = g + (residual if residual is not None else 0.0)
    scale = jax.lax.pmax(jnp.max(jnp.abs(g_in)), axis_name)
    scale = jnp.maximum(scale, 1e-30)
    codes = _quantize_shard(g_in, scale, bits, key)
    sent = codes.astype(jnp.float32) * (scale / k)       # what the wire carries
    new_residual = g_in - sent if residual is not None else None
    total = jax.lax.psum(codes, axis_name)
    mean = total.astype(jnp.float32) * (scale / k) / n
    return mean, new_residual


def make_qgrad_allreduce(mesh: Mesh, axis_name: str, bits: int):
    """A pytree-level quantized-mean all-reduce over one mesh axis, as a
    shard_map'd function: tree, key -> tree (mean over axis replicas)."""

    def per_shard(flat_g, key):
        outs = []
        for i, g in enumerate(flat_g):
            m, _ = quantized_allreduce_mean(
                g, axis_name=axis_name, bits=bits, key=jax.random.fold_in(key, i)
            )
            outs.append(m)
        return tuple(outs)

    def run(tree, key):
        flat, treedef = jax.tree_util.tree_flatten(tree)
        specs = tuple(P(axis_name, *([None] * (g.ndim - 1))) for g in flat)
        fn = _shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(specs, P()),
            out_specs=tuple(P(None, *([None] * (g.ndim - 1))) for g in flat),
        )
        out = fn(tuple(flat), key)
        return jax.tree_util.tree_unflatten(treedef, list(out))

    return run


def fake_grad_compression(grads, bits: int, key: jax.Array):
    """Numerical twin of the quantized all-reduce for pjit-managed steps:
    applies the same unbiased quantize-dequantize to each gradient leaf
    (per-tensor global scale). Used when XLA owns the collective schedule."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    k = BY_BITS[bits].half_steps
    outs = []
    for i, g in enumerate(leaves):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30)
        kk = jax.random.fold_in(key, i)
        scaled = jnp.clip(gf / scale, -1, 1) * k
        low = jnp.floor(scaled)
        u = jax.random.uniform(kk, g.shape, jnp.float32)
        codes = jnp.clip(low + (u < (scaled - low)), -k, k)
        outs.append((codes * scale / k).astype(g.dtype))
    return jax.tree_util.tree_unflatten(treedef, outs)
