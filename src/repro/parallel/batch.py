"""Sharded batch recovery: ``qniht_batch`` split over a 1-D device mesh.

This is the *solver* half of the distribution layer (the model-training half —
parameter sharding rules and compressed gradient collectives — lives in
:mod:`repro.parallel.sharding` and :mod:`repro.parallel.collectives`). Per
Blumensath & Davies' analysis, NIHT iterations for independent observations of
the same Φ̂ never interact: all cross-row structure in ``qniht_batch`` is the
shared operator stream, while step sizes, supports, backtracking, and
convergence are per-row. That makes the B (observations) axis embarrassingly
parallel, and this module maps it onto a mesh:

* **mesh** — 1-D, sole axis named ``"batch"`` (:func:`make_batch_mesh`).
* **sharded** — ``Y`` by rows, and with it every piece of per-item solver
  state inside the loop: ``x``, support masks, µ, backtrack counters, and the
  per-item convergence flags that drive ``early_exit``.
* **replicated** — the operator (dense Φ, packed codes + scales, or a
  matrix-free operator's parameters) and the PRNG key. Each shard re-derives
  exactly the quantization draws the single-device path uses, which is what
  makes the result bit-identical per item rather than merely statistically
  equivalent.

Implementation: :func:`jax.experimental.shard_map` around the shared batched
core ``repro.core.niht._qniht_core`` (``check_rep=False`` — the loop's
``lax.while_loop`` backtracking has no replication rule, and the program
contains no collectives to mis-infer: shards are fully independent). B is
zero-padded up to a multiple of the mesh size; an all-zero row is accepted at
iteration 0 and immediately flagged converged, so padding never slows a shard
down. ``jax.jit`` over static solver config gives the compile cache the
serving loop relies on: a stream of equally-shaped chunks compiles once.

:class:`BatchServer` is the multi-chunk driver: fixed chunk shape, operator
packed ONCE at construction (the packed backend's quantize+pack leaves the
per-chunk path entirely), per-chunk observation keys. This is the layer the
heavy-traffic scenarios (MRI fleets, telescope streams) sit on.

User-facing entry points: :func:`repro.core.niht.qniht_batch_sharded`,
``repro.launch.recover --batch B --devices N``, and
``python -m repro.launch.serve``. See ``docs/architecture.md`` for where this
sits in the layer map.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # JAX ≤ 0.4.x ships shard_map under experimental
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # newer JAX promoted it to the top level
    _shard_map = jax.shard_map

from repro.core.niht import (
    _SEG_DEFAULTS,
    _SEG_STATIC,
    _STATIC,
    IHTResult,
    IHTTrace,
    SolverState,
    _qniht_core,
    _segment_core,
    _validate,
)
from repro.core.operators import PackedStreamingOperator
from repro.parallel.journal import ChunkJournal
from repro.quant.formats import as_granularity

BATCH_AXIS = "batch"


def force_host_devices(n: int, env=None) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS in
    ``env`` (default ``os.environ``). The CPU platform reads the flag ONCE,
    at backend initialization, so this must run before the first jax call of
    the target process; it is harmless on non-CPU platforms and merely
    appends for an already-initialized backend. The single owner of this
    contract — the CLIs and the scaling benchmark all call it.
    """
    import os

    target = os.environ if env is None else env
    target["XLA_FLAGS"] = (target.get("XLA_FLAGS", "")
                           + f" --xla_force_host_platform_device_count={int(n)}")

# the solver's own static-argname list — shared, not copied, so a kwarg added
# to the single-device jit can never silently become a traced argument here
_CORE_STATICS = _STATIC

# x is (B_local, N) → rows sharded; trace arrays are (n_iters, B_local) → the
# batch axis is second. The operator/key inputs are replicated (P() prefix).
_OUT_SPECS = IHTResult(
    x=P(BATCH_AXIS),
    trace=IHTTrace(*([P(None, BATCH_AXIS)] * 5)),
)


def make_batch_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """1-D serving mesh over the local devices, axis name ``"batch"``.

    ``n_devices`` takes the first N local devices (all of them by default).
    On CPU, force a multi-device view with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` **before** the
    first jax call — see ``docs/benchmarks.md``.
    """
    devs = list(devices if devices is not None else jax.devices())
    if n_devices is not None:
        if n_devices < 1 or n_devices > len(devs):
            raise ValueError(
                f"n_devices={n_devices} but {len(devs)} device(s) visible; on CPU "
                "set XLA_FLAGS=--xla_force_host_platform_device_count before jax "
                "initializes")
        devs = devs[:n_devices]
    return Mesh(np.array(devs).reshape(len(devs)), (BATCH_AXIS,))


def pad_batch(Y: jax.Array, n_shards: int) -> tuple[jax.Array, int]:
    """Zero-pad rows of (B, M) ``Y`` up to a multiple of ``n_shards``.

    Returns ``(Y_padded, B)``. Zero rows are free riders: NIHT accepts x = 0
    for y = 0 at the first iteration, so the convergence flag of a padding row
    is set immediately and ``early_exit`` shards never wait on it.
    """
    b = Y.shape[0]
    b_pad = -(-b // n_shards) * n_shards
    if b_pad == b:
        return Y, b
    pad = jnp.zeros((b_pad - b, Y.shape[1]), Y.dtype)
    return jnp.concatenate([Y, pad], axis=0), b


@partial(jax.jit, static_argnames=("mesh",) + _CORE_STATICS)
def _sharded_call(phi, Y, key, *, mesh, **statics):
    def local(phi_, Y_, key_):
        return _qniht_core(
            phi_, Y_, statics["s"], statics["n_iters"], statics["bits_phi"],
            statics["bits_y"], key_, statics["requantize"], statics["backend"],
            statics["threshold"], statics["c"], statics["shrink_k"],
            statics["max_backtracks"], statics["real_signal"], statics["nonneg"],
            statics["with_trace"], statics["scale_granularity"],
            statics["group_size"], statics["early_exit"], statics["exit_tol"],
            statics["unroll"],
        )

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(BATCH_AXIS), P()),
        out_specs=_OUT_SPECS,
        check_rep=False,  # lax.while_loop has no replication rule (JAX ≤ 0.4)
    )
    return fn(phi, Y, key)


def sharded_qniht_run(phi, Y, key, *, mesh=None, n_devices=None, **statics) -> IHTResult:
    """Pad → shard_map the batched NIHT core → strip padding.

    The backend of :func:`repro.core.niht.qniht_batch_sharded`; call that
    instead (it validates the solver configuration first).
    """
    mesh = mesh if mesh is not None else make_batch_mesh(n_devices)
    if set(mesh.axis_names) != {BATCH_AXIS}:
        raise ValueError(
            f"qniht_batch_sharded needs a 1-D ('{BATCH_AXIS}',) mesh, got axes "
            f"{mesh.axis_names}; build one with repro.parallel.batch.make_batch_mesh")
    Y_pad, b = pad_batch(Y, mesh.devices.size)
    res = _sharded_call(phi, Y_pad, key, mesh=mesh, **statics)
    if Y_pad.shape[0] == b:
        return res
    return IHTResult(
        x=res.x[:b],
        trace=jax.tree_util.tree_map(lambda t: t[:, :b], res.trace),
    )


# SolverState sharding: every per-row leaf splits by rows (trace second axis),
# the iteration index and PRNG key are replicated — _segment_core guarantees k
# lands on min(k + n_steps, n_iters) on every shard (early-exited shards FILL
# their remaining trace rows), so the replicated out-spec is genuine.
_SEG_SPECS = SolverState(
    k=P(), X=P(BATCH_AXIS), done=P(BATCH_AXIS), streak=P(BATCH_AXIS),
    last=IHTTrace(*([P(BATCH_AXIS)] * 5)),
    trace=IHTTrace(*([P(None, BATCH_AXIS)] * 5)),
    Y=P(BATCH_AXIS), key=P(),
)


def pad_state(state: SolverState, n_shards: int) -> tuple[SolverState, int]:
    """Zero-pad a :class:`SolverState`'s rows up to a multiple of ``n_shards``.

    Returns ``(state_padded, B)``. Pad rows are ``Y = 0, X = 0, done = True``:
    x = 0 is a bitwise fixed point of the iteration map for y = 0, so a pad
    row never changes, never delays a shard under ``early_exit``, and — the
    elastic-resume property — padding a state to ANY width and stripping it
    back is the identity on the real rows. A checkpoint is always saved
    stripped (:func:`strip_state`), so it restores onto any mesh.
    """
    b = state.Y.shape[0]
    b_pad = -(-b // n_shards) * n_shards
    if b_pad == b:
        return state, b
    p = b_pad - b

    def rows(a, fill=0):
        pad = jnp.full((p,) + a.shape[1:], fill, a.dtype)
        return jnp.concatenate([a, pad], axis=0)

    return SolverState(
        k=state.k,
        X=rows(state.X),
        done=rows(state.done, True),
        streak=rows(state.streak),
        last=jax.tree_util.tree_map(rows, state.last),
        trace=jax.tree_util.tree_map(
            lambda t: jnp.concatenate(
                [t, jnp.zeros(t.shape[:1] + (p,) + t.shape[2:], t.dtype)], axis=1),
            state.trace),
        Y=rows(state.Y),
        key=state.key,
    ), b


def strip_state(state: SolverState, b: int) -> SolverState:
    """Drop pad rows again (inverse of :func:`pad_state` on the real rows)."""
    if state.Y.shape[0] == b:
        return state
    return SolverState(
        k=state.k, X=state.X[:b], done=state.done[:b], streak=state.streak[:b],
        last=jax.tree_util.tree_map(lambda t: t[:b], state.last),
        trace=jax.tree_util.tree_map(lambda t: t[:, :b], state.trace),
        Y=state.Y[:b], key=state.key,
    )


def refill_rows(state: SolverState, rows, Y_rows, live) -> SolverState:
    """Splice fresh observations into ``rows`` of a :class:`SolverState`,
    leaving every other row **bitwise untouched** — the continuous-batching
    refill primitive (:mod:`repro.parallel.scheduler`).

    ``rows`` is a sequence of distinct row indices, ``Y_rows`` the matching
    ``(len(rows), M)`` observation block, and ``live`` a boolean per row:
    ``True`` re-initializes the row for a new request (``X = 0``, ``done =
    False``, fresh streak/trace), ``False`` turns it into a *pad* row
    (``Y`` must be zero; ``done = True`` makes it a bitwise fixed point the
    segment loop never waits on — same free-rider argument as
    :func:`pad_state`).

    A spliced row matches :func:`repro.core.niht.solver_init`'s row-0 state
    except ``last``'s residual markers, which are zeroed rather than NaN so a
    spliced state stays NaN-free under ``repro.analysis.sanitize`` (the NaN
    marker is a cosmetic "not recorded yet" value; it never feeds ``X``).

    Purity contract: the functional ``.at[rows]`` scatters rewrite ONLY the
    targeted rows; every other row of every leaf — ``X``, ``done``,
    ``streak``, ``last``, the trace *columns*, ``Y`` — keeps its exact bits
    (pinned by tests/test_scheduler.py::TestSplicePurity).
    """
    rows = [int(r) for r in rows]
    if len(set(rows)) != len(rows):
        raise ValueError(f"refill_rows needs distinct rows, got {rows}")
    b = state.Y.shape[0]
    if any(r < 0 or r >= b for r in rows):
        raise ValueError(f"rows {rows} out of range for B={b}")
    Y_rows = jnp.asarray(Y_rows, state.Y.dtype)
    if Y_rows.shape != (len(rows), state.Y.shape[1]):
        raise ValueError(
            f"Y_rows shape {Y_rows.shape} != {(len(rows), state.Y.shape[1])}")
    live = tuple(bool(v) for v in live)
    if len(live) != len(rows):
        raise ValueError(f"live must be one flag per row, got {len(live)}")
    return _splice_rows(state, Y_rows, rows=tuple(rows), live=live)


@partial(jax.jit, static_argnames=("rows", "live"))
def _splice_rows(state: SolverState, Y_rows, *, rows, live) -> SolverState:
    # rows/live are static: the refill loop revisits a small set of splice
    # patterns (deterministic trace ⇒ deterministic patterns), and a fused
    # scatter program per pattern keeps the per-tick cost off the eager
    # dispatch path
    idx = np.asarray(rows, np.int32)
    live_v = jnp.asarray(np.asarray(live, bool))

    def zero_rows(a):
        return a.at[idx].set(jnp.zeros((len(rows),) + a.shape[1:], a.dtype))

    return SolverState(
        k=state.k,
        X=zero_rows(state.X),
        done=state.done.at[idx].set(~live_v),
        streak=zero_rows(state.streak),
        last=jax.tree_util.tree_map(zero_rows, state.last),
        trace=jax.tree_util.tree_map(
            lambda t: t.at[:, idx].set(
                jnp.zeros(t.shape[:1] + (len(rows),) + t.shape[2:], t.dtype)),
            state.trace),
        Y=state.Y.at[idx].set(Y_rows),
        key=state.key,
    )


def state_shardings(mesh: Mesh) -> SolverState:
    """NamedSharding tree placing a (padded) :class:`SolverState` on ``mesh``
    per ``_SEG_SPECS`` — the elastic re-placement step: a state computed on
    (or restored from a checkpoint written under) one mesh is explicitly
    re-sharded for the target mesh before the next segment."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), _SEG_SPECS,
        is_leaf=lambda x: isinstance(x, P))


@partial(jax.jit, static_argnames=("mesh",) + _SEG_STATIC)
def _sharded_segment_call(phi, state, *, mesh, n_steps, **statics):
    fn = _shard_map(
        lambda phi_, st: _segment_core(phi_, st, n_steps=n_steps, **statics),
        mesh=mesh,
        in_specs=(P(), _SEG_SPECS),
        out_specs=_SEG_SPECS,
        check_rep=False,  # lax.while_loop has no replication rule (JAX ≤ 0.4)
    )
    return fn(phi, state)


def sharded_segment_run(phi, state: SolverState, n_steps: int, *, mesh=None,
                        n_devices: Optional[int] = None, **statics) -> SolverState:
    """:func:`repro.core.niht.solver_segment` with the state's rows split over
    a ``("batch",)`` mesh — the segment engine of the preemption-safe driver
    (:mod:`repro.launch.resilience`).

    Pads the state to the mesh width, advances ``n_steps`` iterations under
    ``shard_map``, and strips the padding again, so the returned (and
    checkpointed) state never records the mesh it ran on: save at ``--devices
    4``, resume at ``--devices 2`` — elastic by construction. Per-item
    bit-identity vs the single-process :func:`solver_segment` carries the same
    batching-invariance hedge as :func:`qniht_batch_sharded`, pinned bitwise
    in the fault-injection tests.
    """
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    mesh = mesh if mesh is not None else make_batch_mesh(n_devices)
    if set(mesh.axis_names) != {BATCH_AXIS}:
        raise ValueError(
            f"sharded_segment_run needs a 1-D ('{BATCH_AXIS}',) mesh, got axes "
            f"{mesh.axis_names}; build one with repro.parallel.batch.make_batch_mesh")
    statics = {**_SEG_DEFAULTS, **statics}
    _validate(phi, statics["bits_phi"], statics["bits_y"], state.key,
              statics["requantize"], statics["backend"], statics["threshold"],
              statics["real_signal"], statics["scale_granularity"],
              statics["group_size"], statics["early_exit"], statics["exit_tol"])
    state_p, b = pad_state(state, mesh.devices.size)
    # elastic: the incoming state may be committed to a different mesh width
    # (a previous segment's placement, or a checkpoint restored as host
    # arrays) — re-place it for THIS mesh before the sharded call
    state_p = jax.device_put(state_p, state_shardings(mesh))
    out = _sharded_segment_call(phi, state_p, mesh=mesh, n_steps=n_steps, **statics)
    return strip_state(out, b)


class BatchServer:
    """Multi-chunk sharded recovery service: the serving loop's driver.

    Holds one measurement operator and one solver configuration, and solves a
    stream of equally-shaped ``(B, M)`` observation chunks over a fixed
    ``batch`` mesh. Amortization contract:

    * **pack once** — with ``backend="packed"``, Φ̂ is quantized and packed at
      construction (keyed exactly as the solver would: ``fold_in(kφ, 0)`` of
      the construction key's second split half), and every chunk streams the
      same codes. ``submit`` then runs the matrix-free operator path, so the
      per-chunk program contains no quantize/pack at all.
    * **compile once** — the sharded call jits on (chunk shape, static solver
      config, mesh); a stream of same-shaped chunks reuses one executable.
      ``compile_cache_keys`` exposes the distinct shapes seen so far.
    * **per-chunk keys** — ``submit(Y, key=k)`` draws the chunk's observation
      quantization from ``k`` (default: the construction key), replicated so
      each row folds it the same way the single-device path would.

    Bit-identity: with construction key K and ``submit(Y, key=K)``, row ``b``
    equals ``qniht_batch(phi, Y, ..., key=K)`` of the corresponding
    single-device backend configuration bit-for-bit (the parity test in
    ``tests/test_sharded_batch.py`` pins this).

    Restartability: with ``journal_dir`` set, every chunk is write-ahead
    journaled (:class:`repro.parallel.journal.ChunkJournal`) — inputs before
    the solve, result after. A restarted server constructed with the same
    ``journal_dir`` and ``resume=True``, fed the same deterministic stream,
    **drains** already-completed chunks from disk (their solve is skipped;
    ``n_drained`` counts them) and **replays** in-flight ones; the resulting
    ``x`` stream is bit-identical to the uninterrupted run. Drained chunks
    carry a NaN/zero placeholder trace (the journal persists ``x``, the
    serving product — traces are diagnostics; re-run without a kill if you
    need them).
    """

    def __init__(self, phi, s: int, n_iters: int = 50, *, mesh=None,
                 n_devices: Optional[int] = None,
                 bits_phi: Optional[int] = None, bits_y: Optional[int] = None,
                 key: Optional[jax.Array] = None, requantize: str = "fixed",
                 backend: str = "dense", threshold: str = "topk",
                 c: float = 0.01, shrink_k: float = 2.0, max_backtracks: int = 30,
                 real_signal: bool = False, nonneg: bool = False,
                 with_trace: bool = False,
                 scale_granularity: str = "per_tensor",
                 group_size: Optional[int] = None, early_exit: bool = True,
                 exit_tol: float = 0.0, unroll: int = 1,
                 journal_dir: Optional[str] = None, resume: bool = False):
        _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold,
                  real_signal, scale_granularity, group_size, early_exit,
                  exit_tol, unroll)
        if resume and journal_dir is None:
            raise ValueError("resume=True needs a journal_dir to resume from")
        self.mesh = mesh if mesh is not None else make_batch_mesh(n_devices)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.phi = phi
        self.journal = ChunkJournal(journal_dir) if journal_dir is not None else None
        self._resume = bool(resume)
        self.n_chunks = 0
        self.n_items = 0
        self.n_drained = 0
        self._shapes: set = set()
        statics = dict(
            s=s, n_iters=n_iters, bits_phi=bits_phi, bits_y=bits_y,
            requantize=requantize, backend=backend, threshold=threshold, c=c,
            shrink_k=shrink_k, max_backtracks=max_backtracks,
            real_signal=real_signal, nonneg=nonneg, with_trace=with_trace,
            scale_granularity=scale_granularity, group_size=group_size,
            early_exit=early_exit, exit_tol=exit_tol, unroll=unroll,
        )
        if backend == "packed":
            # Pack once with the exact key the in-loop pack would fold, then
            # serve through the operator path: per-chunk programs stream the
            # codes but never re-quantize (see repro.core.operators).
            _, kphi = jax.random.split(self.key)
            self.phi = PackedStreamingOperator.pack(
                phi, bits_phi, jax.random.fold_in(kphi, 0),
                granularity=as_granularity(scale_granularity, group_size))
            statics.update(bits_phi=None, backend="dense")
        self._statics = statics

    def submit(self, Y: jax.Array, key: Optional[jax.Array] = None,
               row_mask=None) -> IHTResult:
        """Solve one (B, M) chunk; returns the usual :class:`IHTResult`.

        ``row_mask`` (optional (B,) bool) marks which rows are live user
        requests. The historical contract was all-rows-live; callers that pad
        a partial final chunk (or splice harvested rows) pass the mask so
        padded rows are never journaled as user results: masked rows of ``Y``
        are zeroed before the solve (an all-zero row fixes at ``x = 0``), the
        journal stores only the valid rows of ``x``, and a drained chunk
        reconstructs the full shape with zeros at the invalid rows —
        bit-identical to the live solve.

        With a journal: the chunk index is this server's submission count, the
        inputs (mask included) are journaled before the solve and the result
        after. Under ``resume=True`` a chunk whose result is already journaled
        is drained from disk instead of solved (see the class docstring).
        """
        if Y.ndim != 2:
            raise ValueError(f"BatchServer.submit expects (B, M) chunks, got {Y.shape}")
        mask = ChunkJournal._norm_mask(row_mask, Y.shape[0])
        if mask is not None:
            Y = jnp.where(jnp.asarray(mask, bool)[:, None], Y,
                          jnp.zeros_like(Y))
        idx = self.n_chunks
        self.n_chunks += 1
        self.n_items += Y.shape[0] if mask is None else int(mask.sum())
        k = key if key is not None else self.key
        if self.journal is not None:
            if self._resume and self.journal.is_complete(idx):
                self.journal.verify_submit(idx, Y, k, mask)
                self.n_drained += 1
                return IHTResult(x=jnp.asarray(self.journal.load_result_full(idx)),
                                 trace=self._placeholder_trace(Y.shape[0]))
            self.journal.record_submit(idx, Y, k, mask)
        self._shapes.add(Y.shape)
        res = sharded_qniht_run(self.phi, Y, k, mesh=self.mesh, **self._statics)
        if self.journal is not None:
            self.journal.record_result(idx, res.x, mask)
        return res

    def _placeholder_trace(self, b: int) -> IHTTrace:
        """Trace shell for a drained chunk (the journal persists only x)."""
        n_iters = self._statics["n_iters"]
        # np-built: an eager jnp.full(nan) would trip jax_debug_nans
        # under --sanitize even though this NaN means "not recorded"
        nanbuf = jnp.asarray(np.full((n_iters, b), np.nan, np.float32))
        return IHTTrace(resid_q=nanbuf, resid_true=nanbuf, mu=nanbuf,
                        support_changed=jnp.zeros((n_iters, b), bool),
                        backtracks=jnp.zeros((n_iters, b), jnp.int32))

    def serve(self, chunks, keys=None):
        """Drive a stream: yields one :class:`IHTResult` per chunk. ``keys``
        (optional iterable, any kind — generator included) supplies per-chunk
        observation keys; when exhausted or None, chunks fall back to the
        construction key."""
        key_iter = iter(keys) if keys is not None else None
        for Y in chunks:
            k = next(key_iter, None) if key_iter is not None else None
            yield self.submit(Y, k)

    @property
    def n_shards(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def compile_cache_keys(self) -> tuple:
        """Distinct chunk shapes seen (each costs one compile per config)."""
        return tuple(sorted(self._shapes))
