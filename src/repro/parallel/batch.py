"""Sharded batch recovery: ``qniht_batch`` split over a 1-D device mesh.

This is the *solver* half of the distribution layer (the model-training half —
parameter sharding rules and compressed gradient collectives — lives in
:mod:`repro.parallel.sharding` and :mod:`repro.parallel.collectives`). Per
Blumensath & Davies' analysis, NIHT iterations for independent observations of
the same Φ̂ never interact: all cross-row structure in ``qniht_batch`` is the
shared operator stream, while step sizes, supports, backtracking, and
convergence are per-row. That makes the B (observations) axis embarrassingly
parallel, and this module maps it onto a mesh:

* **mesh** — 1-D, sole axis named ``"batch"`` (:func:`make_batch_mesh`).
* **sharded** — ``Y`` by rows, and with it every piece of per-item solver
  state inside the loop: ``x``, support masks, µ, backtrack counters, and the
  per-item convergence flags that drive ``early_exit``.
* **replicated** — the operator (dense Φ, packed codes + scales, or a
  matrix-free operator's parameters) and the PRNG key. Each shard re-derives
  exactly the quantization draws the single-device path uses, which is what
  makes the result bit-identical per item rather than merely statistically
  equivalent.

Implementation: :func:`jax.experimental.shard_map` around the shared batched
core ``repro.core.niht._qniht_core`` (``check_rep=False`` — the loop's
``lax.while_loop`` backtracking has no replication rule, and the program
contains no collectives to mis-infer: shards are fully independent). B is
zero-padded up to a multiple of the mesh size; an all-zero row is accepted at
iteration 0 and immediately flagged converged, so padding never slows a shard
down. ``jax.jit`` over static solver config gives the compile cache the
serving loop relies on: a stream of equally-shaped chunks compiles once.

:class:`BatchServer` is the multi-chunk driver: fixed chunk shape, operator
packed ONCE at construction (the packed backend's quantize+pack leaves the
per-chunk path entirely), per-chunk observation keys. This is the layer the
heavy-traffic scenarios (MRI fleets, telescope streams) sit on.

User-facing entry points: :func:`repro.core.niht.qniht_batch_sharded`,
``repro.launch.recover --batch B --devices N``, and
``python -m repro.launch.serve``. See ``docs/architecture.md`` for where this
sits in the layer map.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # JAX ≤ 0.4.x ships shard_map under experimental
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # newer JAX promoted it to the top level
    _shard_map = jax.shard_map

from repro.core.niht import _STATIC, IHTResult, IHTTrace, _qniht_core, _validate
from repro.core.operators import PackedStreamingOperator
from repro.quant.formats import as_granularity

BATCH_AXIS = "batch"


def force_host_devices(n: int, env=None) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS in
    ``env`` (default ``os.environ``). The CPU platform reads the flag ONCE,
    at backend initialization, so this must run before the first jax call of
    the target process; it is harmless on non-CPU platforms and merely
    appends for an already-initialized backend. The single owner of this
    contract — the CLIs and the scaling benchmark all call it.
    """
    import os

    target = os.environ if env is None else env
    target["XLA_FLAGS"] = (target.get("XLA_FLAGS", "")
                           + f" --xla_force_host_platform_device_count={int(n)}")

# the solver's own static-argname list — shared, not copied, so a kwarg added
# to the single-device jit can never silently become a traced argument here
_CORE_STATICS = _STATIC

# x is (B_local, N) → rows sharded; trace arrays are (n_iters, B_local) → the
# batch axis is second. The operator/key inputs are replicated (P() prefix).
_OUT_SPECS = IHTResult(
    x=P(BATCH_AXIS),
    trace=IHTTrace(*([P(None, BATCH_AXIS)] * 5)),
)


def make_batch_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """1-D serving mesh over the local devices, axis name ``"batch"``.

    ``n_devices`` takes the first N local devices (all of them by default).
    On CPU, force a multi-device view with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` **before** the
    first jax call — see ``docs/benchmarks.md``.
    """
    devs = list(devices if devices is not None else jax.devices())
    if n_devices is not None:
        if n_devices < 1 or n_devices > len(devs):
            raise ValueError(
                f"n_devices={n_devices} but {len(devs)} device(s) visible; on CPU "
                "set XLA_FLAGS=--xla_force_host_platform_device_count before jax "
                "initializes")
        devs = devs[:n_devices]
    return Mesh(np.array(devs).reshape(len(devs)), (BATCH_AXIS,))


def pad_batch(Y: jax.Array, n_shards: int) -> tuple[jax.Array, int]:
    """Zero-pad rows of (B, M) ``Y`` up to a multiple of ``n_shards``.

    Returns ``(Y_padded, B)``. Zero rows are free riders: NIHT accepts x = 0
    for y = 0 at the first iteration, so the convergence flag of a padding row
    is set immediately and ``early_exit`` shards never wait on it.
    """
    b = Y.shape[0]
    b_pad = -(-b // n_shards) * n_shards
    if b_pad == b:
        return Y, b
    pad = jnp.zeros((b_pad - b, Y.shape[1]), Y.dtype)
    return jnp.concatenate([Y, pad], axis=0), b


@partial(jax.jit, static_argnames=("mesh",) + _CORE_STATICS)
def _sharded_call(phi, Y, key, *, mesh, **statics):
    def local(phi_, Y_, key_):
        return _qniht_core(
            phi_, Y_, statics["s"], statics["n_iters"], statics["bits_phi"],
            statics["bits_y"], key_, statics["requantize"], statics["backend"],
            statics["threshold"], statics["c"], statics["shrink_k"],
            statics["max_backtracks"], statics["real_signal"], statics["nonneg"],
            statics["with_trace"], statics["scale_granularity"],
            statics["group_size"], statics["early_exit"], statics["exit_tol"],
            statics["unroll"],
        )

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(BATCH_AXIS), P()),
        out_specs=_OUT_SPECS,
        check_rep=False,  # lax.while_loop has no replication rule (JAX ≤ 0.4)
    )
    return fn(phi, Y, key)


def sharded_qniht_run(phi, Y, key, *, mesh=None, n_devices=None, **statics) -> IHTResult:
    """Pad → shard_map the batched NIHT core → strip padding.

    The backend of :func:`repro.core.niht.qniht_batch_sharded`; call that
    instead (it validates the solver configuration first).
    """
    mesh = mesh if mesh is not None else make_batch_mesh(n_devices)
    if set(mesh.axis_names) != {BATCH_AXIS}:
        raise ValueError(
            f"qniht_batch_sharded needs a 1-D ('{BATCH_AXIS}',) mesh, got axes "
            f"{mesh.axis_names}; build one with repro.parallel.batch.make_batch_mesh")
    Y_pad, b = pad_batch(Y, mesh.devices.size)
    res = _sharded_call(phi, Y_pad, key, mesh=mesh, **statics)
    if Y_pad.shape[0] == b:
        return res
    return IHTResult(
        x=res.x[:b],
        trace=jax.tree_util.tree_map(lambda t: t[:, :b], res.trace),
    )


class BatchServer:
    """Multi-chunk sharded recovery service: the serving loop's driver.

    Holds one measurement operator and one solver configuration, and solves a
    stream of equally-shaped ``(B, M)`` observation chunks over a fixed
    ``batch`` mesh. Amortization contract:

    * **pack once** — with ``backend="packed"``, Φ̂ is quantized and packed at
      construction (keyed exactly as the solver would: ``fold_in(kφ, 0)`` of
      the construction key's second split half), and every chunk streams the
      same codes. ``submit`` then runs the matrix-free operator path, so the
      per-chunk program contains no quantize/pack at all.
    * **compile once** — the sharded call jits on (chunk shape, static solver
      config, mesh); a stream of same-shaped chunks reuses one executable.
      ``compile_cache_keys`` exposes the distinct shapes seen so far.
    * **per-chunk keys** — ``submit(Y, key=k)`` draws the chunk's observation
      quantization from ``k`` (default: the construction key), replicated so
      each row folds it the same way the single-device path would.

    Bit-identity: with construction key K and ``submit(Y, key=K)``, row ``b``
    equals ``qniht_batch(phi, Y, ..., key=K)`` of the corresponding
    single-device backend configuration bit-for-bit (the parity test in
    ``tests/test_sharded_batch.py`` pins this).
    """

    def __init__(self, phi, s: int, n_iters: int = 50, *, mesh=None,
                 n_devices: Optional[int] = None,
                 bits_phi: Optional[int] = None, bits_y: Optional[int] = None,
                 key: Optional[jax.Array] = None, requantize: str = "fixed",
                 backend: str = "dense", threshold: str = "topk",
                 c: float = 0.01, shrink_k: float = 2.0, max_backtracks: int = 30,
                 real_signal: bool = False, nonneg: bool = False,
                 with_trace: bool = False,
                 scale_granularity: str = "per_tensor",
                 group_size: Optional[int] = None, early_exit: bool = True,
                 exit_tol: float = 0.0, unroll: int = 1):
        _validate(phi, bits_phi, bits_y, key, requantize, backend, threshold,
                  real_signal, scale_granularity, group_size, early_exit,
                  exit_tol, unroll)
        self.mesh = mesh if mesh is not None else make_batch_mesh(n_devices)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.phi = phi
        self.n_chunks = 0
        self.n_items = 0
        self._shapes: set = set()
        statics = dict(
            s=s, n_iters=n_iters, bits_phi=bits_phi, bits_y=bits_y,
            requantize=requantize, backend=backend, threshold=threshold, c=c,
            shrink_k=shrink_k, max_backtracks=max_backtracks,
            real_signal=real_signal, nonneg=nonneg, with_trace=with_trace,
            scale_granularity=scale_granularity, group_size=group_size,
            early_exit=early_exit, exit_tol=exit_tol, unroll=unroll,
        )
        if backend == "packed":
            # Pack once with the exact key the in-loop pack would fold, then
            # serve through the operator path: per-chunk programs stream the
            # codes but never re-quantize (see repro.core.operators).
            _, kphi = jax.random.split(self.key)
            self.phi = PackedStreamingOperator.pack(
                phi, bits_phi, jax.random.fold_in(kphi, 0),
                granularity=as_granularity(scale_granularity, group_size))
            statics.update(bits_phi=None, backend="dense")
        self._statics = statics

    def submit(self, Y: jax.Array, key: Optional[jax.Array] = None) -> IHTResult:
        """Solve one (B, M) chunk; returns the usual :class:`IHTResult`."""
        if Y.ndim != 2:
            raise ValueError(f"BatchServer.submit expects (B, M) chunks, got {Y.shape}")
        self._shapes.add(Y.shape)
        self.n_chunks += 1
        self.n_items += Y.shape[0]
        return sharded_qniht_run(self.phi, Y, key if key is not None else self.key,
                                 mesh=self.mesh, **self._statics)

    def serve(self, chunks, keys=None):
        """Drive a stream: yields one :class:`IHTResult` per chunk. ``keys``
        (optional iterable, any kind — generator included) supplies per-chunk
        observation keys; when exhausted or None, chunks fall back to the
        construction key."""
        key_iter = iter(keys) if keys is not None else None
        for Y in chunks:
            k = next(key_iter, None) if key_iter is not None else None
            yield self.submit(Y, k)

    @property
    def n_shards(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def compile_cache_keys(self) -> tuple:
        """Distinct chunk shapes seen (each costs one compile per config)."""
        return tuple(sorted(self._shapes))
