"""Path-based parameter sharding rules for the MODEL-TRAINING half of the
distribution layer: FSDP (`data`) × TP/EP (`model`) × DP (`pod`), with
divisibility-aware fallback to replication.

This module is about sharding *parameters* of the LM-twin training/serving
workloads over 2-D/3-D meshes (:func:`repro.launch.mesh.make_production_mesh`).
It is deliberately separate from the *solver* mesh story — the CS recovery
path shards only the observation batch axis over a 1-D ``("batch",)`` mesh
with the operator replicated, and none of the rules here apply to it; see
:mod:`repro.parallel.batch` and ``docs/architecture.md`` for that half.

Rules are written against the *logical* (unstacked) weight shapes; scanned
stacks (leading n_periods/n_layers dim) get a ``None`` prepended automatically.
A dim is sharded only when its size divides the mesh axis — otherwise that dim
falls back to ``None`` (replicated), which encodes decisions like kv-head
replication when kv_heads % TP != 0 without special cases.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, spec for the trailing dims). "fsdp" → data axis, "tp" → model axis.
_RULES: list[tuple[str, tuple]] = [
    (r"embed/w$",               ("tp", "fsdp")),
    (r"unembed/w$",             ("tp", "fsdp")),
    (r"attn/wq/w$",             ("fsdp", "tp")),
    (r"attn/wk/w$",             ("fsdp", "tp")),
    (r"attn/wv/w$",             ("fsdp", "tp")),
    (r"attn/wo/w$",             ("tp", "fsdp")),
    (r"xattn/wq/w$",            ("fsdp", "tp")),
    (r"xattn/wk/w$",            ("fsdp", "tp")),
    (r"xattn/wv/w$",            ("fsdp", "tp")),
    (r"xattn/wo/w$",            ("tp", "fsdp")),
    (r"attn/w[qkvo]/b$",        ("tp",)),
    (r"xattn/w[qkvo]/b$",       ("tp",)),
    (r"ffn/wi_gate/w$",         ("fsdp", "tp")),
    (r"ffn/wi_up/w$",           ("fsdp", "tp")),
    (r"ffn/wi/w$",              ("fsdp", "tp")),
    (r"ffn/wo/w$",              ("tp", "fsdp")),
    (r"ffn/router/w$",          ("fsdp", None)),
    # MoE expert stacks (E, d, ff): expert-parallel over the model axis
    (r"ffn/wi_gate$",           ("tp", "fsdp", None)),
    (r"ffn/wi_up$",             ("tp", "fsdp", None)),
    (r"ffn/wo$",                ("tp", None, "fsdp")),
    # SSM
    (r"ssm/in_proj/w$",         ("fsdp", None)),
    (r"ssm/out_proj/w$",        ("tp", "fsdp")),
    (r"ssm/conv_w$",            (None, None)),
    # RG-LRU
    (r"rec/in_x/w$",            ("fsdp", "tp")),
    (r"rec/in_gate/w$",         ("fsdp", "tp")),
    (r"rec/w_r/w$",             ("fsdp", "tp")),
    (r"rec/w_i/w$",             ("fsdp", "tp")),
    (r"rec/out/w$",             ("tp", "fsdp")),
    (r"rec/conv_w$",            (None, None)),
]


def _axis(kind: Optional[str], mesh: Mesh) -> Optional[str]:
    if kind == "fsdp":
        return "data" if "data" in mesh.axis_names else None
    if kind == "tp":
        return "model" if "model" in mesh.axis_names else None
    return None


def _divisible(dim: int, axis: Optional[str], mesh: Mesh) -> bool:
    if axis is None:
        return False
    return dim % mesh.shape[axis] == 0


def spec_for_path(path: str, shape: tuple, mesh: Mesh) -> P:
    """PartitionSpec for one parameter. Unmatched paths → fully replicated."""
    for pattern, rule in _RULES:
        if re.search(pattern, path):
            n_extra = len(shape) - len(rule)
            if n_extra < 0:
                continue
            spec = [None] * n_extra
            for dim_size, kind in zip(shape[n_extra:], rule):
                ax = _axis(kind, mesh)
                spec.append(ax if _divisible(dim_size, ax, mesh) else None)
            return P(*spec)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


# serve-mode overrides: K/V projections are contraction-sharded (their OUTPUT
# must stay head-replicated or the partitioner re-lays-out the whole KV cache
# at the layer-scan boundary every token).
_SERVE_OVERRIDES: list[tuple[str, tuple]] = [
    (r"attn/wk/w$",  ("tp", None)),
    (r"attn/wv/w$",  ("tp", None)),
    (r"xattn/wk/w$", ("tp", None)),
    (r"xattn/wv/w$", ("tp", None)),
    (r"attn/w[kv]/b$",  (None,)),
    (r"xattn/w[kv]/b$", (None,)),
]


def params_shardings(params, mesh: Mesh, mode: str = "train"):
    """NamedSharding tree matching an (abstract or concrete) param tree.

    mode="train": FSDP over `data` × TP over `model` (ZeRO-style).
    mode="serve": TP only — weights replicated across the DP axes so the
    decode loop never all-gathers them (they are read-only and re-streamed
    every token; gathering per step is pure collective waste), with
    K/V projections contraction-sharded (see _SERVE_OVERRIDES)."""

    def one(path, leaf):
        ps = _path_str(path)
        spec = None
        if mode == "serve":
            for pattern, rule in _SERVE_OVERRIDES:
                if re.search(pattern, ps):
                    n_extra = len(leaf.shape) - len(rule)
                    parts = [None] * n_extra
                    for dim_size, kind in zip(leaf.shape[n_extra:], rule):
                        ax = _axis(kind, mesh)
                        parts.append(ax if _divisible(dim_size, ax, mesh) else None)
                    spec = P(*parts)
                    break
        if spec is None:
            spec = spec_for_path(ps, leaf.shape, mesh)
        if mode == "serve":
            spec = P(*(None if ax == "data" else ax for ax in spec))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_axes(mesh: Mesh):
    """Mesh axes over which the global batch is split (DP): pod × data."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def batch_spec(mesh: Mesh, batch_size: int, rank: int) -> P:
    """Spec for a (B, ...) input: batch over pod+data when divisible."""
    axes = batch_axes(mesh)
    if axes is None:
        return P()
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if batch_size % total == 0:
        return P(axes, *([None] * (rank - 1)))
    # try data-only
    if "data" in mesh.axis_names and batch_size % mesh.shape["data"] == 0:
        return P("data", *([None] * (rank - 1)))
    return P(*([None] * rank))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
