"""Distribution layer — two independent stories share this package:

* **solver serving** (:mod:`repro.parallel.batch`): the B axis of
  ``qniht_batch`` sharded over a 1-D ``batch`` mesh, bit-identical per item.
* **model training** (:mod:`repro.parallel.sharding`,
  :mod:`repro.parallel.collectives`): parameter sharding rules and quantized
  gradient collectives for the LM-twin workloads.
"""
from repro.parallel.batch import (
    BatchServer,
    make_batch_mesh,
    pad_batch,
    pad_state,
    sharded_qniht_run,
    sharded_segment_run,
    state_shardings,
    strip_state,
)
from repro.parallel.journal import ChunkJournal
from repro.parallel.collectives import (
    fake_grad_compression,
    make_qgrad_allreduce,
    quantized_allreduce_mean,
)
from repro.parallel.sharding import (
    batch_axes,
    batch_spec,
    params_shardings,
    replicated,
    spec_for_path,
)

__all__ = [
    "BatchServer",
    "ChunkJournal",
    "make_batch_mesh",
    "pad_batch",
    "pad_state",
    "sharded_qniht_run",
    "sharded_segment_run",
    "state_shardings",
    "strip_state",
    "fake_grad_compression",
    "make_qgrad_allreduce",
    "quantized_allreduce_mean",
    "batch_axes",
    "batch_spec",
    "params_shardings",
    "replicated",
    "spec_for_path",
]
