"""Distribution layer: sharding rules, meshes, compressed collectives."""
from repro.parallel.collectives import (
    fake_grad_compression,
    make_qgrad_allreduce,
    quantized_allreduce_mean,
)
from repro.parallel.sharding import (
    batch_axes,
    batch_spec,
    params_shardings,
    replicated,
    spec_for_path,
)

__all__ = [
    "fake_grad_compression",
    "make_qgrad_allreduce",
    "quantized_allreduce_mean",
    "batch_axes",
    "batch_spec",
    "params_shardings",
    "replicated",
    "spec_for_path",
]
