"""Distribution layer — two independent stories share this package:

* **solver serving** (:mod:`repro.parallel.batch`,
  :mod:`repro.parallel.scheduler`): the B axis of ``qniht_batch`` sharded
  over a 1-D ``batch`` mesh, bit-identical per item; the continuous-batching
  scheduler refills freed rows of the live state from an admission queue.
* **model training** (:mod:`repro.parallel.sharding`,
  :mod:`repro.parallel.collectives`): parameter sharding rules and quantized
  gradient collectives for the LM-twin workloads.
"""
from repro.parallel.batch import (
    BatchServer,
    make_batch_mesh,
    pad_batch,
    pad_state,
    refill_rows,
    sharded_qniht_run,
    sharded_segment_run,
    state_shardings,
    strip_state,
)
from repro.parallel.journal import ChunkJournal
from repro.parallel.scheduler import (
    AdmissionQueue,
    ContinuousScheduler,
    Request,
    RequestReport,
    segment_step,
)
from repro.parallel.collectives import (
    fake_grad_compression,
    make_qgrad_allreduce,
    quantized_allreduce_mean,
)
from repro.parallel.sharding import (
    batch_axes,
    batch_spec,
    params_shardings,
    replicated,
    spec_for_path,
)

__all__ = [
    "AdmissionQueue",
    "BatchServer",
    "ChunkJournal",
    "ContinuousScheduler",
    "Request",
    "RequestReport",
    "make_batch_mesh",
    "pad_batch",
    "pad_state",
    "refill_rows",
    "segment_step",
    "sharded_qniht_run",
    "sharded_segment_run",
    "state_shardings",
    "strip_state",
    "fake_grad_compression",
    "make_qgrad_allreduce",
    "quantized_allreduce_mean",
    "batch_axes",
    "batch_spec",
    "params_shardings",
    "replicated",
    "spec_for_path",
]
