"""Write-ahead journal for chunked serving: restartable result streams.

The serving loop (:class:`repro.parallel.batch.BatchServer`,
``python -m repro.launch.serve``) consumes a deterministic stream of
``(Y_chunk, key_chunk)`` pairs. To survive a kill mid-stream the server
journals each chunk *before* solving it and each result *after*:

Layout (one directory per serve run)::

    <dir>/chunk_000003.y.npy      # submitted observations (written pre-solve)
    <dir>/chunk_000003.key.npy    # the chunk's PRNG key (raw uint32 data)
    <dir>/chunk_000003.mask.npy   # row-validity mask (absent = all rows live)
    <dir>/chunk_000003.meta.json  # shape/dtype + status=submitted (fsync'd)
    <dir>/chunk_000003.x.npy      # solved iterate (atomic tmp -> rename)
    <dir>/chunk_000003.done.json  # completion marker (fsync'd, written last)

Chunk identity is **submission order**: the deterministic stream re-presents
the same chunks in the same order on restart, and the journal's job is to
classify each index as

* **completed** — ``done.json`` present: the result is *drained* from disk
  (the solve is skipped entirely; bit-identical by construction, the bytes
  are literally the same).
* **in-flight** — submitted but no ``done.json`` (the kill landed mid-solve):
  the chunk is *replayed* — solved again from the journaled inputs, which the
  deterministic solver maps to the identical result.
* **unseen** — solved and journaled as normal.

The submit record is verified against the re-presented chunk (bitwise Y and
key equality) before draining or replaying: a stream that diverged from the
journaled one is a configuration error, not a resume, and raises.

Durability mirrors :mod:`repro.train.checkpoint`: metadata and markers are
fsync'd and results are published by atomic rename, so a torn write can only
lose the *marker* — which safely demotes a completed chunk to in-flight
(it gets re-solved, to the same bytes) — never publish a torn result.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

__all__ = ["ChunkJournal", "write_json_durable"]


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json_durable(path: str, obj) -> None:
    """Atomically publish ``obj`` as JSON at ``path``: tmp write, fsync,
    ``os.rename``, directory fsync. The one sanctioned way to drop a JSON
    artifact on a durability-critical path (jaxlint JL007 enforces it)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    _fsync_dir(os.path.dirname(path))


class ChunkJournal:
    """Per-chunk write-ahead log under one directory (see module docstring)."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _p(self, index: int, suffix: str) -> str:
        return os.path.join(self.directory, f"chunk_{index:06d}.{suffix}")

    @staticmethod
    def _norm_mask(row_mask, b: int):
        """Canonical row-validity mask: None ⇔ every row valid (the
        historical all-rows-live contract), else a (B,) bool array with at
        least one False. Journals written before masks existed load as
        all-valid, and an explicitly all-true mask journals identically to
        ``None`` — one on-disk spelling per meaning."""
        if row_mask is None:
            return None
        m = np.asarray(row_mask, bool)
        if m.shape != (b,):
            raise ValueError(
                f"row_mask shape {m.shape} != ({b},): one flag per chunk row")
        return None if bool(m.all()) else m

    # -- write side -------------------------------------------------------
    def record_submit(self, index: int, Y, key, row_mask=None,
                      extra: Optional[dict] = None) -> None:
        """WAL entry: journal a chunk's inputs before its solve starts.

        ``row_mask`` marks which rows are live user requests (None = all —
        the historical contract); padded/harvested rows are journaled as
        *invalid* so they are never replayed as user results. ``extra`` is an
        optional dict of identity metadata (request id, priority, deadline —
        the continuous scheduler journals these) merged into ``meta.json``.

        Idempotent on replay: an existing record for ``index`` is verified
        against the new inputs (bitwise, mask included) instead of rewritten —
        a mismatch means the re-presented stream is not the journaled one,
        and raises.
        """
        Y = np.asarray(Y)
        if os.path.exists(self._p(index, "meta.json")):
            self.verify_submit(index, Y, key, row_mask)
            return
        k = np.asarray(key)
        mask = self._norm_mask(row_mask, Y.shape[0])
        # jaxlint: allow=JL007 -- write-ahead inputs, not a commit point:
        np.save(self._p(index, "y.npy"), Y)
        # the fsynced meta.json below is the commit; a torn y/key/mask file
        # with no meta just demotes this chunk back to never-submitted
        # jaxlint: allow=JL007 -- see above, meta.json is the commit point
        np.save(self._p(index, "key.npy"), k)
        if mask is not None:
            # jaxlint: allow=JL007 -- see above, meta.json is the commit point
            np.save(self._p(index, "mask.npy"), mask)
        write_json_durable(self._p(index, "meta.json"), {
            "index": index, "status": "submitted",
            "y_shape": list(Y.shape), "y_dtype": str(Y.dtype),
            "key_dtype": str(k.dtype),
            "rows_valid": int(mask.sum()) if mask is not None else Y.shape[0],
            **(extra or {}),
        })

    def record_result(self, index: int, x, row_mask=None) -> None:
        """Publish a chunk's result: atomic x write, then the done marker.

        With a ``row_mask``, ONLY the valid rows are journaled (``x.npy``
        holds the compacted ``x[mask]`` block): a padded or harvested row is
        scratch space, not a user result, and must never be replayable as
        one. ``load_result_full`` reconstructs the full chunk shape with
        zeros at invalid rows — bit-identical to the live solve, whose
        masked rows are zeroed before the solve (``y = 0`` rows fix at
        ``x = 0``).
        """
        x = np.asarray(x)
        mask = self._norm_mask(row_mask, x.shape[0])
        b_total = x.shape[0]
        if mask is not None:
            x = x[mask]
        tmp = self._p(index, "x.npy.tmp")
        with open(tmp, "wb") as f:  # np.save(path) would append another .npy
            np.save(f, x)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, self._p(index, "x.npy"))
        write_json_durable(self._p(index, "done.json"), {
            "index": index, "status": "complete",
            "x_shape": list(x.shape), "x_dtype": str(x.dtype),
            "b_total": b_total, "rows_valid": int(x.shape[0]),
        })

    # -- read side --------------------------------------------------------
    def is_complete(self, index: int) -> bool:
        done = self._p(index, "done.json")
        if not os.path.exists(done):
            return False
        try:
            with open(done) as f:
                return json.load(f).get("status") == "complete"
        except (json.JSONDecodeError, OSError):
            return False

    def completed(self) -> list:
        """Indices with a published result, ascending."""
        return [i for i in self._indices() if self.is_complete(i)]

    def pending(self) -> list:
        """Indices journaled as submitted but not completed (in-flight at the
        kill) — these get replayed, ascending."""
        return [i for i in self._indices() if not self.is_complete(i)]

    def _indices(self) -> list:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("chunk_") and name.endswith(".meta.json"):
                out.append(int(name[len("chunk_"):len("chunk_") + 6]))
        return sorted(out)

    def load_submit(self, index: int):
        """(Y, key) as journaled for ``index``."""
        return (np.load(self._p(index, "y.npy")),
                np.load(self._p(index, "key.npy")))

    def load_mask(self, index: int):
        """The journaled row-validity mask, or None (= every row valid —
        including journals written before masks existed)."""
        p = self._p(index, "mask.npy")
        return np.load(p) if os.path.exists(p) else None

    def load_result(self, index: int):
        """The journaled result bytes as stored: the full chunk when no mask
        was recorded, else only the valid rows (compacted)."""
        return np.load(self._p(index, "x.npy"))

    def load_result_full(self, index: int):
        """The result at full chunk shape: invalid rows are zeros, exactly as
        the live solve leaves them (masked ``y`` rows are zeroed pre-solve
        and ``x = 0`` is their fixed point)."""
        x = np.load(self._p(index, "x.npy"))
        mask = self.load_mask(index)
        if mask is None:
            return x
        with open(self._p(index, "done.json")) as f:
            b_total = json.load(f)["b_total"]
        full = np.zeros((b_total,) + x.shape[1:], x.dtype)
        full[mask] = x
        return full

    def verify_submit(self, index: int, Y, key, row_mask=None) -> None:
        """Raise unless the journaled inputs for ``index`` equal (Y, key,
        row_mask) bitwise — draining a result for DIFFERENT inputs would
        silently serve the wrong answer."""
        Yj, kj = self.load_submit(index)
        if Yj.shape != tuple(np.asarray(Y).shape) or not np.array_equal(
                Yj, np.asarray(Y)):
            raise ValueError(
                f"journal mismatch at chunk {index}: the re-presented Y differs "
                "from the journaled one — this stream is not the journaled run")
        if not np.array_equal(kj, np.asarray(key)):
            raise ValueError(
                f"journal mismatch at chunk {index}: the re-presented key "
                "differs from the journaled one")
        mj = self.load_mask(index)
        mask = self._norm_mask(row_mask, Yj.shape[0])
        same = (mj is None and mask is None) or (
            mj is not None and mask is not None and np.array_equal(mj, mask))
        if not same:
            raise ValueError(
                f"journal mismatch at chunk {index}: the re-presented row "
                "validity mask differs from the journaled one")
