"""Write-ahead journal for chunked serving: restartable result streams.

The serving loop (:class:`repro.parallel.batch.BatchServer`,
``python -m repro.launch.serve``) consumes a deterministic stream of
``(Y_chunk, key_chunk)`` pairs. To survive a kill mid-stream the server
journals each chunk *before* solving it and each result *after*:

Layout (one directory per serve run)::

    <dir>/chunk_000003.y.npy      # submitted observations (written pre-solve)
    <dir>/chunk_000003.key.npy    # the chunk's PRNG key (raw uint32 data)
    <dir>/chunk_000003.meta.json  # shape/dtype + status=submitted (fsync'd)
    <dir>/chunk_000003.x.npy      # solved iterate (atomic tmp -> rename)
    <dir>/chunk_000003.done.json  # completion marker (fsync'd, written last)

Chunk identity is **submission order**: the deterministic stream re-presents
the same chunks in the same order on restart, and the journal's job is to
classify each index as

* **completed** — ``done.json`` present: the result is *drained* from disk
  (the solve is skipped entirely; bit-identical by construction, the bytes
  are literally the same).
* **in-flight** — submitted but no ``done.json`` (the kill landed mid-solve):
  the chunk is *replayed* — solved again from the journaled inputs, which the
  deterministic solver maps to the identical result.
* **unseen** — solved and journaled as normal.

The submit record is verified against the re-presented chunk (bitwise Y and
key equality) before draining or replaying: a stream that diverged from the
journaled one is a configuration error, not a resume, and raises.

Durability mirrors :mod:`repro.train.checkpoint`: metadata and markers are
fsync'd and results are published by atomic rename, so a torn write can only
lose the *marker* — which safely demotes a completed chunk to in-flight
(it gets re-solved, to the same bytes) — never publish a torn result.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

__all__ = ["ChunkJournal", "write_json_durable"]


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json_durable(path: str, obj) -> None:
    """Atomically publish ``obj`` as JSON at ``path``: tmp write, fsync,
    ``os.rename``, directory fsync. The one sanctioned way to drop a JSON
    artifact on a durability-critical path (jaxlint JL007 enforces it)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    _fsync_dir(os.path.dirname(path))


class ChunkJournal:
    """Per-chunk write-ahead log under one directory (see module docstring)."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _p(self, index: int, suffix: str) -> str:
        return os.path.join(self.directory, f"chunk_{index:06d}.{suffix}")

    # -- write side -------------------------------------------------------
    def record_submit(self, index: int, Y, key) -> None:
        """WAL entry: journal a chunk's inputs before its solve starts.

        Idempotent on replay: an existing record for ``index`` is verified
        against the new inputs (bitwise) instead of rewritten — a mismatch
        means the re-presented stream is not the journaled one, and raises.
        """
        if os.path.exists(self._p(index, "meta.json")):
            self.verify_submit(index, Y, key)
            return
        Y = np.asarray(Y)
        k = np.asarray(key)
        # jaxlint: allow=JL007 -- write-ahead inputs, not a commit point:
        np.save(self._p(index, "y.npy"), Y)
        # the fsynced meta.json below is the commit; a torn y/key file with
        # no meta just demotes this chunk back to never-submitted
        # jaxlint: allow=JL007 -- see above, meta.json is the commit point
        np.save(self._p(index, "key.npy"), k)
        write_json_durable(self._p(index, "meta.json"), {
            "index": index, "status": "submitted",
            "y_shape": list(Y.shape), "y_dtype": str(Y.dtype),
            "key_dtype": str(k.dtype),
        })

    def record_result(self, index: int, x) -> None:
        """Publish a chunk's result: atomic x write, then the done marker."""
        x = np.asarray(x)
        tmp = self._p(index, "x.npy.tmp")
        with open(tmp, "wb") as f:  # np.save(path) would append another .npy
            np.save(f, x)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, self._p(index, "x.npy"))
        write_json_durable(self._p(index, "done.json"), {
            "index": index, "status": "complete",
            "x_shape": list(x.shape), "x_dtype": str(x.dtype),
        })

    # -- read side --------------------------------------------------------
    def is_complete(self, index: int) -> bool:
        done = self._p(index, "done.json")
        if not os.path.exists(done):
            return False
        try:
            with open(done) as f:
                return json.load(f).get("status") == "complete"
        except (json.JSONDecodeError, OSError):
            return False

    def completed(self) -> list:
        """Indices with a published result, ascending."""
        return [i for i in self._indices() if self.is_complete(i)]

    def pending(self) -> list:
        """Indices journaled as submitted but not completed (in-flight at the
        kill) — these get replayed, ascending."""
        return [i for i in self._indices() if not self.is_complete(i)]

    def _indices(self) -> list:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("chunk_") and name.endswith(".meta.json"):
                out.append(int(name[len("chunk_"):len("chunk_") + 6]))
        return sorted(out)

    def load_submit(self, index: int):
        """(Y, key) as journaled for ``index``."""
        return (np.load(self._p(index, "y.npy")),
                np.load(self._p(index, "key.npy")))

    def load_result(self, index: int):
        return np.load(self._p(index, "x.npy"))

    def verify_submit(self, index: int, Y, key) -> None:
        """Raise unless the journaled inputs for ``index`` equal (Y, key)
        bitwise — draining a result for DIFFERENT inputs would silently serve
        the wrong answer."""
        Yj, kj = self.load_submit(index)
        if Yj.shape != tuple(np.asarray(Y).shape) or not np.array_equal(
                Yj, np.asarray(Y)):
            raise ValueError(
                f"journal mismatch at chunk {index}: the re-presented Y differs "
                "from the journaled one — this stream is not the journaled run")
        if not np.array_equal(kj, np.asarray(key)):
            raise ValueError(
                f"journal mismatch at chunk {index}: the re-presented key "
                "differs from the journaled one")
