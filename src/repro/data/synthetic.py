"""Deterministic synthetic LM data pipeline.

Counter-based generation (`fold_in(key, step)`) makes every batch a pure
function of (seed, step) — so a restarted/re-elected worker regenerates the
exact same stream (fault-tolerance requirement: replayable data, no state to
checkpoint beyond the step counter). Batches are laid out as global arrays
sharded over the mesh's batch axes.

The "language" is a Zipf-ish mixture with local n-gram structure so the loss
actually goes down (pure uniform noise has nothing to learn).
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import batch_spec


def synthetic_batch(
    key: jax.Array, step: int, batch: int, seq: int, vocab: int
) -> dict:
    """One (tokens, labels) batch. Next-token labels; ~Zipf unigram with a
    deterministic bigram twist (token_{t+1} correlates with token_t)."""
    k = jax.random.fold_in(key, step)
    k1, k2 = jax.random.split(k)
    u = jax.random.uniform(k1, (batch, seq + 1))
    zipf = jnp.floor((vocab ** u - 1.0) / (vocab - 1) * vocab).astype(jnp.int32)
    zipf = jnp.clip(zipf, 0, vocab - 1)
    # bigram structure: with p=0.5 the next token is a fixed function of current
    follow = jax.random.bernoulli(k2, 0.5, (batch, seq + 1))
    rolled = (zipf * 31 + 7) % vocab
    toks = jnp.where(follow, jnp.roll(rolled, 1, axis=1), zipf)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SyntheticStream:
    """Step-indexed batch source with device placement."""

    def __init__(self, seed: int, batch: int, seq: int, vocab: int,
                 mesh: Optional[Mesh] = None):
        self.key = jax.random.PRNGKey(seed)
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.mesh = mesh

    def at_step(self, step: int) -> dict:
        b = synthetic_batch(self.key, step, self.batch, self.seq, self.vocab)
        if self.mesh is not None:
            spec = batch_spec(self.mesh, self.batch, 2)
            sh = NamedSharding(self.mesh, spec)
            b = {k: jax.device_put(v, sh) for k, v in b.items()}
        return b

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.at_step(step)
            step += 1
