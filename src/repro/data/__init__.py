"""Data pipeline."""
from repro.data.synthetic import SyntheticStream, synthetic_batch

__all__ = ["SyntheticStream", "synthetic_batch"]
