"""Training launcher: ``python -m repro.launch.train --arch starcoder2_3b --smoke``.

On this CPU container, use ``--smoke`` (reduced config) with a small mesh.
On real hardware the same entry point takes the full config and the
production mesh (``--mesh 16x16``), with checkpoint/restore + preemption
handling wired through repro.train.loop.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticStream
from repro.launch.mesh import make_production_mesh
from repro.optim import IHTConfig, adamw, cosine_schedule
from repro.quant.policy import QuantPolicy
from repro.train import LoopConfig, init_state, train_loop
from repro.train.steps import build_sharded_train_step, state_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-bits", type=int, default=0,
                    help="quantized gradient compression (paper's Q on comms)")
    ap.add_argument("--iht-sparsity", type=float, default=0.0,
                    help="H_s weight projection (paper's operator as trainer)")
    ap.add_argument("--mesh", default="1x1", help="data x model, e.g. 2x4")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dm, tm = (int(v) for v in args.mesh.split("x"))
    n_needed = dm * tm
    devs = np.array(jax.devices()[:n_needed]).reshape(dm, tm)
    mesh = Mesh(devs, ("data", "model"))

    policy = QuantPolicy(grad_bits=args.grad_bits or None)
    iht = IHTConfig(sparsity=args.iht_sparsity) if args.iht_sparsity > 0 else None
    opt = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps))
    step, st_sh = build_sharded_train_step(cfg, mesh, opt, args.batch,
                                           policy=policy, iht=iht)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    state = jax.device_put(state, st_sh)
    stream = SyntheticStream(0, args.batch, args.seq, cfg.vocab_size, mesh=mesh)

    def stepper(s, b):
        b = dict(b)
        b.setdefault("memory", None)
        return step(s, b)

    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    final = train_loop(stepper, state, stream, loop_cfg, state_shardings=st_sh)
    print(f"[train] done at step {int(final.step)}")


if __name__ == "__main__":
    main()
