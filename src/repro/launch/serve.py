"""Sharded batch-recovery serving driver — the heavy-traffic loop as a CLI.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --devices 8 --chunks 4

Simulates the production shape of the system: a stream of fixed-size (B, M)
observation chunks arriving against ONE measurement operator, recovered by a
:class:`repro.parallel.batch.BatchServer` whose ``batch`` mesh splits each
chunk's rows across devices. The driver demonstrates the three amortizations
the serving mode is built around:

* the operator is packed once at server construction (``--config
  serve-gaussian-packed``) — chunk programs stream codes, never re-quantize;
* the sharded solve compiles once per (chunk shape, solver config) and every
  later chunk reuses the executable (the driver prints compile vs steady-state
  wall times);
* per-shard ``early_exit`` lets shards of converged rows stop iterating while
  the shard holding the workload's hard rows keeps going.

``--scheduler continuous|lockstep`` switches from pre-cut chunks to the
continuous-batching scheduler (:mod:`repro.parallel.scheduler`) over a bursty
single-request arrival trace (``--config serve-continuous*``): an admission
queue with priorities/deadlines feeds a slot table whose finished rows are
harvested and refilled mid-flight (``continuous``) or only at full-table
drains (``lockstep`` — the chunked baseline in the same engine). ``--verify``
asserts every answer bitwise against its standalone solve; ``--metrics-json``
publishes the p50/p99 latency + occupancy metrics. See ``docs/serving.md``.

The default workload is the heterogeneous stream of
:mod:`repro.configs.serve_batch` (a leading burst of low-SNR rows per chunk);
``--devices N`` picks the mesh width. On CPU the flag above must force the
multi-device view before jax initializes — the driver sets it for you when
run as ``__main__`` with ``--devices`` (it exports XLA_FLAGS before the first
jax call). Scaling numbers live in ``benchmarks/fig_batch_scaling.py`` /
``BENCH_batch.json``; see ``docs/benchmarks.md``.
"""
from __future__ import annotations

import argparse
import time


def build_stream(cfg, key):
    """(phi, chunks, truths): ``cfg.n_chunks`` chunks of ``cfg.chunk`` rows
    sharing one Φ. Rows 0..n_hard-1 of each chunk are the *hard burst* —
    geometrically decaying coefficients (``cfg.hard_decay``) observed at
    ``snr_hard_db`` — and the rest flat s-sparse rows at ``snr_easy_db``."""
    import jax
    import jax.numpy as jnp

    from repro.sensing import make_gaussian_problem

    base = make_gaussian_problem(cfg.m, cfg.n, cfg.s, None, key)

    def sig(k, decay):
        perm = jax.random.permutation(k, cfg.n)[: cfg.s]
        amps = jnp.power(decay, jnp.arange(cfg.s, dtype=jnp.float32))
        signs = jax.random.rademacher(jax.random.fold_in(k, 1), (cfg.s,), jnp.float32)
        return jnp.zeros(cfg.n).at[perm].set(amps * signs)

    def obs(x, snr, k):
        y = x @ base.phi.T
        noise = jax.random.normal(k, y.shape) * jnp.sqrt(
            jnp.mean(y**2) / 10 ** (snr / 10))
        return y + noise

    chunks, truths = [], []
    for ci in range(cfg.n_chunks):
        ys, xs = [], []
        for b in range(cfg.chunk):
            kb = jax.random.fold_in(key, 1 + ci * cfg.chunk + b)
            decay, snr = ((cfg.hard_decay, cfg.snr_hard_db) if b < cfg.n_hard
                          else (1.0, cfg.snr_easy_db))
            x = sig(kb, decay)
            xs.append(x)
            ys.append(obs(x, snr, jax.random.fold_in(kb, 9)))
        chunks.append(jnp.stack(ys))
        truths.append(jnp.stack(xs))
    return base.phi, chunks, truths


def build_requests(cfg, key):
    """(phi, arrivals, truths, hard_rids) for a
    :class:`~repro.configs.serve_batch.ContinuousServeConfig`: single-request
    arrivals on a deterministic bursty Poisson clock.

    Arrival ticks come from a ``numpy`` generator seeded by ``cfg.seed``
    (Poisson(``arrival_rate``) per tick plus a ``burst_size`` burst every
    ``burst_every`` ticks); request contents reuse the hard/easy recipe of
    :func:`build_stream` (request ``rid`` plays the role of the chunk-row
    index, so the same fold_in keys generate the same signals). Priorities
    are round-robin over ``cfg.priority_classes`` (0 = most urgent) and
    deadlines follow ``cfg.deadline_slack`` (None = no deadlines).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.parallel.scheduler import Request
    from repro.sensing import make_gaussian_problem

    base = make_gaussian_problem(cfg.m, cfg.n, cfg.s, None, key)

    def sig(k, decay):
        perm = jax.random.permutation(k, cfg.n)[: cfg.s]
        amps = jnp.power(decay, jnp.arange(cfg.s, dtype=jnp.float32))
        signs = jax.random.rademacher(jax.random.fold_in(k, 1), (cfg.s,), jnp.float32)
        return jnp.zeros(cfg.n).at[perm].set(amps * signs)

    def obs(x, snr, k):
        y = x @ base.phi.T
        noise = jax.random.normal(k, y.shape) * jnp.sqrt(
            jnp.mean(y**2) / 10 ** (snr / 10))
        return y + noise

    rng = np.random.default_rng(cfg.seed)
    hard_stride = max(1, int(round(1.0 / cfg.hard_fraction))) if cfg.hard_fraction > 0 else 0
    arrivals, truths, hard_rids = [], {}, set()
    rid, tick = 0, 0
    while rid < cfg.n_requests:
        n_new = int(rng.poisson(cfg.arrival_rate))
        if cfg.burst_every and tick and tick % cfg.burst_every == 0:
            n_new += cfg.burst_size
        for _ in range(min(n_new, cfg.n_requests - rid)):
            hard = hard_stride and rid % hard_stride == 0
            kb = jax.random.fold_in(key, 1 + rid)
            decay, snr = ((cfg.hard_decay, cfg.snr_hard_db) if hard
                          else (1.0, cfg.snr_easy_db))
            x = sig(kb, decay)
            y = obs(x, snr, jax.random.fold_in(kb, 9))
            prio = rid % cfg.priority_classes
            deadline = (None if cfg.deadline_slack is None
                        else tick + cfg.deadline_slack * (prio + 1))
            budget = (cfg.n_iters if hard or cfg.n_iters_easy is None
                      else cfg.n_iters_easy)
            arrivals.append((tick, Request(rid=rid, y=np.asarray(y),
                                           priority=prio, deadline=deadline,
                                           n_iters=budget)))
            truths[rid] = x
            if hard:
                hard_rids.add(rid)
            rid += 1
        tick += 1
    return base.phi, arrivals, truths, hard_rids


def serve_scheduled(cfg, policy, devices=None, journal_dir=None, resume=False,
                    sanitize=None, verify=False):
    """Run the bursty request trace through a
    :class:`~repro.parallel.scheduler.ContinuousScheduler`; returns metrics.

    ``policy`` is ``"continuous"`` (mid-flight slot refill) or ``"lockstep"``
    (refill only when every slot is free — the chunked baseline in the same
    engine). The metrics dict carries the latency-observability fields the
    benchmark plots: p50/p99 request latency, items/sec, slot occupancy,
    queue-wait and iters-used means, and shed counts.

    ``verify=True`` recomputes every completed request's standalone reference
    (:meth:`~repro.parallel.scheduler.ContinuousScheduler.reference_solve`)
    and asserts bitwise equality — the differential contract as a CLI flag
    (the ``sched`` CI tier runs it on the smoke config).

    ``journal_dir``/``resume`` journal each request under its rid at splice
    time and drain completed results on restart, exactly like the chunked
    path (``metrics["drained"]`` counts requests served from disk).
    """
    import contextlib
    import statistics

    import jax
    import numpy as np

    from repro.core import relative_error
    from repro.parallel import ContinuousScheduler, make_batch_mesh

    if sanitize is None:
        sanitize = getattr(cfg, "sanitize", False)
    key = jax.random.PRNGKey(cfg.seed)
    phi, arrivals, truths, hard_rids = build_requests(cfg, key)
    kw = {}
    if cfg.backend == "packed":
        kw = dict(bits_phi=cfg.bits_phi, bits_y=cfg.bits_y, backend="packed")
    elif cfg.bits_y:
        kw = dict(bits_y=cfg.bits_y)
    if sanitize:
        # same contract as the chunked path: NaN trace markers would trip
        # debug_nans, so sanitized runs pay for the real residual trace
        kw["with_trace"] = True
        from repro.analysis.sanitize import sanitize as sanitize_ctx

        ctx = sanitize_ctx()
    else:
        ctx = contextlib.nullcontext()

    counter = None
    t0 = time.time()
    with ctx as counter:
        sch = ContinuousScheduler(
            phi, cfg.s, cfg.n_iters, slots=cfg.slots, seg_len=cfg.seg_len,
            policy=policy, queue_depth=cfg.queue_depth,
            age_every=cfg.age_every, mesh=make_batch_mesh(devices) if devices else None,
            key=key, exit_tol=cfg.exit_tol, journal_dir=journal_dir,
            resume=resume, **kw)
        reports = sch.run(arrivals)
        if counter is not None:
            counter.mark_warm()
    wall = time.time() - t0
    if counter is not None:
        print(f"[sanitize] ok {counter.summary()} debug_nans=on debug_infs=on",
              flush=True)

    done = [r for r in reports.values() if r.status == "done"]
    if verify:
        for r in done:
            _, req = next(a for a in arrivals if a[1].rid == r.rid)
            ref = np.asarray(sch.reference_solve(req.y, req.n_iters))
            assert np.array_equal(ref, np.asarray(r.x)), (
                f"request {r.rid}: scheduler answer differs from its "
                "standalone reference solve")
        print(f"[serve] verified {len(done)} requests bitwise against "
              "standalone solves", flush=True)
    lat = sorted(r.latency_s for r in done)
    waits = [r.queue_wait_ticks for r in done if r.queue_wait_ticks is not None]
    iters = [r.iters_used for r in done if r.iters_used is not None]
    rels_easy = [float(relative_error(np.asarray(r.x), truths[r.rid]))
                 for r in done if r.rid not in hard_rids]
    rels_hard = [float(relative_error(np.asarray(r.x), truths[r.rid]))
                 for r in done if r.rid in hard_rids]
    stats = sch.stats()

    def pct(xs, q):
        if not xs:
            return None
        return round(xs[min(len(xs) - 1, int(q * (len(xs) - 1) + 0.999999))], 4)

    sanitize_fields = {} if counter is None else {
        "sanitize_compiles": counter.compiles,
    }
    return {
        **sanitize_fields,
        "scheduler": policy,
        "requests": len(reports),
        "completed": len(done),
        "drained": stats["drained"],
        "shed_deadline": stats.get("n_shed_deadline", 0),
        "shed_queue_full": stats.get("n_shed_queue_full", 0),
        "slots": cfg.slots,
        "seg_len": cfg.seg_len,
        "ticks": stats["ticks"],
        "segments_run": stats["segments_run"],
        "segment_lengths": stats["segment_lengths"],
        "slot_occupancy": stats["slot_occupancy"],
        "wall_s": round(wall, 3),
        "items_per_s": round(len(done) / wall, 1) if wall else None,
        "latency_p50_s": pct(lat, 0.50),
        "latency_p99_s": pct(lat, 0.99),
        "queue_wait_ticks_mean": (round(statistics.mean(waits), 2)
                                  if waits else None),
        "iters_used_mean": round(statistics.mean(iters), 1) if iters else None,
        "rel_error_easy_mean": (round(sum(rels_easy) / len(rels_easy), 4)
                                if rels_easy else None),
        "rel_error_hard_mean": (round(sum(rels_hard) / len(rels_hard), 4)
                                if rels_hard else None),
    }


def serve(cfg, devices=None, chunks=None, journal_dir=None, resume=False,
          sanitize=None, profile_dir=None):
    """Run the stream through a BatchServer; returns a metrics dict.

    With ``journal_dir``, each chunk is write-ahead journaled and the loop
    runs under a :class:`~repro.train.fault.PreemptionGuard`: a SIGTERM/SIGINT
    finishes (and journals) the in-flight chunk, then stops cleanly. A
    restarted run with ``resume=True`` re-presents the same deterministic
    stream, drains journaled results and solves the rest — the per-chunk
    ``x_digest`` lines it prints are bit-identical to an uninterrupted run's
    (the fault-injection tests assert exactly that).

    ``profile_dir`` captures a JAX profiler trace of the whole serving loop
    (compile chunk included — filter by the steady-state chunks when reading;
    see docs/performance.md).

    ``sanitize`` (default: ``cfg.sanitize``) runs the whole loop under
    :func:`repro.analysis.sanitize.sanitize`: any NaN/Inf anywhere raises at
    the producing op, and a compile counter is marked warm after the first
    chunk — the ``[sanitize]`` summary line and the ``compiles*`` metrics
    fields report whether the compile-once contract held.
    """
    import contextlib
    import hashlib

    import jax
    import numpy as np

    from repro.core import relative_error
    from repro.parallel import BatchServer, make_batch_mesh
    from repro.train.fault import PreemptionGuard

    if sanitize is None:
        sanitize = getattr(cfg, "sanitize", False)
    key = jax.random.PRNGKey(cfg.seed)
    if chunks is not None:
        cfg = __import__("dataclasses").replace(cfg, n_chunks=chunks)
    phi, stream, truths = build_stream(cfg, key)
    mesh = make_batch_mesh(devices)
    kw = {}
    if cfg.backend == "packed":
        kw = dict(bits_phi=cfg.bits_phi, bits_y=cfg.bits_y, backend="packed")
    elif cfg.bits_y:
        kw = dict(bits_y=cfg.bits_y)
    if sanitize:
        # with_trace=False fills the trace outputs with NaN markers, which
        # debug_nans would (correctly) refuse — sanitized runs pay for the
        # real residual trace instead
        kw["with_trace"] = True
        from repro.analysis.sanitize import sanitize as sanitize_ctx

        ctx = sanitize_ctx()
    else:
        ctx = contextlib.nullcontext()

    prof = (jax.profiler.trace(profile_dir) if profile_dir
            else contextlib.nullcontext())
    walls, rels_easy, rels_hard = [], [], []
    preempted = None
    counter = None
    with prof, ctx as counter, PreemptionGuard() as guard:
        srv = BatchServer(phi, cfg.s, cfg.n_iters, mesh=mesh, key=key,
                          exit_tol=cfg.exit_tol, journal_dir=journal_dir,
                          resume=resume, **kw)
        for ci, Y in enumerate(stream):
            t0 = time.time()
            res = srv.submit(Y, jax.random.fold_in(key, 1000 + ci))
            jax.block_until_ready(res.x)
            walls.append(time.time() - t0)
            digest = hashlib.sha256(np.asarray(res.x).tobytes()).hexdigest()[:16]
            print(f"[serve] chunk {ci} x_digest={digest}", flush=True)
            for b in range(cfg.chunk):
                rel = float(relative_error(res.x[b], truths[ci][b]))
                (rels_hard if b < cfg.n_hard else rels_easy).append(rel)
            if counter is not None and ci == 0:
                # warm-up = chunk 0 end to end, metrics included: later
                # chunks must reuse both the sharded solve executable and
                # the small eager metric programs
                counter.mark_warm()
            if guard.requested and ci + 1 < len(stream):
                preempted = ci + 1
                print(f"[serve] preempted after chunk {ci} "
                      f"(journal has {ci + 1}/{len(stream)} chunks)", flush=True)
                break
    if counter is not None:
        print(f"[sanitize] ok {counter.summary()} debug_nans=on debug_infs=on",
              flush=True)
    steady = walls[1:] if len(walls) > 1 else walls
    items_per_s = cfg.chunk / (sum(steady) / len(steady))
    sanitize_fields = {} if counter is None else {
        "sanitize_compiles": counter.compiles,
        "sanitize_compiles_after_warmup": counter.compiles_since_warm,
    }
    return {
        **sanitize_fields,
        "devices": srv.n_shards,
        "chunks": len(stream),
        "chunks_served": srv.n_chunks,
        "chunks_drained": srv.n_drained,
        "preempted_after": preempted,
        "chunk_rows": cfg.chunk,
        "compile_chunk_s": round(walls[0], 3),
        "steady_chunk_s": round(sum(steady) / len(steady), 3),
        "items_per_s": round(items_per_s, 1),
        "rel_error_easy_mean": round(sum(rels_easy) / len(rels_easy), 4),
        "rel_error_hard_mean": (round(sum(rels_hard) / len(rels_hard), 4)
                                if rels_hard else None),
        "compile_cache_keys": list(map(list, srv.compile_cache_keys)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--config", default="serve-gaussian-smoke",
                    choices=["serve-gaussian", "serve-gaussian-packed",
                             "serve-gaussian-smoke", "serve-gaussian-fault",
                             "serve-gaussian-fault-packed", "serve-continuous",
                             "serve-continuous-packed",
                             "serve-continuous-smoke"])
    ap.add_argument("--scheduler", default="chunked",
                    choices=["chunked", "continuous", "lockstep"],
                    help="chunked = the BatchServer loop over pre-cut chunks "
                         "(ServeConfig); continuous|lockstep = the "
                         "ContinuousScheduler over the bursty request trace "
                         "(ContinuousServeConfig) with mid-flight refill on "
                         "or off")
    ap.add_argument("--metrics-json", default=None,
                    help="also write the metrics dict to this path as JSON "
                         "(atomic publish)")
    ap.add_argument("--verify", action="store_true",
                    help="(scheduler modes) recompute every completed "
                         "request's standalone qniht_batch reference and "
                         "assert bitwise equality")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh width (default: all visible devices); on CPU "
                         "also forces that many host devices when set before "
                         "jax initializes")
    ap.add_argument("--chunks", type=int, default=None,
                    help="override the config's number of stream chunks")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="write-ahead journal directory: each chunk's inputs "
                         "are journaled before its solve and the result after, "
                         "and SIGTERM/SIGINT stops cleanly at a chunk boundary")
    ap.add_argument("--resume", action="store_true",
                    help="drain already-journaled chunk results from "
                         "--checkpoint-dir instead of re-solving them")
    ap.add_argument("--sanitize", action="store_true", default=None,
                    help="run under repro.analysis.sanitize: raise on any "
                         "NaN/Inf and report backend compiles after warm-up "
                         "(default: the config's sanitize flag)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a JAX profiler trace of the serving loop "
                         "under this directory (see docs/performance.md)")
    args = ap.parse_args(argv)
    if args.chunks is not None and args.chunks < 1:
        ap.error("--chunks must be >= 1")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume needs --checkpoint-dir")

    if args.devices:
        # must happen before the first jax call in this process
        from repro.parallel.batch import force_host_devices

        force_host_devices(args.devices)

    from repro.configs.serve_batch import (
        CONFIG, CONTINUOUS, CONTINUOUS_PACKED, CONTINUOUS_SMOKE, FAULT,
        FAULT_PACKED, PACKED, SMOKE)

    cfg = {"serve-gaussian": CONFIG, "serve-gaussian-packed": PACKED,
           "serve-gaussian-smoke": SMOKE, "serve-gaussian-fault": FAULT,
           "serve-gaussian-fault-packed": FAULT_PACKED,
           "serve-continuous": CONTINUOUS,
           "serve-continuous-packed": CONTINUOUS_PACKED,
           "serve-continuous-smoke": CONTINUOUS_SMOKE}[args.config]
    is_continuous_cfg = args.config.startswith("serve-continuous")
    if (args.scheduler != "chunked") != is_continuous_cfg:
        ap.error("--scheduler continuous|lockstep goes with the "
                 "serve-continuous* configs; chunked with the serve-gaussian* "
                 "ones")
    if args.scheduler == "chunked":
        out = serve(cfg, args.devices, args.chunks,
                    journal_dir=args.checkpoint_dir, resume=args.resume,
                    sanitize=args.sanitize, profile_dir=args.profile_dir)
    else:
        if args.profile_dir:
            ap.error("--profile-dir is a chunked-path flag")
        out = serve_scheduled(cfg, args.scheduler, devices=args.devices,
                              journal_dir=args.checkpoint_dir,
                              resume=args.resume, sanitize=args.sanitize,
                              verify=args.verify)
    if args.metrics_json:
        from repro.parallel.journal import write_json_durable

        write_json_durable(args.metrics_json, out)
    print(f"[serve] {cfg.name}: " +
          " ".join(f"{k}={v}" for k, v in out.items()))


if __name__ == "__main__":
    main()
