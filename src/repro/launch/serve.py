"""Sharded batch-recovery serving driver — the heavy-traffic loop as a CLI.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --devices 8 --chunks 4

Simulates the production shape of the system: a stream of fixed-size (B, M)
observation chunks arriving against ONE measurement operator, recovered by a
:class:`repro.parallel.batch.BatchServer` whose ``batch`` mesh splits each
chunk's rows across devices. The driver demonstrates the three amortizations
the serving mode is built around:

* the operator is packed once at server construction (``--config
  serve-gaussian-packed``) — chunk programs stream codes, never re-quantize;
* the sharded solve compiles once per (chunk shape, solver config) and every
  later chunk reuses the executable (the driver prints compile vs steady-state
  wall times);
* per-shard ``early_exit`` lets shards of converged rows stop iterating while
  the shard holding the workload's hard rows keeps going.

The default workload is the heterogeneous stream of
:mod:`repro.configs.serve_batch` (a leading burst of low-SNR rows per chunk);
``--devices N`` picks the mesh width. On CPU the flag above must force the
multi-device view before jax initializes — the driver sets it for you when
run as ``__main__`` with ``--devices`` (it exports XLA_FLAGS before the first
jax call). Scaling numbers live in ``benchmarks/fig_batch_scaling.py`` /
``BENCH_batch.json``; see ``docs/benchmarks.md``.
"""
from __future__ import annotations

import argparse
import time


def build_stream(cfg, key):
    """(phi, chunks, truths): ``cfg.n_chunks`` chunks of ``cfg.chunk`` rows
    sharing one Φ. Rows 0..n_hard-1 of each chunk are the *hard burst* —
    geometrically decaying coefficients (``cfg.hard_decay``) observed at
    ``snr_hard_db`` — and the rest flat s-sparse rows at ``snr_easy_db``."""
    import jax
    import jax.numpy as jnp

    from repro.sensing import make_gaussian_problem

    base = make_gaussian_problem(cfg.m, cfg.n, cfg.s, None, key)

    def sig(k, decay):
        perm = jax.random.permutation(k, cfg.n)[: cfg.s]
        amps = jnp.power(decay, jnp.arange(cfg.s, dtype=jnp.float32))
        signs = jax.random.rademacher(jax.random.fold_in(k, 1), (cfg.s,), jnp.float32)
        return jnp.zeros(cfg.n).at[perm].set(amps * signs)

    def obs(x, snr, k):
        y = x @ base.phi.T
        noise = jax.random.normal(k, y.shape) * jnp.sqrt(
            jnp.mean(y**2) / 10 ** (snr / 10))
        return y + noise

    chunks, truths = [], []
    for ci in range(cfg.n_chunks):
        ys, xs = [], []
        for b in range(cfg.chunk):
            kb = jax.random.fold_in(key, 1 + ci * cfg.chunk + b)
            decay, snr = ((cfg.hard_decay, cfg.snr_hard_db) if b < cfg.n_hard
                          else (1.0, cfg.snr_easy_db))
            x = sig(kb, decay)
            xs.append(x)
            ys.append(obs(x, snr, jax.random.fold_in(kb, 9)))
        chunks.append(jnp.stack(ys))
        truths.append(jnp.stack(xs))
    return base.phi, chunks, truths


def serve(cfg, devices=None, chunks=None, journal_dir=None, resume=False,
          sanitize=None, profile_dir=None):
    """Run the stream through a BatchServer; returns a metrics dict.

    With ``journal_dir``, each chunk is write-ahead journaled and the loop
    runs under a :class:`~repro.train.fault.PreemptionGuard`: a SIGTERM/SIGINT
    finishes (and journals) the in-flight chunk, then stops cleanly. A
    restarted run with ``resume=True`` re-presents the same deterministic
    stream, drains journaled results and solves the rest — the per-chunk
    ``x_digest`` lines it prints are bit-identical to an uninterrupted run's
    (the fault-injection tests assert exactly that).

    ``profile_dir`` captures a JAX profiler trace of the whole serving loop
    (compile chunk included — filter by the steady-state chunks when reading;
    see docs/performance.md).

    ``sanitize`` (default: ``cfg.sanitize``) runs the whole loop under
    :func:`repro.analysis.sanitize.sanitize`: any NaN/Inf anywhere raises at
    the producing op, and a compile counter is marked warm after the first
    chunk — the ``[sanitize]`` summary line and the ``compiles*`` metrics
    fields report whether the compile-once contract held.
    """
    import contextlib
    import hashlib

    import jax
    import numpy as np

    from repro.core import relative_error
    from repro.parallel import BatchServer, make_batch_mesh
    from repro.train.fault import PreemptionGuard

    if sanitize is None:
        sanitize = getattr(cfg, "sanitize", False)
    key = jax.random.PRNGKey(cfg.seed)
    if chunks is not None:
        cfg = __import__("dataclasses").replace(cfg, n_chunks=chunks)
    phi, stream, truths = build_stream(cfg, key)
    mesh = make_batch_mesh(devices)
    kw = {}
    if cfg.backend == "packed":
        kw = dict(bits_phi=cfg.bits_phi, bits_y=cfg.bits_y, backend="packed")
    elif cfg.bits_y:
        kw = dict(bits_y=cfg.bits_y)
    if sanitize:
        # with_trace=False fills the trace outputs with NaN markers, which
        # debug_nans would (correctly) refuse — sanitized runs pay for the
        # real residual trace instead
        kw["with_trace"] = True
        from repro.analysis.sanitize import sanitize as sanitize_ctx

        ctx = sanitize_ctx()
    else:
        ctx = contextlib.nullcontext()

    prof = (jax.profiler.trace(profile_dir) if profile_dir
            else contextlib.nullcontext())
    walls, rels_easy, rels_hard = [], [], []
    preempted = None
    counter = None
    with prof, ctx as counter, PreemptionGuard() as guard:
        srv = BatchServer(phi, cfg.s, cfg.n_iters, mesh=mesh, key=key,
                          exit_tol=cfg.exit_tol, journal_dir=journal_dir,
                          resume=resume, **kw)
        for ci, Y in enumerate(stream):
            t0 = time.time()
            res = srv.submit(Y, jax.random.fold_in(key, 1000 + ci))
            jax.block_until_ready(res.x)
            walls.append(time.time() - t0)
            digest = hashlib.sha256(np.asarray(res.x).tobytes()).hexdigest()[:16]
            print(f"[serve] chunk {ci} x_digest={digest}", flush=True)
            for b in range(cfg.chunk):
                rel = float(relative_error(res.x[b], truths[ci][b]))
                (rels_hard if b < cfg.n_hard else rels_easy).append(rel)
            if counter is not None and ci == 0:
                # warm-up = chunk 0 end to end, metrics included: later
                # chunks must reuse both the sharded solve executable and
                # the small eager metric programs
                counter.mark_warm()
            if guard.requested and ci + 1 < len(stream):
                preempted = ci + 1
                print(f"[serve] preempted after chunk {ci} "
                      f"(journal has {ci + 1}/{len(stream)} chunks)", flush=True)
                break
    if counter is not None:
        print(f"[sanitize] ok {counter.summary()} debug_nans=on debug_infs=on",
              flush=True)
    steady = walls[1:] if len(walls) > 1 else walls
    items_per_s = cfg.chunk / (sum(steady) / len(steady))
    sanitize_fields = {} if counter is None else {
        "sanitize_compiles": counter.compiles,
        "sanitize_compiles_after_warmup": counter.compiles_since_warm,
    }
    return {
        **sanitize_fields,
        "devices": srv.n_shards,
        "chunks": len(stream),
        "chunks_served": srv.n_chunks,
        "chunks_drained": srv.n_drained,
        "preempted_after": preempted,
        "chunk_rows": cfg.chunk,
        "compile_chunk_s": round(walls[0], 3),
        "steady_chunk_s": round(sum(steady) / len(steady), 3),
        "items_per_s": round(items_per_s, 1),
        "rel_error_easy_mean": round(sum(rels_easy) / len(rels_easy), 4),
        "rel_error_hard_mean": (round(sum(rels_hard) / len(rels_hard), 4)
                                if rels_hard else None),
        "compile_cache_keys": list(map(list, srv.compile_cache_keys)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--config", default="serve-gaussian-smoke",
                    choices=["serve-gaussian", "serve-gaussian-packed",
                             "serve-gaussian-smoke", "serve-gaussian-fault",
                             "serve-gaussian-fault-packed"])
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh width (default: all visible devices); on CPU "
                         "also forces that many host devices when set before "
                         "jax initializes")
    ap.add_argument("--chunks", type=int, default=None,
                    help="override the config's number of stream chunks")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="write-ahead journal directory: each chunk's inputs "
                         "are journaled before its solve and the result after, "
                         "and SIGTERM/SIGINT stops cleanly at a chunk boundary")
    ap.add_argument("--resume", action="store_true",
                    help="drain already-journaled chunk results from "
                         "--checkpoint-dir instead of re-solving them")
    ap.add_argument("--sanitize", action="store_true", default=None,
                    help="run under repro.analysis.sanitize: raise on any "
                         "NaN/Inf and report backend compiles after warm-up "
                         "(default: the config's sanitize flag)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a JAX profiler trace of the serving loop "
                         "under this directory (see docs/performance.md)")
    args = ap.parse_args(argv)
    if args.chunks is not None and args.chunks < 1:
        ap.error("--chunks must be >= 1")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume needs --checkpoint-dir")

    if args.devices:
        # must happen before the first jax call in this process
        from repro.parallel.batch import force_host_devices

        force_host_devices(args.devices)

    from repro.configs.serve_batch import CONFIG, FAULT, FAULT_PACKED, PACKED, SMOKE

    cfg = {"serve-gaussian": CONFIG, "serve-gaussian-packed": PACKED,
           "serve-gaussian-smoke": SMOKE, "serve-gaussian-fault": FAULT,
           "serve-gaussian-fault-packed": FAULT_PACKED}[args.config]
    out = serve(cfg, args.devices, args.chunks,
                journal_dir=args.checkpoint_dir, resume=args.resume,
                sanitize=args.sanitize, profile_dir=args.profile_dir)
    print(f"[serve] {cfg.name}: " +
          " ".join(f"{k}={v}" for k, v in out.items()))


if __name__ == "__main__":
    main()
