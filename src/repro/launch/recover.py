"""CS recovery driver — the paper's own end-to-end pipeline as a launcher.

``python -m repro.launch.recover --config lofar --bits-phi 2 --bits-y 8``
simulates the station, builds Φ, quantizes per Algorithm 1 and recovers the
sky, reporting the Fig. 1/4 metrics.

Backends (``--backend``):

* ``dense``  — full-precision NIHT: Φ stays f32/c64, the Theorem 2 baseline.
* ``fake``   — QNIHT with *fake* quantization: Φ̂'s values are quantized
  (``--requantize pair`` redraws the stochastic pair each iteration —
  Algorithm 1 verbatim; ``fixed`` quantizes once) but carried as dense floats.
  Faithful to the paper's math; streams full-precision bytes.
* ``packed`` — QNIHT streaming *packed* uint8 codes through the Pallas qmm
  kernels (forces ``requantize=fixed``: the deployed systems stream
  pre-quantized data). Same iterates as ``fake --requantize fixed`` up to f32
  accumulation, with 32/bits× fewer operator bytes per matvec — the paper's
  Fig. 5/6 speed-up mode.

``--batch B`` recovers B observations of the same Φ̂ at once (``qniht_batch``):
one packed Φ̂ stream serves the whole batch per iteration. Adding
``--devices N`` splits those rows over an N-device ``("batch",)`` mesh
(``qniht_batch_sharded`` — bit-identical per item, with per-shard early exit;
on CPU the driver forces N host devices for you). The multi-chunk streaming
loop lives in ``python -m repro.launch.serve``.

``--scale-granularity`` picks the quantizer scale layout (default
``per_tensor``, the paper's single c): with ``--backend packed`` it selects the
packed Φ̂ scale granularity (``per_channel``, or ``per_block`` with
``--group-size G``); on the MRI configs it selects the *observation* quantizer
(``per_band`` radial k-space scaling, ``--group-size`` = number of bands) —
the mechanism that keeps ``--bits-y 4`` and below usable against k-space's
dynamic range.

``--config mri`` (also ``mri-bench``/``mri-smoke``) runs the paper's §5 MRI
workload: an s-sparse brain phantom recovered from quantized
variable-density-subsampled k-space. Φ is the *matrix-free*
``SubsampledFourierOperator`` (implicit 2D FFT + mask) — no dense Φ ever
exists, which is what makes the 256×256 config representable at all — so the
backend knobs don't apply; ``--bits-y`` is the precision under study and the
driver reports PSNR in image space alongside relative error. With
``--batch B``, B randomized brain phantoms share one sampling mask and are
recovered in a single ``qniht_batch`` call.

``--sparsity-basis`` picks the MRI recovery model: ``pixel`` (the s-sparse
phantom of the exact-sparsity guarantees) or ``haar``/``db4`` — the **full,
unsparsified** phantom recovered through the composed Φ = P_Ω F W†
(``ComposedOperator`` of the Fourier factor with a wavelet synthesis; still
matrix-free end to end). ``--config mri-wavelet`` (also ``-bench``/``-smoke``)
preselects the haar basis with wavelet-sized s and per-band scaling.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.gaussian_toy import CONFIG as GAUSS_CONFIG, SMOKE as GAUSS_SMOKE
from repro.configs.lofar_cs302 import BENCH as LOFAR_BENCH, CONFIG as LOFAR_CONFIG, SMOKE as LOFAR_SMOKE
from repro.configs.mri_brain import (
    BENCH as MRI_BENCH,
    CONFIG as MRI_CONFIG,
    SMOKE as MRI_SMOKE,
    WAVELET as MRI_WAVELET,
    WAVELET_BENCH as MRI_WAVELET_BENCH,
    WAVELET_SMOKE as MRI_WAVELET_SMOKE,
)
from repro.core import (
    niht,
    psnr,
    qniht,
    qniht_batch,
    qniht_batch_sharded,
    relative_error,
    source_recovery,
    support_recovery,
)
from repro.sensing import (
    Station,
    brain_phantom,
    make_gaussian_problem,
    make_mri_problem,
    make_sky,
    measurement_matrix,
    mri_observations,
    quantize_observations,
    sparsify_image,
    visibilities,
)


def _batch_solver(devices, kw, ckpt=None):
    """qniht_batch, or its mesh-sharded twin when ``--devices`` asks for one
    (bit-identical per item — see repro.parallel.batch). ``early_exit`` is on
    whenever the per-iteration operators are stationary (it is invalid under
    requantize='pair', which redraws Φ̂ each iteration).

    ``ckpt`` (``--checkpoint-dir``): route the solve through the segmented
    checkpointed driver (:func:`repro.launch.resilience.recover_resilient`) —
    same arguments, bit-identical result, preemption-safe."""
    early = not (kw.get("bits_phi") and kw.get("requantize", "pair") == "pair")
    if ckpt:
        from repro.launch.resilience import recover_resilient

        def run(phi, Y, s, n_iters, **kws):
            if devices:
                kws.setdefault("early_exit", early)
            return recover_resilient(phi, Y, s, n_iters,
                                     n_devices=devices or None, verbose=True,
                                     **ckpt, **kws)
        return run
    if devices:
        return partial(qniht_batch_sharded, n_devices=devices, early_exit=early)
    return qniht_batch


def _single_via_ckpt(ckpt, phi, y, s, n_iters, **kw):
    """One-problem solve through the segmented checkpointed driver (wraps the
    observation as a 1-row batch, exactly what ``qniht`` itself does)."""
    from repro.launch.resilience import recover_resilient

    res = recover_resilient(phi, y[None, :], s, n_iters, verbose=True,
                            **ckpt, **kw)
    return type(res)(x=res.x[0],
                     trace=jax.tree_util.tree_map(lambda t: t[:, 0], res.trace))


def _solver_kwargs(backend, bits_phi, bits_y, key, requantize,
                   granularity="per_tensor", group_size=None):
    if granularity != "per_tensor" and backend != "packed":
        raise ValueError(
            f"--scale-granularity {granularity} scales the packed Φ̂ stream; "
            f"combine it with --backend packed (got --backend {backend})")
    if backend == "dense":
        return dict()
    kw = dict(
        bits_phi=bits_phi,
        bits_y=bits_y,
        key=key,
        requantize="fixed" if backend == "packed" else requantize,
        backend="packed" if backend == "packed" else "dense",
    )
    if granularity != "per_tensor":
        kw.update(scale_granularity=granularity, group_size=group_size)
    return kw


def recover_lofar(cs, backend, bits_phi, bits_y, key, requantize="pair", batch=0,
                  granularity="per_tensor", group_size=None, devices=None,
                  ckpt=None):
    st = Station(n_antennas=cs.n_antennas, seed=cs.seed)
    phi = measurement_matrix(st, cs.resolution, cs.extent)
    kw = _solver_kwargs(backend, bits_phi, bits_y, key, requantize,
                        granularity, group_size)
    if batch:
        skies = [make_sky(cs.resolution, cs.n_sources, jax.random.fold_in(key, b),
                          min_sep=cs.min_sep) for b in range(batch)]
        Y = jnp.stack([visibilities(phi, x, cs.snr_db, jax.random.fold_in(key, b))[0]
                       for b, x in enumerate(skies)])
        X_true = jnp.stack(skies)
        t0 = time.time()
        res = _batch_solver(devices, kw, ckpt)(phi, Y, cs.n_sources, cs.n_iters,
                                               real_signal=True, nonneg=True, **kw)
        jax.block_until_ready(res.x)
        wall = time.time() - t0
        rel = [float(relative_error(res.x[b], X_true[b])) for b in range(batch)]
        return {"batch": batch, "rel_error_mean": sum(rel) / batch,
                "rel_error_max": max(rel), "wall_s": wall}
    x = make_sky(cs.resolution, cs.n_sources, key, min_sep=cs.min_sep)
    y, _ = visibilities(phi, x, cs.snr_db, key)
    t0 = time.time()
    if ckpt:
        res = _single_via_ckpt(ckpt, phi, y, cs.n_sources, cs.n_iters,
                               real_signal=True, nonneg=True, **kw)
    elif backend == "dense":
        res = niht(phi, y, cs.n_sources, cs.n_iters, real_signal=True, nonneg=True)
    else:
        res = qniht(phi, y, cs.n_sources, cs.n_iters, real_signal=True,
                    nonneg=True, **kw)
    jax.block_until_ready(res.x)
    wall = time.time() - t0
    r = cs.resolution
    return {
        "rel_error": float(relative_error(res.x, x)),
        "support_recovery": float(support_recovery(res.x, x, cs.n_sources)),
        "source_recovery": float(source_recovery(
            jnp.real(res.x).reshape(r, r), x.reshape(r, r), cs.n_sources, 1)),
        "wall_s": wall,
        "resid_true": [float(v) for v in res.trace.resid_true[-3:]],
    }


def recover_gaussian(g, backend, bits_phi, bits_y, key, requantize="pair", batch=0,
                     granularity="per_tensor", group_size=None, devices=None,
                     ckpt=None):
    prob = make_gaussian_problem(g.m, g.n, g.s, 20.0, key)
    kw = _solver_kwargs(backend, bits_phi, bits_y, key, requantize,
                        granularity, group_size)
    if batch:
        # B problems sharing phi: fresh sparse signals + noise per row.
        probs = [make_gaussian_problem(g.m, g.n, g.s, 20.0,
                                       jax.random.fold_in(key, b + 1),
                                       phi=prob.phi) for b in range(batch)]
        Y = jnp.stack([p.y for p in probs])
        X_true = jnp.stack([p.x_true for p in probs])
        t0 = time.time()
        res = _batch_solver(devices, kw, ckpt)(prob.phi, Y, g.s, g.n_iters, **kw)
        jax.block_until_ready(res.x)
        rel = [float(relative_error(res.x[b], X_true[b])) for b in range(batch)]
        return {"batch": batch, "rel_error_mean": sum(rel) / batch,
                "rel_error_max": max(rel), "wall_s": time.time() - t0}
    if ckpt:
        res = _single_via_ckpt(ckpt, prob.phi, prob.y, g.s, g.n_iters, **kw)
    else:
        res = (niht(prob.phi, prob.y, g.s, g.n_iters) if backend == "dense" else
               qniht(prob.phi, prob.y, g.s, g.n_iters, **kw))
    return {"rel_error": float(relative_error(res.x, prob.x_true)),
            "support_recovery": float(support_recovery(res.x, prob.x_true, g.s))}


def recover_mri(cfg, bits_y, key, batch=0, granularity="per_tensor", n_bands=None,
                sparsity_basis=None, devices=None, ckpt=None):
    """Matrix-free §5 workload: image-space PSNR/relative error of the
    recovered phantom. ``bits_y=None`` → full-precision observations (the
    32-bit baseline); ``batch`` recovers B randomized brain phantoms sharing
    one sampling mask in a single batched call. ``granularity="per_band"``
    quantizes the observations with one scale per radial k-space band
    (``n_bands`` of them) instead of the paper's single c_y.
    ``sparsity_basis`` (default: the config's) selects pixel sparsity or the
    composed wavelet model Φ = P_Ω F W† over the full phantom."""
    basis = sparsity_basis if sparsity_basis is not None else cfg.sparsity_basis
    prob = make_mri_problem(cfg.resolution, cfg.n_sparse, cfg.fraction, key,
                            density=cfg.density, center_fraction=cfg.center_fraction,
                            snr_db=cfg.snr_db, phantom=cfg.phantom,
                            sparsity_basis=basis,
                            wavelet_levels=cfg.wavelet_levels)
    r = cfg.resolution
    n_bands = n_bands if n_bands is not None else cfg.n_bands
    # wavelet coefficients are signed; only the pixel basis is a nonneg image
    kw = dict(real_signal=True, nonneg=basis == "pixel")

    def prep(y):
        """Quantize observations per granularity; per-band happens up front
        (qniht's own bits_y path is the per-tensor draw)."""
        if not bits_y:
            return y
        if granularity == "per_band":
            return quantize_observations(y, bits_y, key, granularity="per_band",
                                         op=prob.op, n_bands=n_bands)
        kw.update(bits_y=bits_y, key=key)
        return y

    if batch:
        if basis == "pixel":
            # per-row jitter breaks the phantom skull ring's exact-1.0 top-k
            # ties so the B rows are genuinely distinct problems
            def truth(b):
                img = brain_phantom(r, jax.random.fold_in(key, b))
                jitter = 1e-3 * jax.random.uniform(
                    jax.random.fold_in(key, 100 + b), img.shape)
                return sparsify_image(img + jitter, cfg.n_sparse)
        else:
            # full phantoms: rows differ by construction, no thresholding ties
            def truth(b):
                return brain_phantom(r, jax.random.fold_in(key, b)).ravel()

        Img_true = jnp.stack([truth(b) for b in range(batch)])
        Y, _ = mri_observations(getattr(prob.op, "kspace_op", prob.op), Img_true,
                                cfg.snr_db, jax.random.fold_in(key, batch))
        Y = prep(Y)
        t0 = time.time()
        res = _batch_solver(devices, kw, ckpt)(prob.op, Y, cfg.n_sparse, cfg.n_iters, **kw)
        jax.block_until_ready(res.x)
        wall = time.time() - t0
        Img_hat = prob.to_image(res.x)
        ps = [float(psnr(Img_hat[b].reshape(r, r), Img_true[b].reshape(r, r)))
              for b in range(batch)]
        rel = [float(relative_error(Img_hat[b], Img_true[b])) for b in range(batch)]
        return {"basis": basis, "batch": batch, "m": prob.op.shape[0],
                "psnr_mean": sum(ps) / batch, "psnr_min": min(ps),
                "rel_error_mean": sum(rel) / batch,
                "rel_error_max": max(rel), "wall_s": wall}
    y = prep(prob.y)
    t0 = time.time()
    if ckpt:
        res = _single_via_ckpt(ckpt, prob.op, y, cfg.n_sparse, cfg.n_iters, **kw)
    else:
        res = qniht(prob.op, y, cfg.n_sparse, cfg.n_iters, **kw)
    jax.block_until_ready(res.x)
    wall = time.time() - t0
    img_hat = prob.to_image(res.x)
    out = {
        "basis": basis,
        "m": prob.op.shape[0],
        "psnr": float(psnr(img_hat.reshape(r, r), prob.image_true.reshape(r, r))),
        "rel_error": float(relative_error(img_hat, prob.image_true)),
        "wall_s": wall,
        "phi_nbytes": prob.op.nbytes,
    }
    if bits_y and granularity == "per_band":
        out["y_scale_bytes"] = 4 * n_bands
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--config", default="lofar-bench",
                    choices=["lofar", "lofar-bench", "lofar-smoke",
                             "gaussian", "gaussian-smoke",
                             "mri", "mri-bench", "mri-smoke",
                             "mri-wavelet", "mri-wavelet-bench",
                             "mri-wavelet-smoke"])
    ap.add_argument("--backend", default="fake", choices=["dense", "fake", "packed"],
                    help="dense: f32 NIHT baseline; fake: quantized values, dense "
                         "compute (Algorithm 1); packed: stream packed codes via "
                         "the Pallas qmm kernels (forces --requantize fixed)")
    ap.add_argument("--bits-phi", type=int, default=2)
    ap.add_argument("--bits-y", type=int, default=8)
    ap.add_argument("--full-precision", action="store_true",
                    help="alias for --backend dense")
    ap.add_argument("--requantize", default="pair", choices=["pair", "fixed"])
    ap.add_argument("--batch", type=int, default=0,
                    help="recover B observations of one Φ̂ at once (qniht_batch)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the --batch rows over an N-device ('batch',) "
                         "mesh (qniht_batch_sharded; bit-identical per item). "
                         "On CPU this also forces N host devices when jax has "
                         "not initialized yet")
    ap.add_argument("--scale-granularity", default=None,
                    choices=["per_tensor", "per_channel", "per_block", "per_band"],
                    help="quantizer scale layout: per_channel/per_block apply to "
                         "the packed Φ̂ stream (--backend packed), per_band to "
                         "the MRI observation quantizer (default: the MRI "
                         "config's scale_granularity, else per_tensor)")
    ap.add_argument("--group-size", type=int, default=None,
                    help="per_block group size along the contraction axis, or "
                         "the number of radial k-space bands for per_band "
                         "(default: the MRI config's n_bands)")
    ap.add_argument("--sparsity-basis", default=None,
                    choices=["pixel", "haar", "db4"],
                    help="MRI recovery model: pixel sparsity (exact s-sparse "
                         "phantom) or a wavelet basis — the full unsparsified "
                         "phantom via the composed Φ = P_Ω F W† "
                         "(default: the config's sparsity_basis)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="run the solve in checkpointed segments persisted to "
                         "this directory (preemption-safe: SIGTERM/SIGINT "
                         "writes a final checkpoint and exits cleanly; the "
                         "result is bit-identical to an unsegmented run)")
    ap.add_argument("--ckpt-every", type=int, default=10,
                    help="iterations per segment/checkpoint (with --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest complete checkpoint in "
                         "--checkpoint-dir; works across --devices widths "
                         "(elastic) and falls back to a fresh start when the "
                         "directory has no restorable checkpoint")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the solve under repro.analysis.sanitize: any "
                         "NaN/Inf raises at the producing op, and a "
                         "[sanitize] line reports backend compile counts")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a JAX profiler trace of the whole solve "
                         "under this directory (TensorBoard/Perfetto format; "
                         "see docs/performance.md)")
    args = ap.parse_args(argv)
    if (args.resume or args.ckpt_every != 10) and not args.checkpoint_dir:
        ap.error("--resume/--ckpt-every need --checkpoint-dir")
    if args.ckpt_every < 1:
        ap.error("--ckpt-every must be >= 1")
    ckpt = (dict(checkpoint_dir=args.checkpoint_dir, ckpt_every=args.ckpt_every,
                 resume=args.resume) if args.checkpoint_dir else None)

    if args.devices and not args.batch:
        ap.error("--devices shards the batch axis; combine it with --batch B")
    if args.devices:
        # only effective before the first jax call of this process
        from repro.parallel.batch import force_host_devices

        force_host_devices(args.devices)
    backend = "dense" if args.full_precision else args.backend
    key = jax.random.PRNGKey(args.seed)
    # None = unset: non-MRI configs fall back to per_tensor, MRI configs to
    # their own scale_granularity. An EXPLICIT --scale-granularity always wins
    # (the wavelet configs default to per_band, and the per-tensor baseline
    # must stay reachable against them).
    gran = args.scale_granularity or "per_tensor"
    if args.sparsity_basis and not args.config.startswith("mri"):
        ap.error("--sparsity-basis selects the MRI recovery model; use an mri config")
    from repro.launch.resilience import Preempted

    import contextlib

    if args.sanitize:
        from repro.analysis.sanitize import sanitize as sanitize_ctx

        ctx = sanitize_ctx()
    else:
        ctx = contextlib.nullcontext()
    prof = (jax.profiler.trace(args.profile_dir) if args.profile_dir
            else contextlib.nullcontext())
    try:
        with prof, ctx as counter:
            if args.config.startswith("lofar"):
                if gran == "per_band":
                    ap.error("per_band is the MRI observation granularity; use an mri config")
                cs = {"lofar": LOFAR_CONFIG, "lofar-bench": LOFAR_BENCH,
                      "lofar-smoke": LOFAR_SMOKE}[args.config]
                out = recover_lofar(cs, backend, args.bits_phi, args.bits_y, key,
                                    args.requantize, args.batch, gran, args.group_size,
                                    devices=args.devices, ckpt=ckpt)
                label = ("32bit" if backend == "dense"
                         else f"{args.bits_phi}&{args.bits_y}bit[{backend}]")
            elif args.config.startswith("mri"):
                if gran in ("per_channel", "per_block"):
                    ap.error("the MRI Φ is matrix-free (nothing packed to scale); "
                             "use --scale-granularity per_band for the observations")
                cs = {"mri": MRI_CONFIG, "mri-bench": MRI_BENCH,
                      "mri-smoke": MRI_SMOKE, "mri-wavelet": MRI_WAVELET,
                      "mri-wavelet-bench": MRI_WAVELET_BENCH,
                      "mri-wavelet-smoke": MRI_WAVELET_SMOKE}[args.config]
                bits_y = None if backend == "dense" else args.bits_y
                gran = args.scale_granularity or cs.scale_granularity
                out = recover_mri(cs, bits_y, key, args.batch, gran, args.group_size,
                                  sparsity_basis=args.sparsity_basis,
                                  devices=args.devices, ckpt=ckpt)
                basis = args.sparsity_basis or cs.sparsity_basis
                label = ("32bit[matrix-free]" if bits_y is None
                         else f"y@{bits_y}bit[{gran},matrix-free]") + f"[{basis}]"
            else:
                if gran == "per_band":
                    ap.error("per_band is the MRI observation granularity; use an mri config")
                g = GAUSS_CONFIG if args.config == "gaussian" else GAUSS_SMOKE
                out = recover_gaussian(g, backend, args.bits_phi, args.bits_y, key,
                                       args.requantize, args.batch, gran, args.group_size,
                                       devices=args.devices, ckpt=ckpt)
                label = ("32bit" if backend == "dense"
                         else f"{args.bits_phi}&{args.bits_y}bit[{backend}]")
    except Preempted as e:
        print(f"[recover] {e}; restart with --resume to continue", flush=True)
        return
    if counter is not None:
        print(f"[sanitize] ok {counter.summary()} debug_nans=on "
              "debug_infs=on", flush=True)
    print(f"[recover] {args.config} {label}: " +
          " ".join(f"{k}={v if not isinstance(v, float) else round(v, 4)}"
                   for k, v in out.items()))


if __name__ == "__main__":
    main()
