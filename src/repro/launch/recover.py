"""CS recovery driver — the paper's own end-to-end pipeline as a launcher.

``python -m repro.launch.recover --config lofar --bits-phi 2 --bits-y 8``
simulates the station, builds Φ, quantizes per Algorithm 1 and recovers the
sky, reporting the Fig. 1/4 metrics.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.gaussian_toy import CONFIG as GAUSS_CONFIG, SMOKE as GAUSS_SMOKE
from repro.configs.lofar_cs302 import BENCH as LOFAR_BENCH, CONFIG as LOFAR_CONFIG, SMOKE as LOFAR_SMOKE
from repro.core import niht, qniht, relative_error, source_recovery, support_recovery
from repro.sensing import (
    Station,
    make_gaussian_problem,
    make_sky,
    measurement_matrix,
    visibilities,
)


def recover_lofar(cs, bits_phi, bits_y, key, requantize="pair"):
    st = Station(n_antennas=cs.n_antennas, seed=cs.seed)
    phi = measurement_matrix(st, cs.resolution, cs.extent)
    x = make_sky(cs.resolution, cs.n_sources, key, min_sep=cs.min_sep)
    y, _ = visibilities(phi, x, cs.snr_db, key)
    t0 = time.time()
    if bits_phi is None:
        res = niht(phi, y, cs.n_sources, cs.n_iters, real_signal=True, nonneg=True)
    else:
        res = qniht(phi, y, cs.n_sources, cs.n_iters, bits_phi=bits_phi,
                    bits_y=bits_y, key=key, requantize=requantize,
                    real_signal=True, nonneg=True)
    jax.block_until_ready(res.x)
    wall = time.time() - t0
    r = cs.resolution
    return {
        "rel_error": float(relative_error(res.x, x)),
        "support_recovery": float(support_recovery(res.x, x, cs.n_sources)),
        "source_recovery": float(source_recovery(
            jnp.real(res.x).reshape(r, r), x.reshape(r, r), cs.n_sources, 1)),
        "wall_s": wall,
        "resid_true": [float(v) for v in res.trace.resid_true[-3:]],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="lofar-bench",
                    choices=["lofar", "lofar-bench", "lofar-smoke", "gaussian", "gaussian-smoke"])
    ap.add_argument("--bits-phi", type=int, default=2)
    ap.add_argument("--bits-y", type=int, default=8)
    ap.add_argument("--full-precision", action="store_true")
    ap.add_argument("--requantize", default="pair", choices=["pair", "fixed"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    bits_phi = None if args.full_precision else args.bits_phi
    if args.config.startswith("lofar"):
        cs = {"lofar": LOFAR_CONFIG, "lofar-bench": LOFAR_BENCH,
              "lofar-smoke": LOFAR_SMOKE}[args.config]
        out = recover_lofar(cs, bits_phi, args.bits_y, key, args.requantize)
    else:
        g = GAUSS_CONFIG if args.config == "gaussian" else GAUSS_SMOKE
        prob = make_gaussian_problem(g.m, g.n, g.s, 20.0, key)
        res = (niht(prob.phi, prob.y, g.s, g.n_iters) if bits_phi is None else
               qniht(prob.phi, prob.y, g.s, g.n_iters, bits_phi=bits_phi,
                     bits_y=args.bits_y, key=key, requantize=args.requantize))
        out = {"rel_error": float(relative_error(res.x, prob.x_true)),
               "support_recovery": float(support_recovery(res.x, prob.x_true, g.s))}
    label = "32bit" if bits_phi is None else f"{bits_phi}&{args.bits_y}bit"
    print(f"[recover] {args.config} {label}: " +
          " ".join(f"{k}={v if not isinstance(v, float) else round(v, 4)}"
                   for k, v in out.items()))


if __name__ == "__main__":
    main()
