"""Preemption-safe segmented recovery: checkpointed solves that resume bit-identically.

The solver loop (:mod:`repro.core.niht`) is a deterministic iteration map —
every stochastic input (the ŷ draw, the per-iteration Φ̂ pair) is re-derived
from ``(Y, key)`` and the body consumes the global iteration index. That makes
any iteration boundary an exact restart point, and this module turns that into
an operational property:

* :func:`recover_resilient` runs ``qniht_batch`` (or its mesh-sharded twin) in
  segments of ``ckpt_every`` iterations, persisting the full
  :class:`~repro.core.niht.SolverState` through
  :mod:`repro.train.checkpoint`'s atomic tmp→rename + manifest protocol after
  every segment.
* A ``kill -TERM``/``-INT`` mid-run is absorbed by
  :class:`~repro.train.fault.PreemptionGuard`: the in-flight segment finishes,
  one final *synchronous* checkpoint is written, and :class:`Preempted` is
  raised (a ``RuntimeError`` — :func:`~repro.train.fault.run_with_restarts`
  retries it by default).
* Restarting with ``resume=True`` restores the newest complete checkpoint —
  falling back past torn ones — and continues; the finished result is
  **bit-identical** to the uninterrupted run (pinned in
  ``tests/test_fault_injection.py``).
* Checkpoints are **elastic**: the state is saved stripped of mesh padding, so
  a run checkpointed at ``--devices 4`` resumes at ``--devices 2`` (or on a
  single device) with the same bits — see
  :func:`repro.parallel.batch.pad_state`.

CLI: ``python -m repro.launch.recover --checkpoint-dir CKPT --ckpt-every 10
[--resume]``; the serving loop's chunk-level analogue (write-ahead journal) is
``python -m repro.launch.serve --checkpoint-dir`` — see
``docs/fault-tolerance.md``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.niht import (
    _SEG_DEFAULTS,
    IHTResult,
    SolverState,
    solver_init,
    solver_result,
    solver_segment,
)
from repro.core.operators import PackedStreamingOperator
from repro.quant.formats import as_granularity
from repro.train.checkpoint import restore_latest, save
from repro.train.fault import PreemptionGuard

__all__ = ["Preempted", "recover_resilient"]


class Preempted(RuntimeError):
    """A solve was interrupted by SIGTERM/SIGINT after a durable checkpoint.

    The run can be resumed (``resume=True``, same arguments) and will finish
    bit-identically. Subclasses ``RuntimeError`` so the default ``retry_on`` of
    :func:`repro.train.fault.run_with_restarts` re-enters the solve in-process.
    """

    def __init__(self, k: int, checkpoint_dir: str):
        super().__init__(
            f"preempted at iteration {k}; checkpoint written to {checkpoint_dir}")
        self.k = k
        self.checkpoint_dir = checkpoint_dir


def recover_resilient(
    phi, Y: jax.Array, s: int, n_iters: int = 50, *,
    checkpoint_dir: str, ckpt_every: int = 10, resume: bool = False,
    mesh=None, n_devices: Optional[int] = None, keep: int = 3,
    async_save: bool = False, guard: Optional[PreemptionGuard] = None,
    verbose: bool = False, key: Optional[jax.Array] = None,
    **solver_kw,
) -> IHTResult:
    """``qniht_batch(phi, Y, s, n_iters, ...)`` with segment checkpoints.

    Accepts the batched solver's keyword configuration (``bits_phi``,
    ``backend`` ... — everything except ``unroll``, which is scan-only).
    ``mesh``/``n_devices`` selects the sharded segment engine
    (:func:`repro.parallel.batch.sharded_segment_run`); the checkpoint itself
    is mesh-agnostic either way.

    ``guard``: an *entered* :class:`PreemptionGuard` to poll between segments;
    ``None`` installs one for the duration of this call (SIGTERM/SIGINT →
    final synchronous checkpoint → :class:`Preempted`). ``async_save``
    overlaps checkpoint I/O with the next segment; the final checkpoint (and
    a preemption's last one) is always synchronous, and concurrent writers
    are serialized by the checkpoint layer's per-directory lock.
    """
    if ckpt_every < 1:
        raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
    unknown = set(solver_kw) - set(_SEG_DEFAULTS)
    if unknown:
        raise TypeError(f"recover_resilient got unexpected solver kwargs {sorted(unknown)}")
    statics = {**_SEG_DEFAULTS, **solver_kw}
    key = key if key is not None else jax.random.PRNGKey(0)

    # the restore target carries shapes/dtypes only — no data is touched, and
    # validation runs on the user-facing configuration
    target = jax.eval_shape(
        lambda: solver_init(phi, Y, s, n_iters, key=key, **statics))
    state, step = (restore_latest(checkpoint_dir, target) if resume
                   else (None, None))
    if state is None:
        state = solver_init(phi, Y, s, n_iters, key=key, **statics)
        if verbose:
            print(f"[resilience] fresh start, n_iters={n_iters}", flush=True)
    elif verbose:
        print(f"[resilience] resumed from step {step} (k={int(state.k)})", flush=True)

    # pack once, exactly as BatchServer does: the packed codes are a
    # deterministic function of (phi, key), so a restarted process rebuilds
    # the identical stream — nothing operator-side needs checkpointing
    seg_phi, seg_statics = phi, dict(statics)
    if statics["backend"] == "packed":
        _, kphi = jax.random.split(key)
        seg_phi = PackedStreamingOperator.pack(
            phi, statics["bits_phi"], jax.random.fold_in(kphi, 0),
            granularity=as_granularity(statics["scale_granularity"],
                                       statics["group_size"]))
        seg_statics.update(bits_phi=None, backend="dense")

    def segment(st: SolverState, n: int) -> SolverState:
        if mesh is not None or n_devices:
            from repro.parallel.batch import sharded_segment_run

            return sharded_segment_run(seg_phi, st, n, mesh=mesh,
                                       n_devices=n_devices, s=s, **seg_statics)
        return solver_segment(seg_phi, st, n, s=s, **seg_statics)

    g = guard if guard is not None else PreemptionGuard().__enter__()
    try:
        while int(state.k) < n_iters:
            n = min(ckpt_every, n_iters - int(state.k))
            state = segment(state, n)
            jax.block_until_ready(state.X)
            k = int(state.k)
            final = k >= n_iters
            preempt = g.requested
            # preemption and the horizon both demand a durable (synchronous)
            # write before we let go of the process
            save(checkpoint_dir, k, state, keep=keep,
                 async_=async_save and not final and not preempt)
            if verbose:
                print(f"[resilience] k={k}/{n_iters} checkpointed", flush=True)
            if preempt and not final:
                raise Preempted(k, checkpoint_dir)
    finally:
        if guard is None:
            g.__exit__(None, None, None)
    return solver_result(state)
