import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with NO real allocation (ShapeDtypeStruct inputs):
  * a compiled SPMD executable for the 16×16 single-pod mesh and the
    2×16×16 multi-pod mesh (proving the sharding config is coherent),
  * ``memory_analysis()``  — per-device bytes (proves it fits),
  * ``cost_analysis()``    — HLO FLOPs / bytes for the §Roofline terms,
  * collective bytes parsed from the optimized HLO (scan bodies × trip count),
all recorded as JSON under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen1_5_32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --arch ... --policy w4kv8   # quantized serving
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, BY_NAME, applicable, get_config
from repro.configs.shapes import ALL_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.quant.policy import FULL_PRECISION, W4KV8, W8, QuantPolicy
from repro.train.steps import (
    build_sharded_decode_step,
    build_sharded_prefill,
    build_sharded_train_step,
    init_state,
    train_input_specs,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str, loop_trip: int) -> dict:
    """Estimate per-device collective payload bytes from optimized HLO.

    Sums the result-shape bytes of every collective op; ops inside while-loop
    body computations (the layer scan) are multiplied by ``loop_trip``.
    This is an estimate: result bytes ≈ payload for all-gather/all-reduce,
    and scan bodies dominate, so trip-count weighting is the first-order term.
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = 0
    current_comp = ""
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", stripped)
        if m and stripped.endswith("{"):
            current_comp = m.group(1)
            continue
        for kind in _COLLECTIVES:
            # match the op use, e.g.  %x = f32[..] all-reduce(...)
            if re.search(rf"=\s*[\w()\[\],\s{{}}/#*]*{kind}(-start|-done)?\(", stripped):
                lhs = stripped.split("=", 1)[1]
                b = _shape_bytes(lhs.split(kind)[0])
                mult = loop_trip if ("body" in current_comp or "while" in current_comp) else 1
                per_kind[kind] += b * mult
                count += 1
                break
    per_kind["total"] = sum(per_kind[k] for k in _COLLECTIVES)
    per_kind["op_count"] = count
    return per_kind


def _mem_analysis(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes", "host_argument_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # CPU backend may not support it
        out["error"] = str(e)
    return out


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:
        return {"error": str(e)}


def _loop_trip(cfg: ModelConfig) -> int:
    from repro.models.model import _period_info

    _, n_full, _ = _period_info(cfg)
    return max(n_full, 1)


def _depth_variants(cfg: ModelConfig):
    """(cfg_P, cfg_2P, n_full, tail_frac): shallow configs for the scan-body
    cost extrapolation. HloCostAnalysis counts while bodies ONCE (trip count is
    dynamic), so per-device FLOPs/bytes are reconstructed linearly:

        total ≈ f(P) + (n_full − 1 + |tail|/P) · (f(2P) − f(P))
    """
    import dataclasses as dc

    from repro.models.model import _period_info

    slots, n_full, tail = _period_info(cfg)
    p = len(slots)
    cfg1 = dc.replace(cfg, n_layers=p, scan_unroll=True)
    cfg2 = dc.replace(cfg, n_layers=2 * p, scan_unroll=True)
    return cfg1, cfg2, n_full, len(tail) / p


def _extrapolate(v1: float, v2: float, n_full: int, tail_frac: float) -> float:
    delta = max(v2 - v1, 0.0)
    return v1 + (n_full - 1 + tail_frac) * delta


def _sharded_state_bytes(tree, shardings, n_devices) -> int:
    """Analytic per-device bytes: leaf bytes / number of shards."""
    flat = jax.tree_util.tree_leaves(tree)
    flat_sh = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec")
    )
    total = 0
    for leaf, sh in zip(flat, flat_sh):
        nbytes = leaf.size * jnp.dtype(leaf.dtype).itemsize
        try:
            nshards = sh.num_devices_sharded(leaf.shape) if hasattr(sh, "num_devices_sharded") else None
        except Exception:
            nshards = None
        if nshards is None:
            # count mesh axes used in the spec
            used = 1
            mesh = sh.mesh
            for ax in jax.tree_util.tree_leaves(tuple(sh.spec)):
                if ax is not None:
                    used *= mesh.shape[ax]
            nshards = used
        total += nbytes // max(nshards, 1)
    return total


POLICIES = {
    "fp": FULL_PRECISION,
    "w8": W8,
    "w4kv8": W4KV8,
    "w2kv8": QuantPolicy(weight_bits=2, kv_bits=8),
    "qgrad8": QuantPolicy(grad_bits=8),
}


def _build_lowered(cfg: ModelConfig, shape, mesh, policy, seq_parallel,
                   accum_steps: int = 1, serve_sharding: str = "train",
                   serve_dtype: str = "float32"):
    """Returns (lowered, state_tree, state_shardings, tokens, model_flops)."""
    if shape.kind == "train":
        opt = adamw(3e-4)
        step, st_sh = build_sharded_train_step(
            cfg, mesh, opt, shape.global_batch, policy=policy,
            seq_parallel=seq_parallel, accum_steps=accum_steps,
        )
        state_abs = jax.eval_shape(lambda: init_state(cfg, opt, jax.random.PRNGKey(0)))
        batch_abs = train_input_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        lowered = step.lower(state_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        return (lowered,
                (state_abs.params, state_abs.opt.mu, state_abs.opt.nu),
                (st_sh.params, st_sh.opt.mu, st_sh.opt.nu),
                tokens, 6 * cfg.active_param_count() * tokens)
    if shape.kind == "prefill":
        run, (p_sh, tok_sh, c_sh) = build_sharded_prefill(
            cfg, mesh, shape.global_batch, shape.seq_len, policy=policy,
            serve_sharding=serve_sharding, serve_dtype=serve_dtype,
        )
        params_abs, cache_abs, mem_abs = _serve_abstracts(
            cfg, policy, shape.global_batch, shape.seq_len, serve_dtype
        )
        tokens_abs = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
        lowered = run.lower(params_abs, tokens_abs, cache_abs, mem_abs)
        tokens = shape.global_batch * shape.seq_len
        return (lowered, (params_abs, cache_abs), (p_sh, c_sh),
                tokens, 2 * cfg.active_param_count() * tokens)
    # decode
    cache_len = shape.seq_len + 128
    step, (p_sh, tok_sh, c_sh) = build_sharded_decode_step(
        cfg, mesh, shape.global_batch, cache_len, policy=policy,
        serve_sharding=serve_sharding, serve_dtype=serve_dtype,
    )
    params_abs, cache_abs, _ = _serve_abstracts(cfg, policy, shape.global_batch,
                                                cache_len, serve_dtype)
    token_abs = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = step.lower(params_abs, token_abs, cache_abs, pos_abs)
    tokens = shape.global_batch
    return (lowered, (params_abs, cache_abs), (p_sh, c_sh),
            tokens, 2 * cfg.active_param_count() * tokens)


def run_cell(arch: str, shape_name: str, multi_pod: bool, policy_name: str = "fp",
             seq_parallel: bool = True, depth_correct: bool = True,
             accum_steps: int = 1, serve_sharding: str = "train",
             serve_dtype: str = "float32", ssm_chunk: int = 0,
             moe_group: int = 0) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if ssm_chunk:
        cfg = _dc.replace(cfg, ssm_chunk=ssm_chunk)
    if moe_group:
        cfg = _dc.replace(cfg, moe_group_size=moe_group)
    shape = BY_NAME[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": policy_name,
        "seq_parallel": seq_parallel,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = POLICIES[policy_name]

    rec["accum_steps"] = accum_steps
    rec["serve_sharding"] = serve_sharding
    rec["serve_dtype"] = serve_dtype
    t0 = time.time()
    lowered, state_tree, state_sh, tokens, model_flops = _build_lowered(
        cfg, shape, mesh, policy, seq_parallel, accum_steps, serve_sharding,
        serve_dtype,
    )
    rec["lower_s"] = round(time.time() - t0, 1)
    print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: lowered in {rec['lower_s']}s",
          flush=True)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: compiled in {rec['compile_s']}s",
          flush=True)

    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: memory_analysis={mem}")
    print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: cost_analysis="
          f"{ {k: v for k, v in cost.items() if k in ('flops', 'bytes accessed')} }")

    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo, _loop_trip(cfg))
    rec.update(
        status="ok",
        memory_analysis=mem,
        cost_analysis=cost,
        collective_bytes=coll,
        model_flops=model_flops,
        tokens=tokens,
        n_devices=mesh.devices.size,
        state_bytes_per_device=_sharded_state_bytes_pair(state_tree, state_sh),
        hlo_size=len(hlo),
    )

    # HloCostAnalysis counts scan (while) bodies once; reconstruct full-depth
    # per-device FLOPs/bytes by compiling *fully-unrolled* depth-P and depth-2P
    # probe variants and extrapolating linearly (see _depth_variants). Probes
    # run at a reduced global batch (exactly divisible by the batch shards) so
    # the unrolled HLO stays small; per-token-per-layer work is batch-linear
    # (attention's S² term is preserved — seq_len untouched), so the scale-back
    # factor is exact.
    if depth_correct:
        try:
            import dataclasses as dc

            cfg1, cfg2, n_full, tail_frac = _depth_variants(cfg)
            batch_shards = 32 if multi_pod else 16
            gb_probe = min(shape.global_batch, batch_shards)
            if shape.global_batch % gb_probe:
                gb_probe = shape.global_batch
            probe_shape = dc.replace(shape, global_batch=gb_probe)
            scale = shape.global_batch / gb_probe
            # SSM compute is sequence-LINEAR (independent chunks) — probe at a
            # shorter sequence too, else the unrolled inter-chunk scan
            # (S/ssm_chunk steps) blows up the probe compile.
            if cfg.family == "ssm" and shape.kind != "decode" and shape.seq_len > 4096:
                seq_probe = 4096
                probe_shape = dc.replace(probe_shape, seq_len=seq_probe)
                scale *= shape.seq_len / seq_probe
            if n_full > 1 or tail_frac:
                costs = []
                for c in (cfg1, cfg2):
                    lw, *_ = _build_lowered(c, probe_shape, mesh, policy,
                                            seq_parallel, accum_steps,
                                            serve_sharding, serve_dtype)
                    costs.append(_cost_analysis(lw.compile()))
                corrected = {}
                for k in ("flops", "bytes accessed"):
                    if k in costs[0] and k in costs[1]:
                        corrected[k] = scale * _extrapolate(
                            costs[0][k], costs[1][k], n_full, tail_frac
                        )
                rec["cost_analysis_depth_corrected"] = corrected
                rec["depth_correction"] = {
                    "n_full": n_full, "tail_frac": tail_frac,
                    "depth1": cfg1.n_layers, "depth2": cfg2.n_layers,
                    "probe_batch": gb_probe, "batch_scale": scale,
                    "cost_d1": {k: costs[0].get(k) for k in ("flops", "bytes accessed")},
                    "cost_d2": {k: costs[1].get(k) for k in ("flops", "bytes accessed")},
                }
        except Exception as e:
            rec["depth_correction"] = {"error": f"{type(e).__name__}: {e}"}
    return rec


def _sharded_state_bytes_pair(trees, shardings) -> int:
    total = 0
    for t, s in zip(trees, shardings):
        total += _sharded_state_bytes(t, s, None)
    return total


def _serve_abstracts(cfg, policy, batch, cache_len, serve_dtype="float32"):
    from repro.train.steps import serve_params_abstract

    params_abs = serve_params_abstract(cfg, policy, serve_dtype)
    mem_len = cfg.encoder_seq if cfg.family == "encdec" else (
        cfg.n_image_tokens if cfg.family == "vlm" else 0
    )
    cache_abs = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, cache_len, policy, mem_len=mem_len)
    )
    mem_abs = (
        jax.ShapeDtypeStruct((batch, mem_len, cfg.d_model), jnp.float32)
        if mem_len else None
    )
    return params_abs, cache_abs, mem_abs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--policy", default="fp", choices=sorted(POLICIES))
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--serve-sharding", default="train", choices=["train", "serve"])
    ap.add_argument("--serve-dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--tag-suffix", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}.{shape}.{'multi' if mp else 'single'}.{args.policy}" + args.tag_suffix
        try:
            rec = run_cell(arch, shape, mp, args.policy,
                           seq_parallel=not args.no_seq_parallel,
                           accum_steps=args.accum,
                           serve_sharding=args.serve_sharding,
                           serve_dtype=args.serve_dtype,
                           ssm_chunk=args.ssm_chunk,
                           moe_group=args.moe_group)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "policy": args.policy,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
        from repro.parallel.journal import write_json_durable

        write_json_durable(os.path.join(args.out, tag + ".json"), rec)
        print(f"[dryrun] {tag}: {rec['status']}"
              + (f" ({rec.get('error','')[:160]})" if rec["status"] == "error" else ""))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
