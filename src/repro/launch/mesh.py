"""Device meshes for both halves of the system.

Two mesh shapes exist because the repo runs two kinds of distributed work:

* **Solver serving** — a 1-D ``("batch",)`` mesh for the sharded recovery
  path (``qniht_batch_sharded`` / :class:`repro.parallel.batch.BatchServer`):
  observations split by row, operator replicated. :func:`make_batch_mesh`
  delegates to :func:`repro.parallel.batch.make_batch_mesh`; on CPU, force a
  multi-device view with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
  before jax initializes (see ``docs/benchmarks.md``).
* **Model training** — 2-D/3-D ``(data, model)`` / ``(pod, data, model)``
  meshes for the LM-twin workloads' FSDP × TP (× DP) layout, consumed by
  :func:`repro.parallel.sharding.params_shardings`.

Every factory here is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
XLA_FLAGS before the first jax call, and tests must keep their 1-device view.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_batch_mesh(n_devices: Optional[int] = None):
    """1-D ``("batch",)`` serving mesh over the first ``n_devices`` local
    devices (all by default) — the mesh ``qniht_batch_sharded`` expects."""
    from repro.parallel.batch import make_batch_mesh as _make

    return _make(n_devices)
