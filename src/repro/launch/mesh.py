"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
XLA_FLAGS before the first jax call, and tests must keep their 1-device view.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
