"""The training loop: checkpoint/restore, preemption, telemetry.

Restart-safe by construction: state is a pure function of (seed, step) plus
the newest complete checkpoint, and the data stream is counter-based (see
repro.data.synthetic) — a restarted worker replays identically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.data.synthetic import SyntheticStream
from repro.train import checkpoint as ckpt
from repro.train.fault import PreemptionGuard, StepTimer
from repro.train.state import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10


def train_loop(
    step_fn: Callable,
    state: TrainState,
    stream: SyntheticStream,
    loop_cfg: LoopConfig,
    *,
    state_shardings=None,
    log: Callable[[str], None] = print,
) -> TrainState:
    """Run (or resume) training. Returns the final state."""
    start = 0
    if loop_cfg.ckpt_dir:
        restored, step = ckpt.restore_latest(
            loop_cfg.ckpt_dir, jax.eval_shape(lambda: state), state_shardings
        )
        if restored is not None:
            state = restored
            start = step
            log(f"[loop] resumed from checkpoint step {step}")

    timer = StepTimer()
    pending = None
    with PreemptionGuard() as guard:
        for step in range(start, loop_cfg.total_steps):
            batch = stream.at_step(step)
            state, metrics = step_fn(state, batch)
            timer.tick()
            if step % loop_cfg.log_every == 0:
                log(f"[loop] step={step} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"step_time={timer.mean*1e3:.1f}ms")
            should_ckpt = loop_cfg.ckpt_dir and (
                (step + 1) % loop_cfg.ckpt_every == 0 or guard.requested
            )
            if should_ckpt:
                if pending is not None:
                    pending.join()
                pending = ckpt.save(
                    loop_cfg.ckpt_dir, step + 1, state,
                    keep=loop_cfg.ckpt_keep,
                    async_=loop_cfg.ckpt_async and not guard.requested,
                )
            if guard.requested:
                log(f"[loop] preemption: checkpointed at step {step + 1}, exiting")
                break
    if pending is not None:
        pending.join()
    return state
