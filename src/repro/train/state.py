"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: AdamWState
    rng: jax.Array
