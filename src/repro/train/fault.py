"""Fault tolerance: restart supervision, preemption handling, straggler notes.

Posture for 1000+-node fleets:

* **Node failure** → the job scheduler restarts the worker; `run_with_restarts`
  is the in-process equivalent (used by tests to inject failures): every
  restart re-enters the train loop, which restores the newest complete
  checkpoint and replays the deterministic data stream from that step.
* **Preemption** → SIGTERM triggers one synchronous checkpoint before exit
  (`PreemptionGuard`); the atomic tmp→rename protocol means a kill *during*
  the save leaves the previous checkpoint authoritative.
* **Stragglers** → synchronous SPMD absorbs per-step jitter inside XLA
  collectives; at the framework level we (1) keep steps replayable so a
  drained/replaced worker rejoins at a step boundary, (2) shrink the
  cross-pod payload with b-bit gradient compression
  (repro.parallel.collectives) so slow links stop being the critical path,
  (3) expose per-step wall-time telemetry (`StepTimer`) for drain decisions.
* **Elastic scaling** → checkpoints are mesh-agnostic (train/checkpoint.py);
  changing the mesh between restarts re-places leaves under the new topology.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional


class PreemptionGuard:
    """SIGTERM/SIGINT → request a final checkpoint and a clean exit."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        return False


class StepTimer:
    """Rolling per-step wall-time stats (straggler telemetry)."""

    def __init__(self, window: int = 50):
        self.window = window
        self.times: list[float] = []
        self._last: Optional[float] = None

    def tick(self):
        now = time.monotonic()
        if self._last is not None:
            self.times.append(now - self._last)
            if len(self.times) > self.window:
                self.times.pop(0)
        self._last = now

    @property
    def mean(self) -> float:
        return sum(self.times) / len(self.times) if self.times else 0.0

    @property
    def p_max(self) -> float:
        return max(self.times) if self.times else 0.0

    def straggling(self, factor: float = 2.0) -> bool:
        """Last step took `factor`x the rolling mean → candidate for drain."""
        return bool(self.times) and self.times[-1] > factor * max(self.mean, 1e-9)


def run_with_restarts(body: Callable[[int], object], max_restarts: int = 3,
                      retry_on: tuple = (RuntimeError,), *,
                      backoff: float = 0.0, backoff_factor: float = 2.0,
                      max_backoff: float = 30.0,
                      sleep: Callable[[float], None] = time.sleep):
    """Supervise ``body(attempt)``; re-enter on failure (the in-process stand-in
    for scheduler-level worker restarts). Returns body's result.

    ``backoff`` seconds before the first retry, multiplied by
    ``backoff_factor`` each subsequent retry and capped at ``max_backoff`` —
    a crash-looping worker (bad node, poisoned input) should not hot-spin
    through its restart budget. ``sleep`` is injectable for tests.
    """
    attempt = 0
    delay = backoff
    while True:
        try:
            return body(attempt)
        except retry_on:
            attempt += 1
            if attempt > max_restarts:
                raise
            if delay > 0:
                sleep(min(delay, max_backoff))
                delay = min(delay * backoff_factor, max_backoff)
