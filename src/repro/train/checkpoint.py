"""Sharded checkpointing: atomic, async, retention-pruned, **elastic**.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json        # step, leaf paths, shapes, dtypes, status=complete
        leaf_00000.npy ...   # one file per pytree leaf (host-gathered)
    <dir>/step_000100.tmp/   # in-flight writes (renamed atomically on success)

Elasticity: restore() re-places every leaf under the *current* mesh's
NamedSharding — save on a (4,2) mesh, restore on (2,2): the shardings come
from the target spec tree, not the checkpoint. A torn/partial checkpoint
(missing manifest or status != complete) is skipped and the previous one is
used (fault-tolerance path, exercised in tests).

No tensorstore in this container → plain .npy per leaf; the layout and the
manifest protocol are what an orbax-style backend would slot into.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from collections import defaultdict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"

# One lock per checkpoint directory: concurrent async_=True saves (or an async
# save racing a synchronous final one, the preemption path) must not interleave
# their rmtree/rename/_prune sequences. The registry lock only guards the dict.
_dir_locks: dict = defaultdict(threading.Lock)
_dir_locks_guard = threading.Lock()


def _dir_lock(directory: str) -> threading.Lock:
    with _dir_locks_guard:
        return _dir_locks[os.path.abspath(directory)]


def _fsync_dir(path: str) -> None:
    """Flush a directory entry itself (the rename's durability, not just the
    file contents) — no-op on platforms that refuse O_RDONLY dir fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(directory: str, step: int, state, *, keep: int = 3,
         async_: bool = False) -> Optional[threading.Thread]:
    """Write a checkpoint. With async_=True the disk I/O happens on a
    background thread (device→host transfer is done synchronously first so
    the training step can donate its buffers safely).

    Durability: the manifest is fsync'd, and the parent directory entry is
    fsync'd after the tmp→rename — a crash at ANY point leaves either the
    complete new checkpoint or the previous one authoritative, never a
    half-written directory that parses as complete. Concurrent saves to the
    same directory (two async writers, or an async writer racing the final
    synchronous preemption save) are serialized by a per-directory lock.
    """
    host_leaves = [
        (name, np.asarray(jax.device_get(leaf)))
        for name, leaf in _leaf_paths(state)
    ]

    def write():
        with _dir_lock(directory):
            final = os.path.join(directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            names = []
            for i, (name, arr) in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
                names.append({"path": name, "file": f"leaf_{i:05d}.npy",
                              "shape": list(arr.shape), "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump({"step": step, "leaves": names, "status": "complete"}, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _fsync_dir(directory)
            _prune(directory, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _prune(directory: str, keep: int):
    steps = sorted(available_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def available_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            man = os.path.join(directory, d, _MANIFEST)
            if os.path.exists(man):
                try:
                    with open(man) as f:
                        m = json.load(f)
                    if m.get("status") == "complete":
                        out.append(int(m["step"]))
                except (json.JSONDecodeError, KeyError, ValueError):
                    continue
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, target, shardings=None):
    """Load a checkpoint into the structure of ``target`` (a pytree of arrays
    or ShapeDtypeStructs). ``shardings``: matching tree of NamedShardings for
    elastic re-placement under the current mesh; None → plain host arrays."""
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(target)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    flat_sh = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_t)
    )
    leaves = []
    for (path, tgt), sh in zip(flat_t, flat_sh):
        name = jax.tree_util.keystr(path)
        entry = by_path.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(final, entry["file"]))
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {name}: ckpt {arr.shape} vs {tgt.shape}")
        # dtype is part of the bit-identity contract: a float64 checkpoint
        # silently loaded into a float32 slot (or vice versa) restores a
        # DIFFERENT computation, not a resumed one — validate both that the
        # manifest matches the file and that the file matches the target.
        if str(arr.dtype) != entry["dtype"]:
            raise ValueError(
                f"manifest/file dtype mismatch for {name}: manifest says "
                f"{entry['dtype']}, file holds {arr.dtype} (corrupt checkpoint)")
        if np.dtype(arr.dtype) != np.dtype(tgt.dtype):
            raise ValueError(
                f"dtype mismatch for {name}: ckpt {arr.dtype} vs target "
                f"{np.dtype(tgt.dtype)} — refusing a silent cast that would "
                f"break bit-identical resume")
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            # jaxlint: allow=JL001 -- dtype validated vs manifest+target above
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(directory: str, target, shardings=None):
    """(state, step) from the newest complete checkpoint, falling back past
    corrupt ones; (None, None) when nothing restorable exists."""
    for step in reversed(available_steps(directory)):
        try:
            return restore(directory, step, target, shardings), step
        except (OSError, KeyError, ValueError, json.JSONDecodeError):
            continue
    return None, None
