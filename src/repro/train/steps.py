"""Step builders: sharded train / prefill / decode programs + input specs.

``build_train_step`` assembles loss→grad→(optional QSGD grad compression)→
AdamW→(optional IHT projection) as one pjit program with explicit in/out
shardings and donated state buffers. ``input_specs`` produces the
ShapeDtypeStruct stand-ins the multi-pod dry-run lowers against (the same
pattern shannon/kernels uses: weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import Optimizer
from repro.optim.iht import IHTConfig, maybe_project
from repro.parallel.collectives import fake_grad_compression
from repro.parallel.sharding import batch_spec, params_shardings
from repro.quant.policy import QuantPolicy
from repro.train.state import TrainState


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    policy: QuantPolicy = QuantPolicy(),
                    iht: Optional[IHTConfig] = None,
                    constrain=None,
                    accum_steps: int = 1):
    """The pure function (state, batch) -> (state, metrics).

    ``accum_steps > 1``: gradient accumulation over microbatches (scan) —
    divides live activation memory by the accumulation factor at the cost of
    re-streaming the weights per microbatch."""

    def _grads(params, batch):
        def loss_of(p):
            return M.loss_fn(cfg, p, batch, policy=policy, constrain=constrain)

        return jax.value_and_grad(loss_of)(params)

    def step(state: TrainState, batch):
        rng = jax.random.fold_in(state.rng, state.step)

        if accum_steps > 1:
            def split(leaf):
                if leaf is None or leaf.ndim == 0:
                    return leaf
                b = leaf.shape[0]
                return leaf.reshape((accum_steps, b // accum_steps) + leaf.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def acc(carry, mb):
                loss_sum, g_sum = carry
                l, g = _grads(state.params, mb)
                g_sum = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_sum, g)
                return (loss_sum + l, g_sum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss_sum, g_sum), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), g0),
                                                micro)
            loss = loss_sum / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
        else:
            loss, grads = _grads(state.params, batch)
        if policy.grad_bits:
            # unbiased b-bit compression of the cross-replica gradient payload
            grads = fake_grad_compression(grads, policy.grad_bits, rng)
        new_params, new_opt, om = optimizer.update(grads, state.opt, state.params)
        if iht is not None:
            new_params = maybe_project(new_params, new_opt.step, iht)
        metrics = {"loss": loss, **om}
        return TrainState(step=state.step + 1, params=new_params,
                          opt=new_opt, rng=state.rng), metrics

    return step


def init_state(cfg: ModelConfig, optimizer: Optimizer, key: jax.Array) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=optimizer.init(params), rng=key)


# ---------------------------------------------------------------------------
# sharded (pjit) builders


def state_shardings(state_abs, mesh: Mesh) -> TrainState:
    """Shardings for a TrainState: params rules; moments follow params."""
    p_sh = params_shardings(state_abs.params, mesh)
    rep = NamedSharding(mesh, P())
    return TrainState(
        step=rep,
        params=p_sh,
        opt=type(state_abs.opt)(step=rep,
                                mu=params_shardings(state_abs.opt.mu, mesh),
                                nu=params_shardings(state_abs.opt.nu, mesh)),
        rng=rep,
    )


def batch_shardings(cfg: ModelConfig, mesh: Mesh, global_batch: int, with_memory: bool):
    tok = NamedSharding(mesh, batch_spec(mesh, global_batch, 2))
    out = {"tokens": tok, "labels": tok, "memory": None}
    if with_memory:
        out["memory"] = NamedSharding(mesh, batch_spec(mesh, global_batch, 3))
    return out


def build_sharded_train_step(cfg: ModelConfig, mesh: Mesh, optimizer: Optimizer,
                             global_batch: int,
                             policy: QuantPolicy = QuantPolicy(),
                             iht: Optional[IHTConfig] = None,
                             seq_parallel: bool = True,
                             accum_steps: int = 1):
    """jit-with-shardings train step for lowering or execution.

    ``seq_parallel``: shard the residual-stream activations' sequence dim over
    the `model` axis at period boundaries (Megatron-style sequence parallelism)
    — these are the remat-stored tensors, so this divides the activation
    footprint by the TP degree."""
    constrain = None
    if seq_parallel and "model" in mesh.axis_names:
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        sp = NamedSharding(mesh, P(dp if dp else None, "model", None))

        def constrain(x):
            if x.ndim == 3 and x.shape[1] % mesh.shape["model"] == 0 and (
                not dp or x.shape[0] % __import__("numpy").prod([mesh.shape[a] for a in dp]) == 0
            ):
                return jax.lax.with_sharding_constraint(x, sp)
            return x

    step_fn = make_train_step(cfg, optimizer, policy, iht, constrain=constrain,
                              accum_steps=accum_steps)
    key = jax.random.PRNGKey(0)
    state_abs = jax.eval_shape(lambda: init_state(cfg, optimizer, key))
    st_sh = state_shardings(state_abs, mesh)
    with_mem = cfg.family in ("encdec", "vlm")
    b_sh = batch_shardings(cfg, mesh, global_batch, with_mem)
    rep = NamedSharding(mesh, P())
    metric_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
    return jax.jit(
        step_fn,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, metric_sh),
        donate_argnums=(0,),
    ), st_sh


def build_sharded_decode_step(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                              cache_len: int,
                              policy: QuantPolicy = QuantPolicy(),
                              serve_sharding: str = "train",
                              serve_dtype: str = "float32"):
    """jit-with-shardings one-token serve step (token, cache, params)."""

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    kv_sh = NamedSharding(mesh, P(dp if dp else None, None, None, None))

    def constrain_kv(a):
        if a.ndim == 4 and (not dp or a.shape[0] % int(
                __import__("numpy").prod([mesh.shape[x] for x in dp])) == 0):
            return jax.lax.with_sharding_constraint(a, kv_sh)
        return a

    def step(params, token, cache, position):
        return M.decode_step(cfg, params, token, cache, policy=policy,
                             position=position, constrain_kv=constrain_kv)

    params_abs = serve_params_abstract(cfg, policy, serve_dtype)
    p_sh = params_shardings(params_abs, mesh, mode=serve_sharding)
    mem_len = _mem_len(cfg)
    cache_abs = jax.eval_shape(
        lambda: M.init_cache(cfg, global_batch, cache_len, policy, mem_len=mem_len)
    )
    c_sh = cache_shardings(cache_abs, mesh, global_batch)
    rep = NamedSharding(mesh, P())
    tok_sh = NamedSharding(mesh, batch_spec(mesh, global_batch, 1))
    logit_sh = NamedSharding(mesh, batch_spec(mesh, global_batch, 2))
    return jax.jit(
        step,
        in_shardings=(p_sh, tok_sh, c_sh, rep),
        out_shardings=(logit_sh, c_sh),
        donate_argnums=(2,),
    ), (p_sh, tok_sh, c_sh)


def serve_params_abstract(cfg: ModelConfig, policy: QuantPolicy,
                          serve_dtype: str = "float32"):
    """Abstract serving params: optionally bf16-cast, optionally weight-quantized
    (the paper's low-precision representation of the streamed operand)."""
    key = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(lambda: M.init_params(cfg, key))
    if serve_dtype != "float32":
        dt = jnp.dtype(serve_dtype)
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dt if s.dtype == jnp.float32 else s.dtype),
            params_abs,
        )
    if policy.weight_bits:
        from repro.models.quantized import quantize_params

        params_abs = jax.eval_shape(
            lambda: quantize_params(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_abs),
                policy.weight_bits,
            )
        )
    return params_abs


def build_sharded_prefill(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                          seq_len: int,
                          policy: QuantPolicy = QuantPolicy(),
                          serve_sharding: str = "train",
                          serve_dtype: str = "float32"):
    def run(params, tokens, cache, memory):
        return M.prefill(cfg, params, tokens, cache, policy=policy, memory=memory)

    params_abs = serve_params_abstract(cfg, policy, serve_dtype)
    p_sh = params_shardings(params_abs, mesh, mode=serve_sharding)
    mem_len = _mem_len(cfg)
    cache_abs = jax.eval_shape(
        lambda: M.init_cache(cfg, global_batch, seq_len, policy, mem_len=mem_len)
    )
    c_sh = cache_shardings(cache_abs, mesh, global_batch)
    tok_sh = NamedSharding(mesh, batch_spec(mesh, global_batch, 2))
    mem_sh = NamedSharding(mesh, batch_spec(mesh, global_batch, 3))
    logit_sh = NamedSharding(mesh, batch_spec(mesh, global_batch, 2))
    return jax.jit(
        run,
        in_shardings=(p_sh, tok_sh, c_sh, mem_sh if _mem_len(cfg) else None),
        out_shardings=(logit_sh, c_sh),
        donate_argnums=(2,),
    ), (p_sh, tok_sh, c_sh)


def _mem_len(cfg: ModelConfig) -> int:
    if cfg.family == "encdec":
        return cfg.encoder_seq
    if cfg.family == "vlm":
        return cfg.n_image_tokens
    return 0


def cache_shardings(cache_abs, mesh: Mesh, global_batch: int):
    """Caches: batch over DP axes; kv-heads/state heads over model when
    divisible (falls back automatically via batch_spec/dim checks)."""

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # leading dim may be the scan stack (n_periods) — batch dim is where
        # size == global_batch
        spec = [None] * leaf.ndim
        for i, d in enumerate(leaf.shape):
            if d == global_batch:
                bs = batch_spec(mesh, global_batch, 1)
                spec[i] = bs[0] if bs else None
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_abs)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)


def train_input_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int, seq: int):
    tok = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family in ("encdec", "vlm"):
        batch["memory"] = jax.ShapeDtypeStruct(
            (global_batch, _mem_len(cfg), cfg.d_model), jnp.float32
        )
    else:
        batch["memory"] = None
    return batch
