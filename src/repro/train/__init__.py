"""Training runtime: sharded steps, checkpointing, fault tolerance."""
from repro.train.checkpoint import available_steps, latest_step, restore, restore_latest, save
from repro.train.fault import PreemptionGuard, StepTimer, run_with_restarts
from repro.train.loop import LoopConfig, train_loop
from repro.train.state import TrainState
from repro.train.steps import (
    build_sharded_decode_step,
    build_sharded_prefill,
    build_sharded_train_step,
    init_state,
    make_train_step,
    state_shardings,
    train_input_specs,
)

__all__ = [
    "available_steps", "latest_step", "restore", "restore_latest", "save",
    "PreemptionGuard", "StepTimer", "run_with_restarts",
    "LoopConfig", "train_loop", "TrainState",
    "build_sharded_decode_step", "build_sharded_prefill",
    "build_sharded_train_step", "init_state", "make_train_step",
    "state_shardings", "train_input_specs",
]
