"""Pure-jnp oracle for the stochastic-rounding quantizer kernel.

Contract: given values ``v`` (float32), uniform random words ``u`` (uint32, same
shape), a scalar ``scale`` and bit width ``bits``, produce int8 codes

    scaled = clip(v/scale, -1, 1) * K
    low    = floor(scaled)
    code   = low + (uniform01(u) < scaled - low)

where ``uniform01(u) = (u >> 8) * 2^-24`` (the standard 24-bit mantissa trick —
bit-exact between the oracle and the kernel, unlike float division).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.formats import BY_BITS


def uniform01_from_bits(u: jnp.ndarray) -> jnp.ndarray:
    return (u >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def sqround_ref(v: jnp.ndarray, u: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    k = BY_BITS[bits].half_steps
    scaled = jnp.clip(v / scale, -1.0, 1.0) * k
    low = jnp.floor(scaled)
    p_up = scaled - low
    codes = low + (uniform01_from_bits(u) < p_up).astype(jnp.float32)
    return jnp.clip(codes, -k, k).astype(jnp.int8)
