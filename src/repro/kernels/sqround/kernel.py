"""Pallas TPU kernel: stochastic rounding to the odd-level integer grid.

The paper's CPU implementation burns XORShift + AVX2 lanes on this (§9); on TPU
it is a pure VPU elementwise kernel. Random words are generated *outside* with
``jax.random.bits`` (counter-based, reproducible) and streamed as an operand —
this keeps the kernel deterministic given its inputs and bit-exact against the
``ref.py`` oracle (validated in interpret mode).

Grid: 1-D over row blocks of a 2-D (rows, cols) view; both operands tile
(block_r, cols) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.formats import BY_BITS


def _sqround_kernel(v_ref, u_ref, scale_ref, o_ref, *, bits: int):
    k = BY_BITS[bits].half_steps
    v = v_ref[...]
    scale = scale_ref[0, 0]
    scaled = jnp.clip(v / scale, -1.0, 1.0) * k
    low = jnp.floor(scaled)
    p_up = scaled - low
    u01 = (u_ref[...] >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    codes = low + (u01 < p_up).astype(jnp.float32)
    o_ref[...] = jnp.clip(codes, -k, k).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "block_r", "interpret"))
def sqround_pallas(
    v: jax.Array,
    u: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    block_r: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Stochastically round (R, C) float32 ``v`` to int8 codes. R % block_r == 0."""
    r, c = v.shape
    if r % block_r:
        raise ValueError(f"rows {r} not a multiple of block_r {block_r}; pad in ops.py")
    return pl.pallas_call(
        functools.partial(_sqround_kernel, bits=bits),
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int8),
        interpret=interpret,
    )(v, u, scale)
