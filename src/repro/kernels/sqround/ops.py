"""Public wrapper: stochastic quantization of a matrix with the Pallas kernel.

``sqround(v, bits, key)`` returns (codes int8, scale) — same semantics as
``repro.quant.quantize_codes`` but (a) bit-exact reproducible from the uint32
stream, (b) executed by the TPU kernel when available.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.sqround.kernel import sqround_pallas
from repro.kernels.sqround.ref import sqround_ref


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def sqround(
    v: jax.Array,
    bits: int,
    key: jax.Array,
    scale: Optional[jax.Array] = None,
    *,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    block_r: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Stochastically round a 2-D float32 array to int8 codes in [-K, K]."""
    if v.ndim != 2:
        raise ValueError("sqround expects a 2-D array")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if scale is None:
        m = jnp.max(jnp.abs(v))
        scale = jnp.where(m > 0, m, 1.0).astype(jnp.float32)
    u = jax.random.bits(key, v.shape, dtype=jnp.uint32)
    if not use_pallas:
        return sqround_ref(v, u, scale, bits), scale
    r, c = v.shape
    br = min(block_r, r)
    rp = _round_up(r, br)
    v_p = jnp.pad(v, ((0, rp - r), (0, 0)))
    u_p = jnp.pad(u, ((0, rp - r), (0, 0)))
    codes = sqround_pallas(
        v_p, u_p, scale.reshape(1, 1), bits=bits, block_r=br, interpret=interpret
    )
    return codes[:r], scale
