"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
<name>/ops.py (jit'd public wrapper with padding + backend dispatch) and
<name>/ref.py (pure-jnp oracle used by the tests' assert_allclose sweeps):

* ``qmm``       — packed int2/4/8 dequant matmul (the paper's AVX2/FPGA engines)
* ``sqround``   — stochastic rounding quantizer (paper §9's XORShift path)
* ``hsthresh``  — streaming hard-threshold H_s (paper §8's FPGA top-S search)
* ``flashattn`` — fused online-softmax attention (32k-prefill substrate)

CPU container note: kernels target TPU; ``interpret=True`` executes kernel
bodies on CPU for correctness tests. ops.py wrappers auto-dispatch to ref.py
off-TPU so the multi-pod dry-run lowers portable HLO.
"""
from repro.kernels.flashattn.ops import flash_attention
from repro.kernels.hsthresh.ops import hsthresh
from repro.kernels.qmm.ops import (
    PackedOperator,
    PackedWeights,
    pack_operator,
    pack_weights,
    packed_matvec,
    packed_rmatvec,
    qmm,
)
from repro.kernels.sqround.ops import sqround

__all__ = [
    "flash_attention",
    "hsthresh",
    "PackedOperator",
    "PackedWeights",
    "pack_operator",
    "pack_weights",
    "packed_matvec",
    "packed_rmatvec",
    "qmm",
    "sqround",
]
