"""Public wrapper: streaming H_s via histogram + threshold + mask."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.hsthresh.kernel import hist_pallas, mask_pallas
from repro.kernels.hsthresh.ref import (
    fill_threshold_bin,
    hist_ref,
    hsthresh_ref,
    mask_ref,
    select_threshold,
)


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def hsthresh(
    x: jax.Array,
    s: int,
    *,
    nbins: int = 2048,
    block_n: int = 1024,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
) -> jax.Array:
    """Streaming hard threshold on a real vector. Support size <= s guaranteed;
    equals exact H_s whenever no two magnitudes share the threshold bin.
    Threshold-bin ties are kept (ascending index) up to support size s rather
    than dropped — see :func:`repro.kernels.hsthresh.ref.fill_threshold_bin`
    for why an all-dropped tie bin is solver-fatal."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if not use_pallas:
        return hsthresh_ref(x, s, nbins)
    n = x.shape[0]
    npad = _round_up(n, block_n)
    x2 = jnp.pad(x.astype(jnp.float32), (0, npad - n)).reshape(1, npad)
    mag = jnp.abs(x2)
    vmax = jnp.maximum(jnp.max(mag), 1e-30).reshape(1, 1)
    h = hist_pallas(x2, vmax, nbins=nbins, block_n=block_n, interpret=interpret)
    # padded zeros land in bin 0, which never participates in the tail selection
    t = select_threshold(h[0], vmax[0, 0], s)
    y = mask_pallas(x2, t.reshape(1, 1), block_n=block_n, interpret=interpret)
    out = fill_threshold_bin(x2[0, :n], y[0, :n], t, vmax[0, 0] / nbins, s)
    return out.astype(x.dtype)
