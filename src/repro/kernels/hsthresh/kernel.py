"""Pallas TPU kernels for the streaming hard-threshold operator H_s.

Two passes (see ref.py): a histogram kernel (block-accumulated into a single
(1, nbins) output revisited across the grid) and an elementwise mask kernel.
Both are bandwidth-bound streaming passes over x — the same access pattern the
paper's FPGA uses for its top-S binary search, restructured so each element is
read exactly twice instead of O(log) times.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(x_ref, vmax_ref, o_ref, *, nbins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mag = jnp.abs(x_ref[...])                                  # (1, bn)
    vmax = vmax_ref[0, 0]
    idx = jnp.clip((mag / vmax * nbins).astype(jnp.int32), 0, nbins - 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[1], nbins), 1)
    onehot = (idx[0, :, None] == bins).astype(jnp.int32)       # (bn, nbins)
    o_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)


def _mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0, 0]
    o_ref[...] = jnp.where(jnp.abs(x) > t, x, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("nbins", "block_n", "interpret"))
def hist_pallas(
    x: jax.Array, vmax: jax.Array, *, nbins: int = 2048, block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Histogram of |x| (x: (1, N) f32, N % block_n == 0) → (1, nbins) int32."""
    n = x.shape[1]
    if n % block_n:
        raise ValueError("pad x to a multiple of block_n in ops.py")
    return pl.pallas_call(
        functools.partial(_hist_kernel, nbins=nbins),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nbins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nbins), jnp.int32),
        interpret=interpret,
    )(x, vmax)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mask_pallas(
    x: jax.Array, t: jax.Array, *, block_n: int = 1024, interpret: bool = False
) -> jax.Array:
    """y = where(|x| > t, x, 0) for x (1, N), N % block_n == 0."""
    n = x.shape[1]
    if n % block_n:
        raise ValueError("pad x to a multiple of block_n in ops.py")
    return pl.pallas_call(
        _mask_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, t)
