"""Pure-jnp oracle for the hard-threshold (H_s) kernel pair.

The TPU design mirrors the paper's FPGA §8 ("binary search on the updated model
to find the threshold value satisfying that only top S values are larger"),
but in two streaming passes instead of a sequential bisection:

  pass 1 (``hist``):  histogram of |x| over ``nbins`` uniform bins in [0, max],
  select (jnp):       the finest bin edge t with  count(|x| > t) <= s,
  pass 2 (``mask``):  y = where(|x| > t, x, 0).

With ``nbins`` large the within-bin ties are rare; the operator always returns
support size <= s (a valid H_s relaxation, identical in kind to the FPGA one).

Threshold-bin ties are FILLED rather than dropped (:func:`fill_threshold_bin`):
a strict ``|x| > t`` cut returns the *empty* support when every magnitude lands
in one bin (flat / piecewise-constant phantoms — their tied top values ARE the
signal), which silently re-triggers the solver's x=0 init branch forever. The
fill keeps the strict survivors plus same-bin entries in ascending-index order
up to support size s, so the support degrades gracefully to exactly s under
ties instead of collapsing.
"""
from __future__ import annotations

import jax.numpy as jnp


def hist_ref(mag: jnp.ndarray, vmax: jnp.ndarray, nbins: int) -> jnp.ndarray:
    """Counts of |x| in uniform bins over [0, vmax]; shape (nbins,), int32."""
    idx = jnp.clip((mag / vmax * nbins).astype(jnp.int32), 0, nbins - 1)
    return jnp.zeros((nbins,), jnp.int32).at[idx].add(1)


def select_threshold(hist: jnp.ndarray, vmax: jnp.ndarray, s: int) -> jnp.ndarray:
    """Smallest bin edge t with count(|x| > t) <= s (edges = i*vmax/nbins)."""
    nbins = hist.shape[0]
    # tail[i] = number of elements in bins >= i  (all of them have |x| > edge i-... )
    tail = jnp.cumsum(hist[::-1])[::-1]
    # count(|x| > edge_i) <= tail[i]  (edge_i = i * vmax / nbins)
    ok = tail <= s
    first_ok = jnp.argmax(ok)  # first True (ok is monotone non-decreasing)
    any_ok = jnp.any(ok)
    idx = jnp.where(any_ok, first_ok, nbins)
    return idx.astype(jnp.float32) * vmax / nbins


def mask_ref(x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(jnp.abs(x) > t, x, jnp.zeros_like(x))


def tie_fill_mask(strict: jnp.ndarray, tied: jnp.ndarray, s: int) -> jnp.ndarray:
    """Mask of the ``tied`` entries to ADD to the ``strict`` survivors: the
    first (ascending index) ties up to a total support of s. The tie-fill
    primitive shared by BOTH H_s relaxations — this histogram oracle and the
    bisection variant in :mod:`repro.core.threshold` (which imports it from
    here: this module has no repro deps, so it is the one home that avoids the
    core↔kernels import cycle). Support never exceeds s by construction of
    the cumsum cap."""
    return tied & (jnp.cumsum(tied) <= s - jnp.sum(strict))


def fill_threshold_bin(
    x: jnp.ndarray,
    y: jnp.ndarray,
    t: jnp.ndarray,
    binw: jnp.ndarray,
    s: int,
) -> jnp.ndarray:
    """Top up the strict-cut output ``y = where(|x| > t, x, 0)`` with
    threshold-bin entries (``t - binw <= |x| <= t``, zeros excluded) in
    ascending-index order until the support reaches s (see module docstring).
    ``select_threshold`` guarantees count(|x| >= t - binw) > s whenever it had
    a choice, so the result has exactly min(s, plausible) nonzeros."""
    mag = jnp.abs(x)
    strict = mag > t
    tied = (mag >= t - binw) & ~strict & (mag > 0)
    return jnp.where(tie_fill_mask(strict, tied, s), x, y)


def hsthresh_ref(x: jnp.ndarray, s: int, nbins: int = 4096) -> jnp.ndarray:
    """Full oracle: histogram-select-mask-fill H_s on a vector."""
    mag = jnp.abs(x)
    vmax = jnp.maximum(jnp.max(mag), 1e-30)
    h = hist_ref(mag, vmax, nbins)
    t = select_threshold(h, vmax, s)
    return fill_threshold_bin(x, mask_ref(x, t), t, vmax / nbins, s)
