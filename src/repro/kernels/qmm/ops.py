"""Jit'd public wrappers around the packed low-precision matmul.

* :func:`pack_weights` — quantize + pack a weight/measurement matrix for qmm.
* :func:`qmm` — padded dispatch: Pallas kernel on TPU, oracle elsewhere.
* :func:`qmm_complex` — complex Φ̂ × real/complex vectors via real matmuls.
* :class:`PackedOperator` / :func:`pack_operator` — both orientations of a CS
  measurement matrix (Φ̂ and Φ̂†), the pair QNIHT streams every iteration;
  ``shared=True`` packs one quantization in both orientations (the
  ``requantize="fixed"`` deployment mode behind ``qniht(backend="packed")``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels.qmm.kernel import qmm_group_pallas, qmm_pallas
from repro.kernels.qmm.ref import qmm_group_ref, qmm_ref
from repro.quant.formats import (
    BY_BITS,
    PER_CHANNEL,
    PER_TENSOR,
    Granularity,
    as_granularity,
)
from repro.quant.pack import pack_codes, validate_group_packing
from repro.quant.quantize import quantize, quantize_codes


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedWeights:
    """(N, K) weight matrix quantized & packed along K.

    ``scale`` layout follows ``granularity``: (1, N) per-output-channel f32 for
    ``per_tensor``/``per_channel`` (per-tensor broadcasts one value), or
    (N, ⌈K/group_size⌉) blockwise-along-K for ``per_block`` (consumed by the
    group-scaled kernel, which dequantizes inside the contraction).

    Registered pytree: the arrays (``packed``, ``scale``) are children and the
    config (``bits``, ``k_dim``, ``granularity``) is aux data, so packed
    weights — and every operator built from them — cross jit/shard_map
    boundaries as ordinary arguments (e.g. a pre-packed Φ̂ handed to the
    sharded serving loop, :class:`repro.parallel.batch.BatchServer`).
    """

    packed: jax.Array      # (N, packed_len(K)) uint8
    scale: jax.Array       # see granularity note above
    bits: int
    k_dim: int
    granularity: Granularity = PER_TENSOR

    def tree_flatten(self):
        return (self.packed, self.scale), (self.bits, self.k_dim, self.granularity)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        """Packed code bytes only (the precision-proportional stream the paper's
        bandwidth law counts); the f32 scale overhead is ``scale_nbytes``."""
        return self.packed.size  # uint8

    @property
    def scale_nbytes(self) -> int:
        """Bytes of actual scale information at this granularity (per_tensor is
        ONE f32 even though the stored array broadcasts it to (1, N))."""
        return self.granularity.scale_nbytes((self.packed.shape[0], self.k_dim))


def _resolve_granularity(granularity, per_channel: bool) -> Granularity:
    """Map the legacy ``per_channel`` bool and the new ``granularity`` arg onto
    one :class:`Granularity` (an explicit granularity wins)."""
    if granularity is not None:
        return as_granularity(granularity)
    return PER_CHANNEL if per_channel else PER_TENSOR


def pack_weights(
    w: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    per_channel: bool = True,
    granularity: Union[Granularity, str, None] = None,
) -> PackedWeights:
    """Quantize (stochastic if key given) and pack an (N, K) real matrix.

    ``granularity`` (overrides the legacy ``per_channel`` bool when given):
    ``per_tensor`` — one scale; ``per_channel`` — one scale per output row N;
    ``per_block(g)`` — one scale per g contiguous K elements (g a multiple of
    the packing word, see :func:`repro.quant.pack.validate_group_packing`).
    """
    if w.ndim != 2:
        raise ValueError("pack_weights expects (N, K)")
    gran = _resolve_granularity(granularity, per_channel)
    if gran.kind == "per_block":
        validate_group_packing(gran.group_size, bits)
        codes, scale = quantize_codes(w, bits, key, granularity=gran)
        return PackedWeights(
            packed=pack_codes(codes, bits),
            scale=scale.astype(jnp.float32),            # (N, ⌈K/g⌉)
            bits=bits,
            k_dim=w.shape[1],
            granularity=gran,
        )
    if gran.kind == "per_channel":
        codes, scale = quantize_codes(w, bits, key, channel_axis=0)
    else:
        codes, scale = quantize_codes(w, bits, key)
        scale = jnp.full((w.shape[0], 1), scale)
    return PackedWeights(
        packed=pack_codes(codes, bits),
        scale=scale.reshape(1, -1).astype(jnp.float32),
        bits=bits,
        k_dim=w.shape[1],
        granularity=gran,
    )


def qmm(
    x: jax.Array,
    w: PackedWeights,
    *,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """y = x @ dequant(w)ᵀ with padding to kernel block multiples.

    ``use_pallas=None`` auto-dispatches: the Mosaic kernel on TPU, the pure-jnp
    oracle otherwise (interpret=True forces the kernel body on CPU for tests).
    Group-scaled weights (``granularity=per_block``) route to the group kernel,
    whose K blocks are additionally aligned to the scale group size.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    m, k = x.shape
    n = w.packed.shape[0]
    if w.granularity.kind == "per_block":
        return _qmm_group(x, w, use_pallas, interpret, block_m, block_n, block_k)
    if not use_pallas:
        return qmm_ref(x, w.packed, w.scale, w.bits, w.k_dim)

    vpb = BY_BITS[w.bits].values_per_byte
    # shrink blocks for small problems, keeping MXU-friendly minima
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(w.k_dim, 128 * vpb))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(w.k_dim, bk)
    x_p = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    packed_k = kp // vpb
    w_p = jnp.pad(w.packed, ((0, np_ - n), (0, packed_k - w.packed.shape[1])),
                  constant_values=_zero_byte(w.bits))
    s_p = jnp.pad(w.scale, ((0, 0), (0, np_ - n)))
    y = qmm_pallas(x_p, w_p, s_p, bits=w.bits, k_dim=kp,
                   block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return y[:m, :n]


def _qmm_group(x, w: PackedWeights, use_pallas, interpret, block_m, block_n, block_k):
    """Group-scaled qmm dispatch: pad to blocks whose K size the scale groups
    tile exactly (padded codes are biased-zero, padded scale groups are 1.0 —
    both contribute nothing to the sliced-out output)."""
    g = w.granularity.group_size
    if not use_pallas:
        return qmm_group_ref(x, w.packed, w.scale, w.bits, w.k_dim, g)
    m, k = x.shape
    n = w.packed.shape[0]
    vpb = BY_BITS[w.bits].values_per_byte
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 128))
    # K blocks must tile BOTH the 128-lane packed layout and the scale groups
    unit = math.lcm(g, 128 * vpb)
    bk = min(_round_up(block_k, unit), _round_up(w.k_dim, unit))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(w.k_dim, bk)
    x_p = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    w_p = jnp.pad(w.packed, ((0, np_ - n), (0, kp // vpb - w.packed.shape[1])),
                  constant_values=_zero_byte(w.bits))
    s_p = jnp.pad(w.scale, ((0, np_ - n), (0, kp // g - w.scale.shape[1])),
                  constant_values=1.0)
    y = qmm_group_pallas(x_p, w_p, s_p, bits=w.bits, k_dim=kp, group_size=g,
                         block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return y[:m, :n]


def _zero_byte(bits: int) -> int:
    """uint8 word whose every packed code is 0 (biased representation of 0)."""
    fmt = BY_BITS[bits]
    k = fmt.half_steps
    word = 0
    for i in range(fmt.values_per_byte):
        word |= k << (bits * i)
    return word


class PackedOperator(NamedTuple):
    """A quantized CS measurement matrix in both orientations.

    ``fwd``  computes Φ̂ x  (stores Φ̂ as (M, N) packed along N),
    ``adj``  computes Φ̂† r (stores Φ̂ᵀ* as (N, M) packed along M).
    Complex matrices store stacked real/imag parts with a leading axis of 2.
    """

    fwd_re: PackedWeights
    fwd_im: Optional[PackedWeights]
    adj_re: PackedWeights
    adj_im: Optional[PackedWeights]

    @property
    def is_complex(self) -> bool:
        return self.fwd_im is not None

    @property
    def nbytes(self) -> int:
        total = self.fwd_re.nbytes + self.adj_re.nbytes
        if self.is_complex:
            total += self.fwd_im.nbytes + self.adj_im.nbytes
        return total

    @property
    def scale_nbytes(self) -> int:
        """f32 scale bytes riding alongside the packed codes (the documented
        per-block overhead; per-tensor/per-channel carry (1, N) as before)."""
        total = self.fwd_re.scale_nbytes + self.adj_re.scale_nbytes
        if self.is_complex:
            total += self.fwd_im.scale_nbytes + self.adj_im.scale_nbytes
        return total


def _pack_from_codes(codes: jax.Array, scale: jax.Array, bits: int) -> PackedWeights:
    """Build PackedWeights from pre-quantized (N, K) int codes + scalar scale."""
    return PackedWeights(
        packed=pack_codes(codes, bits),
        scale=jnp.full((1, codes.shape[0]), scale, jnp.float32),
        bits=bits,
        k_dim=codes.shape[1],
    )


def pack_operator(
    phi: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    per_channel: bool = False,
    shared: bool = False,
    granularity: Union[Granularity, str, None] = None,
) -> PackedOperator:
    """Quantize a dense (M, N) measurement matrix for streaming IHT.

    Per-tensor scale by default (faithful to the paper's single c_Φ);
    ``granularity`` selects per_channel / per_block(g) scaling instead
    (overriding the legacy ``per_channel`` bool).

    ``shared=False`` draws an *independent* quantization for each orientation
    (Algorithm 1's Φ̂_{2n-1}/Φ̂_{2n} pairing, unbiased in expectation with a
    key). ``shared=True`` quantizes **once** — the same codes back both Φ̂ and
    Φ̂†, which is what a deployed ``requantize="fixed"`` system streaming
    pre-quantized data does, and makes the adjoint identity ⟨Φ̂x, r⟩ = ⟨x, Φ̂†r⟩
    exact. Shared codes match ``fake_quantize(phi, bits, key)`` bit-for-bit.

    Sharing is only possible with ONE scale per tensor: a per-channel or
    per-block scale is tied to an orientation's own row/contraction axis, so
    the transposed orientation cannot reuse the codes (its scale groups run
    across the other axis). Per-orientation scales therefore require
    ``shared=False``.
    """
    gran = _resolve_granularity(granularity, per_channel)
    if shared and not gran.is_per_tensor:
        raise ValueError(
            f"pack_operator(shared=True) streams ONE per-tensor quantization "
            f"through both orientations; a {gran} scale is tied to each "
            f"orientation's own axes, so shared codes cannot carry it. Pass "
            f"shared=False (per-orientation quantization, adjoint identity "
            f"approximate) or granularity='per_tensor' (exact shared codes).")
    if shared:
        q = quantize(phi, bits, key)
        if q.is_complex:
            cre, cim = q.codes[0], q.codes[1]
            return PackedOperator(
                fwd_re=_pack_from_codes(cre, q.scale, bits),
                fwd_im=_pack_from_codes(cim, q.scale, bits),
                adj_re=_pack_from_codes(cre.T, q.scale, bits),
                adj_im=_pack_from_codes(cim.T, q.scale, bits),
            )
        return PackedOperator(
            fwd_re=_pack_from_codes(q.codes, q.scale, bits),
            fwd_im=None,
            adj_re=_pack_from_codes(q.codes.T, q.scale, bits),
            adj_im=None,
        )
    if jnp.iscomplexobj(phi):
        re, im = jnp.real(phi), jnp.imag(phi)
        keys = jax.random.split(key, 4) if key is not None else [None] * 4
        return PackedOperator(
            fwd_re=pack_weights(re, bits, keys[0], granularity=gran),
            fwd_im=pack_weights(im, bits, keys[1], granularity=gran),
            adj_re=pack_weights(re.T, bits, keys[2], granularity=gran),
            adj_im=pack_weights(im.T, bits, keys[3], granularity=gran),
        )
    keys = jax.random.split(key, 2) if key is not None else [None, None]
    return PackedOperator(
        fwd_re=pack_weights(phi, bits, keys[0], granularity=gran),
        fwd_im=None,
        adj_re=pack_weights(phi.T, bits, keys[1], granularity=gran),
        adj_im=None,
    )


def packed_matvec(op: PackedOperator, x: jax.Array, **kw) -> jax.Array:
    """Φ̂ x for real or complex Φ̂ (x may be real or complex).

    ``x`` is a single vector (N,) or a batch (B, N); a batch is served by ONE
    kernel invocation per real matmul, amortizing the packed Φ̂ stream over B.
    """
    single = x.ndim == 1
    xb = x[None, :] if single else x
    if not op.is_complex:
        out = qmm(xb.astype(jnp.float32), op.fwd_re, **kw)
        return out[0] if single else out
    xr = jnp.real(xb).astype(jnp.float32)
    rr = qmm(xr, op.fwd_re, **kw)
    ir = qmm(xr, op.fwd_im, **kw)
    if not jnp.iscomplexobj(x):
        # real input (e.g. a real sky through complex Φ̂): the imaginary-part
        # products are identically zero — skip their kernel calls so the packed
        # matrices stream once, not twice.
        out = jax.lax.complex(rr, ir)
        return out[0] if single else out
    xi = jnp.imag(xb).astype(jnp.float32)
    ri = qmm(xi, op.fwd_re, **kw)
    ii = qmm(xi, op.fwd_im, **kw)
    out = jax.lax.complex(rr - ii, ri + ir)
    return out[0] if single else out


def packed_rmatvec(op: PackedOperator, r: jax.Array, **kw) -> jax.Array:
    """Φ̂† r (conjugate transpose) for real or complex Φ̂; (M,) or batched (B, M)."""
    single = r.ndim == 1
    rb = r[None, :] if single else r
    if not op.is_complex:
        out = qmm(rb.astype(jnp.float32), op.adj_re, **kw)
        return out[0] if single else out
    # Φ† = (Re − j·Im)ᵀ ; (Φ† r) = (Reᵀ r_re + Imᵀ r_im) + j(Reᵀ r_im − Imᵀ r_re)
    rr_ = jnp.real(rb).astype(jnp.float32)
    t1 = qmm(rr_, op.adj_re, **kw)
    t4 = qmm(rr_, op.adj_im, **kw)
    if not jnp.iscomplexobj(r):
        out = jax.lax.complex(t1, -t4)
        return out[0] if single else out
    ri_ = jnp.imag(rb).astype(jnp.float32)
    t2 = qmm(ri_, op.adj_im, **kw)
    t3 = qmm(ri_, op.adj_re, **kw)
    out = jax.lax.complex(t1 + t2, t3 - t4)
    return out[0] if single else out
