"""Jit'd public wrappers around the packed low-precision matmul.

* :func:`pack_weights` — quantize + pack a weight/measurement matrix for qmm.
* :func:`qmm` — padded dispatch: Pallas kernel on TPU, oracle elsewhere.
* :func:`qmm_complex` — complex Φ̂ × real/complex vectors via real matmuls.
* :class:`PackedOperator` / :func:`pack_operator` — both orientations of a CS
  measurement matrix (Φ̂ and Φ̂†), the pair QNIHT streams every iteration;
  ``shared=True`` packs one quantization in both orientations (the
  ``requantize="fixed"`` deployment mode behind ``qniht(backend="packed")``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.qmm.kernel import qmm_pallas
from repro.kernels.qmm.ref import qmm_ref
from repro.quant.formats import BY_BITS
from repro.quant.pack import pack_codes
from repro.quant.quantize import quantize, quantize_codes


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


class PackedWeights(NamedTuple):
    """(N, K) weight matrix quantized & packed along K."""

    packed: jax.Array      # (N, packed_len(K)) uint8
    scale: jax.Array       # (1, N) f32 per-channel
    bits: int
    k_dim: int

    @property
    def nbytes(self) -> int:
        return self.packed.size  # uint8


def pack_weights(
    w: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    per_channel: bool = True,
) -> PackedWeights:
    """Quantize (stochastic if key given) and pack an (N, K) real matrix."""
    if w.ndim != 2:
        raise ValueError("pack_weights expects (N, K)")
    codes, scale = quantize_codes(w, bits, key, channel_axis=0 if per_channel else None)
    if not per_channel:
        scale = jnp.full((w.shape[0], 1), scale)
    return PackedWeights(
        packed=pack_codes(codes, bits),
        scale=scale.reshape(1, -1).astype(jnp.float32),
        bits=bits,
        k_dim=w.shape[1],
    )


def qmm(
    x: jax.Array,
    w: PackedWeights,
    *,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """y = x @ dequant(w)ᵀ with padding to kernel block multiples.

    ``use_pallas=None`` auto-dispatches: the Mosaic kernel on TPU, the pure-jnp
    oracle otherwise (interpret=True forces the kernel body on CPU for tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    m, k = x.shape
    n = w.packed.shape[0]
    if not use_pallas:
        return qmm_ref(x, w.packed, w.scale, w.bits, w.k_dim)

    vpb = BY_BITS[w.bits].values_per_byte
    # shrink blocks for small problems, keeping MXU-friendly minima
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(w.k_dim, 128 * vpb))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(w.k_dim, bk)
    x_p = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    packed_k = kp // vpb
    w_p = jnp.pad(w.packed, ((0, np_ - n), (0, packed_k - w.packed.shape[1])),
                  constant_values=_zero_byte(w.bits))
    s_p = jnp.pad(w.scale, ((0, 0), (0, np_ - n)))
    y = qmm_pallas(x_p, w_p, s_p, bits=w.bits, k_dim=kp,
                   block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return y[:m, :n]


def _zero_byte(bits: int) -> int:
    """uint8 word whose every packed code is 0 (biased representation of 0)."""
    fmt = BY_BITS[bits]
    k = fmt.half_steps
    word = 0
    for i in range(fmt.values_per_byte):
        word |= k << (bits * i)
    return word


class PackedOperator(NamedTuple):
    """A quantized CS measurement matrix in both orientations.

    ``fwd``  computes Φ̂ x  (stores Φ̂ as (M, N) packed along N),
    ``adj``  computes Φ̂† r (stores Φ̂ᵀ* as (N, M) packed along M).
    Complex matrices store stacked real/imag parts with a leading axis of 2.
    """

    fwd_re: PackedWeights
    fwd_im: Optional[PackedWeights]
    adj_re: PackedWeights
    adj_im: Optional[PackedWeights]

    @property
    def is_complex(self) -> bool:
        return self.fwd_im is not None

    @property
    def nbytes(self) -> int:
        total = self.fwd_re.nbytes + self.adj_re.nbytes
        if self.is_complex:
            total += self.fwd_im.nbytes + self.adj_im.nbytes
        return total


def _pack_from_codes(codes: jax.Array, scale: jax.Array, bits: int) -> PackedWeights:
    """Build PackedWeights from pre-quantized (N, K) int codes + scalar scale."""
    return PackedWeights(
        packed=pack_codes(codes, bits),
        scale=jnp.full((1, codes.shape[0]), scale, jnp.float32),
        bits=bits,
        k_dim=codes.shape[1],
    )


def pack_operator(
    phi: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    per_channel: bool = False,
    shared: bool = False,
) -> PackedOperator:
    """Quantize a dense (M, N) measurement matrix for streaming IHT.

    Per-tensor scale by default (faithful to the paper's single c_Φ).

    ``shared=False`` draws an *independent* stochastic quantization for each
    orientation (Algorithm 1's Φ̂_{2n-1}/Φ̂_{2n} pairing, unbiased in
    expectation). ``shared=True`` quantizes **once** — the same codes back both
    Φ̂ and Φ̂†, which is what a deployed ``requantize="fixed"`` system streaming
    pre-quantized data does, and makes the adjoint identity ⟨Φ̂x, r⟩ = ⟨x, Φ̂†r⟩
    exact. Shared codes match ``fake_quantize(phi, bits, key)`` bit-for-bit.
    """
    if shared:
        if per_channel:
            raise ValueError("shared codes use the paper's single per-tensor scale")
        q = quantize(phi, bits, key)
        if q.is_complex:
            cre, cim = q.codes[0], q.codes[1]
            return PackedOperator(
                fwd_re=_pack_from_codes(cre, q.scale, bits),
                fwd_im=_pack_from_codes(cim, q.scale, bits),
                adj_re=_pack_from_codes(cre.T, q.scale, bits),
                adj_im=_pack_from_codes(cim.T, q.scale, bits),
            )
        return PackedOperator(
            fwd_re=_pack_from_codes(q.codes, q.scale, bits),
            fwd_im=None,
            adj_re=_pack_from_codes(q.codes.T, q.scale, bits),
            adj_im=None,
        )
    if jnp.iscomplexobj(phi):
        re, im = jnp.real(phi), jnp.imag(phi)
        keys = jax.random.split(key, 4) if key is not None else [None] * 4
        return PackedOperator(
            fwd_re=pack_weights(re, bits, keys[0], per_channel),
            fwd_im=pack_weights(im, bits, keys[1], per_channel),
            adj_re=pack_weights(re.T, bits, keys[2], per_channel),
            adj_im=pack_weights(im.T, bits, keys[3], per_channel),
        )
    keys = jax.random.split(key, 2) if key is not None else [None, None]
    return PackedOperator(
        fwd_re=pack_weights(phi, bits, keys[0], per_channel),
        fwd_im=None,
        adj_re=pack_weights(phi.T, bits, keys[1], per_channel),
        adj_im=None,
    )


def packed_matvec(op: PackedOperator, x: jax.Array, **kw) -> jax.Array:
    """Φ̂ x for real or complex Φ̂ (x may be real or complex).

    ``x`` is a single vector (N,) or a batch (B, N); a batch is served by ONE
    kernel invocation per real matmul, amortizing the packed Φ̂ stream over B.
    """
    single = x.ndim == 1
    xb = x[None, :] if single else x
    if not op.is_complex:
        out = qmm(xb.astype(jnp.float32), op.fwd_re, **kw)
        return out[0] if single else out
    xr = jnp.real(xb).astype(jnp.float32)
    rr = qmm(xr, op.fwd_re, **kw)
    ir = qmm(xr, op.fwd_im, **kw)
    if not jnp.iscomplexobj(x):
        # real input (e.g. a real sky through complex Φ̂): the imaginary-part
        # products are identically zero — skip their kernel calls so the packed
        # matrices stream once, not twice.
        out = jax.lax.complex(rr, ir)
        return out[0] if single else out
    xi = jnp.imag(xb).astype(jnp.float32)
    ri = qmm(xi, op.fwd_re, **kw)
    ii = qmm(xi, op.fwd_im, **kw)
    out = jax.lax.complex(rr - ii, ri + ir)
    return out[0] if single else out


def packed_rmatvec(op: PackedOperator, r: jax.Array, **kw) -> jax.Array:
    """Φ̂† r (conjugate transpose) for real or complex Φ̂; (M,) or batched (B, M)."""
    single = r.ndim == 1
    rb = r[None, :] if single else r
    if not op.is_complex:
        out = qmm(rb.astype(jnp.float32), op.adj_re, **kw)
        return out[0] if single else out
    # Φ† = (Re − j·Im)ᵀ ; (Φ† r) = (Reᵀ r_re + Imᵀ r_im) + j(Reᵀ r_im − Imᵀ r_re)
    rr_ = jnp.real(rb).astype(jnp.float32)
    t1 = qmm(rr_, op.adj_re, **kw)
    t4 = qmm(rr_, op.adj_im, **kw)
    if not jnp.iscomplexobj(r):
        out = jax.lax.complex(t1, -t4)
        return out[0] if single else out
    ri_ = jnp.imag(rb).astype(jnp.float32)
    t2 = qmm(ri_, op.adj_im, **kw)
    t3 = qmm(ri_, op.adj_re, **kw)
    out = jax.lax.complex(t1 + t2, t3 - t4)
    return out[0] if single else out
