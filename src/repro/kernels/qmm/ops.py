"""Jit'd public wrappers around the packed low-precision matmul.

* :func:`pack_weights` — quantize + pack a weight/measurement matrix for qmm.
* :func:`qmm` — padded dispatch: Pallas kernel on TPU, fused blocked
  pipeline (:func:`qmm_fused`) elsewhere.
* :func:`qmm_complex` — complex Φ̂ × real/complex vectors via real matmuls.
* :class:`PackedOperator` / :func:`pack_operator` — both orientations of a CS
  measurement matrix (Φ̂ and Φ̂†), the pair QNIHT streams every iteration;
  ``shared=True`` packs one quantization in both orientations (the
  ``requantize="fixed"`` deployment mode behind ``qniht(backend="packed")``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels.qmm.kernel import qmm_group_pallas, qmm_pallas, select_block_config
from repro.quant.formats import (
    BY_BITS,
    PER_CHANNEL,
    PER_TENSOR,
    Granularity,
    as_granularity,
)
from repro.quant.pack import pack_codes, validate_group_packing
from repro.quant.quantize import expand_block_scale, quantize, quantize_codes


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedWeights:
    """(N, K) weight matrix quantized & packed along K.

    ``scale`` layout follows ``granularity``: (1, N) per-output-channel f32 for
    ``per_tensor``/``per_channel`` (per-tensor broadcasts one value), or
    (N, ⌈K/group_size⌉) blockwise-along-K for ``per_block`` (consumed by the
    group-scaled kernel, which dequantizes inside the contraction).

    Registered pytree: the arrays (``packed``, ``scale``) are children and the
    config (``bits``, ``k_dim``, ``granularity``) is aux data, so packed
    weights — and every operator built from them — cross jit/shard_map
    boundaries as ordinary arguments (e.g. a pre-packed Φ̂ handed to the
    sharded serving loop, :class:`repro.parallel.batch.BatchServer`).
    """

    packed: jax.Array      # (N, packed_len(K)) uint8
    scale: jax.Array       # see granularity note above
    bits: int
    k_dim: int
    granularity: Granularity = PER_TENSOR

    def tree_flatten(self):
        return (self.packed, self.scale), (self.bits, self.k_dim, self.granularity)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        """Packed code bytes only (the precision-proportional stream the paper's
        bandwidth law counts); the f32 scale overhead is ``scale_nbytes``."""
        return self.packed.size  # uint8

    @property
    def scale_nbytes(self) -> int:
        """Bytes of actual scale information at this granularity (per_tensor is
        ONE f32 even though the stored array broadcasts it to (1, N))."""
        return self.granularity.scale_nbytes((self.packed.shape[0], self.k_dim))


def _resolve_granularity(granularity, per_channel: bool) -> Granularity:
    """Map the legacy ``per_channel`` bool and the new ``granularity`` arg onto
    one :class:`Granularity` (an explicit granularity wins)."""
    if granularity is not None:
        return as_granularity(granularity)
    return PER_CHANNEL if per_channel else PER_TENSOR


def pack_weights(
    w: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    per_channel: bool = True,
    granularity: Union[Granularity, str, None] = None,
) -> PackedWeights:
    """Quantize (stochastic if key given) and pack an (N, K) real matrix.

    ``granularity`` (overrides the legacy ``per_channel`` bool when given):
    ``per_tensor`` — one scale; ``per_channel`` — one scale per output row N;
    ``per_block(g)`` — one scale per g contiguous K elements (g a multiple of
    the packing word, see :func:`repro.quant.pack.validate_group_packing`).
    """
    if w.ndim != 2:
        raise ValueError("pack_weights expects (N, K)")
    gran = _resolve_granularity(granularity, per_channel)
    if gran.kind == "per_block":
        validate_group_packing(gran.group_size, bits)
        codes, scale = quantize_codes(w, bits, key, granularity=gran)
        return PackedWeights(
            packed=pack_codes(codes, bits),
            scale=scale.astype(jnp.float32),            # (N, ⌈K/g⌉)
            bits=bits,
            k_dim=w.shape[1],
            granularity=gran,
        )
    if gran.kind == "per_channel":
        codes, scale = quantize_codes(w, bits, key, channel_axis=0)
    else:
        codes, scale = quantize_codes(w, bits, key)
        scale = jnp.full((w.shape[0], 1), scale)
    return PackedWeights(
        packed=pack_codes(codes, bits),
        scale=scale.reshape(1, -1).astype(jnp.float32),
        bits=bits,
        k_dim=w.shape[1],
        granularity=gran,
    )


def qmm(
    x: jax.Array,
    w: PackedWeights,
    *,
    w_t: Optional[PackedWeights] = None,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """y = x @ dequant(w)ᵀ with padding to kernel block multiples.

    ``use_pallas=None`` auto-dispatches: the Mosaic kernel on TPU, the fused
    blocked jnp pipeline (:func:`qmm_fused`) elsewhere (interpret=True forces
    the kernel body on CPU for tests). Group-scaled weights
    (``granularity=per_block``) route to the group kernel, whose K blocks are
    additionally aligned to the scale group size. Block shapes default to
    :func:`select_block_config`'s problem-sized choice; explicit values are
    validated strictly (misalignment or pure-padding tiles raise).

    ``w_t`` optionally carries the same quantization packed in the transposed
    orientation (``pack_operator(shared=True)`` stores the pair anyway); the
    fused CPU path uses it to run batched calls as canonical-layout gemms.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if not use_pallas:
        return qmm_fused(x, w, w_t)
    m, k = x.shape
    n = w.packed.shape[0]
    if w.granularity.kind == "per_block":
        return _qmm_group(x, w, interpret, block_m, block_n, block_k)

    vpb = BY_BITS[w.bits].values_per_byte
    bm, bn, bk = select_block_config(m, n, w.k_dim, w.bits,
                                     block_m=block_m, block_n=block_n,
                                     block_k=block_k)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(w.k_dim, bk)
    x_p = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    packed_k = kp // vpb
    w_p = jnp.pad(w.packed, ((0, np_ - n), (0, packed_k - w.packed.shape[1])),
                  constant_values=_zero_byte(w.bits))
    s_p = jnp.pad(w.scale, ((0, 0), (0, np_ - n)))
    y = qmm_pallas(x_p, w_p, s_p, bits=w.bits, k_dim=kp,
                   block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return y[:m, :n]


def _qmm_group(x, w: PackedWeights, interpret, block_m, block_n, block_k):
    """Group-scaled qmm dispatch: pad to blocks whose K size the scale groups
    tile exactly (padded codes are biased-zero, padded scale groups are 1.0 —
    both contribute nothing to the sliced-out output)."""
    g = w.granularity.group_size
    m, k = x.shape
    n = w.packed.shape[0]
    vpb = BY_BITS[w.bits].values_per_byte
    bm, bn, bk = select_block_config(m, n, w.k_dim, w.bits, group_size=g,
                                     block_m=block_m, block_n=block_n,
                                     block_k=block_k)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(w.k_dim, bk)
    x_p = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    w_p = jnp.pad(w.packed, ((0, np_ - n), (0, kp // vpb - w.packed.shape[1])),
                  constant_values=_zero_byte(w.bits))
    s_p = jnp.pad(w.scale, ((0, np_ - n), (0, kp // g - w.scale.shape[1])),
                  constant_values=1.0)
    y = qmm_group_pallas(x_p, w_p, s_p, bits=w.bits, k_dim=kp, group_size=g,
                         block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return y[:m, :n]


# ---------------------------------------------------------------------------
# Fused blocked pipeline for backends without Mosaic (CPU/GPU fallback).
#
# Two XLA:CPU pathologies make the naive oracle (unpack whole matrix → dot
# against wᵀ) slow: (a) a full-matrix uint8→f32 convert is write-bound on the
# (N, K) f32 temporary; (b) any matmul that is not a plain gemv / canonical
# row-major gemm falls off the fast library path (an `x @ w.T` transpose is a
# physical copy of Φ per application, ~100× at serving shapes). The fused
# path streams the *packed* codes block-by-block, unpacking each tile into a
# cache-resident f32 buffer, so the bytes that move from memory are the
# packed codes — the paper's bandwidth law. Three formulations, chosen
# statically from the problem shape:
#
# * M == 1  — multiply+reduce over N blocks (the only matvec formulation
#   XLA:CPU keeps vectorized when the matrix operand is an internal value).
# * M > 1 with shared transposed codes (``w_t``) — the batch-serving fast
#   path: the *other* orientation's packed array is the weight matrix already
#   transposed in memory, so each K-slab unpacks into a canonical row-major
#   (bk, N) tile and the contraction is an ordinary gemm accumulation. One
#   codes-stream serves all B rows per call.
# * M > 1 without ``w_t`` — minor×minor dot per N block (no transposes).
# ---------------------------------------------------------------------------

_FUSED_TILE_BYTES = 1 << 20    # target f32 dequant-tile footprint (cache-resident)


def _fused_tile_rows(rows: int, row_values: int) -> int:
    """Largest power-of-two row block whose f32 tile fits the target bytes."""
    cap = max(1, _FUSED_TILE_BYTES // max(4 * row_values, 1))
    b = 1
    while b * 2 <= cap:
        b *= 2
    return min(b, rows)


def _unpack_parts_f32(packed: jax.Array, bits: int) -> list[jax.Array]:
    """uint8 (..., Kp) → vpb arrays of f32 unit-scale codes, part-major.

    ``parts[i][..., j]`` is code ``j·vpb + i``; callers either interleave the
    parts (stack on a minor axis) or slice their x operand with the same
    stride so no interleave copy is needed."""
    fmt = BY_BITS[bits]
    k_half = jnp.float32(fmt.half_steps)
    if fmt.values_per_byte == 1:
        return [packed.astype(jnp.float32) - k_half]
    mask = jnp.uint8((1 << bits) - 1)
    return [((packed >> jnp.uint8(bits * i)) & mask).astype(jnp.float32) - k_half
            for i in range(fmt.values_per_byte)]


def _unpack_interleaved_f32(packed: jax.Array, bits: int) -> jax.Array:
    """uint8 (..., Kp) → (..., Kp·vpb) f32 unit-scale codes in storage order."""
    parts = _unpack_parts_f32(packed, bits)
    if len(parts) == 1:
        return parts[0]
    return jnp.stack(parts, axis=-1).reshape(
        *packed.shape[:-1], packed.shape[-1] * len(parts))


def _x_parts(x32: jax.Array, vpb: int, kp: int) -> list[jax.Array]:
    """Slice x (M, K) into the per-part operands matching _unpack_parts_f32:
    part i pairs with x columns i, i+vpb, …, zero-padded to length kp."""
    if vpb == 1:
        return [x32]
    m = x32.shape[0]
    return [jnp.pad(x32[:, i::vpb], ((0, 0), (0, kp - x32[:, i::vpb].shape[1])))
            for i in range(vpb)]


def _fused_matvec(x32: jax.Array, packed: jax.Array, bits: int, n: int) -> jax.Array:
    """M == 1: multiply+reduce over N blocks. Returns unit-scale (1, N)."""
    n_rows, kp = packed.shape
    vpb = BY_BITS[bits].values_per_byte
    xs = [xp[0] for xp in _x_parts(x32, vpb, kp)]
    bn = _fused_tile_rows(n_rows, kp * vpb)
    nb = _round_up(n_rows, bn) // bn
    if nb * bn != n_rows:
        packed = jnp.pad(packed, ((0, nb * bn - n_rows), (0, 0)),
                         constant_values=_zero_byte(bits))

    def block_y(p_blk):
        parts = _unpack_parts_f32(p_blk, bits)
        acc = jnp.sum(parts[0] * xs[0], axis=-1)
        for part, xv in zip(parts[1:], xs[1:]):
            acc = acc + jnp.sum(part * xv, axis=-1)
        return acc

    if nb == 1:
        return block_y(packed).reshape(1, nb * bn)[:, :n]
    _, ys = jax.lax.scan(lambda c, p_blk: (c, block_y(p_blk)), None,
                         packed.reshape(nb, bn, kp))
    return ys.reshape(1, nb * bn)[:, :n]


def _fused_batch_minor(x32: jax.Array, packed: jax.Array, bits: int, n: int) -> jax.Array:
    """M > 1, no transposed codes: minor×minor dot per N block. Unit scale."""
    m = x32.shape[0]
    n_rows, kp = packed.shape
    vpb = BY_BITS[bits].values_per_byte
    xps = _x_parts(x32, vpb, kp)
    bn = _fused_tile_rows(n_rows, kp * vpb)
    nb = _round_up(n_rows, bn) // bn
    if nb * bn != n_rows:
        packed = jnp.pad(packed, ((0, nb * bn - n_rows), (0, 0)),
                         constant_values=_zero_byte(bits))

    def block_y(p_blk):
        parts = _unpack_parts_f32(p_blk, bits)
        acc = jax.lax.dot_general(xps[0], parts[0], (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        for part, xp in zip(parts[1:], xps[1:]):
            acc = acc + jax.lax.dot_general(xp, part, (((1,), (1,)), ((), ())),
                                            preferred_element_type=jnp.float32)
        return acc

    if nb == 1:
        return block_y(packed)[:, :n]
    _, ys = jax.lax.scan(lambda c, p_blk: (c, block_y(p_blk)), None,
                         packed.reshape(nb, bn, kp))
    return jnp.moveaxis(ys, 0, 1).reshape(m, nb * bn)[:, :n]


def _fused_batch_canonical(x32: jax.Array, w_t: PackedWeights, n: int) -> jax.Array:
    """M > 1 with shared codes: ``w_t`` stores wᵀ's bytes, so each row slab
    unpacks straight into a canonical (bk, N) tile — gemm accumulation over
    K slabs, one packed stream amortized across the whole batch. Unit scale."""
    m, k = x32.shape
    k_rows, np_bytes = w_t.packed.shape
    bits = w_t.bits
    vpb = BY_BITS[bits].values_per_byte
    bk = _fused_tile_rows(k_rows, np_bytes * vpb)
    nbk = _round_up(k_rows, bk) // bk
    packed = w_t.packed
    if nbk * bk != k_rows:
        # padded K rows pair with zero-padded x columns: no contribution
        packed = jnp.pad(packed, ((0, nbk * bk - k_rows), (0, 0)),
                         constant_values=_zero_byte(bits))
        x32 = jnp.pad(x32, ((0, 0), (0, nbk * bk - k)))

    def tile(p_blk):
        return _unpack_interleaved_f32(p_blk, bits)     # (bk, N_padded) canonical

    if nbk == 1:
        return jax.lax.dot_general(x32, tile(packed), (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)[:, :n]
    x_blocks = jnp.moveaxis(x32.reshape(m, nbk, bk), 1, 0)  # (nbk, m, bk)

    def step(acc, blk):
        p_blk, x_blk = blk
        return acc + jax.lax.dot_general(x_blk, tile(p_blk), (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32), None

    acc0 = jnp.zeros((m, np_bytes * vpb), jnp.float32)
    y, _ = jax.lax.scan(step, acc0, (packed.reshape(nbk, bk, np_bytes), x_blocks))
    return y[:, :n]


def _fused_per_block(x32: jax.Array, w: PackedWeights) -> jax.Array:
    """Group-scaled fused path: the scale varies along K, so each tile is
    dequantized in full (codes × expanded scale) before its dot."""
    m, k = x32.shape
    n, kp = w.packed.shape
    g = w.granularity.group_size
    inv_half = 1.0 / BY_BITS[w.bits].half_steps
    bn = _fused_tile_rows(n, kp * BY_BITS[w.bits].values_per_byte)
    nb = _round_up(n, bn) // bn
    packed, scale = w.packed, w.scale
    if nb * bn != n:
        packed = jnp.pad(packed, ((0, nb * bn - n), (0, 0)),
                         constant_values=_zero_byte(w.bits))
        scale = jnp.pad(scale, ((0, nb * bn - n), (0, 0)), constant_values=1.0)

    def block_y(p_blk, s_blk):
        wt = (_unpack_interleaved_f32(p_blk, w.bits)[:, :k]
              * (expand_block_scale(s_blk, g, k) * inv_half))
        return jax.lax.dot_general(x32, wt, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    if nb == 1:
        return block_y(packed, scale)[:, :n]
    _, ys = jax.lax.scan(lambda c, blk: (c, block_y(*blk)), None,
                         (packed.reshape(nb, bn, kp),
                          scale.reshape(nb, bn, scale.shape[1])))
    return jnp.moveaxis(ys, 0, 1).reshape(m, nb * bn)[:, :n]


def qmm_fused(
    x: jax.Array,
    w: PackedWeights,
    w_t: Optional[PackedWeights] = None,
) -> jax.Array:
    """Fused unpack→dequant→dot on cache-resident tiles. Returns (M, N) f32.

    ``w_t``, when given, must hold the SAME quantization's codes packed in the
    transposed orientation (``pack_operator(shared=True)`` stores exactly that
    pair); it unlocks the canonical-layout batch gemm. Handles every scale
    granularity and batched x — B rows share one pass over the packed codes,
    which is what amortizes the stream across a batch."""
    m, k = x.shape
    n = w.packed.shape[0]
    if k != w.k_dim:
        raise ValueError(f"x K dim {k} != packed k_dim {w.k_dim}")
    x32 = x.astype(jnp.float32)
    if w.granularity.kind == "per_block":
        return _fused_per_block(x32, w)
    if m == 1:
        y = _fused_matvec(x32, w.packed, w.bits, n)
    elif w_t is not None and w.granularity.is_per_tensor:
        y = _fused_batch_canonical(x32, w_t, n)
    else:
        y = _fused_batch_minor(x32, w.packed, w.bits, n)
    return y * (w.scale.reshape(1, -1) / BY_BITS[w.bits].half_steps)


def _zero_byte(bits: int) -> int:
    """uint8 word whose every packed code is 0 (biased representation of 0)."""
    fmt = BY_BITS[bits]
    k = fmt.half_steps
    word = 0
    for i in range(fmt.values_per_byte):
        word |= k << (bits * i)
    return word


class PackedOperator(NamedTuple):
    """A quantized CS measurement matrix in both orientations.

    ``fwd``  computes Φ̂ x  (stores Φ̂ as (M, N) packed along N),
    ``adj``  computes Φ̂† r (stores Φ̂ᵀ* as (N, M) packed along M).
    Complex matrices store stacked real/imag parts with a leading axis of 2.
    """

    fwd_re: PackedWeights
    fwd_im: Optional[PackedWeights]
    adj_re: PackedWeights
    adj_im: Optional[PackedWeights]

    @property
    def is_complex(self) -> bool:
        return self.fwd_im is not None

    @property
    def nbytes(self) -> int:
        total = self.fwd_re.nbytes + self.adj_re.nbytes
        if self.is_complex:
            total += self.fwd_im.nbytes + self.adj_im.nbytes
        return total

    @property
    def scale_nbytes(self) -> int:
        """f32 scale bytes riding alongside the packed codes (the documented
        per-block overhead; per-tensor/per-channel carry (1, N) as before)."""
        total = self.fwd_re.scale_nbytes + self.adj_re.scale_nbytes
        if self.is_complex:
            total += self.fwd_im.scale_nbytes + self.adj_im.scale_nbytes
        return total


def _pack_from_codes(codes: jax.Array, scale: jax.Array, bits: int) -> PackedWeights:
    """Build PackedWeights from pre-quantized (N, K) int codes + scalar scale."""
    return PackedWeights(
        packed=pack_codes(codes, bits),
        scale=jnp.full((1, codes.shape[0]), scale, jnp.float32),
        bits=bits,
        k_dim=codes.shape[1],
    )


def pack_operator(
    phi: jax.Array,
    bits: int,
    key: Optional[jax.Array] = None,
    per_channel: bool = False,
    shared: bool = False,
    granularity: Union[Granularity, str, None] = None,
) -> PackedOperator:
    """Quantize a dense (M, N) measurement matrix for streaming IHT.

    Per-tensor scale by default (faithful to the paper's single c_Φ);
    ``granularity`` selects per_channel / per_block(g) scaling instead
    (overriding the legacy ``per_channel`` bool).

    ``shared=False`` draws an *independent* quantization for each orientation
    (Algorithm 1's Φ̂_{2n-1}/Φ̂_{2n} pairing, unbiased in expectation with a
    key). ``shared=True`` quantizes **once** — the same codes back both Φ̂ and
    Φ̂†, which is what a deployed ``requantize="fixed"`` system streaming
    pre-quantized data does, and makes the adjoint identity ⟨Φ̂x, r⟩ = ⟨x, Φ̂†r⟩
    exact. Shared codes match ``fake_quantize(phi, bits, key)`` bit-for-bit.

    Sharing is only possible with ONE scale per tensor: a per-channel or
    per-block scale is tied to an orientation's own row/contraction axis, so
    the transposed orientation cannot reuse the codes (its scale groups run
    across the other axis). Per-orientation scales therefore require
    ``shared=False``.
    """
    gran = _resolve_granularity(granularity, per_channel)
    if shared and not gran.is_per_tensor:
        raise ValueError(
            f"pack_operator(shared=True) streams ONE per-tensor quantization "
            f"through both orientations; a {gran} scale is tied to each "
            f"orientation's own axes, so shared codes cannot carry it. Pass "
            f"shared=False (per-orientation quantization, adjoint identity "
            f"approximate) or granularity='per_tensor' (exact shared codes).")
    if shared:
        q = quantize(phi, bits, key)
        if q.is_complex:
            cre, cim = q.codes[0], q.codes[1]
            return PackedOperator(
                fwd_re=_pack_from_codes(cre, q.scale, bits),
                fwd_im=_pack_from_codes(cim, q.scale, bits),
                adj_re=_pack_from_codes(cre.T, q.scale, bits),
                adj_im=_pack_from_codes(cim.T, q.scale, bits),
            )
        return PackedOperator(
            fwd_re=_pack_from_codes(q.codes, q.scale, bits),
            fwd_im=None,
            adj_re=_pack_from_codes(q.codes.T, q.scale, bits),
            adj_im=None,
        )
    if jnp.iscomplexobj(phi):
        re, im = jnp.real(phi), jnp.imag(phi)
        keys = jax.random.split(key, 4) if key is not None else [None] * 4
        return PackedOperator(
            fwd_re=pack_weights(re, bits, keys[0], granularity=gran),
            fwd_im=pack_weights(im, bits, keys[1], granularity=gran),
            adj_re=pack_weights(re.T, bits, keys[2], granularity=gran),
            adj_im=pack_weights(im.T, bits, keys[3], granularity=gran),
        )
    keys = jax.random.split(key, 2) if key is not None else [None, None]
    return PackedOperator(
        fwd_re=pack_weights(phi, bits, keys[0], granularity=gran),
        fwd_im=None,
        adj_re=pack_weights(phi.T, bits, keys[1], granularity=gran),
        adj_im=None,
    )


def packed_matvec(op: PackedOperator, x: jax.Array, shared: bool = False, **kw) -> jax.Array:
    """Φ̂ x for real or complex Φ̂ (x may be real or complex).

    ``x`` is a single vector (N,) or a batch (B, N); a batch is served by ONE
    kernel invocation per real matmul, amortizing the packed Φ̂ stream over B.
    ``shared=True`` asserts the operator was built with
    ``pack_operator(shared=True)`` (adjoint bytes == forward bytes transposed),
    letting batched calls borrow the other orientation as a pre-transposed
    canonical layout. Never pass it for independently quantized orientations.
    """
    single = x.ndim == 1
    xb = x[None, :] if single else x
    if not op.is_complex:
        out = qmm(xb.astype(jnp.float32), op.fwd_re,
                  w_t=op.adj_re if shared else None, **kw)
        return out[0] if single else out
    xr = jnp.real(xb).astype(jnp.float32)
    rr = qmm(xr, op.fwd_re, w_t=op.adj_re if shared else None, **kw)
    ir = qmm(xr, op.fwd_im, w_t=op.adj_im if shared else None, **kw)
    if not jnp.iscomplexobj(x):
        # real input (e.g. a real sky through complex Φ̂): the imaginary-part
        # products are identically zero — skip their kernel calls so the packed
        # matrices stream once, not twice.
        out = jax.lax.complex(rr, ir)
        return out[0] if single else out
    xi = jnp.imag(xb).astype(jnp.float32)
    ri = qmm(xi, op.fwd_re, w_t=op.adj_re if shared else None, **kw)
    ii = qmm(xi, op.fwd_im, w_t=op.adj_im if shared else None, **kw)
    out = jax.lax.complex(rr - ii, ri + ir)
    return out[0] if single else out


def packed_rmatvec(op: PackedOperator, r: jax.Array, shared: bool = False, **kw) -> jax.Array:
    """Φ̂† r (conjugate transpose) for real or complex Φ̂; (M,) or batched (B, M).

    ``shared`` as in :func:`packed_matvec` (here the *forward* bytes serve as
    the adjoint's pre-transposed canonical layout)."""
    single = r.ndim == 1
    rb = r[None, :] if single else r
    if not op.is_complex:
        out = qmm(rb.astype(jnp.float32), op.adj_re,
                  w_t=op.fwd_re if shared else None, **kw)
        return out[0] if single else out
    # Φ† = (Re − j·Im)ᵀ ; (Φ† r) = (Reᵀ r_re + Imᵀ r_im) + j(Reᵀ r_im − Imᵀ r_re)
    rr_ = jnp.real(rb).astype(jnp.float32)
    t1 = qmm(rr_, op.adj_re, w_t=op.fwd_re if shared else None, **kw)
    t4 = qmm(rr_, op.adj_im, w_t=op.fwd_im if shared else None, **kw)
    if not jnp.iscomplexobj(r):
        out = jax.lax.complex(t1, -t4)
        return out[0] if single else out
    ri_ = jnp.imag(rb).astype(jnp.float32)
    t2 = qmm(ri_, op.adj_im, w_t=op.fwd_im if shared else None, **kw)
    t3 = qmm(ri_, op.adj_re, w_t=op.fwd_re if shared else None, **kw)
    out = jax.lax.complex(t1 + t2, t3 - t4)
    return out[0] if single else out
