"""Pallas TPU kernel: packed low-precision matmul (the paper's hot spot).

TPU adaptation of the paper's AVX2/FPGA low-precision dot-product engines: the
*packed* integer codes are what stream HBM→VMEM (4–16× fewer bytes than f32),
unpacking is shift/mask arithmetic on VMEM-resident vregs, and the MXU does the
f32-accumulated matmul on 128-aligned tiles. Performance is therefore bound by
``size(Φ̂)/BW_HBM`` — the same precision-proportional law as the paper's
``T = size(Φ)/P`` on FPGA (supplementary §8.1).

Layout contract (see ref.py): ``y[m, n] = Σ_k x[m, k] · ŵ[n, k]`` with ``ŵ``
packed along K (minor-most axis → contiguous packed words).

Grid: ``(M/bm, N/bn, K/bk)``; K is the fastest-varying (sequential on TPU), and
the output block (bm, bn) is revisited across the K steps and accumulated in
place (initialized at k==0). Block shapes default to MXU-aligned
``bm=128, bn=128, bk=512`` (packed K-block = bk/vpb bytes per row).

Two scale layouts, two kernels:

* :func:`qmm_pallas`       — one scale per output channel, shape (1, N): the
  dot runs on unit-scale codes and the scale multiplies the *accumulated*
  (bm, bn) block (cheapest; per_tensor is the broadcast special case).
* :func:`qmm_group_pallas` — blockwise scales along the contraction axis,
  shape (N, K/g): each K-tile loads its (bn, bk/g) scale slab alongside the
  packed codes and applies it to the codes *before* the dot (the scale varies
  within the contraction, so it cannot be factored out of the accumulator).
  ``g`` must divide ``block_k`` so scale slabs tile cleanly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.formats import BY_BITS


def _unpack_block(w_packed_blk: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(bn, bk/vpb) uint8 → (bn, bk) f32 codes (unit scale, in [-K, K])."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    k = fmt.half_steps
    w32 = w_packed_blk.astype(jnp.int32)
    if vpb == 1:
        codes = w32
    else:
        mask = (1 << bits) - 1
        parts = [(w32 >> (bits * i)) & mask for i in range(vpb)]
        # parts[i] holds code (j*vpb + i): interleave on a new minor axis.
        codes = jnp.stack(parts, axis=-1).reshape(w32.shape[0], w32.shape[1] * vpb)
    return (codes - k).astype(jnp.float32)


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref, *, bits: int, n_k_steps: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_blk = x_ref[...].astype(jnp.float32)              # (bm, bk)
    codes = _unpack_block(w_ref[...], bits)             # (bn, bk) unit-scale codes
    # contract over k: (bm, bk) x (bn, bk) -> (bm, bn)
    acc = jax.lax.dot_general(
        x_blk, codes, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += acc * (scale_ref[...] / BY_BITS[bits].half_steps)  # (1, bn) bcast


def _qmm_group_kernel(x_ref, w_ref, scale_ref, o_ref, *, bits: int, group_size: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_blk = x_ref[...].astype(jnp.float32)              # (bm, bk)
    codes = _unpack_block(w_ref[...], bits)             # (bn, bk) unit-scale codes
    scales = scale_ref[...]                             # (bn, bk/g)
    bn, bkg = scales.shape
    # dequantize in-register: code (n, j) scales with scales[n, j // g]
    w_blk = (codes.reshape(bn, bkg, group_size) * scales[:, :, None]
             ).reshape(bn, bkg * group_size) * (1.0 / BY_BITS[bits].half_steps)
    acc = jax.lax.dot_general(
        x_blk, w_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k_dim", "group_size", "block_m", "block_n", "block_k",
                     "interpret"),
)
def qmm_group_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    k_dim: int,
    group_size: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Group-scaled packed matmul. Shapes must be pre-padded to block multiples:
    x (M, K), w_packed (N, K/vpb) uint8, scale (N, K/g) f32. Returns (M, N) f32."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    m, k = x.shape
    n = w_packed.shape[0]
    if k != k_dim:
        raise ValueError(f"x K dim {k} != k_dim {k_dim}")
    if k % block_k or m % block_m or n % block_n:
        raise ValueError(f"shapes ({m},{k}),({n}) must be multiples of blocks "
                         f"({block_m},{block_n},{block_k}); pad in ops.py")
    if block_k % group_size:
        raise ValueError(f"group_size {group_size} must divide block_k {block_k}")
    if w_packed.shape[1] * vpb != k:
        raise ValueError("w_packed minor dim inconsistent with k_dim/bits")
    if scale.shape != (n, k // group_size):
        raise ValueError(f"scale shape {scale.shape} != (N, K/g) = ({n}, {k // group_size})")
    bk_packed = block_k // vpb
    bk_groups = block_k // group_size
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_qmm_group_kernel, bits=bits, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, bk_packed), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, bk_groups), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_packed, scale)


@functools.partial(
    jax.jit, static_argnames=("bits", "k_dim", "block_m", "block_n", "block_k", "interpret")
)
def qmm_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    k_dim: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Packed low-precision matmul. Shapes must be pre-padded to block multiples:
    x (M, K), w_packed (N, K/vpb) uint8, scale (1, N). Returns (M, N) f32."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    m, k = x.shape
    n = w_packed.shape[0]
    if k != k_dim:
        raise ValueError(f"x K dim {k} != k_dim {k_dim}")
    if k % block_k or m % block_m or n % block_n:
        raise ValueError(f"shapes ({m},{k}),({n}) must be multiples of blocks "
                         f"({block_m},{block_n},{block_k}); pad in ops.py")
    if w_packed.shape[1] * vpb != k:
        raise ValueError("w_packed minor dim inconsistent with k_dim/bits")
    bk_packed = block_k // vpb
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits, n_k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, bk_packed), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_packed, scale)
