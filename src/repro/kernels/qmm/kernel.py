"""Pallas TPU kernel: packed low-precision matmul (the paper's hot spot).

TPU adaptation of the paper's AVX2/FPGA low-precision dot-product engines: the
*packed* integer codes are what stream HBM→VMEM (4–16× fewer bytes than f32),
unpacking is shift/mask arithmetic on VMEM-resident vregs, and the MXU does the
f32-accumulated matmul on 128-aligned tiles. Performance is therefore bound by
``size(Φ̂)/BW_HBM`` — the same precision-proportional law as the paper's
``T = size(Φ)/P`` on FPGA (supplementary §8.1).

Layout contract (see ref.py): ``y[m, n] = Σ_k x[m, k] · ŵ[n, k]`` with ``ŵ``
packed along K (minor-most axis → contiguous packed words).

Grid: ``(M/bm, N/bn, K/bk)``; K is the fastest-varying (sequential on TPU), and
the output block (bm, bn) is revisited across the K steps and accumulated in
place (initialized at k==0). Block shapes are chosen by
:func:`select_block_config`: MXU-aligned ``bm=128, bn=128, bk=512`` for large
problems, clamped down to the aligned problem size for small ones so tiny
shapes (the recovery benchmarks run m=64, n=128) are not dwarfed by padding.
Explicitly passed block shapes are validated strictly — misalignment or a
block that pads the problem more than the hardware minima require raises
instead of silently burning bandwidth on padding.

Two scale layouts, two kernels:

* :func:`qmm_pallas`       — one scale per output channel, shape (1, N): the
  dot runs on unit-scale codes and the scale multiplies the *accumulated*
  (bm, bn) block (cheapest; per_tensor is the broadcast special case).
* :func:`qmm_group_pallas` — blockwise scales along the contraction axis,
  shape (N, K/g): each K-tile loads its (bn, bk/g) scale slab alongside the
  packed codes and applies it to the codes *before* the dot (the scale varies
  within the contraction, so it cannot be factored out of the accumulator).
  ``g`` must divide ``block_k`` so scale slabs tile cleanly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.formats import BY_BITS

# Hardware minima the tiles must respect regardless of problem size: 8
# sublanes (M), 128 lanes (N), and a K step that is whole packed bytes on
# 128 lanes (128·vpb values). Defaults below are the MXU sweet spot for
# large problems; select_block_config clamps them to the aligned problem.
_MIN_BM = 8
_MIN_BN = 128
_DEFAULT_BM = 128
_DEFAULT_BN = 128
_DEFAULT_BK = 512


def _round_up(value: int, multiple: int) -> int:
    return -(-value // multiple) * multiple


def select_block_config(
    m: int,
    n: int,
    k_dim: int,
    bits: int,
    *,
    group_size: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> tuple[int, int, int]:
    """Choose (bm, bn, bk) for a packed matmul of logical shape (m, k)×(n, k).

    Auto mode (a block dim left ``None``): start from the MXU defaults and
    clamp each tile to the problem dimension rounded up to its hardware
    minimum, so small problems (the fig5 bench runs m=64, n=128) pay only the
    unavoidable alignment padding instead of a full 128×128×512 tile.

    Explicit mode (a block dim passed): validate strictly — misaligned blocks,
    ``g ∤ bk``, or a block that exceeds the aligned problem size (pure padding)
    raise ``ValueError`` instead of silently blowing up the padded footprint.
    """
    k_unit = 128 * BY_BITS[bits].values_per_byte
    if group_size is not None:
        k_unit = math.lcm(k_unit, group_size)

    m_cap = _round_up(max(m, 1), _MIN_BM)
    n_cap = _round_up(max(n, 1), _MIN_BN)
    k_cap = _round_up(max(k_dim, 1), k_unit)

    if block_m is None:
        bm = min(_DEFAULT_BM, m_cap)
    else:
        bm = block_m
        if bm % _MIN_BM:
            raise ValueError(f"block_m={bm} must be a multiple of {_MIN_BM}")
        if bm > m_cap:
            raise ValueError(
                f"block_m={bm} exceeds aligned problem size {m_cap} (m={m}): "
                "the tile would be mostly padding; shrink it or leave it unset"
            )
    if block_n is None:
        bn = min(_DEFAULT_BN, n_cap)
    else:
        bn = block_n
        if bn % _MIN_BN:
            raise ValueError(f"block_n={bn} must be a multiple of {_MIN_BN}")
        if bn > n_cap:
            raise ValueError(
                f"block_n={bn} exceeds aligned problem size {n_cap} (n={n}): "
                "the tile would be mostly padding; shrink it or leave it unset"
            )
    if block_k is None:
        bk = min(_round_up(_DEFAULT_BK, k_unit), k_cap)
    else:
        bk = block_k
        if bk % k_unit:
            raise ValueError(
                f"block_k={bk} must be a multiple of {k_unit} "
                f"(128 lanes × values/byte at {bits} bits"
                + (f", lcm group_size={group_size}" if group_size else "")
                + ")"
            )
        if bk > k_cap:
            raise ValueError(
                f"block_k={bk} exceeds aligned problem size {k_cap} (k={k_dim}): "
                "the tile would be mostly padding; shrink it or leave it unset"
            )
    return bm, bn, bk


def _unpack_block(w_packed_blk: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(bn, bk/vpb) uint8 → (bn, bk) f32 codes (unit scale, in [-K, K])."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    k = fmt.half_steps
    w32 = w_packed_blk.astype(jnp.int32)
    if vpb == 1:
        codes = w32
    else:
        mask = (1 << bits) - 1
        parts = [(w32 >> (bits * i)) & mask for i in range(vpb)]
        # parts[i] holds code (j*vpb + i): interleave on a new minor axis.
        codes = jnp.stack(parts, axis=-1).reshape(w32.shape[0], w32.shape[1] * vpb)
    return (codes - k).astype(jnp.float32)


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref, *, bits: int, n_k_steps: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_blk = x_ref[...].astype(jnp.float32)              # (bm, bk)
    codes = _unpack_block(w_ref[...], bits)             # (bn, bk) unit-scale codes
    # contract over k: (bm, bk) x (bn, bk) -> (bm, bn)
    acc = jax.lax.dot_general(
        x_blk, codes, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += acc * (scale_ref[...] / BY_BITS[bits].half_steps)  # (1, bn) bcast


def _qmm_group_kernel(x_ref, w_ref, scale_ref, o_ref, *, bits: int, group_size: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_blk = x_ref[...].astype(jnp.float32)              # (bm, bk)
    codes = _unpack_block(w_ref[...], bits)             # (bn, bk) unit-scale codes
    scales = scale_ref[...]                             # (bn, bk/g)
    bn, bkg = scales.shape
    # dequantize in-register: code (n, j) scales with scales[n, j // g]
    w_blk = (codes.reshape(bn, bkg, group_size) * scales[:, :, None]
             ).reshape(bn, bkg * group_size) * (1.0 / BY_BITS[bits].half_steps)
    acc = jax.lax.dot_general(
        x_blk, w_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("bits", "k_dim", "group_size", "block_m", "block_n", "block_k",
                     "interpret"),
)
def qmm_group_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    k_dim: int,
    group_size: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Group-scaled packed matmul. Shapes must be pre-padded to block multiples:
    x (M, K), w_packed (N, K/vpb) uint8, scale (N, K/g) f32. Returns (M, N) f32."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    m, k = x.shape
    n = w_packed.shape[0]
    if k != k_dim:
        raise ValueError(f"x K dim {k} != k_dim {k_dim}")
    if k % block_k or m % block_m or n % block_n:
        raise ValueError(f"shapes ({m},{k}),({n}) must be multiples of blocks "
                         f"({block_m},{block_n},{block_k}); pad in ops.py")
    if block_k % group_size:
        raise ValueError(f"group_size {group_size} must divide block_k {block_k}")
    if w_packed.shape[1] * vpb != k:
        raise ValueError("w_packed minor dim inconsistent with k_dim/bits")
    if scale.shape != (n, k // group_size):
        raise ValueError(f"scale shape {scale.shape} != (N, K/g) = ({n}, {k // group_size})")
    bk_packed = block_k // vpb
    bk_groups = block_k // group_size
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_qmm_group_kernel, bits=bits, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, bk_packed), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, bk_groups), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_packed, scale)


@functools.partial(
    jax.jit, static_argnames=("bits", "k_dim", "block_m", "block_n", "block_k", "interpret")
)
def qmm_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    k_dim: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Packed low-precision matmul. Shapes must be pre-padded to block multiples:
    x (M, K), w_packed (N, K/vpb) uint8, scale (1, N). Returns (M, N) f32."""
    fmt = BY_BITS[bits]
    vpb = fmt.values_per_byte
    m, k = x.shape
    n = w_packed.shape[0]
    if k != k_dim:
        raise ValueError(f"x K dim {k} != k_dim {k_dim}")
    if k % block_k or m % block_m or n % block_n:
        raise ValueError(f"shapes ({m},{k}),({n}) must be multiples of blocks "
                         f"({block_m},{block_n},{block_k}); pad in ops.py")
    if w_packed.shape[1] * vpb != k:
        raise ValueError("w_packed minor dim inconsistent with k_dim/bits")
    bk_packed = block_k // vpb
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits, n_k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, bk_packed), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_packed, scale)
