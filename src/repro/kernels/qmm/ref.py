"""Pure-jnp oracle for the packed low-precision matmul (qmm).

Contract (shared with the Pallas kernel):

    y = x @ dequant(w)ᵀ

* ``x``        — (M, K) float32/bfloat16 activations,
* ``w_packed`` — (N, packed_len(K, bits)) uint8, codes packed along K
                 (the contraction axis — minor-most, so packed words stream
                 contiguously HBM→VMEM on TPU),
* ``scale``    — (1, N) per-output-channel scale (per-tensor = broadcast), or
                 for the group-scaled variant (N, ⌈K/g⌉) blockwise scales along
                 the contraction axis,
* ``bits``     — 2 / 4 / 8.

Dequantized value of code k is ``scale * k / K_steps`` (see repro.quant.formats);
group-scaled code (n, j) uses ``scale[n, j // g]``. Accumulation is float32.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.formats import BY_BITS
from repro.quant.pack import unpack_codes
from repro.quant.quantize import expand_block_scale


def qmm_ref(x: jnp.ndarray, w_packed: jnp.ndarray, scale: jnp.ndarray, bits: int, k_dim: int) -> jnp.ndarray:
    """Reference packed matmul. Returns (M, N) float32."""
    fmt = BY_BITS[bits]
    codes = unpack_codes(w_packed, bits, k_dim)              # (N, K) int8
    w = codes.astype(jnp.float32) / fmt.half_steps           # (N, K), unit scale
    y = jnp.dot(x.astype(jnp.float32), w.T, preferred_element_type=jnp.float32)
    return y * scale.reshape(1, -1)


def qmm_group_ref(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int,
    k_dim: int,
    group_size: int,
) -> jnp.ndarray:
    """Reference group-scaled packed matmul: scale (N, ⌈K/g⌉). Returns (M, N) f32."""
    fmt = BY_BITS[bits]
    codes = unpack_codes(w_packed, bits, k_dim)              # (N, K) int8
    w = (codes.astype(jnp.float32)
         * expand_block_scale(scale, group_size, k_dim) / fmt.half_steps)
    return jnp.dot(x.astype(jnp.float32), w.T, preferred_element_type=jnp.float32)
