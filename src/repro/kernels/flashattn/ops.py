"""Public attention wrapper with GQA handling and backend dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flashattn.kernel import flash_attention_pallas
from repro.kernels.flashattn.ref import attention_ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    block_q: int = 256,
    block_k: int = 256,
) -> jax.Array:
    """Multi-head attention with GQA.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q.dtype.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    if scale is None:
        scale = 1.0 / (d**0.5)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret

    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, sk, d)
    vf = v.reshape(b * hq, sk, d)
    if not use_pallas:
        out = attention_ref(qf, kf, vf, causal=causal, scale=scale).astype(q.dtype)
        return out.reshape(b, hq, sq, d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, scale=scale, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    return out.reshape(b, hq, sq, d)
