"""Pallas TPU kernel: fused online-softmax attention (flash attention).

Needed because the assigned 32k-prefill shapes make the naive score matrix
(S², per head) unmaterializable; the kernel keeps a (block_q, block_k) tile in
VMEM with running row-max/row-sum statistics in VMEM scratch, MXU-aligned.

Grid: (batch·heads, q_blocks, kv_blocks) — kv innermost (sequential on TPU), so
the scratch accumulators persist across the kv sweep of each q block. Causal
blocks strictly above the diagonal are skipped entirely (`pl.when`); the
diagonal block applies an elementwise mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_k: int, n_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip kv blocks strictly above the q block's last row
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                       # (bq, d)
        k = k_ref[0].astype(jnp.float32)                       # (bk, d)
        v = v_ref[0].astype(jnp.float32)                       # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                              # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                                    # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                                 # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    scale: float,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Sk, D); Sq % block_q == Sk % block_k == 0."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    if sq % block_q or sk % block_k:
        raise ValueError("pad sequence lengths to block multiples in ops.py")
    grid = (bh, sq // block_q, sk // block_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal,
            block_q=block_q, block_k=block_k, n_kv=grid[2],
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
