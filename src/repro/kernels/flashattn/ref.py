"""Pure-jnp oracle for fused attention: plain softmax(QKᵀ)V with optional
causal masking. Small shapes only (materializes the score matrix)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool, scale: float
) -> jnp.ndarray:
    """q,k,v: (BH, S, D) (same S for q and kv in the oracle). Returns (BH, S, D) f32."""
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vf)
