#!/usr/bin/env python
"""Inject the machine-generated roofline table into EXPERIMENTS.md."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import markdown_table

MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    table = markdown_table("experiments/dryrun")
    head, _, _ = text.partition(MARK)
    with open(path, "w") as f:
        f.write(head + MARK + "\n\n" + table + "\n")
    print(f"injected {table.count(chr(10))} table rows")


if __name__ == "__main__":
    main()
