#!/usr/bin/env python
"""Docs gate (scripts/ci.sh docs): snippets execute, links resolve.

* Every fenced ```python block in README.md and docs/*.md is executed as a
  standalone program (fresh namespace, repo root as cwd). Blocks are the
  docs' executable examples — if one breaks, the docs lie. Mark a block
  non-executable by using a different fence language (```text, ```bash, …).
* Every relative markdown link/image target must exist on disk (http(s) and
  #anchors are skipped).

Exit code 0 = all good; prints one line per failure otherwise.
"""
from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

FENCE_RE = re.compile(r"^```(\w*)\s*$")
# [text](target) — excluding images' leading ! only for clarity (same rule)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def python_blocks(text: str):
    """Yield (start_line, source) for each ```python fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1) == "python":
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            yield start + 1, "\n".join(lines[start:j])
            i = j + 1
        else:
            i += 1


def check_links(path: Path, text: str) -> list[str]:
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not (path.parent / rel).resolve().exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    return errors


def run_block(path: Path, line: int, src: str) -> list[str]:
    try:
        code = compile(src, f"{path.name}:{line}", "exec")
        exec(code, {"__name__": "__docs_snippet__"})
        return []
    except Exception:
        tb = traceback.format_exc(limit=3)
        return [f"{path.relative_to(ROOT)}:{line}: snippet failed\n{tb}"]


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    errors: list[str] = []
    n_blocks = 0
    for f in md_files():
        text = f.read_text()
        errors += check_links(f, text)
        for line, src in python_blocks(text):
            n_blocks += 1
            errors += run_block(f, line, src)
    if errors:
        print("\n".join(errors))
        print(f"[check_docs] FAILED ({len(errors)} problem(s))")
        return 1
    print(f"[check_docs] OK: {len(md_files())} files, {n_blocks} snippets "
          "executed, all links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
