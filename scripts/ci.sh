#!/usr/bin/env bash
# Tier-1 test entry point with a quick pre-commit tier.
#
#   scripts/ci.sh          # fast: analyze tier first, then skip @slow tests
#                          # (model-arch compiles, subprocess dry-run / multidevice,
#                          # large-grid MRI acceptance, and the kill/restart
#                          # fault-injection matrix) — <2 min; the in-process
#                          # segment-resume parity smokes (tests/test_resilience.py)
#                          # DO run in this tier
#   scripts/ci.sh fast     # same
#   scripts/ci.sh full     # everything — the driver's tier-1 command; includes the
#                          # @slow SIGTERM kill + --resume subprocess matrix
#                          # (tests/test_fault_injection.py)
#   scripts/ci.sh analyze  # blocking static analysis, both tiers: jaxlint
#                          # (JL001-JL007) over src/tests/benchmarks/examples
#                          # plus the jaxpr IR tier (JX101-JX106) tracing the
#                          # entry-point registry, both against the checked-in
#                          # baseline; fixture self-checks per rule; ruff
#                          # (pinned in pyproject.toml) when installed — see
#                          # docs/static-analysis.md
#   scripts/ci.sh lint     # byte-compile src/tests/benchmarks (+ ruff if installed)
#   scripts/ci.sh docs     # docs gate: README/docs snippets execute, links resolve
#   scripts/ci.sh perf     # perf smoke: benchmarks/kernels_micro.py --perf-smoke
#                          # times the fused packed batched matvec vs dense f32
#                          # on a tiny serving shape and fails if the ratio
#                          # regresses past BENCH_thresholds.json (pinned
#                          # deliberately; see docs/performance.md)
#   scripts/ci.sh sched    # continuous-batching smoke: the bursty
#                          # serve-continuous-smoke trace through --scheduler
#                          # continuous (with --verify: every answer bitwise
#                          # equal to its standalone solve) AND --scheduler
#                          # lockstep; asserts both metrics JSONs carry the
#                          # latency observability fields (p50/p99, items/sec,
#                          # slot occupancy) — see docs/serving.md
#
# Extra args go straight to pytest: scripts/ci.sh fast -k mri
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

analyze() {
  fmt=()
  [ -n "${GITHUB_ACTIONS:-}" ] && fmt=(--format github)  # PR annotations
  # 1. both tiers over the repo against .jaxlint-baseline.json — always
  #    blocking. The jaxpr tier traces the full entry-point registry and
  #    prints per-rule counts; --budget fails the gate if tracing slows past
  #    60 s (it must stay cheap enough to block every PR).
  python -m repro.analysis --tier both --budget 60 "${fmt[@]}"
  # 2. self-check: a rule that silently stopped firing is worse than no rule.
  #    Every bad fixture must still trip (exit 1), every ok twin stay clean.
  for rule in jl001 jl002 jl003 jl004 jl005 jl006 jl007; do
    sub=""; [ "$rule" = jl007 ] && sub="launch/"
    bad="tests/jaxlint_fixtures/${sub}${rule}_bad.py"
    ok="tests/jaxlint_fixtures/${sub}${rule}_ok.py"
    if python -m repro.analysis "$bad" --baseline none >/dev/null 2>&1; then
      echo "[analyze] FIXTURE REGRESSION: $bad no longer trips ${rule^^}" >&2
      exit 1
    fi
    if ! python -m repro.analysis "$ok" --baseline none >/dev/null 2>&1; then
      echo "[analyze] FIXTURE REGRESSION: $ok false-positives" >&2
      exit 1
    fi
  done
  echo "[analyze] fixture self-check ok (7 rules trip on bad, clean on ok)"
  # 3. jaxpr fixture self-check: the deliberately broken registry (one entry
  #    per JX rule, incl. the JX106 broken-adjoint operator) must keep failing
  if python -m repro.analysis --tier jaxpr \
      --registry tests/jaxlint_fixtures/jaxpr_bad.py --baseline none \
      >/dev/null 2>&1; then
    echo "[analyze] FIXTURE REGRESSION: jaxpr_bad.py no longer trips the JX rules" >&2
    exit 1
  fi
  echo "[analyze] jaxpr fixture self-check ok (broken registry trips)"
  # 4. ruff, config pinned in pyproject.toml; advisory-absent, blocking-present
  if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples
  else
    echo "[analyze] ruff not installed; jaxlint + fixture self-check only"
  fi
}

mode="${1:-fast}"
[ $# -gt 0 ] && shift
case "$mode" in
  fast) analyze; exec python -m pytest -x -q -m "not slow" "$@" ;;
  full) analyze; exec python -m pytest -x -q "$@" ;;
  analyze) analyze ;;
  lint)
    python -m compileall -q src tests benchmarks
    if command -v ruff >/dev/null 2>&1; then
      ruff check src tests benchmarks "$@"
    else
      echo "[lint] ruff not installed; compileall only"
    fi
    ;;
  docs) exec python scripts/check_docs.py "$@" ;;
  perf) exec python -m benchmarks.kernels_micro --perf-smoke ;;
  sched)
    tmp="$(mktemp -d)"; trap 'rm -rf "$tmp"' EXIT
    # continuous with the differential contract enforced end to end
    python -m repro.launch.serve --config serve-continuous-smoke \
      --scheduler continuous --verify --metrics-json "$tmp/continuous.json"
    # lockstep baseline: same engine, refill barrier
    python -m repro.launch.serve --config serve-continuous-smoke \
      --scheduler lockstep --metrics-json "$tmp/lockstep.json"
    python - "$tmp" <<'PY'
import json, sys
for policy in ("continuous", "lockstep"):
    with open(f"{sys.argv[1]}/{policy}.json") as f:
        m = json.load(f)
    for field in ("latency_p50_s", "latency_p99_s", "items_per_s",
                  "slot_occupancy", "queue_wait_ticks_mean"):
        assert m.get(field) is not None, f"{policy}: missing {field}"
    assert m["scheduler"] == policy and m["completed"] == m["requests"]
print("[sched] smoke ok: parity verified, latency fields present in both "
      "metrics JSONs")
PY
    ;;
  *) echo "usage: scripts/ci.sh [fast|full|analyze|lint|docs|perf|sched] [pytest args...]" >&2; exit 2 ;;
esac
