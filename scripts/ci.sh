#!/usr/bin/env bash
# Tier-1 test entry point with a quick pre-commit tier.
#
#   scripts/ci.sh        # fast: skip @slow tests (model-arch compiles, subprocess
#                        # dry-run / multidevice, large-grid MRI acceptance, and the
#                        # kill/restart fault-injection matrix) — <2 min; the
#                        # in-process segment-resume parity smokes
#                        # (tests/test_resilience.py) DO run in this tier
#   scripts/ci.sh fast   # same
#   scripts/ci.sh full   # everything — the driver's tier-1 command; includes the
#                        # @slow SIGTERM kill + --resume subprocess matrix
#                        # (tests/test_fault_injection.py)
#   scripts/ci.sh lint   # byte-compile src/tests/benchmarks (+ ruff if installed)
#   scripts/ci.sh docs   # docs gate: README/docs snippets execute, links resolve
#
# Extra args go straight to pytest: scripts/ci.sh fast -k mri
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mode="${1:-fast}"
[ $# -gt 0 ] && shift
case "$mode" in
  fast) exec python -m pytest -x -q -m "not slow" "$@" ;;
  full) exec python -m pytest -x -q "$@" ;;
  lint)
    python -m compileall -q src tests benchmarks
    if command -v ruff >/dev/null 2>&1; then
      ruff check src tests benchmarks "$@"
    else
      echo "[lint] ruff not installed; compileall only"
    fi
    ;;
  docs) exec python scripts/check_docs.py "$@" ;;
  *) echo "usage: scripts/ci.sh [fast|full|lint|docs] [pytest args...]" >&2; exit 2 ;;
esac
