#!/usr/bin/env python
"""Sweep driver: run every (arch × shape × mesh) dry-run cell as its own
subprocess (bounded parallelism, per-cell timeout), writing JSON per cell.

    python scripts/run_dryruns.py --out experiments/dryrun --jobs 3
"""
import argparse
import itertools
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCHS = [
    "qwen1_5_32b", "starcoder2_3b", "minitron_4b", "stablelm_12b",
    "mamba2_370m", "whisper_tiny", "recurrentgemma_2b", "llama32_vision_11b",
    "qwen3_moe_30b", "qwen3_moe_235b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_cell(arch, shape, multi, out, timeout, extra):
    tag = f"{arch}.{shape}.{'multi' if multi else 'single'}"
    done_marker = os.path.join(out, f"{tag}.fp.json")
    if os.path.exists(done_marker):
        import json

        with open(done_marker) as f:
            st = json.load(f).get("status")
        if st in ("ok", "skipped"):
            return tag, "cached-" + st, 0.0
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out] + (["--multi-pod"] if multi else []) + extra
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    t0 = time.time()
    try:
        res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=timeout)
        ok = "ok" if res.returncode == 0 else "FAIL"
        if ok == "FAIL":
            sys.stderr.write(res.stdout[-800:] + res.stderr[-1500:] + "\n")
    except subprocess.TimeoutExpired:
        ok = "TIMEOUT"
    return tag, ok, time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--archs", nargs="*", default=ARCHS)
    ap.add_argument("--shapes", nargs="*", default=SHAPES)
    ap.add_argument("--meshes", nargs="*", default=["single", "multi"])
    ap.add_argument("--extra", nargs="*", default=[])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = [
        (a, s, m == "multi")
        for a, s, m in itertools.product(args.archs, args.shapes, args.meshes)
    ]
    results = []
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {
            ex.submit(run_cell, a, s, m, args.out, args.timeout, args.extra): (a, s, m)
            for a, s, m in cells
        }
        for fut in as_completed(futs):
            tag, status, dt = fut.result()
            results.append((tag, status, dt))
            print(f"[{len(results)}/{len(cells)}] {tag}: {status} ({dt:.0f}s)",
                  flush=True)
    bad = [r for r in results if r[1] not in ("ok", "cached-ok", "cached-skipped")]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells ok; failures: {bad}")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
