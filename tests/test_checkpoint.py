"""Checkpointing: atomicity, retention, corruption fallback, elastic reshard."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "step": jnp.asarray(7, jnp.int32),
        "params": {"a": jax.random.normal(key, (16, 8)),
                   "nested": {"b": jnp.arange(12.0).reshape(3, 4)}},
    }


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        s = _state()
        ckpt.save(str(tmp_path), 100, s)
        r = ckpt.restore(str(tmp_path), 100, jax.eval_shape(lambda: s))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_roundtrip(self, tmp_path):
        s = _state()
        t = ckpt.save(str(tmp_path), 5, s, async_=True)
        t.join()
        assert ckpt.latest_step(str(tmp_path)) == 5

    def test_retention(self, tmp_path):
        s = _state()
        for step in [1, 2, 3, 4, 5]:
            ckpt.save(str(tmp_path), step, s, keep=2)
        assert ckpt.available_steps(str(tmp_path)) == [4, 5]

    def test_shape_mismatch_raises(self, tmp_path):
        s = _state()
        ckpt.save(str(tmp_path), 1, s)
        bad = jax.eval_shape(lambda: {"step": s["step"],
                                      "params": {"a": jnp.zeros((4, 4)),
                                                 "nested": s["params"]["nested"]}})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 1, bad)

    def test_dtype_mismatch_raises(self, tmp_path):
        """The manifest records dtypes and restore enforces them: loading a
        float32 checkpoint into an int32 slot (or any silent cast) would break
        bit-identical resume."""
        s = _state()
        ckpt.save(str(tmp_path), 1, s)
        bad = jax.eval_shape(lambda: {
            "step": s["step"],
            "params": {"a": jnp.zeros((16, 8), jnp.int32),
                       "nested": s["params"]["nested"]}})
        with pytest.raises(ValueError, match="dtype"):
            ckpt.restore(str(tmp_path), 1, bad)

    def test_manifest_file_dtype_disagreement_raises(self, tmp_path):
        """A leaf file whose dtype contradicts its own manifest entry is a
        corrupt checkpoint, not a restorable one."""
        s = _state()
        ckpt.save(str(tmp_path), 1, s)
        man = os.path.join(str(tmp_path), "step_00000001", "manifest.json")
        with open(man) as f:
            m = json.load(f)
        entry = next(e for e in m["leaves"] if "float32" in e["dtype"])
        entry["dtype"] = "float64"
        with open(man, "w") as f:
            json.dump(m, f)
        with pytest.raises(ValueError, match="manifest/file dtype"):
            ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: s))

    def test_concurrent_async_saves_serialized(self, tmp_path):
        """Many async writers to one directory must interleave cleanly (the
        per-directory lock): every step lands complete, retention holds."""
        s = _state()
        threads = [ckpt.save(str(tmp_path), step, s, keep=3, async_=True)
                   for step in range(1, 9)]
        for t in threads:
            t.join()
        steps = ckpt.available_steps(str(tmp_path))
        assert len(steps) == 3 and steps[-1] == 8
        restored = ckpt.restore(str(tmp_path), 8, jax.eval_shape(lambda: s))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_corrupt_manifest_fallback(self, tmp_path):
        s = _state()
        ckpt.save(str(tmp_path), 1, s)
        ckpt.save(str(tmp_path), 2, s)
        # corrupt the newest
        man = os.path.join(str(tmp_path), "step_00000002", "manifest.json")
        with open(man, "w") as f:
            f.write("{not json")
        restored, step = ckpt.restore_latest(str(tmp_path), jax.eval_shape(lambda: s))
        assert step == 1 and restored is not None

    def test_torn_write_ignored(self, tmp_path):
        """A .tmp dir (kill mid-save) is never considered a checkpoint."""
        s = _state()
        ckpt.save(str(tmp_path), 1, s)
        os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
        assert ckpt.available_steps(str(tmp_path)) == [1]

    def test_incomplete_status_ignored(self, tmp_path):
        s = _state()
        ckpt.save(str(tmp_path), 1, s)
        d = os.path.join(str(tmp_path), "step_00000003")
        os.makedirs(d)
        with open(os.path.join(d, "manifest.json"), "w") as f:
            json.dump({"step": 3, "leaves": [], "status": "writing"}, f)
        assert ckpt.available_steps(str(tmp_path)) == [1]

    def test_empty_dir(self, tmp_path):
        restored, step = ckpt.restore_latest(str(tmp_path), {})
        assert restored is None and step is None


class TestElasticReshard:
    def test_restore_under_different_mesh(self, tmp_path):
        """Save under a (2,) data mesh, restore under (1,) and re-place —
        the multi-node elastic-rescale path, scaled to 1 host device."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        s = _state()
        ckpt.save(str(tmp_path), 3, s)
        dev = np.array(jax.devices()[:1]).reshape(1,)
        mesh = Mesh(dev, ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
        restored, step = ckpt.restore_latest(str(tmp_path), jax.eval_shape(lambda: s), sh)
        assert step == 3
        for leaf in jax.tree.leaves(restored):
            assert isinstance(leaf.sharding, NamedSharding)
