"""jaxlint self-tests: per-rule fixtures, suppression mechanics, and the
end-to-end "this repo is clean against its baseline" contract.

The fixtures under ``tests/jaxlint_fixtures/`` are parsed, never imported —
each ``*_bad.py`` distills the historical bug its rule mechanizes and each
``*_ok.py`` is the shipped fix in the same shape, so a rule that stops
firing on its bug (or starts firing on the fix) fails here before it lies
in CI.
"""
import json
import os

import pytest

from repro.analysis.cli import main as cli_main
from repro.analysis.engine import (
    find_repo_root,
    iter_python_files,
    lint_file,
    run_jaxlint,
)
from repro.analysis.findings import Baseline, Finding, pragma_suppresses
from repro.analysis.rules import ALL_RULES, RULE_SUMMARIES

REPO = find_repo_root(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
FIXTURES = os.path.join(REPO, "tests", "jaxlint_fixtures")

RULES = ["JL001", "JL002", "JL003", "JL004", "JL005", "JL006", "JL007"]


def _fixture(rule, kind):
    sub = "launch" if rule == "JL007" else ""
    return os.path.join(FIXTURES, sub, f"{rule.lower()}_{kind}.py")


def _lint(path):
    findings, err = lint_file(path, os.path.relpath(path, REPO).replace(os.sep, "/"))
    assert err is None, err
    return findings


# ---------------------------------------------------------------- fixtures


@pytest.mark.parametrize("rule", RULES)
def test_bad_fixture_trips_its_rule(rule):
    findings = _lint(_fixture(rule, "bad"))
    fired = {f.rule for f in findings}
    assert rule in fired, f"{rule} did not fire on its own bug fixture"
    # the bad fixture is a distilled single-bug file: no OTHER rule may
    # false-positive on it
    assert fired == {rule}, f"unexpected extra rules on {rule} fixture: {fired}"


@pytest.mark.parametrize("rule", RULES)
def test_ok_fixture_is_clean(rule):
    findings = _lint(_fixture(rule, "ok"))
    assert findings == [], [f.format() for f in findings]


@pytest.mark.parametrize("rule", RULES)
def test_bad_fixture_fails_cli_and_ok_passes(rule):
    assert cli_main([_fixture(rule, "bad"), "--baseline", "none"]) == 1
    assert cli_main([_fixture(rule, "ok"), "--baseline", "none"]) == 0


def test_bad_fixtures_report_multiple_sites():
    # each bad fixture carries >= 2 seeded bugs except where one suffices
    multi = {"JL001": 2, "JL003": 2, "JL004": 2, "JL005": 2, "JL006": 2,
             "JL007": 2}
    for rule, n in multi.items():
        findings = _lint(_fixture(rule, "bad"))
        assert len(findings) >= n, (rule, [f.format() for f in findings])


# ---------------------------------------------------------------- engine


def test_fixture_dir_excluded_from_default_walk():
    files = list(iter_python_files(REPO))
    assert not any("jaxlint_fixtures" in p for p in files)
    # but explicit paths bypass the exclusion (how CI lints the fixtures)
    explicit = list(iter_python_files(REPO, [_fixture("JL001", "bad")]))
    assert len(explicit) == 1


def test_findings_carry_location_and_hint():
    f = _lint(_fixture("JL003", "bad"))[0]
    assert f.path.endswith("jl003_bad.py")
    assert f.line > 0
    assert f.snippet and f.hint
    assert f"{f.path}:{f.line}" in f.format()
    assert "hint:" in f.format()


def test_rule_registry_consistent():
    assert set(ALL_RULES) == set(RULE_SUMMARIES) == set(RULES)


# ---------------------------------------------------------------- pragmas


def test_pragma_same_line_and_line_above(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "def f(x, y):\n"
        "    a = x.astype(jnp.complex64)  # jaxlint: allow=JL001 -- widening\n"
        "    # jaxlint: allow=JL001 -- also fine\n"
        "    b = y.astype(jnp.complex64)\n"
        "    c = y.astype(jnp.complex64)\n"
        "    return a, b, c\n"
    )
    p = tmp_path / "prag.py"
    p.write_text(src)
    findings, _ = lint_file(str(p), "prag.py")
    assert len(findings) == 3
    lines = src.splitlines()
    kept = [f for f in findings if not pragma_suppresses(lines, f)]
    assert [f.line for f in kept] == [6]  # only the unpragma'd cast survives


def test_pragma_names_must_match_rule(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    # jaxlint: allow=JL002 -- wrong rule named\n"
        "    return x.astype(jnp.complex64)\n"
    )
    p = tmp_path / "prag2.py"
    p.write_text(src)
    findings, _ = lint_file(str(p), "prag2.py")
    assert len(findings) == 1
    assert not pragma_suppresses(src.splitlines(), findings[0])


def test_bare_pragma_allows_everything(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    return x.astype(jnp.complex64)  # jaxlint: allow\n"
    )
    p = tmp_path / "prag3.py"
    p.write_text(src)
    findings, _ = lint_file(str(p), "prag3.py")
    assert len(findings) == 1
    assert pragma_suppresses(src.splitlines(), findings[0])


# ---------------------------------------------------------------- baseline


def test_baseline_matches_on_snippet_not_line():
    f = Finding(rule="JL001", path="a.py", line=10, message="m", hint="h",
                snippet="x = y.astype(jnp.complex64)")
    bl = Baseline([{"rule": "JL001", "path": "a.py",
                    "snippet": "x = y.astype(jnp.complex64)", "reason": "r"}])
    assert bl.matches(f)
    # unrelated line drift keeps matching
    assert bl.matches(Finding(rule="JL001", path="a.py", line=99, message="m",
                              hint="h", snippet="x = y.astype(jnp.complex64)"))
    # but editing the flagged code breaks the match (forces re-review)
    assert not bl.matches(Finding(rule="JL001", path="a.py", line=10,
                                  message="m", hint="h",
                                  snippet="x = z.astype(jnp.complex64)"))


def test_baseline_entries_require_reason(tmp_path):
    p = tmp_path / "bl.json"
    p.write_text(json.dumps({"version": 1, "entries": [
        {"rule": "JL001", "path": "a.py", "snippet": "s"}]}))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(str(p))


def test_checked_in_baseline_is_well_formed():
    from repro.analysis.jaxpr.rules import JAXPR_RULE_SUMMARIES

    bl = Baseline.load(os.path.join(REPO, ".jaxlint-baseline.json"))
    assert bl.entries, "baseline exists but is empty — drop the file instead"
    for e in bl.entries:
        assert e["rule"] in ALL_RULES or e["rule"] in JAXPR_RULE_SUMMARIES
        assert len(e["reason"]) > 10, f"throwaway reason on {e}"
        assert os.path.exists(os.path.join(REPO, e["path"])), e["path"]


# ---------------------------------------------------------------- repo e2e


def test_repo_is_clean_against_baseline():
    """The blocking CI contract: src/tests/benchmarks/examples lint clean
    modulo the checked-in baseline + inline pragmas."""
    report = run_jaxlint(root=REPO)
    assert report.files > 100  # sanity: the walk actually covered the repo
    assert report.parse_errors == []
    assert report.findings == [], "\n".join(f.format() for f in report.findings)


def test_repo_baseline_has_no_stale_entries():
    """Every baseline entry must still match a live finding — stale entries
    are suppressions waiting to hide a future bug."""
    report = run_jaxlint(root=REPO)
    matched = {(f.rule, f.path, f.snippet) for f, how in report.suppressed
               if how == "baseline"}
    bl = Baseline.load(os.path.join(REPO, ".jaxlint-baseline.json"))
    stale = [e for e in bl.entries
             if e["rule"].startswith("JL")  # JX entries match in the jaxpr tier
             and (e["rule"], e["path"], e["snippet"]) not in matched]
    assert stale == [], f"stale baseline entries: {stale}"


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out
