"""Preemption-safe recovery tests (fast tier, in-process).

The contract under test: the solver loop segmented at ANY iteration boundary —
including through a disk checkpoint and a simulated preemption — produces the
bit-identical result of the one-shot run. The subprocess kill/restart matrix
(real SIGTERM, multi-device meshes) lives in ``tests/test_fault_injection.py``
(slow tier).
"""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    qniht_batch,
    solver_init,
    solver_result,
    solver_segment,
)
from repro.launch.resilience import Preempted, recover_resilient
from repro.parallel import ChunkJournal, sharded_segment_run
from repro.parallel.batch import BatchServer, pad_state, strip_state
from repro.sensing import make_gaussian_problem
from repro.train.fault import PreemptionGuard, run_with_restarts


def _problem(B=6, m=48, n=96, s=5, key=None):
    key = key if key is not None else jax.random.PRNGKey(3)
    base = make_gaussian_problem(m, n, s, 20.0, key)
    Y = jnp.stack([
        make_gaussian_problem(m, n, s, 20.0, jax.random.fold_in(key, b + 1),
                              phi=base.phi).y for b in range(B)
    ])
    return base.phi, Y, key


def _run_segments(phi, Y, s, n_iters, seg, kw):
    init_kw = {k: v for k, v in kw.items()}
    state = solver_init(phi, Y, s, n_iters, **init_kw)
    seg_kw = {k: v for k, v in kw.items() if k != "key"}
    while int(state.k) < n_iters:
        state = solver_segment(phi, state, seg, s=s, **seg_kw)
    return state


CONFIGS = {
    "fp": dict(),
    "pair": dict(bits_phi=4, bits_y=8, requantize="pair"),
    "packed": dict(bits_phi=4, bits_y=8, requantize="fixed", backend="packed"),
    "freeze": dict(early_exit=True, exit_tol=1e-5),
}


class TestSegmentedSolver:
    @pytest.mark.parametrize("name", sorted(CONFIGS))
    @pytest.mark.parametrize("seg", [1, 7, 30])
    def test_segmented_equals_one_shot(self, name, seg):
        """Any segmentation of [0, n_iters) reproduces qniht_batch bit-for-bit
        — x AND the full per-iteration trace."""
        phi, Y, key = _problem()
        kw = dict(CONFIGS[name])
        if kw.get("bits_phi") or kw.get("bits_y"):
            kw["key"] = key
        ref = qniht_batch(phi, Y, 5, 30, **kw)
        state = _run_segments(phi, Y, 5, 30, seg, kw)
        got = solver_result(state)
        assert bool(jnp.all(ref.x == got.x))
        for a, b in zip(ref.trace, got.trace):
            np.testing.assert_array_equal(np.nan_to_num(np.asarray(a)),
                                          np.nan_to_num(np.asarray(b)))

    def test_sharded_segment_single_device_mesh(self):
        """The shard_map segment engine (width-1 mesh) matches the
        single-process segment path, padding in play (B=5)."""
        phi, Y, key = _problem(B=5)
        kw = dict(bits_y=8, key=key)
        ref = qniht_batch(phi, Y, 5, 20, **kw)
        state = solver_init(phi, Y, 5, 20, **kw)
        while int(state.k) < 20:
            state = sharded_segment_run(phi, state, 7, n_devices=1, s=5, bits_y=8)
        got = solver_result(state)
        assert got.x.shape == ref.x.shape
        assert bool(jnp.all(ref.x == got.x))
        assert bool(jnp.all(ref.trace.mu == got.trace.mu))

    def test_pad_strip_roundtrip(self):
        phi, Y, key = _problem(B=5)
        state = solver_init(phi, Y, 5, 10, key=key)
        padded, b = pad_state(state, 4)
        assert b == 5 and padded.Y.shape[0] == 8
        assert bool(jnp.all(padded.done[5:]))  # pad rows born converged
        back = strip_state(padded, b)
        for leaf_a, leaf_b in zip(jax.tree_util.tree_leaves(state),
                                  jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))

    def test_validation(self):
        phi, Y, key = _problem(B=2)
        state = solver_init(phi, Y, 5, 10)
        with pytest.raises(ValueError, match="n_steps"):
            solver_segment(phi, state, 0, s=5)
        with pytest.raises(ValueError, match="B, M"):
            solver_init(phi, Y[0], 5, 10)


class TestRecoverResilient:
    def test_parity_and_resume(self, tmp_path):
        """Uninterrupted segmented run, then a preempted + resumed run — both
        bit-identical to qniht_batch."""
        phi, Y, key = _problem()
        kw = dict(bits_phi=4, bits_y=8, requantize="pair", key=key)
        ref = qniht_batch(phi, Y, 5, 30, **kw)
        got = recover_resilient(phi, Y, 5, 30, checkpoint_dir=str(tmp_path / "a"),
                                ckpt_every=7, **kw)
        assert bool(jnp.all(ref.x == got.x))
        assert bool(jnp.all(ref.trace.resid_q == got.trace.resid_q))

        class FakeGuard:
            def __init__(self):
                self.polls = 0

            @property
            def requested(self):
                self.polls += 1
                return self.polls >= 2

        d = str(tmp_path / "b")
        with pytest.raises(Preempted) as exc:
            recover_resilient(phi, Y, 5, 30, checkpoint_dir=d, ckpt_every=7,
                              guard=FakeGuard(), **kw)
        assert exc.value.k == 14
        got2 = recover_resilient(phi, Y, 5, 30, checkpoint_dir=d, ckpt_every=7,
                                 resume=True, **kw)
        assert bool(jnp.all(ref.x == got2.x))
        assert bool(jnp.all(ref.trace.mu == got2.trace.mu))

    def test_resume_empty_dir_is_fresh_start(self, tmp_path):
        phi, Y, key = _problem(B=3)
        ref = qniht_batch(phi, Y, 5, 12)
        got = recover_resilient(phi, Y, 5, 12, checkpoint_dir=str(tmp_path),
                                ckpt_every=5, resume=True)
        assert bool(jnp.all(ref.x == got.x))

    def test_resume_falls_back_past_torn_checkpoint(self, tmp_path):
        """Corrupting the newest checkpoint (truncated leaf, then bad manifest
        status) must fall back to the previous one and still finish bitwise."""
        phi, Y, key = _problem(B=3)
        d = str(tmp_path)
        ref = qniht_batch(phi, Y, 5, 20)
        with pytest.raises(Preempted):
            recover_resilient(phi, Y, 5, 20, checkpoint_dir=d, ckpt_every=5,
                              keep=10, guard=type("G", (), {"requested": True})())
        # newest = step_00000005; tear it two ways
        top = os.path.join(d, "step_00000005")
        leaf = os.path.join(top, "leaf_00001.npy")
        with open(leaf, "r+b") as f:
            f.truncate(8)
        got = recover_resilient(phi, Y, 5, 20, checkpoint_dir=d, ckpt_every=5,
                                resume=True)
        assert bool(jnp.all(ref.x == got.x))

    def test_torn_manifest_status(self, tmp_path):
        phi, Y, key = _problem(B=3)
        d = str(tmp_path)
        with pytest.raises(Preempted):
            recover_resilient(phi, Y, 5, 20, checkpoint_dir=d, ckpt_every=5,
                              keep=10, guard=type("G", (), {"requested": True})())
        man = os.path.join(d, "step_00000005", "manifest.json")
        with open(man) as f:
            m = json.load(f)
        m["status"] = "writing"
        with open(man, "w") as f:
            json.dump(m, f)
        # the torn newest checkpoint is invisible; resume restarts from scratch
        # (no earlier step exists) and still matches
        ref = qniht_batch(phi, Y, 5, 20)
        got = recover_resilient(phi, Y, 5, 20, checkpoint_dir=d, ckpt_every=5,
                                resume=True)
        assert bool(jnp.all(ref.x == got.x))

    def test_rejects_unknown_kwargs(self, tmp_path):
        phi, Y, key = _problem(B=2)
        with pytest.raises(TypeError, match="unroll"):
            recover_resilient(phi, Y, 5, 10, checkpoint_dir=str(tmp_path),
                              unroll=4)
        with pytest.raises(ValueError, match="ckpt_every"):
            recover_resilient(phi, Y, 5, 10, checkpoint_dir=str(tmp_path),
                              ckpt_every=0)


class TestChunkJournal:
    def test_drain_and_replay(self, tmp_path):
        phi, Y, key = _problem(B=4)
        d = str(tmp_path)
        keys = [jax.random.fold_in(key, 1000 + ci) for ci in range(3)]
        chunks = [Y, Y * 0.5, Y * 2.0]
        srv = BatchServer(phi, 5, 20, key=key, journal_dir=d)
        ref = [np.asarray(srv.submit(c, k).x) for c, k in zip(chunks, keys)]

        # full drain: nothing re-solved
        srv2 = BatchServer(phi, 5, 20, key=key, journal_dir=d, resume=True)
        got = [np.asarray(srv2.submit(c, k).x) for c, k in zip(chunks, keys)]
        assert srv2.n_drained == 3
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b)

        # drop chunk 1's done marker -> demoted to in-flight, replayed to the
        # same bytes
        os.remove(os.path.join(d, "chunk_000001.done.json"))
        j = ChunkJournal(d)
        assert j.completed() == [0, 2] and j.pending() == [1]
        srv3 = BatchServer(phi, 5, 20, key=key, journal_dir=d, resume=True)
        got3 = [np.asarray(srv3.submit(c, k).x) for c, k in zip(chunks, keys)]
        assert srv3.n_drained == 2
        for a, b in zip(ref, got3):
            np.testing.assert_array_equal(a, b)

    def test_divergent_stream_rejected(self, tmp_path):
        phi, Y, key = _problem(B=4)
        srv = BatchServer(phi, 5, 10, key=key, journal_dir=str(tmp_path))
        srv.submit(Y, key)
        srv2 = BatchServer(phi, 5, 10, key=key, journal_dir=str(tmp_path),
                           resume=True)
        with pytest.raises(ValueError, match="journal mismatch"):
            srv2.submit(Y + 1.0, key)

    def test_drained_chunk_placeholder_trace(self, tmp_path):
        phi, Y, key = _problem(B=4)
        srv = BatchServer(phi, 5, 10, key=key, journal_dir=str(tmp_path))
        srv.submit(Y, key)
        srv2 = BatchServer(phi, 5, 10, key=key, journal_dir=str(tmp_path),
                           resume=True)
        r = srv2.submit(Y, key)
        assert r.trace.mu.shape == (10, 4)
        assert bool(jnp.all(jnp.isnan(r.trace.mu)))

    def test_resume_requires_journal(self):
        phi, _, _ = _problem(B=2)
        with pytest.raises(ValueError, match="journal_dir"):
            BatchServer(phi, 5, 10, resume=True)


class TestPreemptionGuard:
    @pytest.mark.parametrize("sig", [signal.SIGTERM, signal.SIGINT])
    def test_both_signals_set_requested(self, sig):
        with PreemptionGuard() as g:
            assert not g.requested
            signal.raise_signal(sig)
            assert g.requested

    def test_restores_previous_handlers(self):
        seen = []
        prev_term = signal.signal(signal.SIGTERM, lambda *a: seen.append("term"))
        prev_int = signal.signal(signal.SIGINT, lambda *a: seen.append("int"))
        try:
            with PreemptionGuard():
                assert signal.getsignal(signal.SIGTERM) is not prev_term
            # both handlers back in place after exit
            signal.raise_signal(signal.SIGTERM)
            signal.raise_signal(signal.SIGINT)
            assert seen == ["term", "int"]
        finally:
            signal.signal(signal.SIGTERM, prev_term)
            signal.signal(signal.SIGINT, prev_int)


class TestRunWithRestarts:
    def test_exponential_backoff_schedule(self):
        delays = []
        calls = []

        def body(attempt):
            calls.append(attempt)
            if attempt < 4:
                raise RuntimeError("boom")
            return "ok"

        out = run_with_restarts(body, max_restarts=4, backoff=1.0,
                                backoff_factor=2.0, max_backoff=3.0,
                                sleep=delays.append)
        assert out == "ok"
        assert calls == [0, 1, 2, 3, 4]
        assert delays == [1.0, 2.0, 3.0, 3.0]  # doubled, then capped

    def test_no_backoff_by_default(self):
        delays = []

        def body(attempt):
            if attempt == 0:
                raise RuntimeError
            return attempt

        assert run_with_restarts(body, sleep=delays.append) == 1
        assert delays == []

    def test_exhausted_restarts_reraise(self):
        with pytest.raises(RuntimeError):
            run_with_restarts(lambda a: (_ for _ in ()).throw(RuntimeError()),
                              max_restarts=2, sleep=lambda _: None)

    def test_preempted_is_retryable(self, tmp_path):
        """Preempted subclasses RuntimeError: a supervised solve that gets
        preempted re-enters with resume and finishes."""
        phi, Y, key = _problem(B=3)
        d = str(tmp_path)
        ref = qniht_batch(phi, Y, 5, 20)

        class OnceGuard:
            def __init__(self):
                self.polls = 0

            @property
            def requested(self):
                self.polls += 1
                return self.polls == 1

        def body(attempt):
            return recover_resilient(
                phi, Y, 5, 20, checkpoint_dir=d, ckpt_every=5,
                resume=attempt > 0, guard=OnceGuard() if attempt == 0 else None)

        got = run_with_restarts(body)
        assert bool(jnp.all(ref.x == got.x))
