"""Periodized orthonormal 2D DWT (transforms/wavelet) + the operator algebra
built on it (WaveletSynthesisOperator, ComposedOperator → Φ = P_Ω F W†).

Covers:
* exact round trip (≤ 1e-5) and norm preservation for haar/db4 at several
  sizes and level counts,
* the adjoint/transpose identity ⟨W x, y⟩ == ⟨x, W† y⟩ that makes the
  synthesis operator's ``rmv`` exact,
* batch semantics (leading axes = stacked independent transforms),
* the pyramid layout (coarsest approximation in the top-left block),
* compressibility of the MRI phantoms — the property the whole Φ = P_Ω F W†
  model rides on,
* validation errors (bad wavelet, bad sizes, bad level counts).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import WaveletSynthesisOperator
from repro.sensing import shepp_logan
from repro.transforms import dwt2, idwt2, max_levels, wavelet_filters

WAVS = ["haar", "db4"]


class TestFilters:
    @pytest.mark.parametrize("wav", WAVS)
    def test_orthonormal_taps(self, wav):
        lo, hi = wavelet_filters(wav)
        assert sum(v * v for v in lo) == pytest.approx(1.0, abs=1e-12)
        assert sum(v * v for v in hi) == pytest.approx(1.0, abs=1e-12)
        # QMF: lo ⊥ hi
        assert sum(a * b for a, b in zip(lo, hi)) == pytest.approx(0.0, abs=1e-12)

    def test_unknown_wavelet(self):
        with pytest.raises(ValueError, match="unknown wavelet"):
            wavelet_filters("sym9")

    def test_max_levels(self):
        assert max_levels(128, "haar") == 7   # down to a 1×1 approximation
        assert max_levels(128, "db4") == 6    # stops at the 4-tap filter length
        assert max_levels(96, "haar") == 5    # 96 = 2^5 · 3
        assert max_levels(3, "haar") == 0


class TestTransform:
    @pytest.mark.parametrize("wav", WAVS)
    @pytest.mark.parametrize("r", [8, 32])
    def test_round_trip_and_norm(self, wav, r):
        x = jax.random.normal(jax.random.PRNGKey(0), (r, r), jnp.float32)
        c = dwt2(x, wav)
        rec = idwt2(c, wav)
        assert float(jnp.max(jnp.abs(rec - x))) <= 1e-5
        assert float(jnp.linalg.norm(c)) == pytest.approx(
            float(jnp.linalg.norm(x)), rel=1e-5)

    @pytest.mark.parametrize("wav", WAVS)
    @pytest.mark.parametrize("levels", [1, 2, 3])
    def test_round_trip_partial_levels(self, wav, levels):
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 32), jnp.float32)
        rec = idwt2(dwt2(x, wav, levels), wav, levels)
        assert float(jnp.max(jnp.abs(rec - x))) <= 1e-5

    @pytest.mark.parametrize("wav", WAVS)
    def test_adjoint_identity(self, wav):
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (16, 16), jnp.float32)
        y = jax.random.normal(jax.random.fold_in(key, 1), (16, 16), jnp.float32)
        lhs = float(jnp.vdot(dwt2(x, wav), y))
        rhs = float(jnp.vdot(x, idwt2(y, wav)))
        assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)

    def test_batch_matches_singles(self):
        X = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16), jnp.float32)
        C = dwt2(X, "db4")
        for b in range(2):
            np.testing.assert_allclose(np.asarray(C[b]),
                                       np.asarray(dwt2(X[b], "db4")),
                                       rtol=1e-5, atol=1e-6)

    def test_single_level_haar_is_quadrant_averages(self):
        """One Haar level on a 2×2-blocky image: LL = 2×2 block sums / 2,
        detail quadrants vanish."""
        blocks = jax.random.normal(jax.random.PRNGKey(4), (4, 4))
        x = jnp.repeat(jnp.repeat(blocks, 2, axis=0), 2, axis=1)
        c = dwt2(x, "haar", levels=1)
        np.testing.assert_allclose(np.asarray(c[:4, :4]),
                                   np.asarray(2.0 * blocks), rtol=1e-5, atol=1e-6)
        assert float(jnp.max(jnp.abs(c[4:, :]))) <= 1e-6
        assert float(jnp.max(jnp.abs(c[:, 4:]))) <= 1e-6

    def test_constant_image_energy_all_in_dc(self):
        """The degenerate tied-magnitude image: every level's details vanish,
        all energy lands in the single coarsest coefficient."""
        x = jnp.ones((16, 16), jnp.float32)
        c = np.array(dwt2(x, "haar"))
        assert c[0, 0] == pytest.approx(16.0, rel=1e-5)  # ‖x‖₂ = √256
        c[0, 0] = 0.0
        assert np.max(np.abs(c)) <= 1e-5

    def test_complex_input_linear(self):
        z = (jax.random.normal(jax.random.PRNGKey(5), (16, 16))
             + 1j * jax.random.normal(jax.random.PRNGKey(6), (16, 16))
             ).astype(jnp.complex64)
        c = dwt2(z, "haar")
        ref = dwt2(jnp.real(z), "haar") + 1j * dwt2(jnp.imag(z), "haar")
        np.testing.assert_allclose(np.asarray(c), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_validation(self):
        with pytest.raises(ValueError, match="square"):
            dwt2(jnp.ones((8, 4)))
        with pytest.raises(ValueError, match="levels"):
            dwt2(jnp.ones((8, 8)), "haar", levels=9)
        with pytest.raises(ValueError, match="no 'haar' level"):
            dwt2(jnp.ones((3, 3)))

    def test_phantom_compressible(self):
        """The load-bearing property: Shepp–Logan is far sparser in Haar than
        in pixels — 12% of the coefficients hold ≥ 99% of the energy (the
        same pixel budget holds < 95%)."""
        img = np.asarray(dwt2(shepp_logan(64), "haar"))
        top = np.sort(img.ravel() ** 2)[::-1]
        frac = np.cumsum(top) / np.sum(top)
        k = int(0.12 * img.size)
        assert frac[k - 1] >= 0.99
        pix = np.sort(np.asarray(shepp_logan(64)).ravel() ** 2)[::-1]
        assert (np.cumsum(pix) / np.sum(pix))[k - 1] < 0.95


class TestWaveletSynthesisOperator:
    @pytest.mark.parametrize("wav", WAVS)
    def test_mv_rmv_inverse_pair(self, wav):
        op = WaveletSynthesisOperator(32, wav)
        c = jax.random.normal(jax.random.PRNGKey(0), (32 * 32,), jnp.float32)
        rec = op.rmv(op.mv(c))
        assert float(jnp.max(jnp.abs(rec - c))) <= 1e-5

    def test_adjoint_identity(self):
        op = WaveletSynthesisOperator(16, "db4")
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (256,), jnp.float32)
        y = jax.random.normal(jax.random.fold_in(key, 1), (256,), jnp.float32)
        lhs = float(jnp.vdot(op.mv(x), y))
        rhs = float(jnp.vdot(x, op.rmv(y)))
        assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)

    def test_shape_dtype_nbytes(self):
        op = WaveletSynthesisOperator(16, "haar")
        assert op.shape == (256, 256)
        assert op.dtype == jnp.float32
        assert op.nbytes == 4 * 4  # 2 taps × (lo + hi) × f32
        assert WaveletSynthesisOperator(16, "db4").nbytes == 4 * 8

    def test_is_pytree_and_jittable(self):
        op = WaveletSynthesisOperator(16, "haar")
        leaves, treedef = jax.tree_util.tree_flatten(op)
        op2 = jax.tree_util.tree_unflatten(treedef, leaves)
        c = jax.random.normal(jax.random.PRNGKey(2), (256,), jnp.float32)
        # jaxlint: allow=JL006 -- one-shot jit: the test IS the trace-through
        out = jax.jit(lambda o, v: o.mv(v))(op2, c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(op.mv(c)),
                                   rtol=1e-6, atol=1e-7)

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown wavelet"):
            WaveletSynthesisOperator(16, "sym9")
        with pytest.raises(ValueError, match="levels"):
            WaveletSynthesisOperator(16, "haar", levels=10)
