"""Matrix-free MRI workload: SubsampledFourierOperator + sensing/mri contracts.

Covers:
* adjointness ⟨Φx, r⟩ == ⟨x, Φ†r⟩ for real and complex inputs (F unitary ⇒ the
  zero-fill/IFFT adjoint is exact, not approximate),
* parity of the matrix-free operator vs an explicitly materialized partial-DFT
  Φ on small grids (mv, rmv, and full qniht iterates),
* phantom/mask/observation substrate properties,
* the ISSUE-2 acceptance run: 128×128 (N = 16384) recovery at b_y = 8 reaching
  PSNR ≥ 30 dB without a dense Φ,
* operator-input validation (bits_phi/backend rejected, 2-D y rejected).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DenseOperator,
    SubsampledFourierOperator,
    niht,
    psnr,
    qniht,
    qniht_batch,
    relative_error,
)
from repro.sensing import (
    brain_phantom,
    cartesian_mask,
    make_mri_problem,
    mri_observations,
    quantize_observations,
    shepp_logan,
    sparsify_image,
)


def _small_op(r=16, frac=0.4, seed=0):
    mask = cartesian_mask(r, frac, jax.random.PRNGKey(seed))
    return SubsampledFourierOperator.from_mask(mask), mask


def _materialize(op):
    """Explicit (M, N) partial-DFT matrix: Φ e_j for every basis vector."""
    n = op.shape[1]
    eye = jnp.eye(n, dtype=jnp.float32)
    return op.mv(eye).T  # column j = Φ e_j


class TestSubsampledFourierOperator:
    def test_adjoint_identity_real_input(self):
        op, _ = _small_op()
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (op.shape[1],), jnp.float32)
        r = (jax.random.normal(jax.random.fold_in(key, 1), (op.shape[0],))
             + 1j * jax.random.normal(jax.random.fold_in(key, 2), (op.shape[0],))
             ).astype(jnp.complex64)
        lhs = jnp.vdot(op.mv(x), r)
        rhs = jnp.vdot(x.astype(jnp.complex64), op.rmv(r))
        assert float(jnp.abs(lhs - rhs)) / float(jnp.abs(lhs)) < 1e-5

    def test_adjoint_identity_complex_input(self):
        op, _ = _small_op(r=12, frac=0.5, seed=3)
        key = jax.random.PRNGKey(2)
        x = (jax.random.normal(key, (op.shape[1],))
             + 1j * jax.random.normal(jax.random.fold_in(key, 1), (op.shape[1],))
             ).astype(jnp.complex64)
        r = (jax.random.normal(jax.random.fold_in(key, 2), (op.shape[0],))
             + 1j * jax.random.normal(jax.random.fold_in(key, 3), (op.shape[0],))
             ).astype(jnp.complex64)
        lhs = jnp.vdot(op.mv(x), r)
        rhs = jnp.vdot(x, op.rmv(r))
        assert float(jnp.abs(lhs - rhs)) / float(jnp.abs(lhs)) < 1e-5

    def test_parity_with_materialized_phi(self):
        op, _ = _small_op(r=8, frac=0.6, seed=4)
        phi = _materialize(op)
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (op.shape[1],), jnp.float32)
        v = (jax.random.normal(jax.random.fold_in(key, 1), (op.shape[0],))
             + 1j * jax.random.normal(jax.random.fold_in(key, 2), (op.shape[0],))
             ).astype(jnp.complex64)
        np.testing.assert_allclose(np.asarray(op.mv(x)), np.asarray(phi @ x),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(op.rmv(v)),
                                   np.asarray(jnp.conj(phi.T) @ v),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.slow
    def test_qniht_parity_matrix_free_vs_dense(self):
        """The solver produces the same iterates whether Φ is implicit or an
        explicitly materialized dense array (full-precision path)."""
        op, _ = _small_op(r=12, frac=0.6, seed=5)
        phi = _materialize(op)
        key = jax.random.PRNGKey(4)
        n = op.shape[1]
        x = jnp.zeros((n,)).at[jax.random.choice(key, n, (6,), replace=False)].set(
            # jaxlint: allow=JL002 -- fixture: support/amplitude correlation is harmless
            jax.random.uniform(key, (6,), minval=0.5, maxval=1.0))
        y = op.mv(x)
        kw = dict(real_signal=True, nonneg=True)
        r_free = qniht(op, y, 6, 25, **kw)
        r_dense = qniht(phi, y, 6, 25, **kw)
        ref = float(jnp.linalg.norm(r_dense.x)) + 1e-12
        assert float(jnp.linalg.norm(r_free.x - r_dense.x)) <= 1e-4 * ref
        np.testing.assert_allclose(np.asarray(r_free.trace.resid_q),
                                   np.asarray(r_dense.trace.resid_q),
                                   rtol=1e-3, atol=1e-5)

    def test_batched_mv_matches_singles(self):
        op, _ = _small_op()
        X = jax.random.normal(jax.random.PRNGKey(5), (4, op.shape[1]), jnp.float32)
        batched = op.mv(X)
        assert batched.shape == (4, op.shape[0])
        for b in range(4):
            np.testing.assert_allclose(np.asarray(batched[b]),
                                       np.asarray(op.mv(X[b])), rtol=1e-5, atol=1e-6)

    def test_nbytes_counts_pattern_only(self):
        op, mask = _small_op(r=16)
        m = int(np.asarray(mask).sum())
        assert op.nbytes == m * 4 + (16 * 16 + 7) // 8
        # the point: orders of magnitude below the dense complex64 Φ
        assert op.nbytes < m * 16 * 16 * 8 / 100

    def test_from_mask_rejects_bad_masks(self):
        with pytest.raises(ValueError):
            SubsampledFourierOperator.from_mask(np.zeros((8, 8), bool))
        with pytest.raises(ValueError):
            SubsampledFourierOperator.from_mask(np.ones((8, 4), bool))

    def test_mask_round_trip(self):
        op, mask = _small_op(r=16, seed=7)
        np.testing.assert_array_equal(np.asarray(op.mask()), np.asarray(mask))


class TestMRISubstrate:
    def test_shepp_logan_range_and_structure(self):
        img = np.asarray(shepp_logan(64))
        assert img.shape == (64, 64)
        assert img.min() >= 0.0 and img.max() == pytest.approx(1.0)
        assert (img == 0).any()  # background stays empty

    def test_brain_phantom_deterministic_in_key(self):
        a = brain_phantom(48, jax.random.PRNGKey(0))
        b = brain_phantom(48, jax.random.PRNGKey(0))
        c = brain_phantom(48, jax.random.PRNGKey(1))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))
        # piecewise constant: few distinct intensity levels
        assert len(np.unique(np.round(np.asarray(a), 5))) < 40

    def test_sparsify_keeps_top_s(self):
        img = shepp_logan(32)
        s = 50
        x = sparsify_image(img, s)
        assert int(jnp.sum(jnp.abs(x) > 0)) == s
        kept = np.sort(np.abs(np.asarray(x)[np.abs(np.asarray(x)) > 0]))
        dropped = np.sort(np.abs(np.asarray(img.ravel() - x)))
        assert kept.min() >= dropped.max() - 1e-6

    @pytest.mark.parametrize("density", ["uniform", "variable"])
    def test_cartesian_mask_fraction_and_center(self, density):
        r, frac = 32, 0.3
        mask = cartesian_mask(r, frac, jax.random.PRNGKey(0), density=density)
        assert mask.shape == (r, r) and mask.dtype == bool
        assert abs(int(mask.sum()) - int(round(frac * r * r))) <= 1
        assert mask[0, 0]  # DC always sampled (center block, unshifted convention)

    def test_variable_density_concentrates_low_freq(self):
        r = 64
        key = jax.random.PRNGKey(1)
        mu = np.fft.fftshift(cartesian_mask(r, 0.3, key, density="uniform"))
        mv = np.fft.fftshift(cartesian_mask(r, 0.3, key, density="variable"))
        lin = np.arange(r) - r // 2
        xx, yy = np.meshgrid(lin, lin, indexing="ij")
        d = np.sqrt(xx**2 + yy**2)
        assert d[mv].mean() < d[mu].mean() - 1.0

    def test_mask_validation(self):
        with pytest.raises(ValueError):
            cartesian_mask(16, 0.0, jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            cartesian_mask(16, 0.3, jax.random.PRNGKey(0), density="radial")
        with pytest.raises(ValueError, match="center block"):
            # center block alone would exceed the requested 2% budget
            cartesian_mask(256, 0.02, jax.random.PRNGKey(0), center_fraction=0.04)

    def test_batched_observations_noise_per_row(self):
        op, _ = _small_op(r=32, frac=0.5)
        X = jnp.stack([sparsify_image(shepp_logan(32), 60),
                       sparsify_image(brain_phantom(32, jax.random.PRNGKey(0)), 60)])
        Y, E = mri_observations(op, X, 20.0, jax.random.PRNGKey(1))
        assert Y.shape == (2, op.shape[0]) and E.shape == Y.shape
        for b in range(2):
            snr = 10 * np.log10(
                float(jnp.real(jnp.vdot(Y[b] - E[b], Y[b] - E[b])))
                / float(jnp.real(jnp.vdot(E[b], E[b]))))
            assert abs(snr - 20.0) < 2.5

    def test_observation_noise_calibration(self):
        op, _ = _small_op(r=32, frac=0.5)
        x = sparsify_image(shepp_logan(32), 60)
        y, e = mri_observations(op, x, 20.0, jax.random.PRNGKey(2))
        snr = 10 * np.log10(float(jnp.real(jnp.vdot(y - e, y - e)))
                            / float(jnp.real(jnp.vdot(e, e))))
        assert abs(snr - 20.0) < 2.0
        y0, e0 = mri_observations(op, x, None, jax.random.PRNGKey(2))
        assert float(jnp.max(jnp.abs(e0))) == 0.0

    def test_quantize_observations_unbiased_scale(self):
        op, _ = _small_op(r=16, frac=0.5)
        x = sparsify_image(shepp_logan(16), 30)
        y, _ = mri_observations(op, x, None, jax.random.PRNGKey(3))
        yq = quantize_observations(y, 8, jax.random.PRNGKey(4))
        assert yq.dtype == y.dtype
        rel = float(jnp.linalg.norm(yq - y) / jnp.linalg.norm(y))
        assert 0.0 < rel < 0.05


class TestEndToEndMRI:
    @pytest.mark.slow
    def test_acceptance_128_psnr30_at_8bit(self):
        """ISSUE 2 acceptance: 128×128 (N = 16384) matrix-free recovery at
        b_y = 8 reaches PSNR ≥ 30 dB — a size whose dense Φ (~750 MB) the
        old array-only qniht could not represent sensibly."""
        r, s = 128, 500
        key = jax.random.PRNGKey(5)
        prob = make_mri_problem(r, s, 0.35, key)
        res = qniht(prob.op, prob.y, s, 40, bits_y=8, key=key,
                    real_signal=True, nonneg=True)
        ps = float(psnr(res.x.reshape(r, r), prob.x_true.reshape(r, r)))
        assert ps >= 30.0
        assert float(relative_error(res.x, prob.x_true)) < 0.15

    @pytest.mark.slow
    def test_batch_matches_single(self):
        r, s = 32, 40
        key = jax.random.PRNGKey(6)
        prob = make_mri_problem(r, s, 0.45, key)
        Y = jnp.stack([prob.y, 0.5 * prob.y])
        kw = dict(bits_y=8, key=key, real_signal=True, nonneg=True)
        res_b = qniht_batch(prob.op, Y, s, 20, **kw)
        res_s = qniht(prob.op, prob.y, s, 20, **kw)
        ref = float(jnp.linalg.norm(res_s.x)) + 1e-12
        assert float(jnp.linalg.norm(res_b.x[0] - res_s.x)) <= 1e-4 * ref

    def test_operator_input_validation(self):
        prob = make_mri_problem(16, 10, 0.5, jax.random.PRNGKey(7))
        key = jax.random.PRNGKey(0)
        with pytest.raises(ValueError):  # operators own their representation
            qniht(prob.op, prob.y, 10, 5, bits_phi=8, key=key)
        with pytest.raises(ValueError):  # nothing dense to pack
            qniht(prob.op, prob.y, 10, 5, backend="packed", bits_phi=8,
                  key=key, requantize="fixed")

    def test_qniht_rejects_2d_y(self):
        prob = make_mri_problem(16, 10, 0.5, jax.random.PRNGKey(8))
        with pytest.raises(ValueError, match="qniht_batch"):
            qniht(prob.op, jnp.stack([prob.y, prob.y]), 10, 5)

    def test_dense_operator_input_matches_array_input(self):
        """as_operator seam: passing DenseOperator(phi) is the same
        computation as passing phi itself."""
        key = jax.random.PRNGKey(9)
        phi = jax.random.normal(key, (32, 64), jnp.float32)
        x = jnp.zeros((64,)).at[:3].set(jnp.asarray([1.0, -0.7, 0.4]))
        y = phi @ x
        r_arr = niht(phi, y, 3, 15)
        r_op = qniht(DenseOperator(phi), y, 3, 15)
        np.testing.assert_allclose(np.asarray(r_op.x), np.asarray(r_arr.x),
                                   rtol=1e-6, atol=1e-7)


class TestCartesianMaskEdgeCases:
    """ISSUE-4: mask writes pinned down — .flat guarantees write-through where
    ravel() only happens to (contiguity-dependent) — plus budget edge cases."""

    def test_fraction_one_fills_grid(self):
        mask = cartesian_mask(16, 1.0, jax.random.PRNGKey(0))
        assert mask.all()

    def test_center_block_consumes_whole_budget(self):
        # r=8, center_fraction=0.04 → half_c = max(1, ...) = 1 → 2×2 center
        # block = 4 samples = the entire requested budget: no random picks.
        r, frac = 8, 4 / 64
        mask = cartesian_mask(r, frac, jax.random.PRNGKey(1))
        assert int(mask.sum()) == 4
        centered = np.fft.fftshift(mask)
        c = r // 2
        assert centered[c - 1:c + 1, c - 1:c + 1].all()

    def test_tiny_resolution(self):
        mask = cartesian_mask(4, 0.5, jax.random.PRNGKey(2))
        assert mask.shape == (4, 4) and int(mask.sum()) == 8

    def test_random_picks_actually_land(self):
        """Every requested random sample must materialize in the mask."""
        for seed in range(3):
            mask = cartesian_mask(32, 0.3, jax.random.PRNGKey(seed))
            assert int(mask.sum()) == round(0.3 * 32 * 32)


class TestWaveletBasisProblem:
    def test_problem_fields_and_shapes(self):
        prob = make_mri_problem(32, 80, 0.5, jax.random.PRNGKey(10),
                                sparsity_basis="haar")
        assert prob.sparsity_basis == "haar"
        assert prob.op.shape == (prob.op.kspace_op.shape[0], 32 * 32)
        assert prob.x_true.shape == (32 * 32,)
        assert prob.image_true.shape == (32 * 32,)
        # truth is the FULL phantom, not a thresholded one
        img = shepp_logan(32).ravel()
        np.testing.assert_allclose(np.asarray(prob.image_true), np.asarray(img),
                                   rtol=1e-6, atol=1e-6)
        # x_true is its wavelet transform; to_image inverts it exactly
        np.testing.assert_allclose(np.asarray(prob.to_image(prob.x_true)),
                                   np.asarray(img), rtol=1e-4, atol=1e-5)

    def test_pixel_problem_unchanged_defaults(self):
        prob = make_mri_problem(32, 80, 0.5, jax.random.PRNGKey(11))
        assert prob.sparsity_basis == "pixel"
        assert prob.synthesis is None
        np.testing.assert_array_equal(np.asarray(prob.image_true),
                                      np.asarray(prob.x_true))
        np.testing.assert_array_equal(np.asarray(prob.to_image(prob.x_true)),
                                      np.asarray(prob.x_true))

    def test_observations_consistent_with_composed_operator(self):
        """y sampled from the image's k-space == op.mv(x_true) up to the
        (orthonormal) W†W round trip."""
        prob = make_mri_problem(32, 80, 0.5, jax.random.PRNGKey(12),
                                sparsity_basis="db4")
        via_op = prob.op.mv(prob.x_true)
        assert float(jnp.linalg.norm(via_op - prob.y)) <= \
            1e-4 * float(jnp.linalg.norm(prob.y))

    def test_quantize_observations_per_band_on_composition(self):
        prob = make_mri_problem(32, 80, 0.5, jax.random.PRNGKey(13),
                                sparsity_basis="haar")
        yq = quantize_observations(prob.y, 8, jax.random.PRNGKey(14),
                                   granularity="per_band", op=prob.op, n_bands=8)
        assert yq.shape == prob.y.shape and yq.dtype == prob.y.dtype
        rel = float(jnp.linalg.norm(yq - prob.y) / jnp.linalg.norm(prob.y))
        assert 0.0 < rel < 0.05

    def test_invalid_basis_rejected(self):
        with pytest.raises(ValueError, match="sparsity_basis"):
            make_mri_problem(32, 80, 0.5, jax.random.PRNGKey(15),
                             sparsity_basis="dct")

    @pytest.mark.slow
    def test_acceptance_full_image_128_psnr30(self):
        """ISSUE-4 acceptance: the FULL (non-sparsified) 128×128 phantom at
        35% variable-density sampling recovers through Φ = P_Ω F W† (matrix-
        free throughout) at ≥ 30 dB — for f32 observations AND the bits_y=8
        per-band quantized path."""
        r, s = 128, 2000
        key = jax.random.PRNGKey(16)
        prob = make_mri_problem(r, s, 0.35, key, sparsity_basis="haar")
        img_true = prob.image_true.reshape(r, r)

        res = qniht(prob.op, prob.y, s, 40, real_signal=True)
        ps_f32 = float(psnr(prob.to_image(res.x).reshape(r, r), img_true))
        assert ps_f32 >= 30.0

        yq = quantize_observations(prob.y, 8, key, granularity="per_band",
                                   op=prob.op, n_bands=16)
        res_q = qniht(prob.op, yq, s, 40, real_signal=True)
        ps_q = float(psnr(prob.to_image(res_q.x).reshape(r, r), img_true))
        assert ps_q >= 30.0

    @pytest.mark.slow
    def test_wavelet_recovery_beats_pixel_on_full_image(self):
        """The point of the tentpole, at smoke size: recovering the full
        phantom through W† beats pretending it is pixel-sparse."""
        r, s = 64, 500
        key = jax.random.PRNGKey(17)
        prob = make_mri_problem(r, s, 0.35, key, sparsity_basis="haar")
        img_true = prob.image_true.reshape(r, r)
        res_w = qniht(prob.op, prob.y, s, 25, real_signal=True)
        ps_w = float(psnr(prob.to_image(res_w.x).reshape(r, r), img_true))
        res_p = qniht(prob.op.kspace_op, prob.y, s, 25,
                      real_signal=True, nonneg=True)
        ps_p = float(psnr(jnp.real(res_p.x).reshape(r, r), img_true))
        assert ps_w >= ps_p + 3.0
