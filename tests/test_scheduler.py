"""Continuous-batching scheduler tests (repro.parallel.scheduler).

Three layers, mirroring the module's contracts:

* **Differential parity** (the tentpole contract): for fuzzed arrival
  orders, priorities, slot counts, segment lengths, and per-request
  horizons, every request recovered through the continuous scheduler is
  **bitwise** equal to its standalone solve — ``qniht_batch`` over
  ``[y, 0, ..., 0]`` at the scheduler's slot width with the same key
  (``ContinuousScheduler.reference_solve``). The fuzz is seeded-numpy
  parametrization (13 seeds x 4 solver configs x 5 requests = 260 cases,
  guaranteed to run with or without hypothesis); a hypothesis variant rides
  along through the shim when the package is installed.

  The reference is deliberately the request *at slot width*, not a ``B = 1``
  solve: XLA lowers a one-row batch through a different gemv kernel whose
  accumulation differs in the last ulp, so single-row parity is not a
  property any scheduler could have. Fixed-width row independence is the
  property that holds, and these tests are what pin it.

* **Queue/scheduling invariants**: FIFO within a priority class, bounded
  wait under aging (no starvation), deadline-expired requests shed with a
  reported status rather than solved late, shed-on-overflow with
  urgency-based eviction, and decision-log determinism given (seed, arrival
  trace).

* **State purity**: splicing a row via ``refill_rows`` leaves every other
  row of every ``SolverState`` leaf — ``done``/``streak``/``last``/trace
  *columns* included — bit-identical, both immediately and after the next
  segment (the failure mode lockstep parity tests can't see).

The multi-device case runs in a subprocess with 4 forced host devices (slow
tier, per the dry-run rule). It uses slots=8 so every shard holds >= 2 rows:
at 1 row per shard XLA again picks the gemv path and parity degrades to
ulp-level — the same hedge tests/test_sharded_batch.py carries.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st
from repro.core.niht import solver_init, solver_segment
from repro.parallel import (
    AdmissionQueue,
    BatchServer,
    ChunkJournal,
    ContinuousScheduler,
    Request,
    make_batch_mesh,
    refill_rows,
)

M, N, S, N_ITERS = 16, 32, 3, 12
KEY = jax.random.PRNGKey(7)


def _phi():
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.standard_normal((M, N)) / np.sqrt(M), jnp.float32)


PHI = _phi()


def _mk_y(rng):
    x = np.zeros(N, np.float32)
    x[rng.choice(N, S, replace=False)] = rng.standard_normal(S).astype(np.float32)
    return np.asarray(PHI) @ x


def _fuzz_trace(rng, n_req):
    """Arrivals with fuzzed ticks (bursty: repeated ticks), priorities, and
    per-request horizons."""
    ticks = np.cumsum(rng.integers(0, 3, n_req))
    return [
        (int(ticks[i]),
         Request(rid=i, y=_mk_y(rng), priority=int(rng.integers(0, 3)),
                 n_iters=int(rng.choice([4, 8, 12]))))
        for i in range(n_req)
    ]


# the early_exit-compatible solver configs: full precision (exact bitwise
# fixed-point rule), the freeze rule, fake-quant int8 fixed, packed int4
CONFIGS = [
    dict(),
    dict(exit_tol=1e-4),
    dict(bits_phi=8, bits_y=8, requantize="fixed"),
    dict(bits_phi=4, bits_y=8, requantize="fixed", backend="packed"),
]
CONFIG_IDS = ["f32", "f32-freeze", "fakequant8", "packed4"]


class TestDifferentialParity:
    @pytest.mark.parametrize("ci", range(len(CONFIGS)), ids=CONFIG_IDS)
    @pytest.mark.parametrize("seed", range(13))
    def test_fuzzed_arrivals_bitwise(self, seed, ci):
        """13 seeds x 4 configs x 5 requests = 260 fuzzed cases: whatever the
        arrival order, co-tenants, slot count, segment length, or refill
        timing, each answer is bitwise its standalone solve."""
        rng = np.random.default_rng(1000 * ci + seed)
        slots = int(rng.integers(2, 5))
        seg_len = int(rng.choice([2, 4]))
        arrivals = _fuzz_trace(rng, 5)
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=slots,
                                  seg_len=seg_len, key=KEY, queue_depth=32,
                                  **CONFIGS[ci])
        reports = sch.run(arrivals)
        for _, req in arrivals:
            rep = reports[req.rid]
            assert rep.status == "done"
            assert rep.iters_used is not None and rep.iters_used <= req.n_iters
            assert rep.latency_s is not None and rep.latency_s >= 0
            np.testing.assert_array_equal(
                np.asarray(rep.x),
                np.asarray(sch.reference_solve(req.y, req.n_iters)))

    def test_lockstep_same_answers(self):
        """Policy changes when rows run, never what they compute: lockstep
        and continuous produce bitwise identical answers per request."""
        rng = np.random.default_rng(5)
        arrivals = _fuzz_trace(rng, 6)
        outs = {}
        for policy in ("continuous", "lockstep"):
            sch = ContinuousScheduler(PHI, S, N_ITERS, slots=3, seg_len=4,
                                      key=KEY, policy=policy)
            outs[policy] = sch.run(arrivals)
        for _, req in arrivals:
            np.testing.assert_array_equal(
                np.asarray(outs["continuous"][req.rid].x),
                np.asarray(outs["lockstep"][req.rid].x))

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1))
        def test_fuzzed_arrivals_bitwise_hypothesis(self, seed):
            """Hypothesis variant of the differential property (extra cases
            when the optional dependency is installed)."""
            rng = np.random.default_rng(seed)
            arrivals = _fuzz_trace(rng, 4)
            sch = ContinuousScheduler(PHI, S, N_ITERS,
                                      slots=int(rng.integers(2, 5)),
                                      seg_len=int(rng.choice([2, 4])),
                                      key=KEY)
            reports = sch.run(arrivals)
            for _, req in arrivals:
                np.testing.assert_array_equal(
                    np.asarray(reports[req.rid].x),
                    np.asarray(sch.reference_solve(req.y, req.n_iters)))


class TestAdmissionQueue:
    def test_fifo_within_class(self):
        q = AdmissionQueue(depth=8)
        for seq in range(4):
            q.offer(Request(rid=seq, y=np.zeros(M)), tick=0, seq=seq)
        assert [q.pop(1).req.rid for _ in range(4)] == [0, 1, 2, 3]

    def test_strict_priority_between_classes(self):
        q = AdmissionQueue(depth=8)
        q.offer(Request(rid=0, y=np.zeros(M), priority=2), tick=0, seq=0)
        q.offer(Request(rid=1, y=np.zeros(M), priority=0), tick=0, seq=1)
        assert q.pop(0).req.rid == 1

    def test_aging_promotes_old_requests(self):
        q = AdmissionQueue(depth=8, age_every=2)
        q.offer(Request(rid=0, y=np.zeros(M), priority=2), tick=0, seq=0)
        q.offer(Request(rid=1, y=np.zeros(M), priority=0), tick=4, seq=1)
        # at tick 6 the old priority-2 entry has aged to effective 2-3=-1
        assert q.pop(6).req.rid == 0

    def test_overflow_sheds_incoming_unless_more_urgent(self):
        q = AdmissionQueue(depth=1)
        q.offer(Request(rid=0, y=np.zeros(M), priority=1), tick=0, seq=0)
        # equal urgency: incumbent keeps its place (FIFO), incoming shed
        admitted, shed = q.offer(Request(rid=1, y=np.zeros(M), priority=1),
                                 tick=0, seq=1)
        assert not admitted and shed.req.rid == 1
        # strictly more urgent: evicts the incumbent
        admitted, shed = q.offer(Request(rid=2, y=np.zeros(M), priority=0),
                                 tick=0, seq=2)
        assert admitted and shed.req.rid == 0
        assert q.pop(0).req.rid == 2

    def test_shed_expired(self):
        q = AdmissionQueue(depth=8)
        q.offer(Request(rid=0, y=np.zeros(M), deadline=3), tick=0, seq=0)
        q.offer(Request(rid=1, y=np.zeros(M)), tick=0, seq=1)
        assert q.shed_expired(3) == []           # deadline tick itself is ok
        assert [e.req.rid for e in q.shed_expired(4)] == [0]
        assert len(q) == 1


class TestSchedulerInvariants:
    def test_fifo_within_class_end_to_end(self):
        """Same-priority requests start in arrival order (slot contention
        forces queueing)."""
        rng = np.random.default_rng(0)
        arrivals = [(0, Request(rid=i, y=_mk_y(rng), n_iters=4))
                    for i in range(6)]
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=1, seg_len=4, key=KEY)
        sch.run(arrivals)
        starts = [rid for _, ev, rid, _ in sch.log if ev == "start"]
        assert starts == [0, 1, 2, 3, 4, 5]

    def test_no_starvation_under_aging(self):
        """A low-priority request under a sustained high-priority flood
        starts within ~priority*age_every ticks of arriving — and without
        aging the same trace starves it to the very end."""
        rng = np.random.default_rng(1)
        victim = Request(rid=99, y=_mk_y(rng), priority=2, n_iters=4)
        flood = [(t, Request(rid=t, y=_mk_y(rng), priority=0, n_iters=4))
                 for t in range(20)]
        arrivals = sorted([(0, victim)] + flood, key=lambda a: a[0])
        aged = ContinuousScheduler(PHI, S, N_ITERS, slots=1, seg_len=4,
                                   key=KEY, age_every=2, queue_depth=64)
        rep = aged.run(arrivals)[99]
        assert rep.status == "done"
        assert rep.start_tick <= 2 * 2 + 4   # priority*age_every + drain slack
        starved = ContinuousScheduler(PHI, S, N_ITERS, slots=1, seg_len=4,
                                      key=KEY, age_every=0, queue_depth=64)
        rep0 = starved.run(arrivals)[99]
        assert rep0.start_tick > rep.start_tick  # strict priorities starve it

    def test_deadline_expired_is_shed_not_solved_late(self):
        rng = np.random.default_rng(2)
        long_job = Request(rid=0, y=_mk_y(rng), n_iters=12)
        doomed = Request(rid=1, y=_mk_y(rng), deadline=1, n_iters=4)
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=1, seg_len=4, key=KEY)
        reports = sch.run([(0, long_job), (0, doomed)])
        rep = reports[1]
        assert rep.status == "shed_deadline"
        assert rep.x is None and rep.finish_tick is not None
        assert 1 not in [rid for _, ev, rid, _ in sch.log if ev == "start"]
        assert reports[0].status == "done"
        assert sch.stats()["n_shed_deadline"] == 1

    def test_deadline_met_requests_run(self):
        """A deadline is the last admissible start tick, not a kill switch:
        a request granted a slot in time runs to completion."""
        rng = np.random.default_rng(3)
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4, key=KEY)
        reports = sch.run([(0, Request(rid=0, y=_mk_y(rng), deadline=5,
                                       n_iters=12))])
        assert reports[0].status == "done"

    def test_queue_overflow_shed_reported(self):
        rng = np.random.default_rng(4)
        blocker = Request(rid=0, y=_mk_y(rng), n_iters=12)
        first = Request(rid=1, y=_mk_y(rng), priority=1, n_iters=4)
        urgent = Request(rid=2, y=_mk_y(rng), priority=0, n_iters=4)
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=1, seg_len=4,
                                  key=KEY, queue_depth=1)
        # blocker is granted the slot at tick 0; the two rivals then contend
        # for the single queue seat at tick 1
        reports = sch.run([(0, blocker), (1, first), (1, urgent)])
        # the urgent late-comer evicts the queued priority-1 entry
        assert reports[1].status == "shed_queue_full"
        assert reports[2].status == "done"
        assert sch.stats()["n_shed_queue_full"] == 1

    def test_decisions_deterministic_given_trace(self):
        """Same (seed, arrival trace) => identical decision log and bitwise
        identical answers — wall-clock observability never feeds back."""
        rng = np.random.default_rng(6)
        arrivals = _fuzz_trace(rng, 6)
        runs = []
        for _ in range(2):
            sch = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4,
                                      key=KEY, queue_depth=3, age_every=2)
            reports = sch.run(arrivals)
            runs.append((sch.log, reports))
        assert runs[0][0] == runs[1][0]
        for rid, rep in runs[0][1].items():
            other = runs[1][1][rid]
            assert rep.status == other.status
            if rep.x is not None:
                np.testing.assert_array_equal(np.asarray(rep.x),
                                              np.asarray(other.x))

    def test_stats_fields(self):
        rng = np.random.default_rng(7)
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4, key=KEY)
        sch.run(_fuzz_trace(rng, 4))
        st_ = sch.stats()
        assert 0 < st_["slot_occupancy"] <= 1
        assert st_["segments_run"] >= 1 and st_["n_done"] == 4
        assert sum(st_["segment_lengths"].values()) == st_["segments_run"]

    def test_input_validation(self):
        rng = np.random.default_rng(8)
        with pytest.raises(ValueError, match="policy"):
            ContinuousScheduler(PHI, S, N_ITERS, policy="roundrobin")
        with pytest.raises(ValueError, match="early_exit"):
            # pair requantize redraws operators: not stationary, no refill
            ContinuousScheduler(PHI, S, N_ITERS, bits_phi=8, bits_y=8,
                                key=KEY, requantize="pair")
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4, key=KEY)
        with pytest.raises(ValueError, match="n_iters"):
            sch.run([(0, Request(rid=0, y=_mk_y(rng), n_iters=99))])
        with pytest.raises(ValueError, match="duplicate"):
            sch2 = ContinuousScheduler(PHI, S, N_ITERS, slots=2, key=KEY)
            sch2.run([(0, Request(rid=1, y=_mk_y(rng), n_iters=4)),
                      (0, Request(rid=1, y=_mk_y(rng), n_iters=4))])
        with pytest.raises(ValueError, match="nondecreasing"):
            sch3 = ContinuousScheduler(PHI, S, N_ITERS, slots=2, key=KEY)
            sch3.run([(3, Request(rid=0, y=_mk_y(rng))),
                      (1, Request(rid=1, y=_mk_y(rng)))])


class TestSplicePurity:
    """The regression the ISSUE names: refilling row b must leave every other
    row of SolverState bit-identical — the failure mode lockstep parity
    can't see (it never splices)."""

    def _advanced_state(self):
        rng = np.random.default_rng(9)
        Y = jnp.stack([jnp.asarray(_mk_y(rng)) for _ in range(4)])
        state = solver_init(PHI, Y, S, n_iters=N_ITERS, early_exit=True)
        return solver_segment(PHI, state, 4, s=S, early_exit=True)

    @staticmethod
    def _rows_equal(a, b, rows, axis=0):
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            la, lb = np.asarray(la), np.asarray(lb)
            if la.ndim == 0:
                np.testing.assert_array_equal(la, lb)
            else:
                take = (np.take(la, rows, axis=axis),
                        np.take(lb, rows, axis=axis))
                np.testing.assert_array_equal(*take)

    def test_untouched_rows_bit_identical(self):
        state = self._advanced_state()
        rng = np.random.default_rng(10)
        spliced = refill_rows(state, [2], np.asarray(_mk_y(rng))[None], [True])
        others = [0, 1, 3]
        # batch-axis leaves: X, done, streak, Y, every leaf of `last`
        self._rows_equal(
            (state.X, state.done, state.streak, state.Y, state.last),
            (spliced.X, spliced.done, spliced.streak, spliced.Y, spliced.last),
            others)
        # trace buffers carry the batch on axis 1 (columns)
        self._rows_equal(state.trace, spliced.trace, others, axis=1)
        assert np.asarray(spliced.k) == np.asarray(state.k)
        # the spliced row is a fresh request row
        assert not bool(np.asarray(spliced.done)[2])
        assert np.all(np.asarray(spliced.X)[2] == 0)
        assert np.all(np.asarray(spliced.streak)[2] == 0)

    def test_untouched_rows_identical_through_next_segment(self):
        """Stronger: the splice must not perturb the other rows' *future*
        either — the next segment computes bitwise the same rows with or
        without the refill."""
        state = self._advanced_state()
        rng = np.random.default_rng(11)
        spliced = refill_rows(state, [2], np.asarray(_mk_y(rng))[None], [True])
        a = solver_segment(PHI, state, 4, s=S, early_exit=True)
        b = solver_segment(PHI, spliced, 4, s=S, early_exit=True)
        others = [0, 1, 3]
        self._rows_equal((a.X, a.done, a.streak), (b.X, b.done, b.streak),
                         others)
        self._rows_equal(a.trace, b.trace, others, axis=1)

    def test_pad_rows_are_done_and_zero(self):
        state = self._advanced_state()
        padded = refill_rows(state, [1, 3], np.zeros((2, M), np.float32),
                             [False, False])
        done = np.asarray(padded.done)
        assert bool(done[1]) and bool(done[3])
        assert np.all(np.asarray(padded.Y)[[1, 3]] == 0)

    def test_validation(self):
        state = self._advanced_state()
        with pytest.raises(ValueError, match="distinct"):
            refill_rows(state, [1, 1], np.zeros((2, M), np.float32),
                        [True, True])
        with pytest.raises(ValueError, match="out of range"):
            refill_rows(state, [7], np.zeros((1, M), np.float32), [True])
        with pytest.raises(ValueError, match="Y_rows shape"):
            refill_rows(state, [0], np.zeros((2, M), np.float32), [True])


class TestJournal:
    def test_scheduler_journals_request_identity(self, tmp_path):
        rng = np.random.default_rng(12)
        arrivals = _fuzz_trace(rng, 4)
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4,
                                  key=KEY, journal_dir=str(tmp_path))
        reports = sch.run(arrivals)
        j = ChunkJournal(str(tmp_path))
        for _, req in arrivals:
            assert j.is_complete(req.rid)
            Yj, _ = j.load_submit(req.rid)
            np.testing.assert_array_equal(Yj[0], np.asarray(req.y))
            import json
            with open(j._p(req.rid, "meta.json")) as f:
                meta = json.load(f)
            assert meta["rid"] == req.rid
            assert meta["priority"] == req.priority
            assert meta["n_iters"] == req.n_iters
            assert "arrival_tick" in meta
            np.testing.assert_array_equal(j.load_result_full(req.rid)[0],
                                          np.asarray(reports[req.rid].x))

    def test_scheduler_drains_on_resume(self, tmp_path):
        """A restarted scheduler fed the same trace serves every journaled
        result from disk — bitwise, zero segments run."""
        rng = np.random.default_rng(13)
        arrivals = _fuzz_trace(rng, 4)
        first = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4,
                                    key=KEY, journal_dir=str(tmp_path))
        before = first.run(arrivals)
        again = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4,
                                    key=KEY, journal_dir=str(tmp_path),
                                    resume=True)
        after = again.run(arrivals)
        assert again.segments_run == 0
        assert again.n_drained == len(arrivals)
        for _, req in arrivals:
            assert after[req.rid].drained
            np.testing.assert_array_equal(np.asarray(after[req.rid].x),
                                          np.asarray(before[req.rid].x))

    def test_resume_rejects_diverged_request(self, tmp_path):
        rng = np.random.default_rng(14)
        arrivals = _fuzz_trace(rng, 2)
        ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4, key=KEY,
                            journal_dir=str(tmp_path)).run(arrivals)
        tick, req = arrivals[0]
        tampered = [(tick, Request(rid=req.rid, y=req.y + 1.0,
                                   priority=req.priority,
                                   n_iters=req.n_iters))] + arrivals[1:]
        sch = ContinuousScheduler(PHI, S, N_ITERS, slots=2, seg_len=4,
                                  key=KEY, journal_dir=str(tmp_path),
                                  resume=True)
        with pytest.raises(ValueError, match="journal mismatch"):
            sch.run(tampered)


class TestRowValidityMask:
    """BatchServer.submit / ChunkJournal row_mask: padded or harvested rows
    must never be journaled (or replayed) as user results."""

    def test_journal_contents_pinned(self, tmp_path):
        j = ChunkJournal(str(tmp_path))
        Y = np.arange(12, dtype=np.float32).reshape(3, 4)
        x = np.arange(15, dtype=np.float32).reshape(3, 5)
        mask = np.array([True, False, True])
        j.record_submit(0, Y, np.zeros(2, np.uint32), row_mask=mask)
        j.record_result(0, x, row_mask=mask)
        # on disk: the mask itself, rows_valid counts, and a COMPACTED x —
        # the invalid row's bytes are not in the journal at all
        np.testing.assert_array_equal(j.load_mask(0), mask)
        assert j.load_result(0).shape == (2, 5)
        np.testing.assert_array_equal(j.load_result(0), x[mask])
        full = j.load_result_full(0)
        assert full.shape == (3, 5)
        np.testing.assert_array_equal(full[mask], x[mask])
        assert np.all(full[1] == 0)
        import json
        with open(j._p(0, "meta.json")) as f:
            assert json.load(f)["rows_valid"] == 2
        with open(j._p(0, "done.json")) as f:
            done = json.load(f)
        assert done["b_total"] == 3 and done["rows_valid"] == 2

    def test_all_true_mask_is_canonical_none(self, tmp_path):
        """An explicit all-valid mask journals identically to no mask — one
        on-disk spelling per meaning, so pre-mask journals stay compatible."""
        j = ChunkJournal(str(tmp_path))
        Y = np.ones((2, 4), np.float32)
        j.record_submit(0, Y, np.zeros(2, np.uint32),
                        row_mask=np.array([True, True]))
        assert j.load_mask(0) is None
        j.verify_submit(0, Y, np.zeros(2, np.uint32))  # and vice versa

    def test_verify_submit_checks_mask(self, tmp_path):
        j = ChunkJournal(str(tmp_path))
        Y = np.ones((2, 4), np.float32)
        mask = np.array([True, False])
        j.record_submit(0, Y, np.zeros(2, np.uint32), row_mask=mask)
        j.verify_submit(0, Y, np.zeros(2, np.uint32), row_mask=mask)
        with pytest.raises(ValueError, match="mask"):
            j.verify_submit(0, Y, np.zeros(2, np.uint32))

    def test_batchserver_masked_submit(self, tmp_path):
        rng = np.random.default_rng(15)
        Y = jnp.stack([jnp.asarray(_mk_y(rng)) for _ in range(4)])
        mask = np.array([True, True, False, True])
        srv = BatchServer(PHI, S, N_ITERS, mesh=make_batch_mesh(1), key=KEY,
                          journal_dir=str(tmp_path))
        res = srv.submit(Y, KEY, row_mask=mask)
        # invalid rows are zeroed pre-solve and fix at x = 0
        assert np.all(np.asarray(res.x)[2] == 0)
        assert srv.n_items == 3          # masked rows are not served items
        j = ChunkJournal(str(tmp_path))
        np.testing.assert_array_equal(j.load_mask(0), mask)
        assert j.load_result(0).shape[0] == 3
        # drain on resume reconstructs the full shape bitwise
        srv2 = BatchServer(PHI, S, N_ITERS, mesh=make_batch_mesh(1), key=KEY,
                           journal_dir=str(tmp_path), resume=True)
        res2 = srv2.submit(Y, KEY, row_mask=mask)
        np.testing.assert_array_equal(np.asarray(res2.x), np.asarray(res.x))
        assert srv2.n_drained == 1


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.parallel import ContinuousScheduler, Request, make_batch_mesh

M, N, S = 16, 32, 3
rng = np.random.default_rng(42)
phi = jnp.asarray(rng.standard_normal((M, N)) / np.sqrt(M), jnp.float32)
def mk_y():
    x = np.zeros(N, np.float32)
    x[rng.choice(N, S, replace=False)] = rng.standard_normal(S).astype(np.float32)
    return np.asarray(phi) @ x
key = jax.random.PRNGKey(7)
arrivals = [(i // 2, Request(rid=i, y=mk_y(), priority=i % 2,
                             n_iters=[4, 8, 12][i % 3])) for i in range(8)]
# slots=8 on 4 devices: 2 rows per shard, so the sharded segment hits the
# batched-op path and parity stays bitwise (1 row/shard would be gemv)
sch = ContinuousScheduler(phi, S, 12, slots=8, seg_len=4, key=key,
                          mesh=make_batch_mesh(4))
reports = sch.run(arrivals)
for _, req in arrivals:
    ref = np.asarray(sch.reference_solve(req.y, req.n_iters))
    assert np.array_equal(ref, reports[req.rid].x), f"rid {req.rid} diverged"
print("MULTIDEV_SCHED_OK", len(arrivals))
"""


@pytest.mark.slow
def test_scheduler_multidevice_parity_subprocess():
    """Differential parity holds with the slot table sharded over 4 forced
    host devices (sharded_segment_run path)."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                         cwd=root, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MULTIDEV_SCHED_OK 8" in res.stdout
