"""JL006 fixture (clean): hashable defaults; wrappers hoisted or assigned so
the compile cache can work — the kernels_micro timing idiom."""
import jax
import jax.numpy as jnp


@jax.jit
def solve(y, scale=1.0):
    return y * scale


_dot = jax.jit(lambda v: jnp.dot(v, v))


def hot_loop(xs):
    return [_dot(x) for x in xs]


def timed(time_fn, x):
    # assigning / passing the wrapper (not calling it inline) is the idiom
    fn = jax.jit(lambda v: v * 2.0)
    return time_fn(fn, x)
