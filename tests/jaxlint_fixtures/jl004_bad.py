"""JL004 fixture: the PR 5 trap — lax.cond under shard_map/vmap is rewritten
to select, so BOTH branches run on every element."""
from functools import partial

import jax
from jax import lax
from jax.experimental.shard_map import shard_map


@partial(shard_map, mesh=None, in_specs=None, out_specs=None)
def solve_shard(y):
    # BUG: under SPMD both branches execute — the "skip the solve" branch
    # does not skip anything
    return lax.cond(y.sum() > 0, lambda v: v * 2.0, lambda v: v, y)


def batched(xs):
    def per_row(x):
        # BUG: vmap batches cond into select — both branches per row
        return lax.cond(x[0] > 0, expensive, cheap, x)

    return jax.vmap(per_row)(xs)


def expensive(x):
    return x * 2.0


def cheap(x):
    return x
