"""JL005 fixture: the PR 5 PackedWeights bug — containers crossing jit with
static config riding along as pytree leaves (or, for dataclasses, not being
pytrees at all)."""
import dataclasses
from typing import NamedTuple

import jax


class PackedCodes(NamedTuple):
    codes: jax.Array
    scale: jax.Array
    granularity: str  # BUG: auto-pytree makes this str a traced leaf


@dataclasses.dataclass
class Weights:
    w: jax.Array  # BUG: a plain dataclass is one opaque leaf to jit
    b: jax.Array


@jax.jit
def apply(pw: PackedCodes, x):
    return pw.codes * pw.scale * x
