"""JL003 fixture: the PR 4 cartesian_mask gamble — writing through a ravel()
result only works when numpy happens to hand back a view."""
import numpy as np


def cartesian_mask(resolution, picks):
    mask = np.zeros((resolution, resolution), bool)
    # BUG: ravel() may copy; the write would land in the temporary
    mask.ravel()[picks] = True
    return mask


def reshape_write(a, idx, v):
    # BUG: same gamble through reshape
    a.reshape(-1)[idx] = v
    return a
