"""Deliberately-buggy fixtures for the jaxlint self-tests.

Each ``jlNNN_bad.py`` distills the historical bug its rule mechanizes (see
``docs/static-analysis.md``); each ``jlNNN_ok.py`` is the shipped fix in the
same shape. The engine's default walk skips this directory
(``repro.analysis.engine.EXCLUDED_DIR_NAMES``) — the files are only linted
when named explicitly, which is exactly what ``tests/test_jaxlint.py`` and
``scripts/ci.sh analyze``'s self-check do. They are parsed, never imported.
"""
