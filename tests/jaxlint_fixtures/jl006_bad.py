"""JL006 fixture: jit hygiene — mutable defaults on jitted functions, and
fresh-wrapper-per-call jits that can never hit the compile cache."""
import jax
import jax.numpy as jnp


@jax.jit
def solve(y, opts={}):  # BUG: unhashable default on a jitted function
    return y * opts.get("scale", 1.0)


def hot_loop(xs):
    out = []
    for x in xs:
        # BUG: a fresh jit wrapper every iteration — 100% cache misses
        out.append(jax.jit(lambda v: jnp.dot(v, v))(x))
    return out
