"""JL007 fixture (clean): tmp write + fsync + os.replace — the atomic-commit
shape of repro.parallel.journal / repro.train.checkpoint."""
import json
import os

import numpy as np


def checkpoint(path, state, meta):
    tmp = path + ".json.tmp"
    with open(tmp, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path + ".json")
    npy_tmp = path + ".npy.tmp"
    with open(npy_tmp, "wb") as f:
        np.save(f, state)
        f.flush()
        os.fsync(f.fileno())
    os.replace(npy_tmp, path + ".npy")


def save_manifest(path, manifest):
    # pathlib write into a tmp path committed by os.replace is the ok shape
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(manifest))
    os.replace(tmp, path)


def load_manifest(path):
    # reads are never flagged
    return json.loads(path.read_text())
