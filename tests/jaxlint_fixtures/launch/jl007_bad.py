"""JL007 fixture: the PR 6 lesson — direct writes on a durability-critical
path (this fixture lives under launch/) with no atomic-rename commit."""
import json

import numpy as np


def checkpoint(path, state, meta):
    # BUG: a preemption mid-dump leaves torn JSON that resume will parse
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    # BUG: torn .npy with no commit marker
    np.save(path + ".npy", state)


def save_manifest(path, manifest):
    # BUG: whole-file pathlib write, no tmp+replace commit
    path.write_text(json.dumps(manifest))


def save_blob(path, blob):
    # BUG: same torn-file shape through write_bytes
    path.write_bytes(blob)


def save_meta(path, meta):
    # BUG: json.dump straight into an inline open — torn JSON, leaked handle
    json.dump(meta, open(path, "w"))
