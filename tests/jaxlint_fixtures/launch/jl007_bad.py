"""JL007 fixture: the PR 6 lesson — direct writes on a durability-critical
path (this fixture lives under launch/) with no atomic-rename commit."""
import json

import numpy as np


def checkpoint(path, state, meta):
    # BUG: a preemption mid-dump leaves torn JSON that resume will parse
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    # BUG: torn .npy with no commit marker
    np.save(path + ".npy", state)
