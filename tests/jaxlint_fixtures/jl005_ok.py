"""JL005 fixture (clean): the PR 5 fix — register the container, static
config in aux_data. All-array NamedTuples are fine as-is."""
from typing import NamedTuple

import jax


class PackedCodes(NamedTuple):
    codes: jax.Array
    scale: jax.Array
    granularity: str


jax.tree_util.register_pytree_node(
    PackedCodes,
    lambda pw: ((pw.codes, pw.scale), pw.granularity),
    lambda gran, kids: PackedCodes(*kids, granularity=gran),
)


class SolverState(NamedTuple):
    # all-array NamedTuple: auto-pytree is exactly right, never flagged
    x: jax.Array
    resid: jax.Array


@jax.jit
def apply(pw: PackedCodes, x):
    return pw.codes * pw.scale * x
