"""JL001 fixture: the PR 4 dequantize bug — a hard-coded complex64 cast
demotes complex128 reference data, and a dtype-defaulting jnp.asarray
canonicalizes f64 down to f32."""
import jax.numpy as jnp


def dequantize(codes, scale, v):
    # BUG: a c128 `codes * scale` is silently demoted to c64
    return (codes * scale).astype(jnp.complex64)


def to_device(x_f64):
    # BUG: default canonicalization narrows float64 -> float32
    return jnp.asarray(x_f64)
