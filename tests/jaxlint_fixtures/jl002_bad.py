"""JL002 fixture: one key, two draws — Φ and the noise become correlated."""
import jax


def make_problem(key, m, n):
    phi = jax.random.normal(key, (m, n))
    # BUG: same key — the noise is a deterministic function of Φ's draw
    noise = jax.random.normal(key, (m,))
    return phi, noise
