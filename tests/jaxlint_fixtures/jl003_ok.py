"""JL003 fixture (clean): .flat (a guaranteed-aliasing view) or functional
updates — the PR 4 fix."""
import jax.numpy as jnp
import numpy as np


def cartesian_mask(resolution, picks):
    mask = np.zeros((resolution, resolution), bool)
    mask.flat[picks] = True
    return mask


def functional_write(a, idx, v):
    return a.at[idx].set(v)


def read_through_view(a, idx):
    # reading through ravel() is fine; only writes are the gamble
    return a.ravel()[idx] + jnp.ones(())
