"""JL001 fixture (clean): dtype derived from the operand, explicit dtype=."""
import jax.numpy as jnp


def dequantize(codes, scale, v):
    return (codes * scale).astype(v.dtype)


def to_device(x_f64):
    return jnp.asarray(x_f64, dtype=x_f64.dtype)


def working_precision(x):
    # float32 is the repo's working precision, deliberately not flagged
    return x.astype(jnp.float32)
