"""JL004 fixture (clean): the PR 5 fix shape — while_loop over iterations
(element-uniform trip decision) and masked arithmetic instead of branches."""
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map


@partial(shard_map, mesh=None, in_specs=None, out_specs=None)
def solve_shard(y):
    def body(st):
        k, v = st
        return k + 1, jnp.where(v.sum() > 0, v * 2.0, v)

    def cond_fn(st):
        return st[0] < 4

    return lax.while_loop(cond_fn, body, (0, y))[1]


def batched(xs):
    def per_row(x):
        return jnp.where(x[0] > 0, x * 2.0, x)

    return jax.vmap(per_row)(xs)


def unmapped(y):
    # cond OUTSIDE any SPMD wrapper is fine — both-branch execution only
    # bites under shard_map/vmap tracing
    return lax.cond(y.sum() > 0, lambda v: v * 2.0, lambda v: v, y)
