"""JL002 fixture (clean): split between draws; branch-exclusive reuse."""
import jax


def make_problem(key, m, n):
    kphi, knoise = jax.random.split(key)
    phi = jax.random.normal(kphi, (m, n))
    noise = jax.random.normal(knoise, (m,))
    return phi, noise


def branchy(key, flat):
    # one draw per mutually exclusive branch is NOT reuse (gaussian.py kflux)
    if flat:
        amps = jax.random.uniform(key, (8,))
    else:
        amps = jax.random.normal(key, (8,))
    return amps
