"""JL002 fixture (clean): split between draws; branch-exclusive reuse."""
import jax


def make_problem(key, m, n):
    kphi, knoise = jax.random.split(key)
    phi = jax.random.normal(kphi, (m, n))
    noise = jax.random.normal(knoise, (m,))
    return phi, noise


def branchy(key, flat):
    # one draw per mutually exclusive branch is NOT reuse (gaussian.py kflux)
    if flat:
        amps = jax.random.uniform(key, (8,))
    else:
        amps = jax.random.normal(key, (8,))
    return amps


def rebind_in_branch(key, warm):
    # `key` re-bound by the split inside the branch is FRESH after the merge:
    # the second draw consumes the new key, not the one `a` used
    a = jax.random.normal(key, (8,))
    if warm:
        key, sub = jax.random.split(key)
    b = jax.random.normal(key, (8,))
    return a, b


def rebind_in_loop(key, chunks):
    # same shape through a loop body: each refresh resets the draw counter
    total = 0.0
    for c in chunks:
        total = total + jax.random.normal(key, (c,)).sum()
        key, _ = jax.random.split(key)
    tail = jax.random.uniform(key, (4,))
    return total, tail
