"""jaxpr-tier fixtures: one deliberately broken entry per JX rule.

Loaded via ``python -m repro.analysis --tier jaxpr --registry <this file>``
(and by tests/test_jaxpr_tier.py). Every entry here MUST keep producing its
finding — a rule that silently stops firing is worse than no rule. The
module lives under jaxlint_fixtures/ so the AST tier's default walk skips
it.
"""
import numpy as np

from repro.analysis.jaxpr.registry import (EntryPoint, OperatorSpec,
                                           TraceSpec, anchor_of)


def _jx101_narrowing():
    import jax.numpy as jnp

    def fn(x):
        # f32 -> bf16 demotion buried one call deep
        return jnp.tanh(x).astype(jnp.bfloat16).astype(jnp.float32)

    import jax

    return TraceSpec(fn=fn, args=(jax.ShapeDtypeStruct((8,), jnp.float32),),
                     anchor=anchor_of(fn))


def _jx102_weak_output():
    import jax
    import jax.numpy as jnp

    def fn(x):
        # second output is built from a bare python scalar -> weak f32
        return x, jnp.sin(0.5)

    return TraceSpec(fn=fn, args=(jax.ShapeDtypeStruct((8,), jnp.float32),),
                     anchor=anchor_of(fn))


def _jx102_shape_branch():
    import jax
    import jax.numpy as jnp

    def fn(x):
        # Python branch keyed on the abstract shape: every serving shape
        # on one side of the split compiles a different program
        if x.shape[0] > 8:
            return jnp.cumsum(x) * 2.0
        return x + 1.0

    return TraceSpec(fn=fn, args=(jax.ShapeDtypeStruct((8,), jnp.float32),),
                     alt_args=(jax.ShapeDtypeStruct((16,), jnp.float32),),
                     anchor=anchor_of(fn))


def _jx103_dead_carry():
    import jax
    import jax.numpy as jnp

    def fn(x):
        def body(carry, _):
            acc, dead = carry
            return (acc + 1.0, dead), acc  # `dead` hauled, never read

        (acc, _), ys = jax.lax.scan(body, (x, jnp.zeros((32,))), None,
                                    length=4)
        return acc, ys

    return TraceSpec(fn=fn, args=(jax.ShapeDtypeStruct((8,), jnp.float32),),
                     anchor=anchor_of(fn))


def _jx104_callback_in_loop():
    import jax

    def fn(x):
        def body(c, _):
            jax.debug.print("iter {}", c[0])  # host hop per iteration
            return c + 1.0, None

        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    return TraceSpec(fn=fn, args=(jax.ShapeDtypeStruct((8,), jnp_f32()),),
                     anchor=anchor_of(fn))


def jnp_f32():
    import jax.numpy as jnp

    return jnp.float32


_BIG = np.arange(32768, dtype=np.float32)  # 128 KiB, well over threshold


def _jx105_baked_const():
    import jax
    import jax.numpy as jnp

    def fn(x):
        return x + jnp.asarray(_BIG)[: x.shape[0]]  # closed-over constant

    return TraceSpec(fn=fn, args=(jax.ShapeDtypeStruct((8,), jnp.float32),),
                     anchor=anchor_of(fn))


class BrokenAdjointOperator:
    """rmv maps (m,) -> (m,): the adjoint pairing can never type-check."""

    shape = (16, 32)
    dtype = np.float32

    def mv(self, x):
        import jax.numpy as jnp

        return jnp.zeros(x.shape[:-1] + (self.shape[0],), jnp.float32)

    def rmv(self, r):
        import jax.numpy as jnp

        return jnp.zeros(r.shape, jnp.float32)  # BUG: should be (..., n)


class NarrowingOperator:
    """mv silently demotes the operator dtype c64 -> f32 (drops imag)."""

    shape = (16, 32)
    dtype = np.complex64

    def mv(self, x):
        import jax.numpy as jnp

        return jnp.zeros(x.shape[:-1] + (self.shape[0],), jnp.float32)

    def rmv(self, r):
        import jax.numpy as jnp

        return jnp.zeros(r.shape[:-1] + (self.shape[1],), jnp.complex64)


def _jx106_broken():
    return OperatorSpec(ops=[BrokenAdjointOperator()],
                        anchor=anchor_of(BrokenAdjointOperator),
                        trace_mv=False)


def _jx106_narrowing():
    return OperatorSpec(ops=[NarrowingOperator()],
                        anchor=anchor_of(NarrowingOperator), trace_mv=False)


ENTRIES = [
    EntryPoint("fixture.jx101.narrowing", _jx101_narrowing),
    EntryPoint("fixture.jx102.weak_output", _jx102_weak_output),
    EntryPoint("fixture.jx102.shape_branch", _jx102_shape_branch),
    EntryPoint("fixture.jx103.dead_carry", _jx103_dead_carry),
    EntryPoint("fixture.jx104.callback_in_loop", _jx104_callback_in_loop),
    EntryPoint("fixture.jx105.baked_const", _jx105_baked_const),
    EntryPoint("fixture.jx106.broken_adjoint", _jx106_broken),
    EntryPoint("fixture.jx106.narrowing_mv", _jx106_narrowing),
]
