"""Operator backends + packed/batched QNIHT: the PR-1 hot-path contracts.

Covers:
* operator protocol units (dense adjoint identity, packed nbytes law, batched
  mv == stacked single mvs),
* packed-backend qniht parity vs the dense ``requantize="fixed"`` path at
  8/4/2 bits (shared codes → same iterates up to f32 accumulation),
* ``qniht_batch`` vs a Python loop of single recoveries,
* the streaming ``hsthresh`` H_s inside the loop (support-size parity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DenseOperator,
    FakeQuantPairOperator,
    PackedStreamingOperator,
    niht_iteration,
    qniht,
    qniht_batch,
    relative_error,
)
from repro.quant import fake_quantize
from repro.sensing import make_gaussian_problem

BITS = [8, 4, 2]


class TestOperatorProtocol:
    def test_dense_matches_matmul(self):
        key = jax.random.PRNGKey(0)
        mat = jax.random.normal(key, (24, 48), jnp.float32)
        op = DenseOperator(mat)
        x = jax.random.normal(jax.random.fold_in(key, 1), (48,), jnp.float32)
        r = jax.random.normal(jax.random.fold_in(key, 2), (24,), jnp.float32)
        np.testing.assert_allclose(np.asarray(op.mv(x)), np.asarray(mat @ x),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(op.rmv(r)), np.asarray(mat.T @ r),
                                   rtol=1e-5, atol=1e-6)
        assert op.nbytes == mat.size * 4

    def test_dense_complex_adjoint_identity(self):
        key = jax.random.PRNGKey(1)
        mat = (jax.random.normal(key, (16, 32)) +
               1j * jax.random.normal(jax.random.fold_in(key, 1), (16, 32))
               ).astype(jnp.complex64)
        op = DenseOperator(mat)
        x = jax.random.normal(jax.random.fold_in(key, 2), (32,), jnp.float32
                              ).astype(jnp.complex64)
        r = (jax.random.normal(jax.random.fold_in(key, 3), (16,)) +
             1j * jax.random.normal(jax.random.fold_in(key, 4), (16,))
             ).astype(jnp.complex64)
        lhs = jnp.vdot(op.mv(x), r)
        rhs = jnp.vdot(x, op.rmv(r))
        assert float(jnp.abs(lhs - rhs)) / float(jnp.abs(lhs)) < 1e-5

    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_packed_adjoint_identity_shared_codes(self, bits):
        """Shared codes make ⟨Φ̂x, r⟩ = ⟨x, Φ̂†r⟩ exact (one quantization backs
        both orientations), even with a stochastic key."""
        key = jax.random.PRNGKey(2)
        phi = jax.random.normal(key, (24, 40), jnp.float32)
        op = PackedStreamingOperator.pack(phi, bits, jax.random.fold_in(key, 1))
        x = jax.random.normal(jax.random.fold_in(key, 2), (40,), jnp.float32)
        r = jax.random.normal(jax.random.fold_in(key, 3), (24,), jnp.float32)
        lhs = float(jnp.vdot(op.mv(x), r))
        rhs = float(jnp.vdot(x, op.rmv(r)))
        assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-5

    @pytest.mark.parametrize("bits", BITS)
    def test_packed_matches_fake_quantize(self, bits):
        """Shared-codes packing dequantizes to fake_quantize(phi) bit-for-bit."""
        key = jax.random.PRNGKey(3)
        phi = jax.random.normal(key, (16, 24), jnp.float32)
        kq = jax.random.fold_in(key, 1)
        op = PackedStreamingOperator.pack(phi, bits, kq)
        phi_hat = fake_quantize(phi, bits, kq)
        x = jax.random.normal(jax.random.fold_in(key, 2), (24,), jnp.float32)
        np.testing.assert_allclose(np.asarray(op.mv(x)), np.asarray(phi_hat @ x),
                                   rtol=1e-5, atol=1e-5)
        r = jax.random.normal(jax.random.fold_in(key, 3), (16,), jnp.float32)
        np.testing.assert_allclose(np.asarray(op.rmv(r)), np.asarray(phi_hat.T @ r),
                                   rtol=1e-5, atol=1e-5)

    def test_packed_batched_mv_matches_singles(self):
        key = jax.random.PRNGKey(4)
        phi = jax.random.normal(key, (24, 40), jnp.float32)
        op = PackedStreamingOperator.pack(phi, 4, jax.random.fold_in(key, 1))
        X = jax.random.normal(jax.random.fold_in(key, 2), (5, 40), jnp.float32)
        batched = op.mv(X)
        assert batched.shape == (5, 24)
        for b in range(5):
            np.testing.assert_allclose(np.asarray(batched[b]),
                                       np.asarray(op.mv(X[b])), rtol=1e-5, atol=1e-5)

    def test_packed_nbytes_law(self):
        phi = jax.random.normal(jax.random.PRNGKey(5), (64, 128), jnp.float32)
        dense = DenseOperator(phi)
        for bits, factor in ((8, 4), (4, 8), (2, 16)):
            op = PackedStreamingOperator.pack(phi, bits)
            assert dense.nbytes == factor * op.nbytes

    def test_fake_quant_pair_draws_fresh(self):
        key = jax.random.PRNGKey(6)
        phi = jax.random.normal(key, (16, 24), jnp.float32)
        fam = FakeQuantPairOperator(phi, 2, key)
        op1a, op2a = fam.at_iteration(jnp.asarray(0))
        op1b, _ = fam.at_iteration(jnp.asarray(1))
        assert not np.array_equal(np.asarray(op1a.mat), np.asarray(op2a.mat))
        assert not np.array_equal(np.asarray(op1a.mat), np.asarray(op1b.mat))

    @pytest.mark.slow
    def test_niht_iteration_operator_api(self):
        prob = make_gaussian_problem(32, 64, 3, snr_db=None, key=jax.random.PRNGKey(7))
        op = DenseOperator(prob.phi)
        x0 = jnp.zeros((64,), jnp.float32)
        x1, mu, changed, n_bt = niht_iteration(
            x0, prob.y, op, op, 3, 0.01, 2.0, 30, False, False)
        assert x1.shape == (64,)
        assert int(jnp.sum(jnp.abs(x1) > 0)) <= 3
        assert float(mu) > 0


@pytest.mark.slow
class TestPackedBackendParity:
    @pytest.mark.parametrize("bits", BITS)
    def test_matches_dense_fixed(self, bits):
        """backend='packed' streams the same codes the dense fixed path
        materializes as f32 → same iterates up to accumulation order."""
        key = jax.random.PRNGKey(10)
        prob = make_gaussian_problem(64, 128, 6, snr_db=25.0, key=key)
        kw = dict(bits_phi=bits, bits_y=8, key=key, requantize="fixed")
        r_dense = qniht(prob.phi, prob.y, prob.s, 30, **kw)
        r_packed = qniht(prob.phi, prob.y, prob.s, 30, backend="packed", **kw)
        ref = float(jnp.linalg.norm(r_dense.x))
        assert float(jnp.linalg.norm(r_packed.x - r_dense.x)) <= 1e-3 * ref
        np.testing.assert_allclose(np.asarray(r_packed.trace.resid_q),
                                   np.asarray(r_dense.trace.resid_q),
                                   rtol=1e-3, atol=1e-5)

    def test_packed_rejects_pair_mode(self):
        prob = make_gaussian_problem(32, 64, 3, key=jax.random.PRNGKey(11))
        with pytest.raises(ValueError):
            qniht(prob.phi, prob.y, prob.s, 5, bits_phi=4, key=jax.random.PRNGKey(0),
                  requantize="pair", backend="packed")

    def test_packed_requires_bits(self):
        prob = make_gaussian_problem(32, 64, 3, key=jax.random.PRNGKey(12))
        with pytest.raises(ValueError):
            qniht(prob.phi, prob.y, prob.s, 5, backend="packed")

    @pytest.mark.slow
    def test_complex_packed_matches_dense_fixed(self):
        key = jax.random.PRNGKey(13)
        m, n = 48, 96
        phi = (jax.random.normal(key, (m, n)) +
               1j * jax.random.normal(jax.random.fold_in(key, 1), (m, n))
               ).astype(jnp.complex64)
        x = jnp.zeros((n,), jnp.float32).at[:4].set(jnp.asarray([1.0, 0.8, -0.5, 0.3]))
        y = phi @ x
        kw = dict(bits_phi=8, bits_y=8, key=key, requantize="fixed",
                  real_signal=True, nonneg=False)
        r_dense = qniht(phi, y, 4, 25, **kw)
        r_packed = qniht(phi, y, 4, 25, backend="packed", **kw)
        ref = float(jnp.linalg.norm(r_dense.x)) + 1e-12
        assert float(jnp.linalg.norm(r_packed.x - r_dense.x)) <= 1e-3 * ref


class TestBatchedRecovery:
    @pytest.mark.slow
    def test_batch_matches_looped_singles(self):
        key = jax.random.PRNGKey(20)
        prob = make_gaussian_problem(64, 128, 6, snr_db=25.0, key=key)
        B = 5
        # B observations of the same Φ: fresh sparse signals per row
        probs = [make_gaussian_problem(64, 128, 6, snr_db=25.0,
                                       key=jax.random.fold_in(key, b + 1),
                                       phi=prob.phi) for b in range(B)]
        X_true = [p.x_true for p in probs]
        Y = jnp.stack([p.y for p in probs])
        kw = dict(bits_phi=8, bits_y=8, key=key, requantize="fixed",
                  backend="packed")
        res_b = qniht_batch(prob.phi, Y, 6, 30, **kw)
        assert res_b.x.shape == (B, 128)
        assert res_b.trace.resid_q.shape == (30, B)
        for b in range(B):
            res_s = qniht(prob.phi, Y[b], 6, 30, **kw)
            ref = float(jnp.linalg.norm(res_s.x)) + 1e-12
            assert float(jnp.linalg.norm(res_b.x[b] - res_s.x)) <= 1e-3 * ref
            # every row actually recovers its own signal
            assert float(relative_error(res_b.x[b], X_true[b])) < 0.15

    @pytest.mark.slow
    def test_batch_full_precision_and_support(self):
        key = jax.random.PRNGKey(21)
        prob = make_gaussian_problem(48, 96, 4, snr_db=None, key=key)
        Y = jnp.stack([prob.y, 2.0 * prob.y])
        res = qniht_batch(prob.phi, Y, 4, 40)
        # linearity: doubling y doubles the recovered x
        np.testing.assert_allclose(np.asarray(res.x[1]), 2 * np.asarray(res.x[0]),
                                   rtol=1e-3, atol=1e-5)
        counts = jnp.sum(jnp.abs(res.x) > 0, axis=1)
        assert int(jnp.max(counts)) <= 4

    def test_batch_rejects_vector(self):
        prob = make_gaussian_problem(32, 64, 3, key=jax.random.PRNGKey(22))
        with pytest.raises(ValueError):
            qniht_batch(prob.phi, prob.y, 3, 5)


@pytest.mark.slow
class TestHsthreshInLoop:
    def test_support_size_parity_with_topk(self):
        """The streaming H_s keeps the loop's support invariant: |supp| ≤ s,
        and on this (distinct-magnitude) toy it matches exact top-k."""
        key = jax.random.PRNGKey(30)
        prob = make_gaussian_problem(64, 128, 6, snr_db=25.0, key=key)
        kw = dict(bits_phi=8, bits_y=8, key=key, requantize="fixed",
                  backend="packed", real_signal=True)
        r_hs = qniht(prob.phi, prob.y, prob.s, 30, threshold="hsthresh", **kw)
        r_tk = qniht(prob.phi, prob.y, prob.s, 30, threshold="topk", **kw)
        n_hs = int(jnp.sum(jnp.abs(r_hs.x) > 0))
        n_tk = int(jnp.sum(jnp.abs(r_tk.x) > 0))
        assert n_hs <= prob.s
        assert n_hs == n_tk
        assert (float(relative_error(r_hs.x, prob.x_true))
                <= float(relative_error(r_tk.x, prob.x_true)) + 0.05)

    def test_hsthresh_requires_real_signal(self):
        prob = make_gaussian_problem(32, 64, 3, key=jax.random.PRNGKey(31))
        with pytest.raises(ValueError):
            qniht(prob.phi, prob.y, 3, 5, threshold="hsthresh")

    def test_hsthresh_in_batch(self):
        key = jax.random.PRNGKey(32)
        prob = make_gaussian_problem(48, 96, 4, snr_db=20.0, key=key)
        Y = jnp.stack([prob.y, 0.5 * prob.y, -prob.y])
        res = qniht_batch(prob.phi, Y, 4, 25, bits_phi=8, bits_y=8, key=key,
                          requantize="fixed", backend="packed",
                          threshold="hsthresh", real_signal=True)
        counts = jnp.sum(jnp.abs(res.x) > 0, axis=1)
        assert int(jnp.max(counts)) <= 4


class TestTraceToggle:
    def test_with_trace_false_skips_residuals(self):
        prob = make_gaussian_problem(32, 64, 3, snr_db=20.0, key=jax.random.PRNGKey(40))
        res = qniht(prob.phi, prob.y, 3, 10, with_trace=False)
        assert bool(jnp.all(jnp.isnan(res.trace.resid_q)))
        assert bool(jnp.all(jnp.isnan(res.trace.resid_true)))
        # the iterates themselves are unaffected
        ref = qniht(prob.phi, prob.y, 3, 10)
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x), rtol=1e-6)


class TestComposedOperator:
    """The operator algebra: B∘A with exact adjoint A†∘B† (ISSUE-4 tentpole)."""

    def _dense_pair(self, key, m=12, k=20, n=28):
        from repro.core import ComposedOperator, DenseOperator

        b = DenseOperator(jax.random.normal(key, (m, k), jnp.float32))
        a = DenseOperator(jax.random.normal(jax.random.fold_in(key, 1), (k, n),
                                            jnp.float32))
        return ComposedOperator(b, a), b, a

    def test_mv_is_product(self):
        key = jax.random.PRNGKey(50)
        comp, b, a = self._dense_pair(key)
        x = jax.random.normal(jax.random.fold_in(key, 2), (28,), jnp.float32)
        np.testing.assert_allclose(np.asarray(comp.mv(x)),
                                   np.asarray(b.mat @ (a.mat @ x)),
                                   rtol=1e-5, atol=1e-5)
        r = jax.random.normal(jax.random.fold_in(key, 3), (12,), jnp.float32)
        np.testing.assert_allclose(np.asarray(comp.rmv(r)),
                                   np.asarray(a.mat.T @ (b.mat.T @ r)),
                                   rtol=1e-5, atol=1e-5)

    def test_exact_adjoint_property(self):
        """Acceptance: ⟨A x, y⟩ == ⟨x, A† y⟩ to f32 tolerance, across random
        draws and for the real CS-MRI composition P_Ω F W†."""
        from repro.core import ComposedOperator, SubsampledFourierOperator, WaveletSynthesisOperator
        from repro.sensing import cartesian_mask

        key = jax.random.PRNGKey(51)
        comp, _, _ = self._dense_pair(key)
        for trial in range(5):
            kx, kr = jax.random.split(jax.random.fold_in(key, trial))
            x = jax.random.normal(kx, (comp.shape[1],), jnp.float32)
            r = jax.random.normal(kr, (comp.shape[0],), jnp.float32)
            lhs = float(jnp.vdot(comp.mv(x), r))
            rhs = float(jnp.vdot(x, comp.rmv(r)))
            assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)

        mask = cartesian_mask(16, 0.4, jax.random.PRNGKey(52))
        mri = ComposedOperator(SubsampledFourierOperator.from_mask(mask),
                               WaveletSynthesisOperator(16, "db4"))
        kx, kr = jax.random.split(jax.random.PRNGKey(53))
        x = jax.random.normal(kx, (mri.shape[1],), jnp.float32)
        r = (jax.random.normal(kr, (mri.shape[0],))
             + 1j * jax.random.normal(jax.random.fold_in(kr, 1), (mri.shape[0],))
             ).astype(jnp.complex64)
        lhs = jnp.vdot(mri.mv(x), r)
        rhs = jnp.vdot(x.astype(jnp.complex64), mri.rmv(r))
        assert float(jnp.abs(lhs - rhs)) <= 1e-4 * float(jnp.abs(lhs))

    def test_shape_dtype_nbytes(self):
        comp, b, a = self._dense_pair(jax.random.PRNGKey(54))
        assert comp.shape == (12, 28)
        assert comp.dtype == jnp.float32
        assert comp.nbytes == b.nbytes + a.nbytes

    def test_shape_mismatch_rejected(self):
        from repro.core import ComposedOperator, DenseOperator

        b = DenseOperator(jnp.ones((4, 6)))
        a = DenseOperator(jnp.ones((5, 8)))
        with pytest.raises(ValueError, match="cannot compose"):
            ComposedOperator(b, a)

    def test_kspace_op_unwrapping(self):
        from repro.core import ComposedOperator, SubsampledFourierOperator, WaveletSynthesisOperator
        from repro.sensing import cartesian_mask

        mask = cartesian_mask(16, 0.5, jax.random.PRNGKey(55))
        fourier = SubsampledFourierOperator.from_mask(mask)
        assert fourier.kspace_op is fourier
        comp = ComposedOperator(fourier, WaveletSynthesisOperator(16, "haar"))
        assert comp.kspace_op is fourier
        # nested composition unwraps too
        from repro.core import DenseOperator

        nested = ComposedOperator(comp, DenseOperator(jnp.eye(256, dtype=jnp.float32)))
        assert nested.kspace_op is fourier

    def test_pytree_crosses_jit(self):
        comp, _, _ = self._dense_pair(jax.random.PRNGKey(56))
        x = jax.random.normal(jax.random.PRNGKey(57), (28,), jnp.float32)
        # jaxlint: allow=JL006 -- one-shot jit: the test IS the trace-through
        out = jax.jit(lambda o, v: o.mv(v))(comp, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(comp.mv(x)),
                                   rtol=1e-6, atol=1e-6)

    def test_batched_mv_matches_singles(self):
        comp, _, _ = self._dense_pair(jax.random.PRNGKey(58))
        X = jax.random.normal(jax.random.PRNGKey(59), (3, 28), jnp.float32)
        B = comp.mv(X)
        for i in range(3):
            np.testing.assert_allclose(np.asarray(B[i]), np.asarray(comp.mv(X[i])),
                                       rtol=1e-5, atol=1e-5)
