"""Optional-``hypothesis`` shim for the property tests.

``hypothesis`` is a test-only extra (``pip install .[test]``) that hermetic CI
containers may not ship. Importing it at module scope used to error 8 of the 17
test modules out of collection; importing from this shim instead keeps every
module collectible: with hypothesis installed the real ``given``/``settings``/
``st`` are re-exported, without it the ``@given`` tests are replaced by stubs
carrying a skip marker (plain unit tests in the same module still run).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # A fresh (*a, **k) stub requests no pytest fixtures, so the
            # strategy kwargs of the wrapped test never reach collection.
            # No functools.wraps: inspect.signature would follow __wrapped__
            # back to the original parameters.
            def stub(*a, **k):
                pass

            stub.__name__ = getattr(fn, "__name__", "property_test")
            stub.__doc__ = fn.__doc__
            return pytest.mark.skip(reason="hypothesis not installed")(stub)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.<anything>(...) placeholder; only consumed by the stubbed @given."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
