"""Pallas qmm kernel: interpret-mode allclose sweeps against the ref.py oracle,
plus the fused CPU path's parity sweep and the block-config selector rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels.qmm.kernel import select_block_config
from repro.kernels.qmm.ops import (
    pack_operator,
    pack_weights,
    packed_matvec,
    packed_rmatvec,
    qmm,
)
from repro.kernels.qmm.ref import qmm_group_ref, qmm_ref
from repro.quant import fake_quantize

BITS = [2, 4, 8]


class TestQmmVsOracle:
    @given(
        bits=st.sampled_from(BITS),
        m=st.integers(1, 40),
        k=st.integers(1, 300),
        n=st.integers(1, 150),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=25, deadline=None)
    def test_shape_sweep(self, bits, m, k, n, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        ref = qmm_ref(x, pw.packed, pw.scale, bits, k)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_dtype_sweep(self, dtype, bits):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (16, 256)).astype(dtype)
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 256), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        ref = qmm_ref(x, pw.packed, pw.scale, bits, 256)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-2, atol=1e-2)

    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_exact_block_multiple_shapes(self, bits):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (128, 512), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 512), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        ref = qmm_ref(x, pw.packed, pw.scale, bits, 512)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


class TestQmmSemantics:
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_matches_dequantized_matmul(self, bits):
        """qmm == x @ Q(w)^T where Q is the framework quantizer (per-channel)."""
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (8, 100), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 100), jnp.float32)
        kq = jax.random.fold_in(key, 2)
        pw = pack_weights(w, bits, kq)
        out = qmm(x, pw, use_pallas=False)
        w_deq = fake_quantize(w, bits, kq, channel_axis=0)
        ref = x @ w_deq.T
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

    @pytest.mark.slow
    def test_8bit_quantization_error_small(self):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (8, 128), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 128), jnp.float32)
        pw = pack_weights(w, 8, jax.random.fold_in(key, 2))
        exact = x @ w.T
        out = qmm(x, pw, use_pallas=True, interpret=True)
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        assert rel < 0.05

    def test_compression_bytes(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (64, 512), jnp.float32)
        assert pack_weights(w, 2).nbytes == 64 * 128   # 16x vs f32
        assert pack_weights(w, 4).nbytes == 64 * 256   # 8x
        assert pack_weights(w, 8).nbytes == 64 * 512   # 4x


class TestPackedOperator:
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_complex_matvec_adjoint_consistency(self, bits):
        """<Φ̂x, r> == <x, Φ̂†r> exactly when fwd/adj share one deterministic
        quantization. (With stochastic keys the two orientations are
        *independent* quantizations by design — Algorithm 1's pairing — and the
        identity only holds in expectation.)"""
        key = jax.random.PRNGKey(5)
        phi = (
            jax.random.normal(key, (24, 48)) + 1j * jax.random.normal(jax.random.fold_in(key, 1), (24, 48))
        ).astype(jnp.complex64)
        op = pack_operator(phi, bits, key=None)
        x = jax.random.normal(jax.random.fold_in(key, 3), (48,), jnp.float32)
        r = (
            jax.random.normal(jax.random.fold_in(key, 4), (24,))
            + 1j * jax.random.normal(jax.random.fold_in(key, 5), (24,))
        ).astype(jnp.complex64)
        lhs = jnp.vdot(packed_matvec(op, x), r)
        rhs = jnp.vdot(x.astype(jnp.complex64), packed_rmatvec(op, r))
        denom = max(float(jnp.abs(lhs)), 1e-6)
        assert float(jnp.abs(lhs - rhs)) / denom < 1e-4

    @pytest.mark.slow
    def test_interpret_matches_ref_path(self):
        key = jax.random.PRNGKey(6)
        phi = (
            jax.random.normal(key, (30, 70)) + 1j * jax.random.normal(jax.random.fold_in(key, 1), (30, 70))
        ).astype(jnp.complex64)
        op = pack_operator(phi, 4, jax.random.fold_in(key, 2))
        x = jax.random.normal(jax.random.fold_in(key, 3), (70,), jnp.float32)
        a = packed_matvec(op, x, use_pallas=True, interpret=True)
        b = packed_matvec(op, x, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


class TestFusedDispatch:
    """The CPU fused path (``use_pallas=False``): every dispatch branch —
    matvec (m=1), batched minor, batched canonical (shared transposed codes),
    per-block — must match the unpack-everything oracle bit-for-bit up to f32
    accumulation order."""

    @pytest.mark.parametrize("gran", ["per_tensor", "per_channel"])
    @pytest.mark.parametrize("m", [1, 8])
    @pytest.mark.parametrize("bits", BITS)
    def test_fused_matches_ref(self, bits, m, gran):
        key = jax.random.PRNGKey(7)
        k, n = 129, 67  # deliberately unaligned: exercises padding in every branch
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2), granularity=gran)
        ref = qmm_ref(x, pw.packed, pw.scale, bits, k)
        out = qmm(x, pw, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("m", [1, 8])
    @pytest.mark.parametrize("bits", BITS)
    def test_fused_per_block_matches_group_ref(self, bits, m):
        key = jax.random.PRNGKey(8)
        k, n, g = 160, 33, 32
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2),
                          granularity=f"per_block:{g}")
        ref = qmm_group_ref(x, pw.packed, pw.scale, bits, k, g)
        out = qmm(x, pw, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("batch", [2, 8])
    @pytest.mark.parametrize("bits", BITS)
    def test_canonical_shared_path_matches_minor(self, bits, batch):
        """shared=True (gemm over the shared transposed codes — the solver's
        batched route) must equal shared=False (per-part minor dot) exactly:
        same codes, different contraction order."""
        key = jax.random.PRNGKey(9)
        phi = jax.random.normal(key, (48, 96), jnp.float32)
        op = pack_operator(phi, bits, jax.random.fold_in(key, 1), shared=True)
        xb = jax.random.normal(jax.random.fold_in(key, 2), (batch, 96), jnp.float32)
        rb = jax.random.normal(jax.random.fold_in(key, 3), (batch, 48), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(packed_matvec(op, xb, shared=True, use_pallas=False)),
            np.asarray(packed_matvec(op, xb, shared=False, use_pallas=False)),
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(packed_rmatvec(op, rb, shared=True, use_pallas=False)),
            np.asarray(packed_rmatvec(op, rb, shared=False, use_pallas=False)),
            rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("batch", [2, 8])
    def test_canonical_shared_path_complex(self, batch):
        key = jax.random.PRNGKey(10)
        # jaxlint: allow=JL001 -- test builds a c64 operator on purpose
        phi = (jax.random.normal(key, (24, 56))
               + 1j * jax.random.normal(jax.random.fold_in(key, 1), (24, 56))
               ).astype(jnp.complex64)
        op = pack_operator(phi, 8, jax.random.fold_in(key, 2), shared=True)
        xb = jax.random.normal(jax.random.fold_in(key, 3), (batch, 56), jnp.float32)
        # jaxlint: allow=JL001 -- test builds c64 observations on purpose
        rb = (jax.random.normal(jax.random.fold_in(key, 4), (batch, 24))
              + 1j * jax.random.normal(jax.random.fold_in(key, 5), (batch, 24))
              ).astype(jnp.complex64)
        np.testing.assert_allclose(
            np.asarray(packed_matvec(op, xb, shared=True, use_pallas=False)),
            np.asarray(packed_matvec(op, xb, shared=False, use_pallas=False)),
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(packed_rmatvec(op, rb, shared=True, use_pallas=False)),
            np.asarray(packed_rmatvec(op, rb, shared=False, use_pallas=False)),
            rtol=1e-5, atol=1e-5)


class TestSelectBlockConfig:
    def test_auto_clamps_to_small_problem(self):
        # fig5 smoke geometry: m=64, n=128, k=128 at 8 bits (k_unit=128)
        bm, bn, bk = select_block_config(64, 128, 128, 8)
        assert (bm, bn, bk) == (64, 128, 128)

    def test_auto_rounds_tiny_dims_to_hardware_minima(self):
        bm, bn, bk = select_block_config(3, 40, 100, 8)
        assert (bm, bn, bk) == (8, 128, 128)  # 8 sublanes, 128 lanes, 1 k-unit

    def test_auto_respects_group_size_lcm(self):
        # bits=2 → k_unit = 128·4 = 512; group 96 → lcm 1536 caps bk
        bm, bn, bk = select_block_config(128, 256, 4096, 2, group_size=96)
        assert bk % 1536 == 0

    def test_explicit_oversized_block_raises(self):
        with pytest.raises(ValueError, match="mostly padding"):
            select_block_config(4, 64, 256, 8, block_m=128)

    def test_explicit_misaligned_block_raises(self):
        with pytest.raises(ValueError, match="multiple of"):
            select_block_config(64, 256, 1024, 8, block_k=100)

    def test_explicit_aligned_block_accepted(self):
        assert select_block_config(128, 256, 1024, 8, block_m=64,
                                   block_n=128, block_k=512) == (64, 128, 512)
