"""Pallas qmm kernel: interpret-mode allclose sweeps against the ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels.qmm.ops import (
    pack_operator,
    pack_weights,
    packed_matvec,
    packed_rmatvec,
    qmm,
)
from repro.kernels.qmm.ref import qmm_ref
from repro.quant import fake_quantize

BITS = [2, 4, 8]


class TestQmmVsOracle:
    @given(
        bits=st.sampled_from(BITS),
        m=st.integers(1, 40),
        k=st.integers(1, 300),
        n=st.integers(1, 150),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=25, deadline=None)
    def test_shape_sweep(self, bits, m, k, n, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        ref = qmm_ref(x, pw.packed, pw.scale, bits, k)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_dtype_sweep(self, dtype, bits):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (16, 256)).astype(dtype)
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 256), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        ref = qmm_ref(x, pw.packed, pw.scale, bits, 256)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-2, atol=1e-2)

    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_exact_block_multiple_shapes(self, bits):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (128, 512), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 512), jnp.float32)
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2))
        ref = qmm_ref(x, pw.packed, pw.scale, bits, 512)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


class TestQmmSemantics:
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_matches_dequantized_matmul(self, bits):
        """qmm == x @ Q(w)^T where Q is the framework quantizer (per-channel)."""
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (8, 100), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 100), jnp.float32)
        kq = jax.random.fold_in(key, 2)
        pw = pack_weights(w, bits, kq)
        out = qmm(x, pw, use_pallas=False)
        w_deq = fake_quantize(w, bits, kq, channel_axis=0)
        ref = x @ w_deq.T
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

    @pytest.mark.slow
    def test_8bit_quantization_error_small(self):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (8, 128), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 128), jnp.float32)
        pw = pack_weights(w, 8, jax.random.fold_in(key, 2))
        exact = x @ w.T
        out = qmm(x, pw, use_pallas=True, interpret=True)
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        assert rel < 0.05

    def test_compression_bytes(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (64, 512), jnp.float32)
        assert pack_weights(w, 2).nbytes == 64 * 128   # 16x vs f32
        assert pack_weights(w, 4).nbytes == 64 * 256   # 8x
        assert pack_weights(w, 8).nbytes == 64 * 512   # 4x


class TestPackedOperator:
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.slow
    def test_complex_matvec_adjoint_consistency(self, bits):
        """<Φ̂x, r> == <x, Φ̂†r> exactly when fwd/adj share one deterministic
        quantization. (With stochastic keys the two orientations are
        *independent* quantizations by design — Algorithm 1's pairing — and the
        identity only holds in expectation.)"""
        key = jax.random.PRNGKey(5)
        phi = (
            jax.random.normal(key, (24, 48)) + 1j * jax.random.normal(jax.random.fold_in(key, 1), (24, 48))
        ).astype(jnp.complex64)
        op = pack_operator(phi, bits, key=None)
        x = jax.random.normal(jax.random.fold_in(key, 3), (48,), jnp.float32)
        r = (
            jax.random.normal(jax.random.fold_in(key, 4), (24,))
            + 1j * jax.random.normal(jax.random.fold_in(key, 5), (24,))
        ).astype(jnp.complex64)
        lhs = jnp.vdot(packed_matvec(op, x), r)
        rhs = jnp.vdot(x.astype(jnp.complex64), packed_rmatvec(op, r))
        denom = max(float(jnp.abs(lhs)), 1e-6)
        assert float(jnp.abs(lhs - rhs)) / denom < 1e-4

    @pytest.mark.slow
    def test_interpret_matches_ref_path(self):
        key = jax.random.PRNGKey(6)
        phi = (
            jax.random.normal(key, (30, 70)) + 1j * jax.random.normal(jax.random.fold_in(key, 1), (30, 70))
        ).astype(jnp.complex64)
        op = pack_operator(phi, 4, jax.random.fold_in(key, 2))
        x = jax.random.normal(jax.random.fold_in(key, 3), (70,), jnp.float32)
        a = packed_matvec(op, x, use_pallas=True, interpret=True)
        b = packed_matvec(op, x, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
