"""jaxpr-tier self-tests: per-JX fixture checks, registry coverage, and the
end-to-end "the traced repo is clean against its baseline" contract.

The fixtures in ``tests/jaxlint_fixtures/jaxpr_bad.py`` are a registry of
deliberately broken entries — one per JX rule (two for JX102/JX106's two
sub-checks). Each must keep producing its finding; the full built-in
registry must keep tracing clean. Mirrors tests/test_jaxlint.py for the
AST tier.
"""
import os

import pytest

from repro.analysis.engine import find_repo_root
from repro.analysis.findings import Baseline
from repro.analysis.jaxpr.registry import build_registry
from repro.analysis.jaxpr.rules import JAXPR_RULE_SUMMARIES
from repro.analysis.jaxpr.runner import load_registry_file, run_jaxpr_tier

REPO = find_repo_root(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
FIXTURE_REGISTRY = os.path.join(REPO, "tests", "jaxlint_fixtures",
                                "jaxpr_bad.py")


@pytest.fixture(scope="module")
def fixture_report():
    registry = load_registry_file(FIXTURE_REGISTRY)
    return run_jaxpr_tier(root=REPO, registry=registry, baseline="none")


# ------------------------------------------------------------ fixture bites


def test_fixture_registry_loads_every_rule():
    registry = load_registry_file(FIXTURE_REGISTRY)
    assert len(registry) >= len(JAXPR_RULE_SUMMARIES)


def test_fixture_registry_has_no_trace_crashes(fixture_report):
    # broken CONTRACTS must surface as findings, not analyzer crashes
    assert fixture_report.parse_errors == []


@pytest.mark.parametrize("rule", sorted(JAXPR_RULE_SUMMARIES))
def test_every_jx_rule_fires_on_its_fixture(fixture_report, rule):
    hits = [f for f in fixture_report.findings if f.rule == rule]
    assert hits, f"{rule} no longer fires on its broken fixture entry"
    # ...and on the entry built to trip it, not by accident elsewhere
    tag = rule.lower()
    assert any(tag in f.message or tag in f.snippet.lower() or
               f"fixture.{tag}" in f.snippet or f.line > 0 for f in hits)


def test_jx106_broken_adjoint_demonstrably_fails(fixture_report):
    """Acceptance criterion: the deliberately broken operator fails the
    adjoint-contract check with a shape-duality finding."""
    msgs = [f.message for f in fixture_report.findings if f.rule == "JX106"]
    assert any("rmv" in m and "contract requires" in m for m in msgs), msgs
    assert any("dtype" in m for m in msgs), msgs  # the narrowing-mv operator


def test_site_anchored_findings_point_into_the_fixture(fixture_report):
    sited = [f for f in fixture_report.findings
             if f.rule in ("JX101", "JX103", "JX104")]
    assert sited
    for f in sited:
        assert f.path == "tests/jaxlint_fixtures/jaxpr_bad.py"
        assert f.line > 1
        assert f.snippet  # stripped source line, AST-tier-compatible identity


# ------------------------------------------------------------ registry shape


def test_registry_names_are_unique_and_cover_the_surfaces():
    names = [e.name for e in build_registry()]
    assert len(names) == len(set(names))
    for required in ("qniht.packed.per_tensor", "qniht.packed.per_block",
                     "qniht_batch.dense.early_exit", "solver_segment.dense",
                     "qmm_fused.batch_canonical", "op.composed.mri",
                     "op.fourier", "batch_server.chunk_fn"):
        assert required in names, f"registry lost {required}"


# ---------------------------------------------------------------- repo e2e


@pytest.fixture(scope="module")
def repo_report():
    return run_jaxpr_tier(root=REPO)


def test_full_registry_traces_clean_against_baseline(repo_report):
    """The blocking CI contract: every entry point traces, and the IR rules
    find nothing unsuppressed."""
    assert repo_report.parse_errors == [], repo_report.parse_errors
    assert repo_report.files == len(build_registry())
    assert repo_report.findings == [], \
        "\n".join(f.format() for f in repo_report.findings)


def test_repo_jx_baseline_entries_are_not_stale(repo_report):
    matched = {(f.rule, f.path, f.snippet)
               for f, how in repo_report.suppressed if how == "baseline"}
    bl = Baseline.load(os.path.join(REPO, ".jaxlint-baseline.json"))
    stale = [e for e in bl.entries if e["rule"].startswith("JX")
             and (e["rule"], e["path"], e["snippet"]) not in matched]
    assert stale == [], f"stale JX baseline entries: {stale}"


def test_known_suppressions_are_exercised(repo_report):
    """The two vetted suppressions this tier ships with stay live: the
    segment-core streak carry (baseline) and the NaN-marker device_put
    (pragma). If either stops firing, the suppression must be removed."""
    hows = {(f.rule, how) for f, how in repo_report.suppressed}
    assert ("JX103", "baseline") in hows
    assert ("JX104", "pragma") in hows
