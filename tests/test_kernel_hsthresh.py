"""Pallas hsthresh kernel: interpret-mode sweeps vs oracle and vs exact H_s."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import hard_threshold
from repro.kernels.hsthresh.kernel import hist_pallas, mask_pallas
from repro.kernels.hsthresh.ops import hsthresh
from repro.kernels.hsthresh.ref import hist_ref, hsthresh_ref, select_threshold


class TestKernelsVsOracle:
    @given(n=st.integers(10, 3000), seed=st.integers(0, 99))
    @settings(max_examples=20, deadline=None)
    def test_hist_matches_ref(self, n, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        npad = (n + 1023) // 1024 * 1024
        x2 = jnp.pad(x, (0, npad - n)).reshape(1, npad)
        vmax = jnp.maximum(jnp.max(jnp.abs(x2)), 1e-30)
        h_pal = hist_pallas(x2, vmax.reshape(1, 1), nbins=256, interpret=True)
        h_ref = hist_ref(jnp.abs(x2[0]), vmax, 256)
        np.testing.assert_array_equal(np.asarray(h_pal[0]), np.asarray(h_ref))

    def test_mask_matches_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 2048))
        t = jnp.float32(0.7)
        a = mask_pallas(x, t.reshape(1, 1), interpret=True)
        b = jnp.where(jnp.abs(x) > t, x, 0.0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestHsthreshSemantics:
    @given(
        n=st.integers(50, 4000),
        s_frac=st.floats(0.01, 0.5),
        seed=st.integers(0, 99),
    )
    @settings(max_examples=20, deadline=None)
    def test_support_at_most_s(self, n, s_frac, seed):
        s = max(1, int(n * s_frac))
        x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        y = hsthresh(x, s, use_pallas=True, interpret=True)
        assert int(jnp.sum(jnp.abs(y) > 0)) <= s

    @given(seed=st.integers(0, 50))
    @settings(max_examples=12, deadline=None)
    def test_matches_exact_topk_generic(self, seed):
        """Gaussian magnitudes rarely collide within a bin: expect exact H_s;
        on a bin collision the tie fill still returns s entries whose
        magnitudes match the exact top-s up to one bin width."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (2000,))
        s = 64
        y_kernel = hsthresh(x, s, nbins=4096, use_pallas=True, interpret=True)
        y_exact = hard_threshold(x, s)
        kept = int(jnp.sum(jnp.abs(y_kernel) > 0))
        assert kept == s
        if not np.array_equal(np.asarray(y_kernel), np.asarray(y_exact)):
            binw = float(jnp.max(jnp.abs(x))) / 4096
            mk = np.sort(np.abs(np.asarray(y_kernel)[np.asarray(y_kernel) != 0]))
            me = np.sort(np.abs(np.asarray(y_exact)[np.asarray(y_exact) != 0]))
            np.testing.assert_allclose(mk, me, atol=binw)

    def test_preserves_values(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (512,))
        y = hsthresh(x, 10, use_pallas=True, interpret=True)
        mask = jnp.abs(y) > 0
        np.testing.assert_array_equal(np.asarray(x[mask]), np.asarray(y[mask]))

    def test_ref_path(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (777,))
        a = hsthresh(x, 33, use_pallas=False)
        b = hsthresh_ref(x, 33)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
