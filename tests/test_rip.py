"""Tests for the RIP machinery (Lemma 1, Theorem 3 terms, Fig. 7/8 quantities)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    corollary1_coeffs,
    eps_q,
    gamma_from_rics,
    gamma_full,
    gamma_hat_bound,
    min_bits_lemma1,
    rics_sampled,
    singular_values,
)
from repro.quant import fake_quantize


class TestSpectra:
    def test_orthogonal_matrix_gamma_zero(self):
        q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(0), (64, 64)))
        assert float(gamma_full(q)) == pytest.approx(0.0, abs=1e-4)

    def test_singular_values_match_svd(self):
        a = jax.random.normal(jax.random.PRNGKey(1), (16, 40))
        sv = np.asarray(singular_values(a))[:16]
        ref = np.asarray(jnp.linalg.svd(a, compute_uv=False))
        np.testing.assert_allclose(sv, ref, rtol=1e-4, atol=1e-4)

    def test_sampled_rics_bracket_submatrix_spectrum(self):
        phi = jax.random.normal(jax.random.PRNGKey(2), (64, 256)) / 8.0
        key = jax.random.PRNGKey(3)
        alpha, beta = rics_sampled(phi, 8, 32, key)
        idx = jax.random.choice(jax.random.PRNGKey(4), 256, (8,), replace=False)
        sv = jnp.linalg.svd(jnp.take(phi, idx, axis=1), compute_uv=False)
        # one more random support cannot exceed sampled extremes by much
        assert float(sv[0]) <= float(beta) * 1.5
        assert float(sv[-1]) >= float(alpha) / 1.5

    def test_gamma_from_rics(self):
        assert float(gamma_from_rics(1.0, 1.0)) == pytest.approx(0.0)
        assert float(gamma_from_rics(1.0, 2.0)) == pytest.approx(1.0)


class TestLemma1:
    def test_more_bits_for_smaller_margin(self):
        b_tight = min_bits_lemma1(gamma=1 / 16 - 1e-3, alpha=1.0, support_size=16)
        b_loose = min_bits_lemma1(gamma=1 / 32, alpha=1.0, support_size=16)
        assert b_tight > b_loose

    def test_infeasible_returns_sentinel(self):
        assert min_bits_lemma1(gamma=0.5, alpha=1.0, support_size=16) == 64

    def test_bound_formula(self):
        # b >= log2(2*sqrt(16)/(eps*alpha)), eps = 1/16 - 1/32 = 1/32, alpha=2
        expected = math.ceil(math.log2(2 * 4 / ((1 / 32) * 2)))
        assert min_bits_lemma1(1 / 32, 2.0, 16) == expected

    def test_gamma_hat_empirical(self):
        """Eqn. 48: quantizing cannot inflate gamma beyond the Lemma-1 bound
        (statistical check on a random well-conditioned matrix)."""
        key = jax.random.PRNGKey(5)
        phi = jax.random.normal(key, (128, 64)) / math.sqrt(128)
        s = 8
        alpha, beta = rics_sampled(phi, s, 24, key)
        gamma = float(gamma_from_rics(alpha, beta))
        bits = 8
        phi_hat = fake_quantize(phi, bits, jax.random.fold_in(key, 1))
        a_h, b_h = rics_sampled(phi_hat, s, 24, key)
        gamma_hat = float(gamma_from_rics(a_h, b_h))
        # Lemma-1 bound uses the worst case; scale by c_phi since entries != [-1,1]
        c_phi = float(jnp.max(jnp.abs(phi)))
        bound = gamma_hat_bound(gamma, float(alpha), s, bits) + c_phi * math.sqrt(s) / (
            2 ** (bits - 1) * float(alpha)
        )
        assert gamma_hat <= bound + 0.05


class TestErrorTerms:
    def test_eps_q_halves_per_bit(self):
        e2 = eps_q(900, 30.0, 5.0, 2, 8)
        e3 = eps_q(900, 30.0, 5.0, 3, 8)
        # phi term dominates here; one more bit ~halves it
        assert e3 < e2 and e3 > e2 / 2.2

    def test_corollary1_coeffs(self):
        c1, c2 = corollary1_coeffs(30, 60.0, 50.0)
        assert c1 == pytest.approx(math.sqrt(30) / 60.0)
        assert c2 == pytest.approx(30 / 50.0)


class TestGranularityAwareRIP:
    """ISSUE-4: Lemma 1 bounds accepting per-group scale vectors (the
    granularity-aware RIP item). The per-group vector enters via its RMS,
    which never exceeds the per-tensor max — so group scaling can only
    tighten the bit bound."""

    def test_effective_scale(self):
        from repro.core import effective_scale

        assert effective_scale(0.5) == pytest.approx(0.5)
        assert effective_scale(jnp.asarray([1.0, 1.0, 1.0])) == pytest.approx(1.0)
        assert effective_scale(jnp.asarray([3.0, 4.0])) == pytest.approx(
            math.sqrt(12.5), rel=1e-6)
        with pytest.raises(ValueError):
            effective_scale(jnp.asarray([]))

    def test_uniform_vector_matches_scalar(self):
        vec = jnp.full((32,), 0.7)
        assert gamma_hat_bound(0.02, 1.0, 8, 8, scale=vec) == pytest.approx(
            gamma_hat_bound(0.02, 1.0, 8, 8, scale=0.7), rel=1e-6)
        assert min_bits_lemma1(0.02, 1.0, 8, scale=vec) == \
            min_bits_lemma1(0.02, 1.0, 8, scale=0.7)

    def test_group_scales_never_raise_the_bound(self):
        key = jax.random.PRNGKey(7)
        scales = 2.0 ** jax.random.uniform(key, (64,), minval=-4.0, maxval=0.0)
        c_tensor = float(jnp.max(scales))
        assert gamma_hat_bound(0.01, 1.0, 16, 4, scale=scales) <= \
            gamma_hat_bound(0.01, 1.0, 16, 4, scale=c_tensor)
        assert min_bits_lemma1(0.01, 1.0, 16, scale=scales) <= \
            min_bits_lemma1(0.01, 1.0, 16, scale=c_tensor)

    def test_high_dynamic_range_saves_bits(self):
        """The ROADMAP claim made concrete: one hot row among many small ones
        (k-space-like dynamic range) needs strictly fewer bits under group
        scaling than the per-tensor worst case prices."""
        scales = jnp.concatenate([jnp.ones((1,)), jnp.full((63,), 1.0 / 64.0)])
        b_group = min_bits_lemma1(0.02, 1.0, 16, scale=scales)
        b_tensor = min_bits_lemma1(0.02, 1.0, 16, scale=1.0)
        assert b_group < b_tensor

    def test_empirical_gamma_hat_group_quantized(self):
        """Tie the vector bound to rics_sampled on a per-channel-quantized
        matrix with strongly varying row scales: the group bound must hold
        empirically AND be tighter than the per-tensor one."""
        key = jax.random.PRNGKey(8)
        phi0 = jax.random.normal(key, (128, 64)) / math.sqrt(128)
        row_scale = 2.0 ** jax.random.uniform(
            jax.random.fold_in(key, 1), (128,), minval=-3.0, maxval=0.0)
        phi = phi0 * row_scale[:, None]
        s, bits = 8, 8
        alpha, beta = rics_sampled(phi, s, 24, key)
        gamma = float(gamma_from_rics(alpha, beta))
        phi_hat = fake_quantize(phi, bits, jax.random.fold_in(key, 2),
                                channel_axis=0)
        a_h, b_h = rics_sampled(phi_hat, s, 24, key)
        gamma_hat = float(gamma_from_rics(a_h, b_h))
        group_scales = jnp.max(jnp.abs(phi), axis=1)  # what channel_axis=0 used
        # ×2 covers stochastic rounding's full-step worst case (Lemma-1 form
        # prices the deterministic half step), same slack style as the
        # per-tensor empirical test above
        bound_group = gamma_hat_bound(gamma, float(alpha), s, bits,
                                      scale=2.0 * group_scales)
        bound_tensor = gamma_hat_bound(gamma, float(alpha), s, bits,
                                       scale=2.0 * float(jnp.max(jnp.abs(phi))))
        assert gamma_hat <= bound_group + 0.05
        assert bound_group < bound_tensor
