"""Pallas sqround kernel: bit-exactness vs oracle + statistical properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels.sqround.ops import sqround
from repro.kernels.sqround.ref import sqround_ref, uniform01_from_bits
from repro.quant import BY_BITS

BITS = [2, 4, 8]


class TestSqroundVsOracle:
    @given(
        bits=st.sampled_from(BITS),
        r=st.integers(1, 70),
        c=st.integers(1, 90),
        seed=st.integers(0, 999),
    )
    @settings(max_examples=25, deadline=None)
    def test_bit_exact_sweep(self, bits, r, c, seed):
        key = jax.random.PRNGKey(seed)
        v = jax.random.normal(key, (r, c), jnp.float32) * 3.0
        c_pal, s_pal = sqround(v, bits, key, use_pallas=True, interpret=True)
        c_ref, s_ref = sqround(v, bits, key, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(c_pal), np.asarray(c_ref))
        assert float(s_pal) == float(s_ref)

    @pytest.mark.parametrize("bits", BITS)
    def test_codes_in_range(self, bits):
        key = jax.random.PRNGKey(1)
        v = jax.random.normal(key, (64, 64), jnp.float32)
        codes, _ = sqround(v, bits, key, use_pallas=True, interpret=True)
        k = BY_BITS[bits].half_steps
        assert codes.dtype == jnp.int8
        assert int(codes.max()) <= k and int(codes.min()) >= -k


class TestStatistics:
    def test_unbiased(self):
        """E[dequant(sqround(v))] == v across many keys (2-bit, harshest)."""
        v = jax.random.uniform(jax.random.PRNGKey(2), (8, 8), minval=-1, maxval=1)
        k = BY_BITS[2].half_steps

        def deq(key):
            codes, scale = sqround(v, 2, key, use_pallas=False)
            return codes.astype(jnp.float32) * scale / k

        keys = jax.random.split(jax.random.PRNGKey(3), 3000)
        mean = jax.vmap(deq)(keys).mean(0)
        np.testing.assert_allclose(np.asarray(mean), np.asarray(v), atol=0.08)

    def test_uniform01_range(self):
        u = jax.random.bits(jax.random.PRNGKey(4), (1000,), dtype=jnp.uint32)
        f = uniform01_from_bits(u)
        assert float(f.min()) >= 0.0 and float(f.max()) < 1.0

    def test_explicit_scale(self):
        v = jnp.full((4, 4), 0.5, jnp.float32)
        codes, scale = sqround(v, 8, jax.random.PRNGKey(5), scale=jnp.float32(1.0))
        assert float(scale) == 1.0
        k = BY_BITS[8].half_steps
        np.testing.assert_allclose(np.asarray(codes).astype(float) / k, 0.5, atol=1 / k)
