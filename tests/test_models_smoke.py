"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train-style loss/grad step + serve consistency, on CPU.

(The FULL assigned configs are exercised only via the dry-run —
ShapeDtypeStruct lowering, no allocation.)

The whole module is @slow: ~3–4 min of per-architecture compiles, peripheral
to the CS solver core — scripts/ci.sh fast skips it, full still runs it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_bytes,
    prefill,
    quantize_params,
)
from repro.quant.policy import QuantPolicy

KEY = jax.random.PRNGKey(0)
POL = QuantPolicy()


def _batch(cfg, b=2, s=16, with_mem=True):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    memory = None
    if with_mem and cfg.family == "encdec":
        # jaxlint: allow=JL002 -- deterministic fixture: tokens/memory feed
        memory = jax.random.normal(KEY, (b, cfg.encoder_seq, cfg.d_model))
    elif with_mem and cfg.family == "vlm":
        # jaxlint: allow=JL002 -- different samplers; the consistency checks
        memory = jax.random.normal(KEY, (b, cfg.n_image_tokens, cfg.d_model))  # do not rely on draw independence
    return tokens, memory


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, KEY)
        tokens, memory = _batch(cfg)
        if cfg.family == "encdec":
            memory = encode(cfg, params, memory, POL)
        logits, _ = forward(cfg, params, tokens, memory=memory)
        assert logits.shape == (2, 16, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_grads(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, KEY)
        tokens, memory = _batch(cfg)   # enc-dec: raw frames (loss_fn encodes)
        batch = {"tokens": tokens, "labels": tokens, "memory": memory}
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        assert bool(jnp.isfinite(loss))
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree_util.tree_leaves(grads)))
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    def test_serve_consistency(self, arch):
        """prefill+decode logits match teacher-forced forward (capacity-drop-free
        MoE config to make routing deterministic across paths)."""
        cfg = get_smoke_config(arch)
        if cfg.n_experts:
            cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
        params = init_params(cfg, KEY)
        b, s = 2, 16
        tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
        memory = None
        if cfg.family == "encdec":
            # jaxlint: allow=JL002 -- deterministic fixture reuse (see _batch)
            frames = jax.random.normal(KEY, (b, cfg.encoder_seq, cfg.d_model))
            memory = encode(cfg, params, frames, POL)
        elif cfg.family == "vlm":
            # jaxlint: allow=JL002 -- deterministic fixture reuse (see _batch)
            memory = jax.random.normal(KEY, (b, cfg.n_image_tokens, cfg.d_model))
        logits_full, _ = forward(cfg, params, tokens, memory=memory)
        mem_len = memory.shape[1] if memory is not None else 0
        cache = init_cache(cfg, b, s + 8, POL, mem_len=mem_len)
        lp, cache = prefill(cfg, params, tokens[:, :s], cache, memory=memory, policy=POL)
        ld, _ = decode_step(cfg, params, tokens[:, s], cache, policy=POL,
                            position=jnp.asarray(s, jnp.int32))
        scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
        assert float(jnp.max(jnp.abs(lp - logits_full[:, s - 1]))) / scale < 2e-2
        assert float(jnp.max(jnp.abs(ld - logits_full[:, s]))) / scale < 2e-2

    def test_full_config_matches_assignment(self, arch):
        """The FULL config carries the exact assigned dimensions."""
        cfg = get_config(arch)
        assigned = {
            "qwen1_5_32b": (64, 5120, 40, 40, 27392, 152064),
            "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
            "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
            "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
            "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
            "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
            "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
            "llama32_vision_11b": (40, 4096, 32, 8, 14336, 128256),
            "qwen3_moe_30b": (48, 2048, 32, 4, 768, 151936),
            "qwen3_moe_235b": (94, 4096, 64, 4, 1536, 151936),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == assigned


class TestWeightQuantization:
    """The paper's technique as a serving feature (per-arch weight quant)."""

    @pytest.mark.parametrize("arch", ["qwen1_5_32b", "qwen3_moe_30b", "mamba2_370m"])
    def test_quantized_forward_error_scaling(self, arch):
        """8-bit output error is small, and the 4-bit error scales like the
        step-size ratio 2^(8-4) = 16 (the Lemma-4 law at the logits level).
        Tiny smoke widths (d=64) make absolute errors large — the *scaling*
        is the meaningful invariant."""
        cfg = get_smoke_config(arch)
        params = init_params(cfg, KEY)
        tokens, _ = _batch(cfg, with_mem=False)
        lf, _ = forward(cfg, params, tokens)

        def rel(bits):
            lq, _ = forward(cfg, quantize_params(params, bits), tokens)
            return float(jnp.linalg.norm(lq - lf) / (jnp.linalg.norm(lf) + 1e-9))

        r8, r4 = rel(8), rel(4)
        assert r8 < 0.08
        assert r4 < 0.75
        assert 4.0 < r4 / max(r8, 1e-9) < 64.0  # ~16x expected

    def test_quantized_bytes_shrink(self):
        """Stored bytes drop with bits (embedding stays f32 → the floor)."""
        cfg = get_smoke_config("qwen1_5_32b")
        params = init_params(cfg, KEY)
        base = param_bytes(params)
        b8 = param_bytes(quantize_params(params, 8))
        b4 = param_bytes(quantize_params(params, 4))
        b2 = param_bytes(quantize_params(params, 2))
        assert b8 < 0.45 * base
        assert b4 < b8 and b4 < 0.36 * base
        assert b2 < b4 and b2 < 0.31 * base

    def test_param_counts_match_family_size(self):
        """Full-config param counts are in the advertised ballpark."""
        approx = {
            "qwen1_5_32b": 32e9,
            "qwen3_moe_30b": 30e9,
            "qwen3_moe_235b": 235e9,
            "mamba2_370m": 0.37e9,
            "recurrentgemma_2b": 2.7e9,
        }
        for arch, target in approx.items():
            n = get_config(arch).param_count()
            assert 0.5 * target < n < 1.7 * target, (arch, n, target)
