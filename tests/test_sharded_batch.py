"""Sharded batch serving tests (repro.parallel.batch + qniht_batch_sharded).

Fast tier: single-device ``("batch",)`` meshes exercise the full shard_map
plumbing (specs, padding arithmetic, the early-exit while_loop, BatchServer)
in-process without touching the global device view. The multi-device parity
matrix — packed / Fourier / composed-wavelet operators on a real 8-host-device
mesh, B-not-divisible padding, freeze-rule grouping invariance — runs in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (slow
tier), per the dry-run rule that the main pytest process keeps one device.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qniht_batch, qniht_batch_sharded
from repro.parallel import BatchServer, make_batch_mesh, pad_batch
from repro.sensing import make_gaussian_problem


def _gaussian_batch(B=6, m=64, n=128, s=6, snr=20.0, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    base = make_gaussian_problem(m, n, s, snr, key)
    Y = jnp.stack([
        make_gaussian_problem(m, n, s, snr, jax.random.fold_in(key, b + 1),
                              phi=base.phi).y
        for b in range(B)
    ])
    return base.phi, Y


class TestEarlyExit:
    def test_exact_rule_bit_identical(self):
        """early_exit (tol=0) reproduces the no-early-exit run bit-for-bit,
        trace included (a bitwise fixed point is absorbing)."""
        phi, Y = _gaussian_batch()
        r0 = qniht_batch(phi, Y, 6, 40)
        r1 = qniht_batch(phi, Y, 6, 40, early_exit=True)
        assert bool(jnp.all(r0.x == r1.x))
        for a, b in zip(r0.trace, r1.trace):
            np.testing.assert_array_equal(np.nan_to_num(np.asarray(a)),
                                          np.nan_to_num(np.asarray(b)))

    def test_exact_rule_packed_backend(self):
        phi, Y = _gaussian_batch()
        key = jax.random.PRNGKey(3)
        kw = dict(bits_phi=4, bits_y=8, key=key, requantize="fixed",
                  backend="packed", with_trace=False)
        r0 = qniht_batch(phi, Y, 6, 30, **kw)
        r1 = qniht_batch(phi, Y, 6, 30, early_exit=True, **kw)
        assert bool(jnp.all(r0.x == r1.x))

    def test_unroll_invariant_and_exclusive_with_early_exit(self):
        """unroll is a compilation knob on the fixed-trip scan: identical
        numerics at any value — and rejected with early_exit, whose while_loop
        trip count is data-dependent and cannot unroll."""
        phi, Y = _gaussian_batch()
        r1 = qniht_batch(phi, Y, 6, 40)
        r4 = qniht_batch(phi, Y, 6, 40, unroll=4)
        assert bool(jnp.all(r1.x == r4.x))
        with pytest.raises(ValueError, match="unroll"):
            qniht_batch(phi, Y, 6, 40, early_exit=True, unroll=4)

    def test_freeze_rule_preserves_recovery_quality(self):
        """The freeze rule is a heuristic (a row on a long saddle plateau may
        freeze short of a late support escape), so the guarantee is quality:
        frozen recovery error stays within a whisker of the full run's."""
        key = jax.random.PRNGKey(0)
        base = make_gaussian_problem(64, 128, 6, 20.0, key)
        probs = [make_gaussian_problem(64, 128, 6, 20.0,
                                       jax.random.fold_in(key, b + 1),
                                       phi=base.phi) for b in range(6)]
        Y = jnp.stack([p.y for p in probs])
        X_true = jnp.stack([p.x_true for p in probs])
        r0 = qniht_batch(base.phi, Y, 6, 40, with_trace=False)
        r1 = qniht_batch(base.phi, Y, 6, 40, with_trace=False, early_exit=True,
                         exit_tol=1e-5)

        def errs(r):
            return jnp.linalg.norm(r.x - X_true, axis=-1) / (
                jnp.linalg.norm(X_true, axis=-1) + 1e-30)

        e0, e1 = errs(r0), errs(r1)
        assert float(jnp.max(e1 - e0)) < 0.05
        assert float(jnp.mean(e1)) < float(jnp.mean(e0)) + 0.01

    def test_validation(self):
        phi, Y = _gaussian_batch(B=2)
        key = jax.random.PRNGKey(0)
        with pytest.raises(ValueError, match="pair"):
            qniht_batch(phi, Y, 6, 5, bits_phi=4, bits_y=8, key=key,
                        requantize="pair", early_exit=True)
        with pytest.raises(ValueError, match="exit_tol"):
            qniht_batch(phi, Y, 6, 5, exit_tol=1e-5)
        with pytest.raises(ValueError, match="unroll"):
            qniht_batch(phi, Y, 6, 5, unroll=0)


class TestPadBatch:
    def test_no_pad_when_divisible(self):
        Y = jnp.ones((8, 3))
        Yp, b = pad_batch(Y, 4)
        assert Yp.shape == (8, 3) and b == 8

    def test_pads_with_zero_rows(self):
        Y = jnp.ones((5, 3))
        Yp, b = pad_batch(Y, 4)
        assert Yp.shape == (8, 3) and b == 5
        assert bool(jnp.all(Yp[5:] == 0.0))
        assert bool(jnp.all(Yp[:5] == 1.0))


class TestShardedSingleDeviceMesh:
    """The shard_map path on a width-1 mesh — full plumbing, fast tier."""

    def test_parity_and_padding(self):
        phi, Y = _gaussian_batch(B=5)
        r0 = qniht_batch(phi, Y, 6, 30)
        r1 = qniht_batch_sharded(phi, Y, 6, 30, n_devices=1)
        assert r1.x.shape == r0.x.shape
        assert bool(jnp.all(r0.x == r1.x))
        assert bool(jnp.all(r0.trace.mu == r1.trace.mu))

    def test_operator_input(self):
        from repro.core import SubsampledFourierOperator
        from repro.sensing import make_mri_problem

        key = jax.random.PRNGKey(1)
        prob = make_mri_problem(16, 20, 0.5, key, snr_db=None)
        assert isinstance(prob.op, SubsampledFourierOperator)
        Y = jnp.stack([prob.y, prob.y * 0.5])
        r0 = qniht_batch(prob.op, Y, 20, 10, real_signal=True, nonneg=True)
        r1 = qniht_batch_sharded(prob.op, Y, 20, 10, n_devices=1,
                                 real_signal=True, nonneg=True)
        assert bool(jnp.all(r0.x == r1.x))

    def test_rejects_wrong_mesh_axes(self):
        from jax.sharding import Mesh

        phi, Y = _gaussian_batch(B=2)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        with pytest.raises(ValueError, match="batch"):
            qniht_batch_sharded(phi, Y, 6, 5, mesh=mesh)

    def test_rejects_1d_y(self):
        phi, Y = _gaussian_batch(B=2)
        with pytest.raises(ValueError, match="B, M"):
            qniht_batch_sharded(phi, Y[0], 6, 5)

    def test_too_many_devices(self):
        with pytest.raises(ValueError, match="device"):
            make_batch_mesh(n_devices=4096)


class TestBatchServer:
    def test_prepack_parity_with_packed_backend(self):
        """Server rows == qniht_batch(backend='packed') rows, same key — the
        pack-once path reproduces the in-loop pack bit-for-bit."""
        phi, Y = _gaussian_batch()
        key = jax.random.PRNGKey(0)
        ref = qniht_batch(phi, Y, 6, 30, bits_phi=4, bits_y=8, key=key,
                          requantize="fixed", backend="packed", with_trace=False)
        srv = BatchServer(phi, 6, 30, bits_phi=4, bits_y=8, key=key,
                          backend="packed")
        got = srv.submit(Y, key)
        assert bool(jnp.all(ref.x == got.x))

    def test_multi_chunk_stream(self):
        phi, Y = _gaussian_batch(B=4)
        srv = BatchServer(phi, 6, 15)
        outs = list(srv.serve([Y, Y * 0.5, Y]))
        assert len(outs) == 3
        assert srv.n_chunks == 3 and srv.n_items == 12
        assert srv.compile_cache_keys == ((4, 64),)
        # same chunk twice → identical results (stateless per chunk)
        assert bool(jnp.all(outs[0].x == outs[2].x))

    def test_server_validates_config(self):
        phi, _ = _gaussian_batch(B=2)
        with pytest.raises(ValueError, match="bits_phi"):
            BatchServer(phi, 6, backend="packed")


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import qniht_batch, qniht_batch_sharded
from repro.parallel import BatchServer, make_batch_mesh
from repro.sensing import make_gaussian_problem, make_mri_problem

key = jax.random.PRNGKey(0)
B = 10  # not divisible by 4 or 8 -> padding in play
base = make_gaussian_problem(48, 96, 5, 20.0, key)
Y = jnp.stack([make_gaussian_problem(48, 96, 5, 20.0, jax.random.fold_in(key, b + 1),
                                     phi=base.phi).y for b in range(B)])

# dense f32, 4-device mesh, padded
r0 = qniht_batch(base.phi, Y, 5, 25)
r4 = qniht_batch_sharded(base.phi, Y, 5, 25, n_devices=4)
assert r4.x.shape == r0.x.shape
assert bool(jnp.all(r0.x == r4.x)), "dense parity"
assert bool(jnp.all(r0.trace.mu == r4.trace.mu)), "dense trace parity"

# packed backend: per_tensor and per_block granularities, 8-device mesh
for gran, gs in (("per_tensor", None), ("per_block", 8)):
    kw = dict(bits_phi=4, bits_y=8, key=key, requantize="fixed",
              backend="packed", with_trace=False,
              scale_granularity=gran, group_size=gs)
    a = qniht_batch(base.phi, Y, 5, 25, **kw)
    b = qniht_batch_sharded(base.phi, Y, 5, 25, n_devices=8, **kw)
    assert bool(jnp.all(a.x == b.x)), f"packed {gran} parity"

# matrix-free Fourier and composed-wavelet operators, 8-device mesh
for basis in ("pixel", "haar"):
    prob = make_mri_problem(16, 24, 0.5, key, snr_db=None, sparsity_basis=basis)
    Ym = jnp.stack([prob.y * (1.0 + 0.1 * t) for t in range(6)])
    kw = dict(real_signal=True, nonneg=basis == "pixel", bits_y=8, key=key,
              with_trace=False)
    a = qniht_batch(prob.op, Ym, 24, 12, **kw)
    b = qniht_batch_sharded(prob.op, Ym, 24, 12, n_devices=8, **kw)
    assert bool(jnp.all(a.x == b.x)), f"operator parity ({basis})"

# freeze rule: grouping-invariant (2-device == 8-device == single-device)
t1 = qniht_batch(base.phi, Y, 5, 25, early_exit=True, exit_tol=1e-5,
                 with_trace=False)
t2 = qniht_batch_sharded(base.phi, Y, 5, 25, n_devices=2, exit_tol=1e-5,
                         with_trace=False)
t8 = qniht_batch_sharded(base.phi, Y, 5, 25, n_devices=8, exit_tol=1e-5,
                         with_trace=False)
assert bool(jnp.all(t1.x == t2.x)) and bool(jnp.all(t1.x == t8.x)), "freeze parity"

# multi-chunk server on a 4-device mesh
srv = BatchServer(base.phi, 5, 25, mesh=make_batch_mesh(4))
outs = list(srv.serve([Y, Y]))
assert len(outs) == 2 and srv.n_items == 2 * B
assert bool(jnp.all(outs[0].x == outs[1].x))
assert bool(jnp.all(outs[0].x == qniht_batch(base.phi, Y, 5, 25,
                                             with_trace=False, early_exit=True).x))
print("SHARDED_MULTIDEV_OK")
"""


@pytest.mark.slow
def test_sharded_parity_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARDED_MULTIDEV_OK" in res.stdout
