"""Extra property tests on system invariants (hypothesis-driven)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.models.layers import cache_update_window, init_kv_cache, rope
from repro.parallel.collectives import quantized_allreduce_mean


class TestRoPE:
    @given(seed=st.integers(0, 99), pos_shift=st.integers(1, 50))
    @settings(max_examples=10, deadline=None)
    def test_relative_position_invariance(self, seed, pos_shift):
        """<rope(q,p1), rope(k,p2)> depends only on p1 - p2 (RoPE's defining
        property — what makes cached keys valid at any absolute offset)."""
        key = jax.random.PRNGKey(seed)
        q = jax.random.normal(key, (1, 1, 1, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))

        def dot_at(p1, p2):
            qr = rope(q, jnp.full((1, 1), p1), 10_000.0)
            kr = rope(k, jnp.full((1, 1), p2), 10_000.0)
            return float(jnp.sum(qr * kr))

        d1 = dot_at(3, 1)
        d2 = dot_at(3 + pos_shift, 1 + pos_shift)
        assert d1 == pytest.approx(d2, abs=1e-3)

    def test_norm_preserving(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 3, 32))
        pos = jnp.broadcast_to(jnp.arange(4)[None], (2, 4))
        out = rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(out, axis=-1)),
            np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)


class TestWindowedCache:
    @given(n_tokens=st.integers(1, 20), window=st.sampled_from([4, 8]))
    @settings(max_examples=15, deadline=None)
    def test_holds_last_window_tokens(self, n_tokens, window):
        """After streaming T tokens one at a time, the cache holds exactly the
        last min(T, w) tokens in chronological order."""
        cache = init_kv_cache(1, 1, window, 4, jnp.float32, None)
        toks = [jnp.full((1, 1, 1, 4), float(i + 1)) for i in range(n_tokens)]
        for t in toks:
            cache = cache_update_window(cache, t, t, window, None)
        valid = min(n_tokens, window)
        got = np.asarray(cache.k[0, 0, :valid, 0])
        expect = np.arange(n_tokens - valid + 1, n_tokens + 1, dtype=float)
        np.testing.assert_array_equal(got, expect)
        assert int(cache.length) == n_tokens


class TestErrorFeedback:
    def test_residual_carries_quantization_error(self):
        """With error feedback, the *accumulated* transmitted signal converges
        to the true gradient even at 2 bits (the residual re-injects what
        quantization dropped)."""
        g = jnp.asarray([0.03, -0.01, 0.5, -0.2])  # small entries would starve
        residual = jnp.zeros_like(g)
        sent_sum = jnp.zeros_like(g)
        n = 200
        for i in range(n):
            # single-device psum: axis over a size-1 vmapped axis is overkill;
            # emulate the per-shard math directly
            from repro.quant.formats import BY_BITS

            k = BY_BITS[2].half_steps
            g_in = g + residual
            scale = jnp.maximum(jnp.max(jnp.abs(g_in)), 1e-30)
            key = jax.random.PRNGKey(i)
            scaled = jnp.clip(g_in / scale, -1, 1) * k
            low = jnp.floor(scaled)
            u = jax.random.uniform(key, g.shape)
            codes = jnp.clip(low + (u < scaled - low), -k, k)
            sent = codes * scale / k
            residual = g_in - sent
            sent_sum = sent_sum + sent
        mean_sent = sent_sum / n
        np.testing.assert_allclose(np.asarray(mean_sent), np.asarray(g), atol=0.02)


class TestQNIHTScaleInvariance:
    def test_quantized_recovery_scale_invariant(self):
        """NIHT's scale invariance survives quantization: scaling (Φ, y) by c
        changes nothing (scales are relative — Q's grid adapts)."""
        from repro.core import qniht
        from repro.sensing import make_gaussian_problem

        prob = make_gaussian_problem(64, 128, 4, snr_db=25.0, key=jax.random.PRNGKey(3))
        r1 = qniht(prob.phi, prob.y, prob.s, 25, bits_phi=4, bits_y=8,
                   key=jax.random.PRNGKey(4))
        r2 = qniht(prob.phi * 13.0, prob.y * 13.0, prob.s, 25, bits_phi=4, bits_y=8,
                   key=jax.random.PRNGKey(4))
        np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                                   rtol=1e-3, atol=1e-5)
